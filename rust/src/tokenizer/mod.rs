//! Tokenizer: text ↔ token ids for the serving path.
//!
//! The synthetic world is defined over token ids; to exercise a realistic
//! request path (clients send *text*), every word id gets a deterministic
//! pronounceable surface form ("zu", "kari", "moresa", …) built from CV
//! syllables. The vocabulary is a bijection, so round-trips are exact —
//! which the tests pin, and which makes the serving demo's inputs/outputs
//! human-readable.

use std::collections::HashMap;

use crate::data::grammar::{CLS, MASK, PAD, SEP, WORD0};

const CONSONANTS: &[&str] = &[
    "b", "d", "f", "g", "h", "j", "k", "l", "m", "n", "p", "r", "s", "t", "v", "z",
];
const VOWELS: &[&str] = &["a", "e", "i", "o", "u"];

/// Deterministic surface form for a word id (id ≥ WORD0).
fn surface(word_index: usize) -> String {
    // base-80 positional code over CV syllables, at least two syllables so
    // words look like words and never collide with specials
    let mut n = word_index;
    let mut syllables = Vec::new();
    loop {
        let c = CONSONANTS[n % CONSONANTS.len()];
        let v = VOWELS[(n / CONSONANTS.len()) % VOWELS.len()];
        syllables.push(format!("{c}{v}"));
        n /= CONSONANTS.len() * VOWELS.len();
        if n == 0 {
            break;
        }
        n -= 1; // bijective numeration: no leading-zero ambiguity
    }
    syllables.reverse();
    syllables.concat()
}

#[derive(Debug, Clone)]
pub struct Tokenizer {
    pub vocab: usize,
    id_to_word: Vec<String>,
    word_to_id: HashMap<String, i32>,
}

impl Tokenizer {
    pub fn new(vocab: usize) -> Tokenizer {
        let mut id_to_word = vec![String::new(); vocab];
        id_to_word[PAD as usize] = "[PAD]".into();
        id_to_word[CLS as usize] = "[CLS]".into();
        id_to_word[SEP as usize] = "[SEP]".into();
        id_to_word[MASK as usize] = "[MASK]".into();
        for id in WORD0..vocab {
            id_to_word[id] = surface(id - WORD0);
        }
        let word_to_id = id_to_word
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as i32))
            .collect();
        Tokenizer { vocab, id_to_word, word_to_id }
    }

    /// Encode whitespace-separated text; unknown words map to `[MASK]`
    /// (the closest analogue of BERT's [UNK] in our 4-special layout).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.split_whitespace()
            .map(|w| *self.word_to_id.get(w).unwrap_or(&MASK))
            .collect()
    }

    /// Encode into the classifier wire format `[CLS] text…` padded to `seq`.
    pub fn encode_for_cls(&self, text: &str, seq: usize) -> (Vec<i32>, Vec<f32>) {
        let mut ids = vec![CLS];
        ids.extend(self.encode(text).into_iter().take(seq - 1));
        let mut mask = vec![1.0; ids.len()];
        while ids.len() < seq {
            ids.push(PAD);
            mask.push(0.0);
        }
        (ids, mask)
    }

    /// Encode a sentence pair into the wire format the pair tasks train
    /// on — `[CLS] a [SEP] b [SEP]` with segment ids 0/1 (matching
    /// `data::tasks::assemble`) — padded to `seq`. Returns
    /// (token ids, segment ids, attention mask).
    pub fn encode_for_pair(
        &self,
        a: &str,
        b: &str,
        seq: usize,
    ) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
        let a_ids = self.encode(a);
        let b_ids = self.encode(b);
        // reserve room for [CLS] and both [SEP]s; split leftover evenly,
        // then let each side reclaim room the other did not use
        let budget = seq.saturating_sub(3);
        let half = (budget + 1) / 2;
        let b_take = b_ids.len().min(budget - a_ids.len().min(half));
        let a_take = a_ids.len().min(budget - b_take);
        let mut ids = vec![CLS];
        let mut segments = vec![0];
        ids.extend(&a_ids[..a_take]);
        segments.extend(std::iter::repeat(0).take(a_take));
        ids.push(SEP);
        segments.push(0);
        ids.extend(&b_ids[..b_take]);
        segments.extend(std::iter::repeat(1).take(b_take));
        ids.push(SEP);
        segments.push(1);
        let mut mask = vec![1.0; ids.len()];
        while ids.len() < seq {
            ids.push(PAD);
            segments.push(0);
            mask.push(0.0);
        }
        (ids, segments, mask)
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .filter(|&&id| id != PAD)
            .map(|&id| self.id_to_word[id as usize].as_str())
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn word(&self, id: i32) -> &str {
        &self.id_to_word[id as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn surfaces_are_unique() {
        let t = Tokenizer::new(1024);
        let mut seen = std::collections::HashSet::new();
        for w in &t.id_to_word {
            assert!(seen.insert(w.clone()), "duplicate surface {w}");
        }
    }

    #[test]
    fn roundtrip_exact() {
        let t = Tokenizer::new(512);
        let ids: Vec<i32> = vec![5, 100, 511, 42, 4];
        let text = t.decode(&ids);
        assert_eq!(t.encode(&text), ids);
    }

    #[test]
    fn encode_for_cls_pads_and_masks() {
        let t = Tokenizer::new(256);
        let text = format!("{} {}", t.word(10), t.word(20));
        let (ids, mask) = t.encode_for_cls(&text, 8);
        assert_eq!(ids.len(), 8);
        assert_eq!(ids[0], CLS);
        assert_eq!(&ids[1..3], &[10, 20]);
        assert_eq!(ids[3..], [PAD; 5]);
        assert_eq!(&mask[0..3], &[1.0, 1.0, 1.0]);
        assert!(mask[3..].iter().all(|&m| m == 0.0));
    }

    #[test]
    fn unknown_words_become_mask() {
        let t = Tokenizer::new(256);
        assert_eq!(t.encode("xyzzyplugh"), vec![MASK]);
    }

    #[test]
    fn encode_for_pair_matches_training_layout() {
        let t = Tokenizer::new(256);
        let a = format!("{} {}", t.word(10), t.word(11));
        let b = t.word(20).to_string();
        let (ids, segs, mask) = t.encode_for_pair(&a, &b, 10);
        assert_eq!(ids.len(), 10);
        assert_eq!(segs.len(), 10);
        assert_eq!(mask.len(), 10);
        // [CLS] a a [SEP] | b [SEP] | pad…
        assert_eq!(&ids[..6], &[CLS, 10, 11, SEP, 20, SEP]);
        assert_eq!(&segs[..6], &[0, 0, 0, 0, 1, 1]);
        assert_eq!(&ids[6..], &[PAD; 4]);
        assert!(mask[..6].iter().all(|&m| m == 1.0));
        assert!(mask[6..].iter().all(|&m| m == 0.0));
    }

    #[test]
    fn encode_for_pair_truncates_both_sides() {
        let t = Tokenizer::new(256);
        let long: Vec<String> = (0..40).map(|_| t.word(9).to_string()).collect();
        let long = long.join(" ");
        let (ids, segs, mask) = t.encode_for_pair(&long, &long, 16);
        assert_eq!(ids.len(), 16);
        assert_eq!(segs.len(), 16);
        assert_eq!(ids[0], CLS);
        // fully packed: no padding, both separators present
        assert!(mask.iter().all(|&m| m == 1.0));
        assert_eq!(ids.iter().filter(|&&i| i == SEP).count(), 2);
        assert_eq!(*ids.last().unwrap(), SEP);
        assert_eq!(*segs.last().unwrap(), 1);
    }

    #[test]
    fn truncates_to_seq() {
        let t = Tokenizer::new(256);
        let long = (0..50).map(|_| t.word(9).to_string()).collect::<Vec<_>>().join(" ");
        let (ids, _) = t.encode_for_cls(&long, 16);
        assert_eq!(ids.len(), 16);
    }
}
