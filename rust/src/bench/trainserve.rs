//! Train-and-serve co-location harness → `BENCH_trainserve.json`.
//!
//! The claim under test: because adapters are independent given the
//! frozen trunk, background training jobs can share the serving
//! runtime's kernels without taking serving latency down. The harness
//! stands up a complete gateway (two pre-trained tenants + the training
//! service), then measures the same closed-loop predict load twice —
//! once **idle** (no jobs) and once **co-trained** (K jobs submitted
//! over `POST /train` right before the load starts) — and records each
//! job's wall time and training throughput from its final `GET /train`
//! status. The report is schema-pinned (v1) like `BENCH_serve.json` /
//! `BENCH_kernels.json`; CI's trainserve smoke job validates it and
//! requires every job to complete and every request to succeed (the
//! in-flight-predictions-never-error-during-install property, over a
//! real socket).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use super::loadgen::{self, LoadgenConfig, LoadReport};
use crate::coordinator::{FlushPolicy, Server, ServerConfig};
use crate::data::grammar::World;
use crate::data::tasks::{self, Metric, TaskKind, TaskSpec};
use crate::serve::{
    self, Client, Gateway, GatewayConfig, TrainJobRequest, TrainJobStatus,
};
use crate::store::AdapterStore;
use crate::train::{self, PretrainConfig, ServiceConfig, TrainConfig, TrainService};
use crate::util::json::Json;
use crate::util::timer::Samples;

/// Harness knobs.
#[derive(Debug, Clone)]
pub struct TrainServeConfig {
    pub preset: String,
    /// Concurrent training jobs in the co-trained phase (= pool workers).
    pub jobs: usize,
    /// Predict requests per phase.
    pub requests: u64,
    /// Closed-loop client threads.
    pub concurrency: usize,
    /// Epochs per training job.
    pub job_epochs: usize,
    /// Training-set size per job.
    pub job_n_train: usize,
    /// Adapter size for tenants and jobs.
    pub m: usize,
    /// MLM pre-training steps when no cached base exists.
    pub pretrain_steps: usize,
    /// How long to wait for jobs to finish after the co-trained phase.
    pub job_timeout: Duration,
}

impl Default for TrainServeConfig {
    fn default() -> Self {
        TrainServeConfig {
            preset: "test".to_string(),
            jobs: 2,
            requests: 120,
            concurrency: 2,
            job_epochs: 3,
            job_n_train: 240,
            m: 8,
            pretrain_steps: 120,
            job_timeout: Duration::from_secs(600),
        }
    }
}

/// One phase's serving-side numbers.
#[derive(Debug, Clone)]
pub struct PhaseStats {
    pub requests: u64,
    pub errors: u64,
    pub wall_s: f64,
    pub throughput_rps: f64,
    pub latencies: Samples,
}

impl PhaseStats {
    fn from_report(r: &LoadReport) -> PhaseStats {
        PhaseStats {
            requests: r.requests,
            errors: r.errors,
            wall_s: r.wall_s,
            throughput_rps: r.throughput_rps(),
            latencies: Samples { durs: r.all.durs.clone() },
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::num(self.requests as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("wall_s", Json::num(self.wall_s)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("latency_ms", loadgen::latency_json(&self.latencies)),
        ])
    }
}

/// One training job's outcome, from its final `GET /train/<id>` status.
#[derive(Debug, Clone)]
pub struct JobOutcome {
    pub job_id: u64,
    pub task: String,
    pub status: String,
    pub wall_s: f64,
    pub steps: usize,
    pub total_steps: usize,
    pub steps_per_sec: f64,
    pub best_val: Option<f64>,
    pub version: Option<usize>,
}

impl JobOutcome {
    fn from_status(s: &TrainJobStatus) -> JobOutcome {
        JobOutcome {
            job_id: s.job_id,
            task: s.task.clone(),
            status: s.status.clone(),
            wall_s: s.wall_s,
            steps: s.step,
            total_steps: s.total_steps,
            steps_per_sec: s.steps_per_sec,
            best_val: s.best_val,
            version: s.version,
        }
    }

    fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("job_id", Json::num(self.job_id as f64)),
            ("task", Json::str(&self.task)),
            ("status", Json::str(&self.status)),
            ("wall_s", Json::num(self.wall_s)),
            ("steps", Json::num(self.steps as f64)),
            ("total_steps", Json::num(self.total_steps as f64)),
            ("steps_per_sec", Json::num(self.steps_per_sec)),
        ];
        if let Some(v) = self.best_val {
            pairs.push(("best_val", Json::num(v)));
        }
        if let Some(v) = self.version {
            pairs.push(("version", Json::num(v as f64)));
        }
        Json::obj(pairs)
    }
}

/// The whole run: idle vs co-trained serving plus per-job outcomes.
#[derive(Debug)]
pub struct TrainServeReport {
    pub idle: PhaseStats,
    pub cotrained: PhaseStats,
    pub jobs: Vec<JobOutcome>,
}

impl TrainServeReport {
    /// The `BENCH_trainserve.json` document (schema v1).
    pub fn to_json(&self, cfg: &TrainServeConfig) -> Json {
        Json::obj(vec![
            ("bench", Json::str("trainserve")),
            ("schema_version", Json::num(1.0)),
            (
                "config",
                Json::obj(vec![
                    ("preset", Json::str(&cfg.preset)),
                    ("jobs", Json::num(cfg.jobs as f64)),
                    ("requests", Json::num(cfg.requests as f64)),
                    ("concurrency", Json::num(cfg.concurrency as f64)),
                    ("job_epochs", Json::num(cfg.job_epochs as f64)),
                    ("job_n_train", Json::num(cfg.job_n_train as f64)),
                    ("m", Json::num(cfg.m as f64)),
                ]),
            ),
            (
                "serving",
                Json::obj(vec![
                    ("idle", self.idle.to_json()),
                    ("cotrained", self.cotrained.to_json()),
                ]),
            ),
            ("jobs", Json::arr(self.jobs.iter().map(JobOutcome::to_json))),
        ])
    }
}

fn tenant_spec(name: &str, seed: u64) -> TaskSpec {
    TaskSpec {
        name: name.to_string(),
        kind: TaskKind::Cls { n_classes: 2, pair: false },
        metric: Metric::Accuracy,
        n_train: 240,
        n_val: 48,
        n_test: 48,
        purity: 0.85,
        noise: 0.0,
        seed,
    }
}

/// Stand up the gateway, run both phases, wait out the jobs.
pub fn run(cfg: &TrainServeConfig) -> Result<TrainServeReport> {
    let rt = Arc::new(crate::runtime::Runtime::open(
        Path::new("artifacts"),
        &cfg.preset,
    )?);
    let world = World::new(rt.manifest.dims.vocab, 0);
    let base = train::load_or_pretrain(
        &rt,
        &world,
        &PretrainConfig { steps: cfg.pretrain_steps, ..Default::default() },
        Path::new(&format!("runs/base_{}.bank", cfg.preset)),
    )?;

    // two pre-trained tenants so the serving side has real traffic
    let store = Arc::new(AdapterStore::in_memory());
    let mut classes = BTreeMap::new();
    let exe = format!("cls_train_adapter_m{}", cfg.m);
    for (name, seed) in [("tsa", 11u64), ("tsb", 12u64)] {
        let data = tasks::generate(&world, &tenant_spec(name, seed), rt.manifest.dims.seq);
        let res = train::train_task(
            &rt,
            &TrainConfig::new(&exe, 1e-3, 3, 0),
            &data,
            &base,
        )?;
        store.register(name, &res.model, res.val_score)?;
        classes.insert(name.to_string(), 2usize);
        println!("  tenant {name}: val {:.3}", res.val_score);
    }

    let server = Arc::new(Server::start(
        rt.clone(),
        &store,
        &base,
        &classes,
        ServerConfig {
            flush: FlushPolicy {
                max_batch: rt.manifest.batch,
                max_delay: Duration::from_millis(2),
            },
            executors: 2,
            ..Default::default()
        },
    )?);
    let store_t = store.clone();
    let server_t = server.clone();
    let install = move |task: &str,
                        n_classes: usize,
                        val: f64,
                        model: &crate::eval::TaskModel| {
        serve::install_trained(&store_t, &server_t, task, n_classes, val, model)
            .map(|meta| meta.version)
    };
    let trainer = Arc::new(TrainService::start(
        rt.clone(),
        Arc::new(base),
        world,
        ServiceConfig { workers: cfg.jobs.max(1), ..Default::default() },
        Box::new(install),
    )?);
    let gw = Gateway::start_with_trainer(
        rt,
        store,
        server,
        Some(trainer),
        GatewayConfig { addr: "127.0.0.1:0".to_string(), ..Default::default() },
    )?;
    let addr = gw.local_addr().to_string();

    let load_cfg = |seed: u64| LoadgenConfig {
        addr: addr.clone(),
        tasks: vec!["tsa".into(), "tsb".into()],
        concurrency: cfg.concurrency,
        requests: cfg.requests,
        seed,
        ..Default::default()
    };

    // phase 1: serving alone
    println!("  idle phase: {} requests …", cfg.requests);
    let idle = loadgen::run(&load_cfg(1))?;
    ensure!(idle.errors == 0, "{} idle-phase request(s) failed", idle.errors);

    // phase 2: K training jobs submitted, then the identical load
    let mut client = Client::connect(&addr)?;
    let mut job_ids = Vec::new();
    for i in 0..cfg.jobs {
        let mut req = TrainJobRequest::new(&format!("job{i}"));
        req.m = Some(cfg.m);
        req.epochs = Some(cfg.job_epochs);
        req.n_train = Some(cfg.job_n_train);
        req.purity = Some(0.85);
        req.data_seed = Some(100 + i as u64);
        req.seed = Some(0);
        let status = client.submit_train(&req)?;
        println!(
            "  submitted job {} ({}, {} total steps)",
            status.job_id, status.task, status.total_steps
        );
        job_ids.push(status.job_id);
    }
    println!("  co-trained phase: {} requests …", cfg.requests);
    let cotrained = loadgen::run(&load_cfg(2))?;
    ensure!(
        cotrained.errors == 0,
        "{} co-trained-phase request(s) failed",
        cotrained.errors
    );

    // wait for every job and collect its final status
    let deadline = Instant::now() + cfg.job_timeout;
    let mut outcomes = Vec::new();
    for id in job_ids {
        loop {
            let s = client.train_status(id)?;
            match s.status.as_str() {
                "completed" => {
                    println!(
                        "  job {id} done in {:.2}s ({:.1} steps/s, val {:.3})",
                        s.wall_s,
                        s.steps_per_sec,
                        s.best_val.unwrap_or(f64::NAN)
                    );
                    outcomes.push(JobOutcome::from_status(&s));
                    break;
                }
                "failed" => bail!(
                    "job {id} failed: {}",
                    s.error.as_deref().unwrap_or("(no message)")
                ),
                _ => {
                    if Instant::now() > deadline {
                        bail!("job {id} still {} after {:?}", s.status, cfg.job_timeout);
                    }
                    std::thread::sleep(Duration::from_millis(100));
                }
            }
        }
    }
    // the trained tasks must now be servable over the same socket
    let tasks_now = client.tasks()?;
    for i in 0..cfg.jobs {
        let name = format!("job{i}");
        ensure!(
            tasks_now.iter().any(|t| t.task == name),
            "completed job's task {name:?} is not in GET /tasks"
        );
        let resp = client
            .predict_text(&name, "moresa zu kari letu")
            .with_context(|| format!("predicting on hot-installed {name:?}"))?;
        ensure!(resp.kind == "cls", "unexpected head kind {:?}", resp.kind);
    }
    drop(client);
    gw.shutdown()?;

    Ok(TrainServeReport {
        idle: PhaseStats::from_report(&idle),
        cotrained: PhaseStats::from_report(&cotrained),
        jobs: outcomes,
    })
}

/// Atomically persist the report (same contract as the other benches).
pub fn write_report(path: &Path, report: &Json) -> Result<()> {
    loadgen::write_report(path, report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn phase(ms: u64) -> PhaseStats {
        let mut s = Samples::default();
        for i in 1..=20u64 {
            s.record(Duration::from_millis(ms + i % 3));
        }
        PhaseStats {
            requests: 20,
            errors: 0,
            wall_s: 0.5,
            throughput_rps: 40.0,
            latencies: s,
        }
    }

    /// Pins the BENCH_trainserve.json v1 schema CI validates against.
    #[test]
    fn report_json_schema() {
        let report = TrainServeReport {
            idle: phase(3),
            cotrained: phase(5),
            jobs: vec![JobOutcome {
                job_id: 1,
                task: "job0".into(),
                status: "completed".into(),
                wall_s: 2.5,
                steps: 90,
                total_steps: 90,
                steps_per_sec: 36.0,
                best_val: Some(0.9),
                version: Some(1),
            }],
        };
        let cfg = TrainServeConfig::default();
        let back = Json::parse(&report.to_json(&cfg).to_string()).unwrap();
        assert_eq!(back.at("bench").as_str(), Some("trainserve"));
        assert_eq!(back.at("schema_version").as_usize(), Some(1));
        assert_eq!(back.at("config").at("jobs").as_usize(), Some(2));
        for phase in ["idle", "cotrained"] {
            let p = back.at("serving").at(phase);
            assert_eq!(p.at("requests").as_usize(), Some(20), "{phase}");
            assert_eq!(p.at("errors").as_usize(), Some(0), "{phase}");
            assert!(p.at("throughput_rps").as_f64().unwrap() > 0.0);
            for key in ["mean", "p50", "p95", "p99", "max"] {
                assert!(
                    p.at("latency_ms").at(key).as_f64().is_some(),
                    "{phase}.latency_ms.{key}"
                );
            }
        }
        let jobs = back.at("jobs").as_arr().unwrap();
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].at("status").as_str(), Some("completed"));
        assert_eq!(jobs[0].at("version").as_usize(), Some(1));
        assert!(jobs[0].at("steps_per_sec").as_f64().unwrap() > 0.0);
        assert!(jobs[0].at("wall_s").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn job_outcome_without_val_or_version_serializes() {
        let j = JobOutcome {
            job_id: 2,
            task: "j".into(),
            status: "failed".into(),
            wall_s: 0.1,
            steps: 3,
            total_steps: 90,
            steps_per_sec: 30.0,
            best_val: None,
            version: None,
        }
        .to_json();
        let back = Json::parse(&j.to_string()).unwrap();
        assert!(back.get("best_val").is_none());
        assert!(back.get("version").is_none());
        assert_eq!(back.at("status").as_str(), Some("failed"));
    }
}
