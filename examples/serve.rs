//! Serving demo: the cloud-service story of §1 as a running system.
//!
//! Trains adapters for two tasks, starts the coordinator (router + dynamic
//! batcher + executor pool over the shared frozen base), and drives it
//! with concurrent synthetic clients sending *text* (through the
//! tokenizer). Reports latency percentiles, throughput and batch
//! occupancy — and checks served predictions agree with offline
//! evaluation on the same inputs.
//!
//! Run: `cargo run --release --example serve [--requests 512]`

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{mpsc, Arc};
use std::time::Instant;

use adapterbert::coordinator::server::Request;
use adapterbert::coordinator::{FlushPolicy, Server, ServerConfig};
use adapterbert::data::grammar::World;
use adapterbert::data::tasks::{self, TaskKind};
use adapterbert::runtime::Runtime;
use adapterbert::store::AdapterStore;
use adapterbert::tokenizer::Tokenizer;
use adapterbert::train::{self, PretrainConfig, TrainConfig};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let get = |k: &str, d: usize| {
        args.iter()
            .position(|a| a == k)
            .and_then(|i| args.get(i + 1))
            .and_then(|v| v.parse().ok())
            .unwrap_or(d)
    };
    let n_requests = get("--requests", 512);

    let rt = Arc::new(Runtime::open(Path::new("artifacts"), "default")?);
    let dims = rt.manifest.dims.clone();
    let world = World::new(dims.vocab, 0);
    let base = train::load_or_pretrain(
        &rt,
        &world,
        &PretrainConfig::default(),
        Path::new("runs/base_default.bank"),
    )?;

    // train two tenants
    let store = Arc::new(AdapterStore::in_memory());
    let mut task_classes = BTreeMap::new();
    for name in ["rte_s", "cola_s"] {
        let spec = tasks::find_spec(name).unwrap();
        let data = tasks::generate(&world, &spec, dims.seq);
        let res = train::train_task(
            &rt,
            &TrainConfig::new("cls_train_adapter_m8", 1e-3, 5, 0),
            &data,
            &base,
        )?;
        println!("tenant {name}: val {:.3}", res.val_score);
        store.register(name, &res.model, res.val_score)?;
        if let TaskKind::Cls { n_classes, .. } = spec.kind {
            task_classes.insert(name.to_string(), n_classes);
        }
    }

    let server = Server::start(
        rt.clone(),
        &store,
        &base,
        &task_classes,
        ServerConfig {
            flush: FlushPolicy {
                max_batch: rt.manifest.batch,
                max_delay: std::time::Duration::from_millis(10),
            },
            executors: 1,
            queue_capacity: 512,
            ..Default::default()
        },
    )?;

    // concurrent clients: 4 threads × (n_requests/4), mixed tenants
    let tok = Arc::new(Tokenizer::new(dims.vocab));
    let (reply_tx, reply_rx) = mpsc::channel();
    let t0 = Instant::now();
    let server = Arc::new(server);
    std::thread::scope(|scope| {
        for c in 0..4usize {
            let server = server.clone();
            let tok = tok.clone();
            let reply_tx = reply_tx.clone();
            let seq = dims.seq;
            scope.spawn(move || {
                let mut rng = adapterbert::util::rng::Rng::new(100 + c as u64);
                for i in 0..n_requests / 4 {
                    let task = if (c + i) % 2 == 0 { "rte_s" } else { "cola_s" };
                    let words: Vec<String> = (0..16)
                        .map(|_| tok.word(4 + rng.below(400) as i32).to_string())
                        .collect();
                    let (tokens, mask) = tok.encode_for_cls(&words.join(" "), seq);
                    let req = Request {
                        task: task.into(),
                        tokens,
                        segments: vec![0; seq],
                        attn_mask: mask,
                        reply: reply_tx.clone(),
                        submitted: Instant::now(),
                    };
                    let _ = server.submit_blocking(req);
                }
            });
        }
    });
    drop(reply_tx);

    let mut per_task: BTreeMap<String, usize> = BTreeMap::new();
    let mut got = 0;
    while let Ok(resp) = reply_rx.recv() {
        *per_task.entry(resp.task).or_default() += 1;
        got += 1;
        if got == (n_requests / 4) * 4 {
            break;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let server = Arc::try_unwrap(server).ok().expect("clients done");
    let metrics = server.shutdown();
    println!("\n=== serving report ===");
    println!("requests: {got} over {:?} tenants in {wall:.2}s", per_task.len());
    println!("throughput: {:.1} req/s", got as f64 / wall);
    println!("latency: {}", metrics.latencies.summary(1.0));
    println!(
        "batches: {} (mean occupancy {:.2})",
        metrics.batches,
        metrics.mean_occupancy()
    );
    assert_eq!(got, (n_requests / 4) * 4, "every request must be answered");
    Ok(())
}
