//! Evaluation: run a trained bank over a split and score it with the
//! task's paper metric (accuracy / F1 / Matthews / Spearman / span EM-F1).
//!
//! Serving-layout evaluation: the trained bank is re-wired into the
//! `*_fwd_*` signature (`model::params::merge_base_for_fwd`) exactly the
//! way the coordinator's server does it, so evaluation exercises the same
//! path requests take.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::data::batcher::eval_batches;
use crate::data::tasks::{Labels, Metric, Split};
use crate::model::params::NamedTensors;
use crate::runtime::fused::{AdapterParams, FusedAdapters, LayerLn};
use crate::runtime::{Bank, FusedTaskBank, Manifest, Runtime};
use crate::util::stats;
use crate::util::tensor::Tensor;

/// A trained task model in store form: the trained bank plus how it was
/// produced (which decides the fwd artifact and base merging).
#[derive(Debug, Clone)]
pub struct TaskModel {
    /// adapter | topk | lnonly
    pub variant: String,
    /// adapter size (adapter variants)
    pub m: Option<usize>,
    /// top-k depth (topk variants)
    pub k: Option<usize>,
    /// artifact kind: cls | reg | span
    pub kind: String,
    pub trained: NamedTensors,
}

impl TaskModel {
    /// Name of the fwd executable that serves this model.
    pub fn fwd_name(&self) -> String {
        match self.variant.as_str() {
            "adapter" => format!("{}_fwd_adapter_m{}", self.kind, self.m.unwrap()),
            // topk / lnonly merge into the plain base graph
            _ => format!("{}_fwd_base", self.kind),
        }
    }

    /// Trained parameters per task *excluding the classifier head* — the
    /// paper's "trained params / task" convention (both methods add a head).
    pub fn trained_param_count_no_head(&self) -> usize {
        self.trained
            .map
            .iter()
            .filter(|(k, _)| !k.starts_with("head/"))
            .map(|(_, t)| t.len())
            .sum()
    }

    pub fn trained_param_count(&self) -> usize {
        self.trained.param_count()
    }

    /// Name of the train executable whose `trained` group defines this
    /// bank's layout.
    pub fn train_name(&self) -> Result<String> {
        match self.variant.as_str() {
            "adapter" => {
                let m = self.m.context("adapter variant needs m")?;
                Ok(format!("{}_train_adapter_m{m}", self.kind))
            }
            "topk" => {
                let k = self.k.context("topk variant needs k")?;
                Ok(format!("{}_train_topk_k{k}", self.kind))
            }
            "lnonly" => Ok(format!("{}_train_lnonly", self.kind)),
            other => bail!("unknown variant {other:?} (expected adapter|topk|lnonly)"),
        }
    }

    /// Validate this bank against the manifest **at registration time**:
    /// the serving executable must exist for the claimed variant/size,
    /// and every trained leaf must match the train executable's `trained`
    /// group in name, shape and dtype (no missing leaves, no extras).
    /// Descriptive errors here replace shape panics/errors that would
    /// otherwise surface later inside `execute`.
    pub fn validate_against(&self, manifest: &Manifest, n_classes: usize) -> Result<()> {
        if !matches!(self.kind.as_str(), "cls" | "reg" | "span") {
            bail!("unservable artifact kind {:?} (expected cls|reg|span)", self.kind);
        }
        if self.kind == "cls" {
            let max = manifest.dims.max_classes;
            anyhow::ensure!(
                (1..=max).contains(&n_classes),
                "n_classes {n_classes} outside the padded head range [1, {max}]"
            );
        }
        let train = self.train_name()?;
        let spec = match manifest.exe(&train) {
            Ok(s) => s,
            Err(_) => match self.variant.as_str() {
                "adapter" => {
                    let mut sizes: Vec<usize> = manifest
                        .find(&self.kind, "adapter")
                        .iter()
                        .filter_map(|e| e.m)
                        .collect();
                    sizes.sort_unstable();
                    bail!(
                        "preset {:?} has no {} adapter of size m={} \
                         (available sizes: {sizes:?})",
                        manifest.preset,
                        self.kind,
                        self.m.unwrap_or(0)
                    );
                }
                "topk" => {
                    let mut depths: Vec<usize> = manifest
                        .find(&self.kind, "topk")
                        .iter()
                        .filter_map(|e| e.k)
                        .collect();
                    depths.sort_unstable();
                    bail!(
                        "preset {:?} has no {} top-k depth k={} \
                         (available depths: {depths:?})",
                        manifest.preset,
                        self.kind,
                        self.k.unwrap_or(0)
                    );
                }
                _ => bail!("preset {:?} has no executable {train:?}", manifest.preset),
            },
        };
        let range = spec.input_group_range("trained")?;
        let mut expected: std::collections::BTreeMap<&str, &crate::runtime::LeafSpec> =
            std::collections::BTreeMap::new();
        for leaf in &spec.inputs[range] {
            let rel = leaf
                .name
                .strip_prefix("trained/")
                .unwrap_or(leaf.name.as_str());
            expected.insert(rel, leaf);
        }
        for (rel, t) in &self.trained.map {
            let Some(leaf) = expected.get(rel.as_str()) else {
                bail!(
                    "bank leaf {rel:?} is not part of {train}'s trained group \
                     (did the variant/m/k metadata get mislabeled?)"
                );
            };
            if t.shape != leaf.shape || t.dtype() != leaf.dtype {
                bail!(
                    "bank leaf {rel:?}: got shape {:?} {}, {train} expects {:?} {}",
                    t.shape,
                    t.dtype().name(),
                    leaf.shape,
                    leaf.dtype.name()
                );
            }
        }
        for rel in expected.keys() {
            if !self.trained.map.contains_key(*rel) {
                bail!("bank is missing leaf {rel:?} required by {train}");
            }
        }
        // the fwd executable that would serve it must exist too
        manifest.exe(&self.fwd_name())?;
        Ok(())
    }
}

/// Build the input banks for this model's fwd executable.
///
/// `gates` (adapter variant only): per-(layer, position) multiplier for the
/// Fig. 6 ablation; `None` = all ones.
pub fn fwd_param_banks(
    rt: &Arc<Runtime>,
    model: &TaskModel,
    pretrained_base: &NamedTensors,
    gates: Option<&[f32]>,
) -> Result<Vec<Bank>> {
    let fwd = model.fwd_name();
    let spec = rt.manifest.exe(&fwd)?.clone();
    let n_layers = rt.manifest.dims.n_layers;
    let base = crate::model::params::merge_base_for_fwd(
        pretrained_base,
        &model.trained,
        &model.variant,
        model.k,
        n_layers,
    )?;
    let mut banks = vec![base.to_bank(&spec, "base")?];
    if model.variant == "adapter" {
        let adapters = model.trained.strip_prefix("adapters");
        banks.push(adapters.to_bank(&spec, "adapters")?);
        banks.push(model.trained.strip_prefix("head").to_bank(&spec, "head")?);
        let g = match gates {
            Some(g) => {
                if g.len() != n_layers * 2 {
                    bail!("gates must be n_layers*2 = {}", n_layers * 2);
                }
                g.to_vec()
            }
            None => vec![1.0; n_layers * 2],
        };
        banks.push(vec![Tensor::f32(vec![n_layers, 2], g)]);
    } else {
        banks.push(model.trained.strip_prefix("head").to_bank(&spec, "head")?);
    }
    Ok(banks)
}

/// Build the gatherable fused-serving bank for a task: its task-tuned
/// LayerNorms (pretrained base overlaid by the trained `base_ln`
/// subtree — exactly the merge the per-task path performs), its adapter
/// stack (adapter variant) and its head.
///
/// Only variants whose trunk differs from the pretrained base by LayerNorm
/// parameters alone can be fused; `topk` rewrites whole trunk layers per
/// task, so it keeps the per-task path and this returns an error.
pub fn fused_bank(
    rt: &Arc<Runtime>,
    model: &TaskModel,
    pretrained_base: &NamedTensors,
    n_classes: usize,
) -> Result<FusedTaskBank> {
    if !matches!(model.variant.as_str(), "adapter" | "lnonly") {
        bail!(
            "variant {:?} has a task-specific trunk and cannot be fused",
            model.variant
        );
    }
    let dims = rt.manifest.dims.clone();
    let merged = crate::model::params::merge_base_for_fwd(
        pretrained_base,
        &model.trained,
        &model.variant,
        model.k,
        dims.n_layers,
    )?;
    let get = |name: &str| -> Result<Tensor> {
        merged
            .get(name)
            .cloned()
            .with_context(|| format!("merged base missing {name:?}"))
    };
    let mut layer_ln = Vec::with_capacity(dims.n_layers);
    for li in 0..dims.n_layers {
        layer_ln.push(LayerLn {
            ln1_g: get(&format!("layers/{li}/ln1_g"))?,
            ln1_b: get(&format!("layers/{li}/ln1_b"))?,
            ln2_g: get(&format!("layers/{li}/ln2_g"))?,
            ln2_b: get(&format!("layers/{li}/ln2_b"))?,
        });
    }
    let adapters = if model.variant == "adapter" {
        let m = model.m.context("adapter variant needs m")?;
        let ad = model.trained.strip_prefix("adapters");
        let mut layers = Vec::with_capacity(dims.n_layers);
        for li in 0..dims.n_layers {
            let part = |which: &str| -> Result<AdapterParams> {
                let g = |leaf: &str| -> Result<Tensor> {
                    ad.get(&format!("layers/{li}/{which}/{leaf}"))
                        .cloned()
                        .with_context(|| {
                            format!(
                                "trained bank missing \
                                 adapters/layers/{li}/{which}/{leaf}"
                            )
                        })
                };
                Ok(AdapterParams {
                    w_down: g("w_down")?,
                    b_down: g("b_down")?,
                    w_up: g("w_up")?,
                    b_up: g("b_up")?,
                })
            };
            layers.push([part("attn")?, part("ffn")?]);
        }
        Some(FusedAdapters { m, layers, gates: vec![1.0; dims.n_layers * 2] })
    } else {
        None
    };
    let head = model.trained.strip_prefix("head");
    let bank = FusedTaskBank {
        kind: model.kind.clone(),
        n_classes,
        embed_ln_g: get("embed_ln_g")?,
        embed_ln_b: get("embed_ln_b")?,
        layer_ln,
        adapters,
        head_w: head.get("w").cloned().context("trained bank missing head/w")?,
        head_b: head.get("b").cloned().context("trained bank missing head/b")?,
    };
    bank.check_shapes(&dims)?;
    Ok(bank)
}

/// Raw forward predictions over a split, in row order.
#[derive(Debug, Clone)]
pub enum Predictions {
    Class(Vec<usize>),
    Score(Vec<f32>),
    Span(Vec<(usize, usize)>),
}

pub fn predict_split(
    rt: &Arc<Runtime>,
    model: &TaskModel,
    pretrained_base: &NamedTensors,
    split: &Split,
    n_classes: usize,
    gates: Option<&[f32]>,
) -> Result<Predictions> {
    let fwd = model.fwd_name();
    let exe = rt.load(&fwd)?;
    let batch_size = exe.spec.batch;
    let param_banks = fwd_param_banks(rt, model, pretrained_base, gates)?;
    let mut preds_cls = Vec::new();
    let mut preds_score = Vec::new();
    let mut preds_span = Vec::new();
    for b in eval_batches(split, batch_size) {
        let (tok, seg, mask) = b.to_fwd_banks();
        let mut banks: Vec<&Bank> = param_banks.iter().collect();
        banks.push(&tok);
        banks.push(&seg);
        banks.push(&mask);
        let out = exe.run(&banks).context("fwd execution")?;
        match model.kind.as_str() {
            "cls" => {
                let logits = &out[0][0]; // [B, max_classes]
                let c = logits.shape[1];
                for row in 0..b.real_rows {
                    let r = &logits.as_f32()[row * c..(row + 1) * c];
                    let pred = r[..n_classes]
                        .iter()
                        .enumerate()
                        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                        .unwrap()
                        .0;
                    preds_cls.push(pred);
                }
            }
            "reg" => {
                let p = &out[0][0]; // [B]
                preds_score.extend_from_slice(&p.as_f32()[..b.real_rows]);
            }
            "span" => {
                let start = &out[0][0]; // [B, S]
                let end = &out[1][0];
                let s = start.shape[1];
                for row in 0..b.real_rows {
                    let rs = &start.as_f32()[row * s..(row + 1) * s];
                    let re = &end.as_f32()[row * s..(row + 1) * s];
                    let ps = argmax(rs);
                    let pe = argmax(re);
                    preds_span.push((ps, pe));
                }
            }
            other => bail!("unknown kind {other}"),
        }
    }
    Ok(match model.kind.as_str() {
        "cls" => Predictions::Class(preds_cls),
        "reg" => Predictions::Score(preds_score),
        _ => Predictions::Span(preds_span),
    })
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}

/// Score predictions against a split's labels with `metric`.
pub fn score(preds: &Predictions, labels: &Labels, metric: Metric) -> Result<f64> {
    Ok(match (preds, labels, metric) {
        (Predictions::Class(p), Labels::Class(t), Metric::Accuracy) => {
            stats::accuracy(p, t)
        }
        (Predictions::Class(p), Labels::Class(t), Metric::F1) => {
            stats::f1_binary(p, t, 1)
        }
        (Predictions::Class(p), Labels::Class(t), Metric::Matthews) => {
            stats::matthews(p, t)
        }
        (Predictions::Score(p), Labels::Score(t), Metric::Spearman) => {
            let p64: Vec<f64> = p.iter().map(|&x| x as f64).collect();
            let t64: Vec<f64> = t.iter().map(|&x| x as f64).collect();
            stats::spearman(&p64, &t64)
        }
        (Predictions::Span(p), Labels::Span(t), Metric::SpanF1) => {
            stats::span_em_f1(p, t).1
        }
        (Predictions::Span(p), Labels::Span(t), Metric::Accuracy) => {
            stats::span_em_f1(p, t).0
        }
        _ => bail!("metric {metric:?} incompatible with prediction/label kinds"),
    })
}

/// Convenience: predict + score in one call.
pub fn evaluate(
    rt: &Arc<Runtime>,
    model: &TaskModel,
    pretrained_base: &NamedTensors,
    split: &Split,
    n_classes: usize,
    metric: Metric,
) -> Result<f64> {
    let preds = predict_split(rt, model, pretrained_base, split, n_classes, None)?;
    score(&preds, &split.labels, metric)
}

/// Evaluate with an adapter ablation gate vector (Fig. 6 left/center).
pub fn evaluate_with_gates(
    rt: &Arc<Runtime>,
    model: &TaskModel,
    pretrained_base: &NamedTensors,
    split: &Split,
    n_classes: usize,
    metric: Metric,
    gates: &[f32],
) -> Result<f64> {
    let preds =
        predict_split(rt, model, pretrained_base, split, n_classes, Some(gates))?;
    score(&preds, &split.labels, metric)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn score_accuracy_and_f1() {
        let p = Predictions::Class(vec![1, 0, 1, 1]);
        let l = Labels::Class(vec![1, 0, 0, 1]);
        assert_eq!(score(&p, &l, Metric::Accuracy).unwrap(), 0.75);
        assert!(score(&p, &l, Metric::F1).unwrap() > 0.0);
    }

    #[test]
    fn score_rejects_mismatch() {
        let p = Predictions::Class(vec![1]);
        let l = Labels::Score(vec![1.0]);
        assert!(score(&p, &l, Metric::Accuracy).is_err());
    }

    #[test]
    fn fwd_name_by_variant() {
        let m = TaskModel {
            variant: "adapter".into(),
            m: Some(8),
            k: None,
            kind: "cls".into(),
            trained: Default::default(),
        };
        assert_eq!(m.fwd_name(), "cls_fwd_adapter_m8");
        let t = TaskModel {
            variant: "topk".into(),
            m: None,
            k: Some(2),
            kind: "span".into(),
            trained: Default::default(),
        };
        assert_eq!(t.fwd_name(), "span_fwd_base");
    }

    #[test]
    fn param_count_excludes_head() {
        let mut trained = NamedTensors::default();
        trained.insert("adapters/x", Tensor::f32(vec![4], vec![0.0; 4]));
        trained.insert("head/w", Tensor::f32(vec![10], vec![0.0; 10]));
        let m = TaskModel {
            variant: "adapter".into(),
            m: Some(8),
            k: None,
            kind: "cls".into(),
            trained,
        };
        assert_eq!(m.trained_param_count(), 14);
        assert_eq!(m.trained_param_count_no_head(), 4);
    }
}
