//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides the subset of `anyhow`'s API that the workspace uses: the
//! [`Error`] type with a context chain, the [`Result`] alias, the
//! [`Context`] extension trait for `Result`/`Option`, and the `anyhow!`,
//! `bail!`, `ensure!` macros.
//!
//! Semantics mirror the real crate where it matters:
//! * `{}` displays the outermost message only; `{:#}` joins the whole
//!   chain with `": "`; `{:?}` prints the message plus a `Caused by:` list.
//! * Any `std::error::Error + Send + Sync + 'static` converts into
//!   [`Error`] via `?`, capturing its `source()` chain as strings.
//!
//! Not implemented (unused in this workspace): downcasting, backtraces.

use std::fmt;

/// A string-chain error: the outermost context first, root cause last.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message (most recent first).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// Iterate the message chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message of the chain.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.chain.first().map(|s| s.as_str()).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait attaching context to `Result` and `Option` values.
pub trait Context<T> {
    /// Wrap the error (or `None`) with a context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap with a lazily evaluated context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a message or format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Error::from(io_err()).context("writing bank");
        assert_eq!(format!("{e}"), "writing bank");
        assert_eq!(format!("{e:#}"), "writing bank: disk on fire");
    }

    #[test]
    fn context_on_result_and_option() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("saving").unwrap_err();
        assert_eq!(format!("{e:#}"), "saving: disk on fire");
        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", 7)).unwrap_err();
        assert_eq!(format!("{e}"), "missing 7");
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(3).unwrap_err()), "three is right out");
        let e = anyhow!("plain");
        assert_eq!(format!("{e}"), "plain");
    }
}
