//! Closed-loop load generator for the serving gateway.
//!
//! Drives `serve::Gateway` over real sockets: N worker threads, each with
//! its own keep-alive connection, issue predict-by-text requests against
//! a configurable task mix until a request budget or deadline runs out
//! (closed loop: a worker sends its next request only after the previous
//! response lands, so concurrency == open requests). The report — total
//! and per-task throughput, latency quantiles, the batch-size histogram
//! observed in responses and the server-side occupancy over the run
//! window — serializes to `BENCH_serve.json` (schema v2), the serving
//! entry in the repo's perf trajectory.
//!
//! The **many-tasks/low-rate preset** (`task_count` + `rate`) recreates
//! the paper's serving regime — 26 tasks, modest traffic each — where
//! per-task batching collapses to 1–2-row batches and the fused engine's
//! cross-task batches win; the recorded `mean_occupancy` is the
//! comparison the CI smoke job pins.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::serve::Client;
use crate::tokenizer::Tokenizer;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::timer::Samples;

/// What to fire at the gateway.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Gateway address (`host:port`).
    pub addr: String,
    /// Task mix, cycled round-robin; empty = every task the gateway lists.
    pub tasks: Vec<String>,
    /// Many-tasks preset: use the first N discovered tasks (errors if the
    /// gateway serves fewer). Ignored when `tasks` is non-empty.
    pub task_count: Option<usize>,
    /// Closed-loop worker threads (= open requests at any moment).
    pub concurrency: usize,
    /// Total request budget (0 = unlimited, stop on `duration`).
    pub requests: u64,
    /// Optional wall-clock cap.
    pub duration: Option<Duration>,
    /// Low-rate preset: pace the closed loop to ≈ this many req/s total
    /// (request `i` is not issued before `t0 + i/rate`). `None` = as
    /// fast as responses come back.
    pub rate: Option<f64>,
    /// Words of random text per request.
    pub words_per_request: usize,
    /// RNG seed for the request text.
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: String::new(),
            tasks: Vec::new(),
            task_count: None,
            concurrency: 4,
            requests: 200,
            duration: None,
            rate: None,
            words_per_request: 12,
            seed: 7,
        }
    }
}

/// Per-task slice of the report.
#[derive(Debug, Default, Clone)]
pub struct TaskLoad {
    pub requests: u64,
    pub errors: u64,
    pub latencies: Samples,
    /// `batch_size → count` as observed in responses (how many real rows
    /// rode in the batch that served each request).
    pub batch_sizes: BTreeMap<usize, u64>,
}

/// Server-side counters over the run window, from `GET /metrics` deltas
/// (absent when the gateway predates them or metrics were unreachable).
#[derive(Debug, Clone)]
pub struct ServerWindow {
    /// `per_task` | `fused`.
    pub exec_mode: String,
    /// Batches executed during the run.
    pub batches: f64,
    /// Of those, batches through the fused engine.
    pub fused_batches: f64,
    /// Sum of per-batch occupancy during the run.
    pub occupancy_sum: f64,
}

impl ServerWindow {
    /// Mean batch occupancy over the run window, in `[0, 1]`.
    pub fn mean_occupancy(&self) -> f64 {
        if self.batches <= 0.0 {
            0.0
        } else {
            self.occupancy_sum / self.batches
        }
    }
}

/// The whole run.
#[derive(Debug)]
pub struct LoadReport {
    /// Resolved task mix (after discovery).
    pub tasks: Vec<String>,
    pub wall_s: f64,
    pub requests: u64,
    pub errors: u64,
    pub per_task: BTreeMap<String, TaskLoad>,
    /// All successful request latencies.
    pub all: Samples,
    /// Aggregate `batch_size → count` across tasks.
    pub batch_size_hist: BTreeMap<usize, u64>,
    /// Server-side occupancy/mode over the run window.
    pub server: Option<ServerWindow>,
}

impl LoadReport {
    pub fn throughput_rps(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.requests as f64 / self.wall_s
        }
    }

    /// The `BENCH_serve.json` document, schema v2 (see `write_report`).
    /// v2 adds `config.rate_rps`, `totals.batch_size_hist` and the
    /// `server` section (exec mode + occupancy over the run window).
    pub fn to_json(&self, cfg: &LoadgenConfig) -> Json {
        let per_task = Json::Obj(
            self.per_task
                .iter()
                .map(|(task, t)| {
                    (
                        task.clone(),
                        Json::obj(vec![
                            ("requests", Json::num(t.requests as f64)),
                            ("errors", Json::num(t.errors as f64)),
                            ("latency_ms", latency_json(&t.latencies)),
                        ]),
                    )
                })
                .collect(),
        );
        let server = match &self.server {
            Some(w) => Json::obj(vec![
                ("exec_mode", Json::str(&w.exec_mode)),
                ("batches", Json::num(w.batches)),
                ("fused_batches", Json::num(w.fused_batches)),
                ("mean_occupancy", Json::num(w.mean_occupancy())),
            ]),
            None => Json::Null,
        };
        Json::obj(vec![
            ("bench", Json::str("serve")),
            ("schema_version", Json::num(2.0)),
            (
                "config",
                Json::obj(vec![
                    ("concurrency", Json::num(cfg.concurrency as f64)),
                    ("requests", Json::num(cfg.requests as f64)),
                    (
                        "duration_s",
                        cfg.duration
                            .map(|d| Json::num(d.as_secs_f64()))
                            .unwrap_or(Json::Null),
                    ),
                    (
                        "rate_rps",
                        cfg.rate.map(Json::num).unwrap_or(Json::Null),
                    ),
                    ("words_per_request", Json::num(cfg.words_per_request as f64)),
                    (
                        "tasks",
                        Json::arr(self.tasks.iter().map(|t| Json::str(t))),
                    ),
                ]),
            ),
            (
                "totals",
                Json::obj(vec![
                    ("requests", Json::num(self.requests as f64)),
                    ("errors", Json::num(self.errors as f64)),
                    ("wall_s", Json::num(self.wall_s)),
                    ("throughput_rps", Json::num(self.throughput_rps())),
                    ("latency_ms", latency_json(&self.all)),
                    (
                        "batch_size_hist",
                        Json::Obj(
                            self.batch_size_hist
                                .iter()
                                .map(|(size, count)| {
                                    (size.to_string(), Json::num(*count as f64))
                                })
                                .collect(),
                        ),
                    ),
                ]),
            ),
            ("server", server),
            ("per_task", per_task),
        ])
    }
}

/// `{mean, p50, p95, p99, max}` in milliseconds (zeros when empty — JSON
/// has no NaN). Shared with the train-and-serve harness.
pub(crate) fn latency_json(s: &Samples) -> Json {
    let (mean, p50, p95, p99, max) = if s.is_empty() {
        (0.0, 0.0, 0.0, 0.0, 0.0)
    } else {
        (
            s.mean_s() * 1e3,
            s.pctl_s(50.0) * 1e3,
            s.pctl_s(95.0) * 1e3,
            s.pctl_s(99.0) * 1e3,
            s.pctl_s(100.0) * 1e3,
        )
    };
    Json::obj(vec![
        ("mean", Json::num(mean)),
        ("p50", Json::num(p50)),
        ("p95", Json::num(p95)),
        ("p99", Json::num(p99)),
        ("max", Json::num(max)),
    ])
}

/// Parse the server-side counters this harness windows over from a
/// `GET /metrics` document (`None` when the fields are missing).
fn server_counters(metrics: &Json) -> Option<(String, f64, f64, f64)> {
    let coord = metrics.get("coordinator")?;
    Some((
        metrics
            .get("exec_mode")
            .and_then(Json::as_str)
            .unwrap_or("per_task")
            .to_string(),
        coord.get("batches").and_then(Json::as_f64)?,
        coord.get("fused_batches").and_then(Json::as_f64).unwrap_or(0.0),
        coord.get("occupancy_sum").and_then(Json::as_f64)?,
    ))
}

/// Run the closed loop and aggregate.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadReport> {
    if cfg.requests == 0 && cfg.duration.is_none() {
        bail!("loadgen needs a request budget or a duration");
    }
    let mut probe = Client::connect(&cfg.addr)?;
    let health = probe.health().context("gateway health check")?;
    let tasks: Vec<String> = if cfg.tasks.is_empty() {
        let discovered: Vec<String> = probe
            .tasks()
            .context("task discovery")?
            .into_iter()
            .map(|t| t.task)
            .collect();
        match cfg.task_count {
            Some(n) => {
                if discovered.len() < n {
                    bail!(
                        "many-tasks preset wants {n} tasks but the gateway \
                         serves only {} ({discovered:?})",
                        discovered.len()
                    );
                }
                discovered.into_iter().take(n).collect()
            }
            None => discovered,
        }
    } else {
        cfg.tasks.clone()
    };
    if tasks.is_empty() {
        bail!("gateway serves no tasks and none were given");
    }
    // snapshot the server counters so the report windows occupancy over
    // exactly this run, not the gateway's whole lifetime
    let before = probe.metrics().ok().as_ref().and_then(server_counters);
    // close the discovery connection before the closed loop starts, so
    // the gateway's worker rotation only carries live load connections
    drop(probe);
    let tok = Tokenizer::new(health.vocab);
    let word_ids = health.vocab.saturating_sub(4).max(1);

    let issued = AtomicU64::new(0);
    let deadline = cfg.duration.map(|d| Instant::now() + d);
    let t0 = Instant::now();
    let mut worker_stats: Vec<Result<BTreeMap<String, TaskLoad>>> = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for w in 0..cfg.concurrency.max(1) {
            let tasks = &tasks;
            let tok = &tok;
            let issued = &issued;
            handles.push(scope.spawn(move || {
                worker_loop(cfg, w as u64, tasks, tok, word_ids, issued, deadline, t0)
            }));
        }
        for h in handles {
            worker_stats.push(match h.join() {
                Ok(r) => r,
                Err(_) => Err(anyhow::anyhow!("loadgen worker panicked")),
            });
        }
    });

    let wall_s = t0.elapsed().as_secs_f64();
    let server = match (before, Client::connect(&cfg.addr)) {
        (Some((mode, b0, f0, o0)), Ok(mut c)) => c
            .metrics()
            .ok()
            .as_ref()
            .and_then(server_counters)
            .map(|(_, b1, f1, o1)| ServerWindow {
                exec_mode: mode,
                batches: (b1 - b0).max(0.0),
                fused_batches: (f1 - f0).max(0.0),
                occupancy_sum: (o1 - o0).max(0.0),
            }),
        _ => None,
    };
    let mut per_task: BTreeMap<String, TaskLoad> = BTreeMap::new();
    for stats in worker_stats {
        for (task, t) in stats? {
            let agg = per_task.entry(task).or_default();
            agg.requests += t.requests;
            agg.errors += t.errors;
            agg.latencies.durs.extend(t.latencies.durs);
            for (size, count) in t.batch_sizes {
                *agg.batch_sizes.entry(size).or_insert(0) += count;
            }
        }
    }
    let mut all = Samples::default();
    let mut requests = 0;
    let mut errors = 0;
    let mut batch_size_hist: BTreeMap<usize, u64> = BTreeMap::new();
    for t in per_task.values() {
        requests += t.requests;
        errors += t.errors;
        all.durs.extend(t.latencies.durs.iter().copied());
        for (size, count) in &t.batch_sizes {
            *batch_size_hist.entry(*size).or_insert(0) += count;
        }
    }
    Ok(LoadReport {
        tasks,
        wall_s,
        requests,
        errors,
        per_task,
        all,
        batch_size_hist,
        server,
    })
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    cfg: &LoadgenConfig,
    worker: u64,
    tasks: &[String],
    tok: &Tokenizer,
    word_ids: usize,
    issued: &AtomicU64,
    deadline: Option<Instant>,
    t0: Instant,
) -> Result<BTreeMap<String, TaskLoad>> {
    let mut client = Client::connect(&cfg.addr)?;
    let mut rng = Rng::new(cfg.seed ^ (worker.wrapping_mul(0x9E37_79B9)));
    let mut stats: BTreeMap<String, TaskLoad> = BTreeMap::new();
    let mut consecutive_errors = 0usize;
    loop {
        let i = issued.fetch_add(1, Ordering::Relaxed);
        if cfg.requests > 0 && i >= cfg.requests {
            break;
        }
        if let Some(d) = deadline {
            if Instant::now() >= d {
                break;
            }
        }
        // low-rate pacing: request i is not issued before t0 + i/rate
        if let Some(rate) = cfg.rate {
            let slot = t0 + Duration::from_secs_f64(i as f64 / rate);
            let now = Instant::now();
            if slot > now {
                std::thread::sleep(slot - now);
            }
        }
        let task = &tasks[(i as usize) % tasks.len()];
        let words: Vec<&str> = (0..cfg.words_per_request.max(1))
            .map(|_| tok.word(4 + rng.below(word_ids) as i32))
            .collect();
        let text = words.join(" ");
        let t_req = Instant::now();
        let entry = stats.entry(task.clone()).or_default();
        match client.predict_text(task, &text) {
            Ok(resp) => {
                entry.requests += 1;
                entry.latencies.record(t_req.elapsed());
                *entry.batch_sizes.entry(resp.batch_size).or_insert(0) += 1;
                consecutive_errors = 0;
            }
            Err(e) => {
                entry.errors += 1;
                consecutive_errors += 1;
                if consecutive_errors > 50 {
                    return Err(e).context("worker giving up after 50 straight errors");
                }
                // connection may be poisoned (timeout mid-response); redial
                let _ = client.reconnect();
            }
        }
    }
    Ok(stats)
}

/// Atomically (write + rename) persist the report document.
pub fn write_report(path: &Path, report: &Json) -> Result<()> {
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, format!("{report}\n"))
        .with_context(|| format!("writing {tmp:?}"))?;
    std::fs::rename(&tmp, path).with_context(|| format!("renaming into {path:?}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_schema() {
        let mut per_task = BTreeMap::new();
        let mut lat = Samples::default();
        lat.record(Duration::from_millis(3));
        let mut batch_sizes = BTreeMap::new();
        batch_sizes.insert(3usize, 10u64);
        per_task.insert(
            "rte_s".to_string(),
            TaskLoad { requests: 10, errors: 0, latencies: lat, batch_sizes },
        );
        let mut all = Samples::default();
        all.record(Duration::from_millis(3));
        let mut hist = BTreeMap::new();
        hist.insert(3usize, 10u64);
        let report = LoadReport {
            tasks: vec!["rte_s".into()],
            wall_s: 0.5,
            requests: 10,
            errors: 0,
            per_task,
            all,
            batch_size_hist: hist,
            server: Some(ServerWindow {
                exec_mode: "fused".into(),
                batches: 4.0,
                fused_batches: 4.0,
                occupancy_sum: 3.0,
            }),
        };
        let cfg = LoadgenConfig {
            addr: "x".into(),
            rate: Some(50.0),
            ..Default::default()
        };
        let j = report.to_json(&cfg);
        // must re-parse as valid JSON with the pinned schema fields
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.at("bench").as_str(), Some("serve"));
        assert_eq!(back.at("schema_version").as_usize(), Some(2));
        assert_eq!(back.at("config").at("rate_rps").as_f64(), Some(50.0));
        assert_eq!(back.at("totals").at("requests").as_usize(), Some(10));
        assert!(back.at("totals").at("throughput_rps").as_f64().unwrap() > 0.0);
        assert_eq!(
            back.at("totals").at("batch_size_hist").at("3").as_usize(),
            Some(10)
        );
        assert_eq!(back.at("server").at("exec_mode").as_str(), Some("fused"));
        assert_eq!(back.at("server").at("mean_occupancy").as_f64(), Some(0.75));
        assert_eq!(back.at("server").at("fused_batches").as_usize(), Some(4));
        let lt = back.at("per_task").at("rte_s").at("latency_ms");
        for key in ["mean", "p50", "p95", "p99", "max"] {
            assert!(lt.at(key).as_f64().is_some(), "missing {key}");
        }
    }

    #[test]
    fn report_without_server_window_emits_null() {
        let report = LoadReport {
            tasks: vec![],
            wall_s: 0.0,
            requests: 0,
            errors: 0,
            per_task: BTreeMap::new(),
            all: Samples::default(),
            batch_size_hist: BTreeMap::new(),
            server: None,
        };
        let cfg = LoadgenConfig { addr: "x".into(), ..Default::default() };
        let back = Json::parse(&report.to_json(&cfg).to_string()).unwrap();
        assert_eq!(back.at("server"), &Json::Null);
        assert_eq!(back.at("config").at("rate_rps"), &Json::Null);
    }

    #[test]
    fn server_counters_parses_metrics_document() {
        let j = Json::parse(
            r#"{"exec_mode":"fused",
                "coordinator":{"batches":7,"fused_batches":5,
                               "occupancy_sum":4.5,"requests":30}}"#,
        )
        .unwrap();
        let (mode, b, f, o) = server_counters(&j).unwrap();
        assert_eq!(mode, "fused");
        assert_eq!(b, 7.0);
        assert_eq!(f, 5.0);
        assert_eq!(o, 4.5);
        // missing occupancy_sum (older gateway) → None
        let j = Json::parse(r#"{"coordinator":{"batches":7}}"#).unwrap();
        assert!(server_counters(&j).is_none());
    }

    #[test]
    fn empty_latency_emits_zeros_not_nan() {
        let j = latency_json(&Samples::default());
        let s = j.to_string();
        assert!(!s.contains("NaN"), "{s}");
        assert_eq!(j.at("p99").as_f64(), Some(0.0));
    }

    #[test]
    fn run_requires_a_stop_condition() {
        let cfg = LoadgenConfig {
            addr: "127.0.0.1:1".into(),
            requests: 0,
            duration: None,
            ..Default::default()
        };
        assert!(run(&cfg).is_err());
    }
}
