//! Compile-time stub of the `xla` crate's PJRT bindings.
//!
//! The real `xla` crate links `xla_extension` (a native PJRT plugin) which
//! is not present in this build environment. This stub keeps the exact API
//! surface `adapterbert` uses so the crate always compiles, with two tiers
//! of fidelity:
//!
//! * [`Literal`] (host tensor data) is **fully implemented** in pure Rust —
//!   `Tensor::to_literal`/`from_literal` and their tests work unchanged.
//! * The PJRT device types ([`PjRtClient`], [`PjRtBuffer`],
//!   [`PjRtLoadedExecutable`], [`HloModuleProto`]) compile but cannot be
//!   constructed: [`PjRtClient::cpu`] returns
//!   [`Error::PjrtUnavailable`]. The runtime's `auto` backend treats that
//!   as "no plugin installed" and falls back to the native Rust backend.
//!
//! To run against real XLA, replace this path dependency in the workspace
//! `Cargo.toml` with the actual bindings; the call sites are unchanged.

use std::fmt;

/// Errors surfaced by the stub.
#[derive(Debug)]
pub enum Error {
    /// No PJRT plugin is linked into this build.
    PjrtUnavailable(&'static str),
    /// Shape/element-count mismatch in a `Literal` operation.
    Shape(String),
    /// Element-type mismatch in a `Literal` operation.
    ElementType(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::PjrtUnavailable(what) => write!(
                f,
                "PJRT unavailable: {what} (this build vendors the xla API \
                 stub; use the native backend, or link the real xla crate)"
            ),
            Error::Shape(msg) => write!(f, "literal shape error: {msg}"),
            Error::ElementType(msg) => write!(f, "literal element type error: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

/// Stub-local result alias.
pub type Result<T> = std::result::Result<T, Error>;

/// XLA element types (subset + common extras so matches stay non-exhaustive).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    F16,
    Bf16,
    F32,
    F64,
}

/// Host payload of an array literal.
#[derive(Debug, Clone, PartialEq)]
pub enum LitData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl LitData {
    fn len(&self) -> usize {
        match self {
            LitData::F32(v) => v.len(),
            LitData::I32(v) => v.len(),
        }
    }
}

/// Rust scalar types that map onto XLA element types.
pub trait NativeType: Copy + 'static {
    /// The XLA element type for this Rust type.
    const TY: ElementType;
    /// Pack a slice into literal payload form.
    fn pack(v: &[Self]) -> LitData;
    /// Borrow the payload back as this type, if the types match.
    fn unpack(d: &LitData) -> Option<&[Self]>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;
    fn pack(v: &[f32]) -> LitData {
        LitData::F32(v.to_vec())
    }
    fn unpack(d: &LitData) -> Option<&[f32]> {
        match d {
            LitData::F32(v) => Some(v),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;
    fn pack(v: &[i32]) -> LitData {
        LitData::I32(v.to_vec())
    }
    fn unpack(d: &LitData) -> Option<&[i32]> {
        match d {
            LitData::I32(v) => Some(v),
            _ => None,
        }
    }
}

/// Array shape of a non-tuple literal: element type + dimensions.
#[derive(Debug, Clone)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    /// Dimension extents, row-major.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Element type of the array.
    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// A host-side XLA literal: an array or a tuple of literals.
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    /// Dense row-major array.
    Array {
        /// Element type of `data`.
        ty: ElementType,
        /// Dimension extents (empty = scalar).
        dims: Vec<i64>,
        /// Flattened payload.
        data: LitData,
    },
    /// Tuple of sub-literals (XLA computations return one of these).
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Scalar literal.
    pub fn scalar<T: NativeType>(v: T) -> Literal {
        Literal::Array { ty: T::TY, dims: Vec::new(), data: T::pack(&[v]) }
    }

    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Literal {
        Literal::Array { ty: T::TY, dims: vec![v.len() as i64], data: T::pack(v) }
    }

    /// Same data, new dimensions (element counts must agree).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        match self {
            Literal::Array { ty, data, .. } => {
                let want: i64 = dims.iter().product();
                if want < 0 || want as usize != data.len() {
                    return Err(Error::Shape(format!(
                        "cannot reshape {} elements to {dims:?}",
                        data.len()
                    )));
                }
                Ok(Literal::Array { ty: *ty, dims: dims.to_vec(), data: data.clone() })
            }
            Literal::Tuple(_) => {
                Err(Error::Shape("cannot reshape a tuple literal".into()))
            }
        }
    }

    /// The array shape, or an error for tuple literals.
    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::Array { ty, dims, .. } => {
                Ok(ArrayShape { ty: *ty, dims: dims.clone() })
            }
            Literal::Tuple(_) => {
                Err(Error::Shape("tuple literal has no array shape".into()))
            }
        }
    }

    /// Copy the payload out as `Vec<T>` (type must match).
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { data, .. } => T::unpack(data)
                .map(|s| s.to_vec())
                .ok_or_else(|| Error::ElementType("to_vec type mismatch".into())),
            Literal::Tuple(_) => {
                Err(Error::ElementType("to_vec on tuple literal".into()))
            }
        }
    }

    /// Split a tuple literal into its elements (self is left empty).
    pub fn decompose_tuple(&mut self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(std::mem::take(parts)),
            Literal::Array { .. } => {
                Err(Error::Shape("decompose_tuple on array literal".into()))
            }
        }
    }
}

const NO_PLUGIN: &str = "no PJRT plugin linked";

/// PJRT client handle (unconstructible in the stub).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Open the CPU PJRT plugin. Always fails in the stub build.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::PjrtUnavailable(NO_PLUGIN))
    }

    /// Compile an XLA computation for this client.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::PjrtUnavailable(NO_PLUGIN))
    }

    /// Transfer a host buffer to the device.
    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        _data: &[T],
        _shape: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(Error::PjrtUnavailable(NO_PLUGIN))
    }
}

/// A device-resident buffer (unconstructible in the stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy the buffer back to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::PjrtUnavailable(NO_PLUGIN))
    }
}

/// A compiled executable (unconstructible in the stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute on device buffers, returning per-device output buffers.
    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[T],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::PjrtUnavailable(NO_PLUGIN))
    }
}

/// Parsed HLO module (unconstructible in the stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse HLO text from a file.
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::PjrtUnavailable(NO_PLUGIN))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        let r = l.reshape(&[2, 2]).unwrap();
        let shape = r.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
        assert!(r.to_vec::<i32>().is_err());
    }

    #[test]
    fn tuple_decomposes_once() {
        let mut t = Literal::Tuple(vec![Literal::scalar(1i32), Literal::scalar(2.5f32)]);
        let parts = t.decompose_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[0].to_vec::<i32>().unwrap(), vec![1]);
        assert!(Literal::scalar(1i32).array_shape().unwrap().dims().is_empty());
    }

    #[test]
    fn pjrt_is_unavailable() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(format!("{e}").contains("PJRT unavailable"));
    }
}
