//! Manifest model: the contract between `python/compile/aot.py` and Rust.
//!
//! The manifest records, for every AOT executable, the *flattened* input
//! and output leaves (group, path, shape, dtype) in the exact positional
//! order of the HLO ENTRY computation. Parameter banks are packed and
//! unpacked positionally against this — there is no reflection at runtime.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;
use crate::util::tensor::DType;

/// Architecture hyper-parameters baked into a preset's artifacts.
#[derive(Debug, Clone)]
pub struct ModelDims {
    pub vocab: usize,
    pub d: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub ffn: usize,
    pub seq: usize,
    pub max_classes: usize,
    pub type_vocab: usize,
    pub mlm_positions: usize,
}

/// One tensor slot in an executable's signature.
#[derive(Debug, Clone)]
pub struct LeafSpec {
    pub name: String,
    pub group: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl LeafSpec {
    /// Element count of this leaf (product of its shape; 1 for scalars).
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT executable (an HLO text file plus its signature).
#[derive(Debug, Clone)]
pub struct ExeSpec {
    pub name: String,
    pub file: String,
    /// task kind: cls | reg | span | mlm | embed
    pub kind: String,
    /// variant: adapter | topk | lnonly | fwd_adapter | fwd_base | pretrain | fwd
    pub variant: String,
    /// adapter bottleneck size (adapter variants)
    pub m: Option<usize>,
    /// top-k depth (topk variants)
    pub k: Option<usize>,
    pub batch: usize,
    pub inputs: Vec<LeafSpec>,
    pub outputs: Vec<LeafSpec>,
}

impl ExeSpec {
    /// Contiguous index range of `group` among the inputs.
    pub fn input_group_range(&self, group: &str) -> Result<std::ops::Range<usize>> {
        group_range(&self.inputs, group)
            .with_context(|| format!("{}: no input group {group:?}", self.name))
    }

    /// Contiguous index range of `group` among the outputs.
    pub fn output_group_range(&self, group: &str) -> Result<std::ops::Range<usize>> {
        group_range(&self.outputs, group)
            .with_context(|| format!("{}: no output group {group:?}", self.name))
    }

    /// Distinct input group names, in positional order.
    pub fn input_groups(&self) -> Vec<&str> {
        distinct_groups(&self.inputs)
    }

    /// Distinct output group names, in positional order.
    pub fn output_groups(&self) -> Vec<&str> {
        distinct_groups(&self.outputs)
    }

    /// Total f32-equivalent element count of one input group (parameter
    /// accounting for the paper's "params per task" columns).
    pub fn group_param_count(&self, group: &str) -> usize {
        match self.input_group_range(group) {
            Ok(r) => self.inputs[r].iter().map(|l| l.elements()).sum(),
            Err(_) => 0,
        }
    }
}

fn group_range(leaves: &[LeafSpec], group: &str) -> Option<std::ops::Range<usize>> {
    let start = leaves.iter().position(|l| l.group == group)?;
    let end = start
        + leaves[start..]
            .iter()
            .take_while(|l| l.group == group)
            .count();
    Some(start..end)
}

fn distinct_groups(leaves: &[LeafSpec]) -> Vec<&str> {
    let mut out: Vec<&str> = Vec::new();
    for l in leaves {
        if out.last() != Some(&l.group.as_str()) {
            out.push(&l.group);
        }
    }
    out
}

/// Parsed `manifest.json` for one preset.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub preset: String,
    pub dir: PathBuf,
    pub dims: ModelDims,
    pub batch: usize,
    pub executables: BTreeMap<String, ExeSpec>,
}

impl Manifest {
    /// Read `manifest.json` from an artifacts directory.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        Self::from_json(&j, dir)
    }

    /// Parse an already-loaded manifest document (`dir` is only recorded).
    pub fn from_json(j: &Json, dir: &Path) -> Result<Manifest> {
        let cfg = j.at("config");
        let dims = ModelDims {
            vocab: need_usize(cfg, "vocab")?,
            d: need_usize(cfg, "d")?,
            n_layers: need_usize(cfg, "n_layers")?,
            n_heads: need_usize(cfg, "n_heads")?,
            ffn: need_usize(cfg, "ffn")?,
            seq: need_usize(cfg, "seq")?,
            max_classes: need_usize(cfg, "max_classes")?,
            type_vocab: need_usize(cfg, "type_vocab")?,
            mlm_positions: need_usize(cfg, "mlm_positions")?,
        };
        let mut executables = BTreeMap::new();
        for e in j.at("executables").as_arr().context("executables")? {
            let spec = parse_exe(e)?;
            executables.insert(spec.name.clone(), spec);
        }
        Ok(Manifest {
            preset: j.at("preset").as_str().context("preset")?.to_string(),
            dir: dir.to_path_buf(),
            dims,
            batch: need_usize(j, "batch")?,
            executables,
        })
    }

    /// Look up an executable's signature by name.
    pub fn exe(&self, name: &str) -> Result<&ExeSpec> {
        self.executables.get(name).with_context(|| {
            format!("manifest has no executable {name:?} (preset {})", self.preset)
        })
    }

    /// On-disk location of an executable's HLO text (PJRT backend only).
    pub fn hlo_path(&self, name: &str) -> Result<PathBuf> {
        Ok(self.dir.join(&self.exe(name)?.file))
    }

    /// Names of executables matching kind/variant (e.g. all adapter sizes).
    pub fn find(&self, kind: &str, variant: &str) -> Vec<&ExeSpec> {
        self.executables
            .values()
            .filter(|e| e.kind == kind && e.variant == variant)
            .collect()
    }

    /// Trainable parameter count of the frozen base model (the paper's
    /// 100% reference for "trained params / task").
    pub fn base_param_count(&self) -> usize {
        let d = &self.dims;
        let per_layer = 4 * (d.d * d.d + d.d)            // attention QKVO
            + d.d * d.ffn + d.ffn + d.ffn * d.d + d.d    // FFN
            + 4 * d.d; // two LayerNorms
        d.vocab * d.d + d.seq * d.d + d.type_vocab * d.d // embeddings
            + 2 * d.d                                    // embedding LN
            + d.vocab                                    // MLM bias
            + d.n_layers * per_layer
    }
}

fn parse_exe(e: &Json) -> Result<ExeSpec> {
    let meta = e.at("meta");
    let parse_leaves = |key: &str| -> Result<Vec<LeafSpec>> {
        e.at(key)
            .as_arr()
            .with_context(|| key.to_string())?
            .iter()
            .map(|l| {
                Ok(LeafSpec {
                    name: l.at("name").as_str().context("leaf name")?.to_string(),
                    group: l.at("group").as_str().context("leaf group")?.to_string(),
                    shape: l
                        .at("shape")
                        .as_arr()
                        .context("leaf shape")?
                        .iter()
                        .map(|d| d.as_usize().context("dim"))
                        .collect::<Result<_>>()?,
                    dtype: DType::from_name(
                        l.at("dtype").as_str().context("leaf dtype")?,
                    )?,
                })
            })
            .collect()
    };
    let opt_usize = |j: &Json, k: &str| j.get(k).and_then(|v| v.as_usize());
    let spec = ExeSpec {
        name: e.at("name").as_str().context("name")?.to_string(),
        file: e.at("file").as_str().context("file")?.to_string(),
        kind: meta.at("kind").as_str().context("kind")?.to_string(),
        variant: meta.at("variant").as_str().context("variant")?.to_string(),
        m: opt_usize(meta, "m"),
        k: opt_usize(meta, "k"),
        batch: need_usize(meta, "batch")?,
        inputs: parse_leaves("inputs")?,
        outputs: parse_leaves("outputs")?,
    };
    if spec.inputs.is_empty() || spec.outputs.is_empty() {
        bail!("{}: empty signature", spec.name);
    }
    Ok(spec)
}

fn need_usize(j: &Json, key: &str) -> Result<usize> {
    j.at(key)
        .as_usize()
        .with_context(|| format!("expected number at {key:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_manifest_json() -> Json {
        Json::parse(
            r#"{
          "preset": "unit",
          "config": {"vocab": 8, "d": 4, "n_layers": 1, "n_heads": 1,
                     "ffn": 8, "seq": 4, "max_classes": 3, "type_vocab": 2,
                     "mlm_positions": 2, "adapter_size": 2, "ln_eps": 1e-6},
          "batch": 2,
          "adam": {"b1": 0.9, "b2": 0.999, "eps": 1e-8},
          "executables": [
            {"name": "toy", "file": "toy.hlo.txt",
             "meta": {"kind": "cls", "variant": "adapter", "m": 2, "batch": 2},
             "inputs": [
               {"name": "frozen/a", "group": "frozen", "shape": [4,4], "dtype": "f32"},
               {"name": "trained/b", "group": "trained", "shape": [2], "dtype": "f32"},
               {"name": "trained/c", "group": "trained", "shape": [], "dtype": "i32"}
             ],
             "outputs": [
               {"name": "out/0", "group": "out0", "shape": [2,3], "dtype": "f32"}
             ]}
          ]
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_and_indexes_groups() {
        let m = Manifest::from_json(&mini_manifest_json(), Path::new("/tmp/x")).unwrap();
        let e = m.exe("toy").unwrap();
        assert_eq!(e.input_group_range("frozen").unwrap(), 0..1);
        assert_eq!(e.input_group_range("trained").unwrap(), 1..3);
        assert!(e.input_group_range("nope").is_err());
        assert_eq!(e.input_groups(), vec!["frozen", "trained"]);
        assert_eq!(e.group_param_count("frozen"), 16);
        assert_eq!(e.m, Some(2));
        assert_eq!(e.k, None);
    }

    #[test]
    fn base_param_count_formula() {
        let m = Manifest::from_json(&mini_manifest_json(), Path::new("/tmp/x")).unwrap();
        // vocab*d + seq*d + type*d + 2d + vocab + L*(4(d²+d) + d*f+f+f*d+d + 4d)
        let d = 4usize;
        let f = 8usize;
        let expect = 8 * d + 4 * d + 2 * d + 2 * d + 8
            + (4 * (d * d + d) + d * f + f + f * d + d + 4 * d);
        assert_eq!(m.base_param_count(), expect);
    }

    #[test]
    fn missing_exe_is_error() {
        let m = Manifest::from_json(&mini_manifest_json(), Path::new("/tmp/x")).unwrap();
        assert!(m.exe("missing").is_err());
    }
}
