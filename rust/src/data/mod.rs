//! Synthetic workload substrates (the paper's corpus + 26 datasets).
//!
//! `grammar` — the latent-topic generative world (shared by pre-training
//! and every downstream task); `tasks` — GLUE / additional / SQuAD
//! stand-in suites; `batcher` — splits → manifest-shaped banks.

pub mod batcher;
pub mod grammar;
pub mod tasks;
