//! JSON wire types for the gateway protocol (via `util::json` — serde is
//! unreachable offline).
//!
//! | route            | request                    | response            |
//! |------------------|----------------------------|---------------------|
//! | `GET  /health`   | —                          | [`Health`]          |
//! | `GET  /tasks`    | —                          | `{"tasks":[TaskEntry…]}` |
//! | `POST /predict`  | [`PredictRequest`] (text)  | [`PredictResponse`] |
//! | `POST /predict_ids` | [`PredictRequest`] (ids) | [`PredictResponse`] |
//! | `POST /tasks`    | [`RegisterRequest`]        | [`RegisterResponse`]|
//! | `POST /train`    | [`TrainJobRequest`]        | [`TrainJobStatus`]  |
//! | `GET  /train`    | —                          | `{"jobs":[TrainJobStatus…]}` |
//! | `GET  /train/<id>` | —                        | [`TrainJobStatus`]  |
//! | `GET  /metrics`  | —                          | per-task latency histograms + [`CacheMetrics`] (raw JSON) |
//! | `GET  /metrics?format=prometheus` | —         | Prometheus text exposition (`obs::prom`) |
//! | `GET  /trace`    | —                          | recent spans from the `obs::trace` ring |
//!
//! Every response carries an `x-request-id` header: the caller's
//! `X-Request-Id` if supplied, a gateway-minted id otherwise — 404/503
//! error shapes included.
//!
//! Trained banks travel as lowercase hex of `NamedTensors::to_bytes` —
//! byte-exact, so a hot-registered bank reloads into the identical
//! `TaskModel` the trainer produced.

use anyhow::{bail, Context, Result};

use crate::coordinator::server::Response;
use crate::coordinator::CacheSnapshot;
use crate::eval::TaskModel;
use crate::model::params::NamedTensors;
use crate::store::BankMeta;
use crate::train::JobRecord;
use crate::util::json::Json;

// ---------------------------------------------------------------------------
// hex (bank payload encoding)
// ---------------------------------------------------------------------------

const HEX_DIGITS: &[u8; 16] = b"0123456789abcdef";

/// Lowercase hex of `bytes`.
pub fn to_hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for &b in bytes {
        s.push(HEX_DIGITS[(b >> 4) as usize] as char);
        s.push(HEX_DIGITS[(b & 0xf) as usize] as char);
    }
    s
}

fn hex_nibble(c: u8) -> Result<u8> {
    match c {
        b'0'..=b'9' => Ok(c - b'0'),
        b'a'..=b'f' => Ok(c - b'a' + 10),
        b'A'..=b'F' => Ok(c - b'A' + 10),
        _ => bail!("invalid hex digit {:?}", c as char),
    }
}

/// Decode hex (case-insensitive).
pub fn from_hex(s: &str) -> Result<Vec<u8>> {
    let b = s.as_bytes();
    if b.len() % 2 != 0 {
        bail!("odd-length hex string");
    }
    let mut out = Vec::with_capacity(b.len() / 2);
    for pair in b.chunks_exact(2) {
        out.push((hex_nibble(pair[0])? << 4) | hex_nibble(pair[1])?);
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// json helpers
// ---------------------------------------------------------------------------

fn get_str(j: &Json, key: &str) -> Result<String> {
    j.get(key)
        .and_then(Json::as_str)
        .map(str::to_string)
        .with_context(|| format!("missing or non-string field {key:?}"))
}

fn get_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(Json::as_usize)
        .with_context(|| format!("missing or non-numeric field {key:?}"))
}

fn get_f64(j: &Json, key: &str) -> Result<f64> {
    j.get(key)
        .and_then(Json::as_f64)
        .with_context(|| format!("missing or non-numeric field {key:?}"))
}

fn opt_str(j: &Json, key: &str) -> Option<String> {
    j.get(key).and_then(Json::as_str).map(str::to_string)
}

fn opt_usize(j: &Json, key: &str) -> Option<usize> {
    j.get(key).and_then(Json::as_usize)
}

fn opt_f64(j: &Json, key: &str) -> Option<f64> {
    j.get(key).and_then(Json::as_f64)
}

fn opt_u64(j: &Json, key: &str) -> Option<u64> {
    j.get(key).and_then(Json::as_f64).map(|n| n as u64)
}

fn opt_bool(j: &Json, key: &str) -> Option<bool> {
    j.get(key).and_then(Json::as_bool)
}

fn opt_i32_vec(j: &Json, key: &str) -> Result<Option<Vec<i32>>> {
    let Some(v) = j.get(key) else { return Ok(None) };
    let arr = v
        .as_arr()
        .with_context(|| format!("field {key:?} must be an array"))?;
    let mut out = Vec::with_capacity(arr.len());
    for x in arr {
        let n = x
            .as_f64()
            .with_context(|| format!("field {key:?} must hold numbers"))?;
        out.push(n as i32);
    }
    Ok(Some(out))
}

// ---------------------------------------------------------------------------
// wire types
// ---------------------------------------------------------------------------

/// `GET /health` response — liveness plus the readiness fields the
/// cluster router's health checker reads. The readiness trio
/// (`resident`/`store_ok`/`train_queue`) is optional on the wire so
/// older gateways still parse: absent fields degrade to "ready".
#[derive(Debug, Clone)]
pub struct Health {
    pub status: String,
    pub backend: String,
    pub preset: String,
    /// model vocabulary size (lets remote clients build a [`crate::tokenizer::Tokenizer`])
    pub vocab: usize,
    /// model sequence length (token-id requests must fit this)
    pub seq: usize,
    pub tasks: usize,
    pub draining: bool,
    /// tasks with banks resident in memory right now (≤ `tasks` under a
    /// byte-budget cache)
    pub resident: usize,
    /// the adapter store answered a cheap probe — a replica that cannot
    /// reach the source of truth cannot cold-load and is not ready
    pub store_ok: bool,
    /// background training jobs queued or running
    pub train_queue: usize,
}

impl Health {
    /// Ready to take routed traffic: live, not draining, store reachable.
    pub fn ready(&self) -> bool {
        self.status == "ok" && !self.draining && self.store_ok
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("status", Json::str(&self.status)),
            ("backend", Json::str(&self.backend)),
            ("preset", Json::str(&self.preset)),
            ("vocab", Json::num(self.vocab as f64)),
            ("seq", Json::num(self.seq as f64)),
            ("tasks", Json::num(self.tasks as f64)),
            ("draining", Json::Bool(self.draining)),
            ("resident", Json::num(self.resident as f64)),
            ("store_ok", Json::Bool(self.store_ok)),
            ("train_queue", Json::num(self.train_queue as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Health> {
        let tasks = get_usize(j, "tasks")?;
        Ok(Health {
            status: get_str(j, "status")?,
            backend: get_str(j, "backend")?,
            preset: get_str(j, "preset")?,
            vocab: get_usize(j, "vocab")?,
            seq: get_usize(j, "seq")?,
            tasks,
            draining: j.get("draining").and_then(Json::as_bool).unwrap_or(false),
            // readiness fields are newer than the wire format: a gateway
            // that omits them counts as fully resident and reachable
            resident: opt_usize(j, "resident").unwrap_or(tasks),
            store_ok: opt_bool(j, "store_ok").unwrap_or(true),
            train_queue: opt_usize(j, "train_queue").unwrap_or(0),
        })
    }
}

/// One row of the `GET /tasks` listing.
#[derive(Debug, Clone)]
pub struct TaskEntry {
    pub task: String,
    pub version: usize,
    pub variant: String,
    pub kind: String,
    pub n_classes: usize,
    pub val_score: f64,
    pub trained_params: usize,
}

impl TaskEntry {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("task", Json::str(&self.task)),
            ("version", Json::num(self.version as f64)),
            ("variant", Json::str(&self.variant)),
            ("kind", Json::str(&self.kind)),
            ("n_classes", Json::num(self.n_classes as f64)),
            ("val_score", Json::num(self.val_score)),
            ("trained_params", Json::num(self.trained_params as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<TaskEntry> {
        Ok(TaskEntry {
            task: get_str(j, "task")?,
            version: get_usize(j, "version")?,
            variant: get_str(j, "variant")?,
            kind: get_str(j, "kind")?,
            n_classes: get_usize(j, "n_classes")?,
            val_score: get_f64(j, "val_score")?,
            trained_params: get_usize(j, "trained_params")?,
        })
    }
}

/// `POST /predict` / `POST /predict_ids` request: exactly one of `text`
/// (optionally with `text_b` for sentence pairs) or `tokens` (optionally
/// with `segments`) must be present.
#[derive(Debug, Clone, Default)]
pub struct PredictRequest {
    pub task: String,
    pub text: Option<String>,
    pub text_b: Option<String>,
    pub tokens: Option<Vec<i32>>,
    pub segments: Option<Vec<i32>>,
}

impl PredictRequest {
    /// Text request (single sentence).
    pub fn text(task: &str, text: &str) -> PredictRequest {
        PredictRequest {
            task: task.to_string(),
            text: Some(text.to_string()),
            ..Default::default()
        }
    }

    /// Text request (sentence pair).
    pub fn pair(task: &str, a: &str, b: &str) -> PredictRequest {
        PredictRequest {
            task: task.to_string(),
            text: Some(a.to_string()),
            text_b: Some(b.to_string()),
            ..Default::default()
        }
    }

    /// Pre-tokenized request.
    pub fn ids(task: &str, tokens: Vec<i32>) -> PredictRequest {
        PredictRequest {
            task: task.to_string(),
            tokens: Some(tokens),
            ..Default::default()
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("task", Json::str(&self.task))];
        if let Some(t) = &self.text {
            pairs.push(("text", Json::str(t)));
        }
        if let Some(t) = &self.text_b {
            pairs.push(("text_b", Json::str(t)));
        }
        if let Some(ids) = &self.tokens {
            pairs.push((
                "tokens",
                Json::arr(ids.iter().map(|&i| Json::num(i as f64))),
            ));
        }
        if let Some(segs) = &self.segments {
            pairs.push((
                "segments",
                Json::arr(segs.iter().map(|&i| Json::num(i as f64))),
            ));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<PredictRequest> {
        let req = PredictRequest {
            task: get_str(j, "task")?,
            text: opt_str(j, "text"),
            text_b: opt_str(j, "text_b"),
            tokens: opt_i32_vec(j, "tokens")?,
            segments: opt_i32_vec(j, "segments")?,
        };
        if req.text.is_none() && req.tokens.is_none() {
            bail!("request needs either \"text\" or \"tokens\"");
        }
        Ok(req)
    }
}

/// `POST /predict*` response: exactly one of `pred_class` / `score` /
/// `span` is set, matching the task's head `kind`.
#[derive(Debug, Clone)]
pub struct PredictResponse {
    pub task: String,
    /// head kind: cls | reg | span
    pub kind: String,
    pub pred_class: Option<usize>,
    pub score: Option<f32>,
    pub span: Option<(usize, usize)>,
    /// coordinator submit→reply latency, as observed server-side
    pub latency_ms: f64,
    /// real rows in the batch this request rode in
    pub batch_size: usize,
}

impl PredictResponse {
    /// Build from a coordinator [`Response`].
    pub fn from_response(resp: &Response) -> PredictResponse {
        PredictResponse {
            task: resp.task.clone(),
            kind: resp.prediction.kind().to_string(),
            pred_class: resp.prediction.class(),
            score: resp.prediction.score(),
            span: resp.prediction.span(),
            latency_ms: resp.latency.as_secs_f64() * 1e3,
            batch_size: resp.batch_size,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("task", Json::str(&self.task)),
            ("kind", Json::str(&self.kind)),
            ("latency_ms", Json::num(self.latency_ms)),
            ("batch_size", Json::num(self.batch_size as f64)),
        ];
        if let Some(c) = self.pred_class {
            pairs.push(("pred_class", Json::num(c as f64)));
        }
        if let Some(s) = self.score {
            pairs.push(("score", Json::num(s as f64)));
        }
        if let Some((s, e)) = self.span {
            pairs.push((
                "span",
                Json::arr([Json::num(s as f64), Json::num(e as f64)]),
            ));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<PredictResponse> {
        let span = match j.get("span") {
            Some(v) => {
                let arr = v.as_arr().context("span must be an array")?;
                if arr.len() != 2 {
                    bail!("span must be [start, end]");
                }
                Some((
                    arr[0].as_usize().context("span start")?,
                    arr[1].as_usize().context("span end")?,
                ))
            }
            None => None,
        };
        Ok(PredictResponse {
            task: get_str(j, "task")?,
            kind: get_str(j, "kind")?,
            pred_class: opt_usize(j, "pred_class"),
            score: j.get("score").and_then(Json::as_f64).map(|f| f as f32),
            span,
            latency_ms: get_f64(j, "latency_ms")?,
            batch_size: get_usize(j, "batch_size")?,
        })
    }
}

/// `POST /tasks` request: hot-register a trained bank under `task`.
#[derive(Debug, Clone)]
pub struct RegisterRequest {
    pub task: String,
    pub n_classes: usize,
    pub val_score: f64,
    /// adapter | topk | lnonly
    pub variant: String,
    pub m: Option<usize>,
    pub k: Option<usize>,
    /// artifact kind: cls | reg | span
    pub kind: String,
    /// hex of `NamedTensors::to_bytes` for the trained bank
    pub bank_hex: String,
}

impl RegisterRequest {
    /// Package a locally trained model for the wire.
    pub fn from_model(
        task: &str,
        n_classes: usize,
        val_score: f64,
        model: &TaskModel,
    ) -> RegisterRequest {
        RegisterRequest {
            task: task.to_string(),
            n_classes,
            val_score,
            variant: model.variant.clone(),
            m: model.m,
            k: model.k,
            kind: model.kind.clone(),
            bank_hex: to_hex(&model.trained.to_bytes()),
        }
    }

    /// Decode the payload back into the trainer's `TaskModel`.
    pub fn to_model(&self) -> Result<TaskModel> {
        let bytes = from_hex(&self.bank_hex).context("bank_hex")?;
        let trained =
            NamedTensors::from_bytes(&bytes).context("decoding trained bank")?;
        Ok(TaskModel {
            variant: self.variant.clone(),
            m: self.m,
            k: self.k,
            kind: self.kind.clone(),
            trained,
        })
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("task", Json::str(&self.task)),
            ("n_classes", Json::num(self.n_classes as f64)),
            ("val_score", Json::num(self.val_score)),
            ("variant", Json::str(&self.variant)),
            ("kind", Json::str(&self.kind)),
            ("bank_hex", Json::str(&self.bank_hex)),
        ];
        if let Some(m) = self.m {
            pairs.push(("m", Json::num(m as f64)));
        }
        if let Some(k) = self.k {
            pairs.push(("k", Json::num(k as f64)));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<RegisterRequest> {
        Ok(RegisterRequest {
            task: get_str(j, "task")?,
            n_classes: get_usize(j, "n_classes")?,
            val_score: get_f64(j, "val_score")?,
            variant: get_str(j, "variant")?,
            m: opt_usize(j, "m"),
            k: opt_usize(j, "k"),
            kind: get_str(j, "kind")?,
            bank_hex: get_str(j, "bank_hex")?,
        })
    }
}

/// `POST /tasks` response.
#[derive(Debug, Clone)]
pub struct RegisterResponse {
    pub task: String,
    /// store version assigned to the new bank (append-only, 1-based)
    pub version: usize,
    pub trained_params: usize,
}

impl RegisterResponse {
    pub fn from_meta(meta: &BankMeta) -> RegisterResponse {
        RegisterResponse {
            task: meta.task.clone(),
            version: meta.version,
            trained_params: meta.trained_params,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("task", Json::str(&self.task)),
            ("version", Json::num(self.version as f64)),
            ("trained_params", Json::num(self.trained_params as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<RegisterResponse> {
        Ok(RegisterResponse {
            task: get_str(j, "task")?,
            version: get_usize(j, "version")?,
            trained_params: get_usize(j, "trained_params")?,
        })
    }
}

/// `POST /train` request: start a background training job for `task`.
///
/// Every field except `task` is optional. A `task` naming a built-in
/// suite task trains that task; any other name defines a custom
/// synthetic classification task (`n_classes`, `pair`, `purity`,
/// `noise`, `data_seed` shape its data — see `serve::registry` for the
/// defaults). `method`/`m`/`lr`/`epochs`/`seed` mirror the CLI `train`
/// flags.
#[derive(Debug, Clone, Default)]
pub struct TrainJobRequest {
    pub task: String,
    /// adapter (default) | lnonly | topk:K | finetune
    pub method: Option<String>,
    /// adapter size (adapter method; default 8)
    pub m: Option<usize>,
    pub lr: Option<f64>,
    pub epochs: Option<usize>,
    /// training seed (init + epoch shuffling)
    pub seed: Option<u64>,
    /// training-set size override
    pub n_train: Option<usize>,
    /// validation-set size override (test split follows it)
    pub n_val: Option<usize>,
    /// custom tasks only: class count (default 2)
    pub n_classes: Option<usize>,
    /// custom tasks only: sentence-pair encoding (default false)
    pub pair: Option<bool>,
    /// custom tasks only: word-from-topic probability (default 0.8)
    pub purity: Option<f64>,
    /// custom tasks only: label-noise rate (default 0)
    pub noise: Option<f64>,
    /// custom tasks only: data-generation seed (default: name hash)
    pub data_seed: Option<u64>,
}

impl TrainJobRequest {
    /// A job request with every knob at its default.
    pub fn new(task: &str) -> TrainJobRequest {
        TrainJobRequest { task: task.to_string(), ..Default::default() }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![("task", Json::str(&self.task))];
        if let Some(v) = &self.method {
            pairs.push(("method", Json::str(v)));
        }
        if let Some(v) = self.m {
            pairs.push(("m", Json::num(v as f64)));
        }
        if let Some(v) = self.lr {
            pairs.push(("lr", Json::num(v)));
        }
        if let Some(v) = self.epochs {
            pairs.push(("epochs", Json::num(v as f64)));
        }
        if let Some(v) = self.seed {
            pairs.push(("seed", Json::num(v as f64)));
        }
        if let Some(v) = self.n_train {
            pairs.push(("n_train", Json::num(v as f64)));
        }
        if let Some(v) = self.n_val {
            pairs.push(("n_val", Json::num(v as f64)));
        }
        if let Some(v) = self.n_classes {
            pairs.push(("n_classes", Json::num(v as f64)));
        }
        if let Some(v) = self.pair {
            pairs.push(("pair", Json::Bool(v)));
        }
        if let Some(v) = self.purity {
            pairs.push(("purity", Json::num(v)));
        }
        if let Some(v) = self.noise {
            pairs.push(("noise", Json::num(v)));
        }
        if let Some(v) = self.data_seed {
            pairs.push(("data_seed", Json::num(v as f64)));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<TrainJobRequest> {
        Ok(TrainJobRequest {
            task: get_str(j, "task")?,
            method: opt_str(j, "method"),
            m: opt_usize(j, "m"),
            lr: opt_f64(j, "lr"),
            epochs: opt_usize(j, "epochs"),
            seed: opt_u64(j, "seed"),
            n_train: opt_usize(j, "n_train"),
            n_val: opt_usize(j, "n_val"),
            n_classes: opt_usize(j, "n_classes"),
            pair: opt_bool(j, "pair"),
            purity: opt_f64(j, "purity"),
            noise: opt_f64(j, "noise"),
            data_seed: opt_u64(j, "data_seed"),
        })
    }
}

/// `POST /train` / `GET /train/<id>` response: one job's live status.
/// `loss`/`best_val` are absent until the first step/eval (JSON has no
/// NaN); `version` appears when the job completes and the task becomes
/// servable.
#[derive(Debug, Clone)]
pub struct TrainJobStatus {
    pub job_id: u64,
    pub task: String,
    /// queued | running | completed | failed
    pub status: String,
    pub epoch: usize,
    pub total_epochs: usize,
    pub step: usize,
    pub total_steps: usize,
    pub loss: Option<f64>,
    pub best_val: Option<f64>,
    pub steps_per_sec: f64,
    pub wall_s: f64,
    /// `(epoch, val score)` per evaluated epoch.
    pub val_history: Vec<(usize, f64)>,
    pub version: Option<usize>,
    pub error: Option<String>,
    pub resumed: bool,
}

impl TrainJobStatus {
    /// Build from a service-side [`JobRecord`].
    pub fn from_record(r: &JobRecord) -> TrainJobStatus {
        TrainJobStatus {
            job_id: r.id,
            task: r.task.clone(),
            status: r.state.name().to_string(),
            epoch: r.epoch,
            total_epochs: r.total_epochs,
            step: r.step,
            total_steps: r.total_steps,
            loss: if r.loss.is_finite() { Some(r.loss) } else { None },
            best_val: if r.best_val.is_finite() { Some(r.best_val) } else { None },
            steps_per_sec: r.steps_per_sec,
            wall_s: r.wall_s,
            val_history: r.val_history.clone(),
            version: r.version,
            error: r.error.clone(),
            resumed: r.resumed,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("job_id", Json::num(self.job_id as f64)),
            ("task", Json::str(&self.task)),
            ("status", Json::str(&self.status)),
            ("epoch", Json::num(self.epoch as f64)),
            ("total_epochs", Json::num(self.total_epochs as f64)),
            ("step", Json::num(self.step as f64)),
            ("total_steps", Json::num(self.total_steps as f64)),
            ("steps_per_sec", Json::num(self.steps_per_sec)),
            ("wall_s", Json::num(self.wall_s)),
            (
                "val_history",
                Json::arr(self.val_history.iter().map(|&(e, v)| {
                    Json::arr([Json::num(e as f64), Json::num(v)])
                })),
            ),
            ("resumed", Json::Bool(self.resumed)),
        ];
        if let Some(l) = self.loss {
            pairs.push(("loss", Json::num(l)));
        }
        if let Some(v) = self.best_val {
            pairs.push(("best_val", Json::num(v)));
        }
        if let Some(v) = self.version {
            pairs.push(("version", Json::num(v as f64)));
        }
        if let Some(e) = &self.error {
            pairs.push(("error", Json::str(e)));
        }
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<TrainJobStatus> {
        let val_history = match j.get("val_history") {
            Some(v) => {
                let arr = v.as_arr().context("val_history must be an array")?;
                let mut out = Vec::with_capacity(arr.len());
                for row in arr {
                    let pair = row.as_arr().context("val_history rows are [epoch, val]")?;
                    if pair.len() != 2 {
                        bail!("val_history rows are [epoch, val]");
                    }
                    out.push((
                        pair[0].as_usize().context("val_history epoch")?,
                        pair[1].as_f64().context("val_history score")?,
                    ));
                }
                out
            }
            None => Vec::new(),
        };
        Ok(TrainJobStatus {
            job_id: opt_u64(j, "job_id").context("missing job_id")?,
            task: get_str(j, "task")?,
            status: get_str(j, "status")?,
            epoch: get_usize(j, "epoch")?,
            total_epochs: get_usize(j, "total_epochs")?,
            step: get_usize(j, "step")?,
            total_steps: get_usize(j, "total_steps")?,
            loss: opt_f64(j, "loss"),
            best_val: opt_f64(j, "best_val"),
            steps_per_sec: get_f64(j, "steps_per_sec")?,
            wall_s: get_f64(j, "wall_s")?,
            val_history,
            version: opt_usize(j, "version"),
            error: opt_str(j, "error"),
            resumed: opt_bool(j, "resumed").unwrap_or(false),
        })
    }
}

/// `GET /metrics` → `"cache"` section: paged adapter-cache residency and
/// cold-load statistics. `budget_bytes` is absent when the cache is
/// unbounded (no `--adapter-cache-mb`).
#[derive(Debug, Clone)]
pub struct CacheMetrics {
    /// banks currently resident in memory
    pub resident: usize,
    pub resident_bytes: u64,
    /// byte budget; `None` → unbounded (everything stays resident)
    pub budget_bytes: Option<u64>,
    /// tasks known to the coordinator directory (resident or evicted)
    pub registered: usize,
    pub resident_tasks: Vec<String>,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub load_errors: u64,
    /// completed cold loads (`misses - load_errors`)
    pub cold_loads: u64,
    pub cold_load_p50_ms: f64,
    pub cold_load_p95_ms: f64,
}

impl CacheMetrics {
    /// Build from a coordinator cache snapshot plus the directory size.
    pub fn from_snapshot(cache: &CacheSnapshot, registered: usize) -> CacheMetrics {
        CacheMetrics {
            resident: cache.resident,
            resident_bytes: cache.resident_bytes,
            budget_bytes: cache.budget_bytes,
            registered,
            resident_tasks: cache.resident_tasks.clone(),
            hits: cache.hits,
            misses: cache.misses,
            evictions: cache.evictions,
            load_errors: cache.load_errors,
            cold_loads: cache.cold_loads,
            cold_load_p50_ms: cache.cold_load_p50_ms,
            cold_load_p95_ms: cache.cold_load_p95_ms,
        }
    }

    /// Fraction of lookups served without a cold load (0 when idle).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("resident", Json::num(self.resident as f64)),
            ("resident_bytes", Json::num(self.resident_bytes as f64)),
        ];
        if let Some(b) = self.budget_bytes {
            pairs.push(("budget_bytes", Json::num(b as f64)));
        }
        pairs.extend([
            ("registered", Json::num(self.registered as f64)),
            (
                "resident_tasks",
                Json::arr(self.resident_tasks.iter().map(|t| Json::str(t))),
            ),
            ("hits", Json::num(self.hits as f64)),
            ("misses", Json::num(self.misses as f64)),
            ("hit_rate", Json::num(self.hit_rate())),
            ("evictions", Json::num(self.evictions as f64)),
            ("load_errors", Json::num(self.load_errors as f64)),
            ("cold_loads", Json::num(self.cold_loads as f64)),
            ("cold_load_p50_ms", Json::num(self.cold_load_p50_ms)),
            ("cold_load_p95_ms", Json::num(self.cold_load_p95_ms)),
        ]);
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<CacheMetrics> {
        let resident_tasks = match j.get("resident_tasks") {
            Some(v) => {
                let arr = v.as_arr().context("resident_tasks must be an array")?;
                arr.iter()
                    .map(|t| {
                        t.as_str()
                            .map(str::to_string)
                            .context("resident_tasks must hold strings")
                    })
                    .collect::<Result<Vec<_>>>()?
            }
            None => Vec::new(),
        };
        Ok(CacheMetrics {
            resident: get_usize(j, "resident")?,
            resident_bytes: opt_u64(j, "resident_bytes")
                .context("missing resident_bytes")?,
            budget_bytes: opt_u64(j, "budget_bytes"),
            registered: get_usize(j, "registered")?,
            resident_tasks,
            hits: opt_u64(j, "hits").context("missing hits")?,
            misses: opt_u64(j, "misses").context("missing misses")?,
            evictions: opt_u64(j, "evictions").context("missing evictions")?,
            load_errors: opt_u64(j, "load_errors").context("missing load_errors")?,
            cold_loads: opt_u64(j, "cold_loads").context("missing cold_loads")?,
            cold_load_p50_ms: get_f64(j, "cold_load_p50_ms")?,
            cold_load_p95_ms: get_f64(j, "cold_load_p95_ms")?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::server::Prediction;
    use crate::util::tensor::Tensor;
    use std::time::Duration;

    #[test]
    fn hex_roundtrip() {
        let data: Vec<u8> = (0..=255).collect();
        let hex = to_hex(&data);
        assert_eq!(hex.len(), 512);
        assert_eq!(from_hex(&hex).unwrap(), data);
        assert_eq!(from_hex("DEADbeef").unwrap(), vec![0xde, 0xad, 0xbe, 0xef]);
        assert!(from_hex("abc").is_err());
        assert!(from_hex("zz").is_err());
    }

    #[test]
    fn predict_request_roundtrip() {
        let req = PredictRequest::pair("rte_s", "zu kari", "moresa");
        let j = Json::parse(&req.to_json().to_string()).unwrap();
        let back = PredictRequest::from_json(&j).unwrap();
        assert_eq!(back.task, "rte_s");
        assert_eq!(back.text.as_deref(), Some("zu kari"));
        assert_eq!(back.text_b.as_deref(), Some("moresa"));
        assert!(back.tokens.is_none());

        let req = PredictRequest::ids("cola_s", vec![1, 5, 9, 0]);
        let back =
            PredictRequest::from_json(&Json::parse(&req.to_json().to_string()).unwrap())
                .unwrap();
        assert_eq!(back.tokens, Some(vec![1, 5, 9, 0]));

        // neither text nor tokens → error
        assert!(
            PredictRequest::from_json(&Json::parse(r#"{"task":"x"}"#).unwrap()).is_err()
        );
    }

    #[test]
    fn predict_response_covers_all_kinds() {
        for (pred, kind) in [
            (Prediction::Class(2), "cls"),
            (Prediction::Score(0.75), "reg"),
            (Prediction::Span(3, 7), "span"),
        ] {
            let resp = Response {
                task: "t".into(),
                prediction: pred,
                latency: Duration::from_millis(4),
                batch_size: 3,
            };
            let wire = PredictResponse::from_response(&resp);
            assert_eq!(wire.kind, kind);
            let back = PredictResponse::from_json(
                &Json::parse(&wire.to_json().to_string()).unwrap(),
            )
            .unwrap();
            assert_eq!(back.kind, kind);
            assert_eq!(back.pred_class, pred.class());
            assert_eq!(back.span, pred.span());
            match (back.score, pred.score()) {
                (Some(a), Some(b)) => assert!((a - b).abs() < 1e-6),
                (None, None) => {}
                other => panic!("score mismatch: {other:?}"),
            }
            assert_eq!(back.batch_size, 3);
        }
    }

    #[test]
    fn register_request_bank_is_byte_exact() {
        let mut trained = NamedTensors::default();
        trained.insert("adapters/x", Tensor::f32(vec![3], vec![1.5, -2.0, 0.25]));
        trained.insert("head/w", Tensor::i32(vec![2], vec![7, -7]));
        let model = TaskModel {
            variant: "adapter".into(),
            m: Some(8),
            k: None,
            kind: "cls".into(),
            trained,
        };
        let req = RegisterRequest::from_model("new_task", 4, 0.91, &model);
        let j = Json::parse(&req.to_json().to_string()).unwrap();
        let back = RegisterRequest::from_json(&j).unwrap();
        let rebuilt = back.to_model().unwrap();
        assert_eq!(rebuilt.trained, model.trained);
        assert_eq!(rebuilt.fwd_name(), "cls_fwd_adapter_m8");
        assert_eq!(back.n_classes, 4);
        assert_eq!(back.val_score, 0.91);
    }

    #[test]
    fn train_job_request_roundtrip() {
        let mut req = TrainJobRequest::new("hot3");
        req.m = Some(4);
        req.epochs = Some(3);
        req.n_train = Some(240);
        req.pair = Some(true);
        req.purity = Some(0.85);
        req.data_seed = Some(77);
        let back =
            TrainJobRequest::from_json(&Json::parse(&req.to_json().to_string()).unwrap())
                .unwrap();
        assert_eq!(back.task, "hot3");
        assert_eq!(back.m, Some(4));
        assert_eq!(back.epochs, Some(3));
        assert_eq!(back.n_train, Some(240));
        assert_eq!(back.pair, Some(true));
        assert_eq!(back.purity, Some(0.85));
        assert_eq!(back.data_seed, Some(77));
        assert!(back.method.is_none() && back.lr.is_none() && back.noise.is_none());
        // task is required
        assert!(TrainJobRequest::from_json(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn train_job_status_roundtrip_and_nan_safety() {
        use crate::train::{JobRecord, JobSpec, TrainConfig};
        use crate::data::tasks::{Metric, TaskKind, TaskSpec};
        let spec = JobSpec {
            task: TaskSpec {
                name: "t".into(),
                kind: TaskKind::Cls { n_classes: 2, pair: false },
                metric: Metric::Accuracy,
                n_train: 240,
                n_val: 48,
                n_test: 48,
                purity: 0.8,
                noise: 0.0,
                seed: 1,
            },
            train: TrainConfig::new("cls_train_adapter_m4", 1e-3, 3, 0),
        };
        let fresh = JobRecord::new(7, &spec, 90);
        // NaN loss/best_val before any step must serialize as *absent*,
        // not produce invalid JSON
        let wire = TrainJobStatus::from_record(&fresh);
        assert!(wire.loss.is_none() && wire.best_val.is_none());
        let text = wire.to_json().to_string();
        assert!(!text.contains("NaN"), "{text}");
        let back = TrainJobStatus::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.job_id, 7);
        assert_eq!(back.status, "queued");
        assert_eq!(back.total_steps, 90);
        assert!(back.loss.is_none());
        assert!(!back.resumed);

        let mut done = fresh;
        done.loss = 0.4;
        done.best_val = 0.9;
        done.val_history = vec![(0, 0.7), (1, 0.9)];
        done.version = Some(2);
        done.resumed = true;
        let back = TrainJobStatus::from_json(
            &Json::parse(&TrainJobStatus::from_record(&done).to_json().to_string())
                .unwrap(),
        )
        .unwrap();
        assert_eq!(back.loss, Some(0.4));
        assert_eq!(back.best_val, Some(0.9));
        assert_eq!(back.val_history, vec![(0, 0.7), (1, 0.9)]);
        assert_eq!(back.version, Some(2));
        assert!(back.resumed);
    }

    #[test]
    fn cache_metrics_roundtrip() {
        let snap = CacheSnapshot {
            resident: 3,
            resident_bytes: 4096,
            budget_bytes: Some(8192),
            resident_tasks: vec!["a".into(), "b".into(), "c".into()],
            hits: 30,
            misses: 10,
            evictions: 7,
            load_errors: 2,
            cold_loads: 8,
            cold_load_p50_ms: 1.5,
            cold_load_p95_ms: 4.0,
        };
        let wire = CacheMetrics::from_snapshot(&snap, 64);
        assert!((wire.hit_rate() - 0.75).abs() < 1e-12);
        let back =
            CacheMetrics::from_json(&Json::parse(&wire.to_json().to_string()).unwrap())
                .unwrap();
        assert_eq!(back.resident, 3);
        assert_eq!(back.resident_bytes, 4096);
        assert_eq!(back.budget_bytes, Some(8192));
        assert_eq!(back.registered, 64);
        assert_eq!(back.resident_tasks, vec!["a", "b", "c"]);
        assert_eq!(back.hits, 30);
        assert_eq!(back.misses, 10);
        assert_eq!(back.evictions, 7);
        assert_eq!(back.load_errors, 2);
        assert_eq!(back.cold_loads, 8);

        // unbounded cache → budget_bytes absent from the wire
        let mut unbounded = wire.clone();
        unbounded.budget_bytes = None;
        let text = unbounded.to_json().to_string();
        assert!(!text.contains("budget_bytes"), "{text}");
        let back = CacheMetrics::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.budget_bytes, None);
    }

    #[test]
    fn health_roundtrip() {
        let h = Health {
            status: "ok".into(),
            backend: "native".into(),
            preset: "test".into(),
            vocab: 256,
            seq: 16,
            tasks: 2,
            draining: false,
            resident: 1,
            store_ok: true,
            train_queue: 3,
        };
        let back =
            Health::from_json(&Json::parse(&h.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.vocab, 256);
        assert_eq!(back.seq, 16);
        assert_eq!(back.tasks, 2);
        assert!(!back.draining);
        assert_eq!(back.resident, 1);
        assert!(back.store_ok);
        assert_eq!(back.train_queue, 3);
        assert!(back.ready());
    }

    #[test]
    fn health_readiness_fields_are_wire_optional() {
        // an older gateway's document (no readiness trio) still parses
        // and degrades to "ready"
        let old = Json::parse(
            r#"{"status":"ok","backend":"native","preset":"test",
                "vocab":256,"seq":16,"tasks":4}"#,
        )
        .unwrap();
        let h = Health::from_json(&old).unwrap();
        assert_eq!(h.resident, 4, "defaults to fully resident");
        assert!(h.store_ok);
        assert_eq!(h.train_queue, 0);
        assert!(h.ready());
        // draining or a dead store makes a live replica not-ready
        let mut d = h.clone();
        d.draining = true;
        assert!(!d.ready());
        let mut s = h;
        s.store_ok = false;
        assert!(!s.ready());
    }
}
