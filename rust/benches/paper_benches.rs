//! End-to-end paper benches: regenerates every table and figure in quick
//! mode on the `test` preset by default (fast, CI-safe). The real runs for
//! EXPERIMENTS.md use `adapterbert bench all [--full] --preset default` —
//! same code path, bigger budget.
//!
//! Select with: `cargo bench --bench paper_benches -- table1 fig6 ...`
//! Flags: `--preset default`, `--full`.

use adapterbert::bench::{figures, tables, Ctx};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let full = args.iter().any(|a| a == "--full");
    let preset = args
        .iter()
        .position(|a| a == "--preset")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "test".into());
    let wanted: Vec<&str> = args
        .iter()
        .filter(|a| !a.starts_with("--") && *a != &preset)
        .map(|s| s.as_str())
        .collect();
    // `test` preset has adapter sizes {4,8} and topk {1,2} only, so the
    // figure sweeps automatically narrow to what the manifest offers.
    // Default = the CI subset (bounded wall-clock on one core); name more
    // benches explicitly, or run the full set against the default preset
    // via `adapterbert bench all` (that is what EXPERIMENTS.md records).
    let all = ["params", "table1", "fig6"];
    let to_run: Vec<&str> = if wanted.is_empty() {
        all.to_vec()
    } else {
        wanted
    };

    let ctx = Ctx::open(&preset, !full)?;
    for name in to_run {
        println!("\n########## paper bench: {name} ##########");
        let t = std::time::Instant::now();
        match name {
            "params" => tables::audit_params(&ctx)?,
            "table1" => tables::table1(&ctx)?,
            "table2" => tables::table2(&ctx)?,
            "fig3" => figures::fig1_fig3(&ctx)?,
            "fig3x" => figures::fig3_extra(&ctx)?,
            "fig4" => figures::fig4(&ctx)?,
            "fig5" => figures::fig5(&ctx)?,
            "fig6" => {
                figures::fig6_heatmap(&ctx)?;
                figures::fig6_init(&ctx)?;
            }
            "fig7" => figures::fig7(&ctx)?,
            "sizes" => figures::size_robustness(&ctx)?,
            other => anyhow::bail!("unknown bench {other}"),
        }
        println!("[{name}] {:.1}s", t.elapsed().as_secs_f64());
    }
    Ok(())
}
