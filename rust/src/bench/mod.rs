//! Experiment harness: one entry point per paper table/figure.
//!
//! Each function regenerates the corresponding artifact (stdout table +
//! CSV under `results/`). `quick` mode trims grids/seeds to a single-core
//! CPU budget (this reproduction's testbed is one core; the paper used
//! 4×TPUv2) — the *shape* of every comparison is preserved: who wins, by
//! roughly what factor, where the crossovers fall. EXPERIMENTS.md records
//! quick-mode results against the paper's numbers.

pub mod chaos;
pub mod cluster;
pub mod figures;
pub mod kernels;
pub mod loadgen;
pub mod profile;
pub mod tables;
pub mod trainserve;

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::data::grammar::World;
use crate::data::tasks::{generate, TaskData, TaskKind, TaskSpec};
use crate::eval::{evaluate, TaskModel};
use crate::model::params::NamedTensors;
use crate::runtime::{BackendKind, Runtime};
use crate::train::{self, PretrainConfig, TrainConfig};

/// Shared experiment context: runtime + world + pre-trained base.
pub struct Ctx {
    pub rt: Arc<Runtime>,
    pub world: World,
    pub base: NamedTensors,
    pub quick: bool,
}

impl Ctx {
    /// Open artifacts, load-or-pretrain the base checkpoint. The backend
    /// comes from `ADAPTERBERT_BACKEND` / the CLI's `--backend` flag.
    pub fn open(preset: &str, quick: bool) -> Result<Ctx> {
        Self::open_with_backend(preset, quick, BackendKind::from_env()?)
    }

    /// Same, with an explicit execution backend.
    pub fn open_with_backend(preset: &str, quick: bool, kind: BackendKind) -> Result<Ctx> {
        let rt = Arc::new(Runtime::open_with(Path::new("artifacts"), preset, kind)?);
        let world = World::new(rt.manifest.dims.vocab, 0);
        let steps = if preset == "test" { 3000 } else { 800 };
        let base = train::load_or_pretrain(
            &rt,
            &world,
            &PretrainConfig { steps, ..Default::default() },
            Path::new(&format!("runs/base_{preset}.bank")),
        )?;
        Ok(Ctx { rt, world, base, quick })
    }

    pub fn gen(&self, spec: &TaskSpec) -> TaskData {
        let mut spec = spec.clone();
        if self.quick {
            // single-core budget: cap train sizes, shrink eval splits
            spec.n_train = spec.n_train.min(1600);
            spec.n_val = spec.n_val.min(192);
            spec.n_test = spec.n_test.min(192);
        }
        generate(&self.world, &spec, self.rt.manifest.dims.seq)
    }

    pub fn n_classes(&self, spec: &TaskSpec) -> usize {
        match &spec.kind {
            TaskKind::Cls { n_classes, .. } => *n_classes,
            _ => 0,
        }
    }

    /// Default epochs for a task under the budget (paper sweeps {3,20};
    /// small tasks get more epochs, as in appendix Table 4).
    pub fn epochs_for(&self, data: &TaskData) -> usize {
        let n = data.train.n;
        let e = if n <= 400 {
            12
        } else if n <= 1200 {
            6
        } else {
            4
        };
        if self.quick {
            e
        } else {
            e * 2
        }
    }

    /// Train once and return (model, val, test) with the task's metric.
    pub fn train_once(
        &self,
        data: &TaskData,
        exe: &str,
        lr: f64,
        epochs: usize,
        seed: u64,
    ) -> Result<(TaskModel, f64, f64)> {
        let cfg = TrainConfig::new(exe, lr, epochs, seed);
        let res = train::train_task(&self.rt, &cfg, data, &self.base)
            .with_context(|| format!("training {} on {}", exe, data.spec.name))?;
        let test = evaluate(
            &self.rt,
            &res.model,
            &self.base,
            &data.test,
            self.n_classes(&data.spec),
            data.spec.metric,
        )?;
        Ok((res.model, res.val_score, test))
    }

    /// Best-of over (exe, lr) pairs by validation score.
    pub fn train_best(
        &self,
        data: &TaskData,
        candidates: &[(String, f64)],
        epochs: usize,
        seeds: &[u64],
    ) -> Result<BestRun> {
        let mut best: Option<BestRun> = None;
        for (exe, lr) in candidates {
            for &seed in seeds {
                let (model, val, test) =
                    self.train_once(data, exe, *lr, epochs, seed)?;
                let run = BestRun {
                    exe: exe.clone(),
                    lr: *lr,
                    seed,
                    val,
                    test,
                    model,
                };
                if best.as_ref().map(|b| val > b.val).unwrap_or(true) {
                    best = Some(run);
                }
            }
        }
        best.context("no candidates ran")
    }

    /// Adapter-method default learning rate (higher than FT, as the paper
    /// finds — Fig. 7 sweeps this explicitly).
    pub fn adapter_lr(&self) -> f64 {
        1e-3
    }

    pub fn ft_lr(&self) -> f64 {
        1e-4
    }
}

impl Ctx {
    /// Adapter sizes actually present in the manifest for `kind`, sorted.
    pub fn available_sizes(&self, kind: &str) -> Vec<usize> {
        let mut ms: Vec<usize> = self
            .rt
            .manifest
            .find(kind, "adapter")
            .iter()
            .filter_map(|e| e.m)
            .collect();
        ms.sort_unstable();
        ms
    }

    /// Top-k depths present in the manifest for `kind`, sorted.
    pub fn available_ks(&self, kind: &str) -> Vec<usize> {
        let mut ks: Vec<usize> = self
            .rt
            .manifest
            .find(kind, "topk")
            .iter()
            .filter_map(|e| e.k)
            .collect();
        ks.sort_unstable();
        ks
    }

    /// Closest available adapter size to `preferred`.
    pub fn pick_size(&self, kind: &str, preferred: usize) -> usize {
        let ms = self.available_sizes(kind);
        *ms.iter()
            .min_by_key(|m| m.abs_diff(preferred))
            .expect("no adapter artifacts")
    }
}

pub struct BestRun {
    pub exe: String,
    pub lr: f64,
    pub seed: u64,
    pub val: f64,
    pub test: f64,
    pub model: TaskModel,
}

/// Trained-parameter count (no head) for an executable name, from the
/// manifest (exact, not the closed form).
pub fn trained_params_of_exe(rt: &Runtime, exe: &str) -> usize {
    let spec = rt.manifest.exe(exe).expect("exe in manifest");
    let r = spec.input_group_range("trained").expect("train exe");
    spec.inputs[r]
        .iter()
        .filter(|l| !l.name.starts_with("trained/head"))
        .map(|l| l.elements())
        .sum()
}
