"""L2 invariants: the model implements the paper's mechanism exactly.

The tests here pin the *semantics* the Rust coordinator relies on:
frozen-base partitions, adapter gating, near-identity init, and the
train-step contract (loss decreases, only the trained set moves).
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import steps

CFG = M.PRESETS["test"]
KEY = jax.random.PRNGKey(0)


def make_batch(cfg, kind, b, seed=0):
    r = np.random.RandomState(seed)
    batch = {
        "tokens": jnp.asarray(r.randint(3, cfg.vocab, (b, cfg.seq)), jnp.int32),
        "segments": jnp.asarray(r.randint(0, 2, (b, cfg.seq)), jnp.int32),
        "attn_mask": jnp.ones((b, cfg.seq), jnp.float32),
    }
    if kind == "cls":
        batch["labels"] = jnp.asarray(r.randint(0, 3, (b,)), jnp.int32)
        valid = np.zeros(cfg.max_classes, np.float32)
        valid[:3] = 1.0
        batch["class_valid"] = jnp.asarray(valid)
    elif kind == "reg":
        batch["targets"] = jnp.asarray(r.randn(b), jnp.float32)
    else:
        starts = r.randint(1, cfg.seq - 2, (b,))
        spans = np.stack([starts, starts + 1], axis=1)
        batch["spans"] = jnp.asarray(spans, jnp.int32)
    return batch


def tree_allclose(a, b, **kw):
    flat_a = jax.tree_util.tree_leaves(a)
    flat_b = jax.tree_util.tree_leaves(b)
    assert len(flat_a) == len(flat_b)
    for x, y in zip(flat_a, flat_b):
        np.testing.assert_allclose(x, y, **kw)


def tree_equal(a, b):
    tree_allclose(a, b, rtol=0, atol=0)


# ---------------------------------------------------------------------------
# partitions round-trip
# ---------------------------------------------------------------------------


def test_topk_split_merge_roundtrip():
    base = M.init_base_params(CFG, KEY)
    for k in range(1, CFG.n_layers + 1):
        tr, fr = M.split_base_for_topk(CFG, base, k)
        merged = M.merge_topk(CFG, tr, fr)
        tree_equal(base, merged)


def test_ln_split_merge_roundtrip():
    base = M.init_base_params(CFG, KEY)
    tr, fr = M.split_base_for_ln(CFG, base)
    tree_equal(base, M.merge_ln(CFG, tr, fr))


def test_topk_full_unlocks_embeddings():
    base = M.init_base_params(CFG, KEY)
    tr_full, fr_full = M.split_base_for_topk(CFG, base, CFG.n_layers)
    assert "tok_embed" in tr_full and not fr_full["layers"]
    tr1, fr1 = M.split_base_for_topk(CFG, base, 1)
    assert "tok_embed" in fr1 and len(tr1["layers"]) == 1


def test_ln_partition_is_exactly_layernorms():
    base = M.init_base_params(CFG, KEY)
    tr, _ = M.split_base_for_ln(CFG, base)
    n = sum(x.size for x in jax.tree_util.tree_leaves(tr))
    # 2 LN per layer * 2 tensors * d + embedding LN (2*d)
    assert n == (2 * CFG.n_layers + 1) * 2 * CFG.d


# ---------------------------------------------------------------------------
# adapter mechanism
# ---------------------------------------------------------------------------


def test_gates_zero_equals_no_adapters():
    """gate=0 must make the adapted encoder *bitwise* the plain encoder's
    semantics (Fig. 6 'ablate all' = majority-class baseline relies on it)."""
    base = M.init_base_params(CFG, KEY)
    adapters = M.init_adapter_params(CFG, jax.random.PRNGKey(1), std=0.5)
    b = make_batch(CFG, "cls", 4)
    h_plain = M.encode(CFG, base, b["tokens"], b["segments"], b["attn_mask"])
    gates = jnp.zeros((CFG.n_layers, 2), jnp.float32)
    h_gated = M.encode(CFG, base, b["tokens"], b["segments"], b["attn_mask"],
                       adapters=adapters, adapter_gates=gates)
    np.testing.assert_allclose(h_gated, h_plain, rtol=1e-5, atol=1e-6)


def test_adapter_init_is_near_identity_through_encoder():
    """Paper §2: at init the adapted network ≈ the original network."""
    base = M.init_base_params(CFG, KEY)
    adapters = M.init_adapter_params(CFG, jax.random.PRNGKey(1), std=1e-2)
    b = make_batch(CFG, "cls", 4)
    ones = jnp.ones((CFG.n_layers, 2), jnp.float32)
    h0 = M.encode(CFG, base, b["tokens"], b["segments"], b["attn_mask"])
    h1 = M.encode(CFG, base, b["tokens"], b["segments"], b["attn_mask"],
                  adapters=adapters, adapter_gates=ones)
    assert float(jnp.max(jnp.abs(h0 - h1))) < 0.15


def test_single_gate_ablation_changes_output():
    base = M.init_base_params(CFG, KEY)
    adapters = M.init_adapter_params(CFG, jax.random.PRNGKey(1), std=0.3)
    b = make_batch(CFG, "cls", 2)
    ones = np.ones((CFG.n_layers, 2), np.float32)
    h_full = M.encode(CFG, base, b["tokens"], b["segments"], b["attn_mask"],
                      adapters=adapters, adapter_gates=jnp.asarray(ones))
    ones[0, 0] = 0.0
    h_ablate = M.encode(CFG, base, b["tokens"], b["segments"], b["attn_mask"],
                        adapters=adapters, adapter_gates=jnp.asarray(ones))
    assert float(jnp.max(jnp.abs(h_full - h_ablate))) > 1e-6


# ---------------------------------------------------------------------------
# train-step contract
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("kind", ["cls", "reg", "span"])
def test_adapter_train_step_moves_only_trained(kind):
    fn = jax.jit(steps.make_train_adapter_step(CFG, kind))
    frozen, trained, opt_m, opt_v, step, batch, lr = steps.example_args_train(
        CFG, kind, "adapter", 4)
    # give real values
    base = M.init_base_params(CFG, KEY)
    base_ln, frozen = M.split_base_for_adapter(CFG, base)
    trained = {
        "adapters": M.init_adapter_params(CFG, jax.random.PRNGKey(2)),
        "base_ln": base_ln,
        "head": M.init_head_params(CFG, jax.random.PRNGKey(3), kind),
    }
    opt_m, opt_v = M.adam_init(trained)
    batch = make_batch(CFG, kind, 4)
    new, m2, v2, loss, metric = fn(frozen, trained, opt_m, opt_v,
                                   jnp.int32(1), batch, jnp.float32(1e-3))
    assert np.isfinite(float(loss))
    # trained set moved
    moved = [
        float(np.abs(np.asarray(a) - np.asarray(b)).max())
        for a, b in zip(jax.tree_util.tree_leaves(new),
                        jax.tree_util.tree_leaves(trained))
    ]
    assert max(moved) > 0
    # loss decreases over a few steps on a fixed batch
    cur, cm, cv = new, m2, v2
    losses = [float(loss)]
    for t in range(2, 12):
        cur, cm, cv, l, _ = fn(frozen, cur, cm, cv, jnp.int32(t), batch,
                               jnp.float32(1e-3))
        losses.append(float(l))
    assert losses[-1] < losses[0]


def test_topk_train_step_loss_decreases():
    fn = jax.jit(steps.make_train_topk_step(CFG, "cls", 1))
    base = M.init_base_params(CFG, KEY)
    top, frozen = M.split_base_for_topk(CFG, base, 1)
    trained = {"base_top": top,
               "head": M.init_head_params(CFG, jax.random.PRNGKey(3), "cls")}
    opt_m, opt_v = M.adam_init(trained)
    batch = make_batch(CFG, "cls", 4)
    losses = []
    cur, cm, cv = trained, opt_m, opt_v
    for t in range(1, 12):
        cur, cm, cv, l, _ = fn(frozen, cur, cm, cv, jnp.int32(t), batch,
                               jnp.float32(1e-3))
        losses.append(float(l))
    assert losses[-1] < losses[0]


def test_pretrain_step_runs_and_decreases():
    fn = jax.jit(steps.make_pretrain_step(CFG))
    base = M.init_base_params(CFG, KEY)
    m, v = M.adam_init(base)
    r = np.random.RandomState(0)
    b = 4
    args = dict(
        tokens=jnp.asarray(r.randint(3, CFG.vocab, (b, CFG.seq)), jnp.int32),
        segments=jnp.zeros((b, CFG.seq), jnp.int32),
        attn_mask=jnp.ones((b, CFG.seq), jnp.float32),
        positions=jnp.asarray(r.randint(0, CFG.seq, (b, CFG.mlm_positions)),
                              jnp.int32),
        targets=jnp.asarray(r.randint(3, CFG.vocab, (b, CFG.mlm_positions)),
                            jnp.int32),
        weights=jnp.ones((b, CFG.mlm_positions), jnp.float32),
    )
    losses = []
    for t in range(1, 10):
        base, m, v, loss = fn(base, m, v, jnp.int32(t), args["tokens"],
                              args["segments"], args["attn_mask"],
                              args["positions"], args["targets"],
                              args["weights"], jnp.float32(1e-3))
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_adam_bias_correction_first_step():
    """After one step with grad g, update ≈ -lr * sign(g) (Adam property)."""
    p = {"w": jnp.asarray([1.0, -2.0, 3.0], jnp.float32)}
    g = {"w": jnp.asarray([0.5, -0.1, 2.0], jnp.float32)}
    m, v = M.adam_init(p)
    new, _, _ = M.adam_update(p, g, m, v, jnp.int32(1), jnp.float32(0.01))
    delta = np.asarray(new["w"] - p["w"])
    np.testing.assert_allclose(delta, -0.01 * np.sign(np.asarray(g["w"])),
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# head padding
# ---------------------------------------------------------------------------


def test_cls_accuracy_respects_class_mask():
    logits = jnp.asarray([[0.0, 1.0, 50.0]], jnp.float32)
    labels = jnp.asarray([1], jnp.int32)
    valid = jnp.asarray([1.0, 1.0, 0.0], jnp.float32)
    cfg = dataclasses.replace(CFG, max_classes=3)
    acc = M.cls_accuracy(cfg, logits, labels, valid)
    assert float(acc) == 1.0  # class 2 is padding, must be ignored
