//! PJRT backend: load HLO text → XLA-compile once → run many.
//!
//! Buffer management: the vendored `xla` crate's literal-based `execute()`
//! leaks every input device buffer (it `release()`s the
//! `BufferFromHostLiteral` results and never frees them), so all execution
//! here goes through `execute_b` with buffers owned on the Rust side. That
//! also enables the key serving optimization: long-lived banks (the frozen
//! base, a task's adapters) are uploaded **once** as a [`PjrtBank`] and
//! reused across steps/batches; only per-step data (batches, scalars,
//! updated trained params) is re-uploaded.
//!
//! Thread-safety: the `xla` wrappers are raw-pointer structs with no
//! `Send`/`Sync`, but the PJRT C API guarantees thread-safe
//! `Compile`/`Execute`/transfers (the CPU client runs its own thread
//! pool). The `SendSync` wrapper asserts that contract so the coordinator
//! can share executables and banks across worker threads.
//!
//! In the default offline build, `vendor/xla` is a compile stub whose
//! `PjRtClient::cpu()` always fails; [`PjrtBackend::new`] then returns an
//! error and the `auto` backend selection falls back to the native one.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use super::backend::{ArgTensor, Backend, BackendExec, Bank, BankStorage};
use super::manifest::{ExeSpec, Manifest};
use crate::util::tensor::{Data, DType, Tensor};

/// Wrapper asserting PJRT thread-safety (see module docs).
struct SendSync<T>(T);
// SAFETY: PJRT's C API is documented thread-safe for compilation,
// execution and host↔device transfers; the CPU plugin serializes
// internally where required. The wrapped values are only used through
// &self methods.
unsafe impl<T> Send for SendSync<T> {}
// SAFETY: same contract as Send above.
unsafe impl<T> Sync for SendSync<T> {}

/// The XLA/PJRT execution backend.
pub struct PjrtBackend {
    client: Arc<SendSync<xla::PjRtClient>>,
}

impl PjrtBackend {
    /// Open the PJRT CPU plugin; fails when no plugin is linked.
    pub fn new() -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtBackend { client: Arc::new(SendSync(client)) })
    }
}

/// Host→device transfer of one tensor (shared by backend and executables).
fn upload_tensor(client: &SendSync<xla::PjRtClient>, t: &Tensor) -> Result<xla::PjRtBuffer> {
    match &t.data {
        Data::F32(v) => client.0.buffer_from_host_buffer::<f32>(v, &t.shape, None),
        Data::I32(v) => client.0.buffer_from_host_buffer::<i32>(v, &t.shape, None),
    }
    .context("host→device transfer")
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn compile(
        &self,
        manifest: &Manifest,
        spec: &ExeSpec,
    ) -> Result<Box<dyn BackendExec>> {
        let path = manifest.hlo_path(&spec.name)?;
        let proto =
            xla::HloModuleProto::from_text_file(path.to_str().context("non-utf8 path")?)
                .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .0
            .compile(&comp)
            .with_context(|| format!("XLA compile of {}", spec.name))?;
        Ok(Box::new(PjrtExec { exe: SendSync(exe), client: self.client.clone() }))
    }

    fn upload_bank(&self, bank: &Bank) -> Result<Box<dyn BankStorage>> {
        let mut bufs = Vec::with_capacity(bank.len());
        let mut shapes = Vec::with_capacity(bank.len());
        for t in bank {
            bufs.push(SendSync(upload_tensor(&self.client, t)?));
            shapes.push((t.shape.clone(), t.dtype()));
        }
        Ok(Box::new(PjrtBank { bufs, shapes }))
    }
}

/// A bank resident on the PJRT device, uploaded once and reused.
pub struct PjrtBank {
    bufs: Vec<SendSync<xla::PjRtBuffer>>,
    shapes: Vec<(Vec<usize>, DType)>,
}

impl BankStorage for PjrtBank {
    fn shapes(&self) -> &[(Vec<usize>, DType)] {
        &self.shapes
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

struct PjrtExec {
    exe: SendSync<xla::PjRtLoadedExecutable>,
    client: Arc<SendSync<xla::PjRtClient>>,
}

impl BackendExec for PjrtExec {
    fn execute(&self, spec: &ExeSpec, args: &[ArgTensor<'_>]) -> Result<Vec<Tensor>> {
        // per-call host tensors are uploaded here and freed after execution;
        // resident banks are referenced in place
        let mut uploads: Vec<xla::PjRtBuffer> = Vec::new();
        for arg in args {
            if let ArgTensor::Host(t) = arg {
                uploads.push(upload_tensor(&self.client, t)?);
            }
        }
        let mut up = 0usize;
        let mut arg_bufs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(args.len());
        for arg in args {
            match arg {
                ArgTensor::Host(_) => {
                    arg_bufs.push(&uploads[up]);
                    up += 1;
                }
                ArgTensor::Stored { bank, index } => {
                    let pb = bank.as_any().downcast_ref::<PjrtBank>().with_context(
                        || {
                            format!(
                                "{}: device bank was not uploaded via the PJRT backend",
                                spec.name
                            )
                        },
                    )?;
                    arg_bufs.push(&pb.bufs[*index].0);
                }
            }
        }
        let outs = self
            .exe
            .0
            .execute_b::<&xla::PjRtBuffer>(&arg_bufs)
            .with_context(|| format!("executing {}", spec.name))?;
        drop(uploads);
        let mut tuple = outs[0][0]
            .to_literal_sync()
            .context("fetching result tuple")?;
        let parts = tuple.decompose_tuple().context("decomposing result")?;
        if parts.len() != spec.outputs.len() {
            bail!(
                "{}: XLA returned {} leaves, manifest says {}",
                spec.name,
                parts.len(),
                spec.outputs.len()
            );
        }
        parts
            .iter()
            .zip(&spec.outputs)
            .map(|(lit, leaf)| {
                Tensor::from_literal(lit)
                    .with_context(|| format!("{}: output {}", spec.name, leaf.name))
            })
            .collect()
    }
}
