//! Kernel benchmark suite: the `bench kernels` subcommand and the
//! `BENCH_kernels.json` perf-trajectory entry.
//!
//! Three sections, all on the native backend:
//!
//! * **GEMM sweep** — GFLOP/s for every matmul shape the preset's
//!   executables actually hit (QKV/output projections, both FFN halves,
//!   the classifier head, the tied MLM vocab projection), comparing the
//!   single-threaded naive i-k-j reference kernel against the blocked
//!   panel-packed kernel across a thread-count sweep (explicit pools, so
//!   the sweep is independent of `ADAPTERBERT_THREADS`).
//! * **Wall times** — end-to-end forward, fused mixed-batch forward and
//!   full train-step latency on synthesized banks.
//! * **Summary** — the largest shape's blocked-vs-naive speedup per
//!   thread count, the number the CI smoke job asserts on.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::model::init;
use crate::runtime::fused::LayerLn;
use crate::runtime::native::kernels as k;
use crate::runtime::native::pool::Pool;
use crate::runtime::native::NativeBackend;
use crate::runtime::synth;
use crate::runtime::{Backend, BackendKind, Bank, FusedSegment, FusedTaskBank, Runtime};
use crate::util::json::Json;
use crate::util::tensor::{DType, Tensor};

/// What to measure.
#[derive(Debug, Clone)]
pub struct KernelBenchConfig {
    /// Built-in preset whose shapes are swept (`default` | `test`).
    pub preset: String,
    /// Thread counts for the blocked-GEMM sweep (explicit pools).
    pub threads: Vec<usize>,
    /// Trimmed timing budget (used by the schema test / CI smoke).
    pub quick: bool,
}

impl Default for KernelBenchConfig {
    fn default() -> Self {
        let avail = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let mut threads = vec![1, 2, 4];
        if !threads.contains(&avail) {
            threads.push(avail);
        }
        threads.sort_unstable();
        threads.dedup();
        KernelBenchConfig { preset: "default".to_string(), threads, quick: false }
    }
}

/// One GEMM shape's measurements.
#[derive(Debug, Clone)]
pub struct GemmBench {
    /// Which executable site this shape comes from.
    pub name: String,
    pub n: usize,
    pub k: usize,
    pub m: usize,
    /// FLOPs per call (`2·n·k·m`).
    pub flops: f64,
    /// Naive single-threaded reference throughput.
    pub naive_st_gflops: f64,
    /// Blocked kernel throughput per thread count, ascending.
    pub blocked_gflops: Vec<(usize, f64)>,
    /// True for the largest shape (by FLOPs) — the CI assertion target.
    pub largest: bool,
}

/// The whole `bench kernels` run.
#[derive(Debug, Clone)]
pub struct KernelBenchReport {
    pub preset: String,
    pub threads_available: usize,
    pub gemm: Vec<GemmBench>,
    /// Per-task serving forward (`cls_fwd_adapter_m8`), ms per call.
    pub wall_forward_ms: f64,
    /// Fused two-segment mixed-batch forward, ms per call.
    pub wall_fused_ms: f64,
    /// Full train step (`cls_train_adapter_m8`), ms per call.
    pub wall_train_ms: f64,
}

impl KernelBenchReport {
    /// The largest swept shape.
    pub fn largest(&self) -> &GemmBench {
        self.gemm.iter().find(|g| g.largest).expect("sweep is non-empty")
    }

    /// Blocked-vs-naive-ST speedup on the largest shape at `threads`.
    pub fn speedup_at(&self, threads: usize) -> Option<f64> {
        let l = self.largest();
        l.blocked_gflops
            .iter()
            .find(|(t, _)| *t == threads)
            .map(|(_, g)| g / l.naive_st_gflops)
    }

    /// The `BENCH_kernels.json` document (schema v1).
    pub fn to_json(&self) -> Json {
        let gemm = self
            .gemm
            .iter()
            .map(|g| {
                let mut by_threads = std::collections::BTreeMap::new();
                for (t, gf) in &g.blocked_gflops {
                    by_threads.insert(t.to_string(), Json::Num(*gf));
                }
                let mut o = std::collections::BTreeMap::new();
                o.insert("name".to_string(), Json::Str(g.name.clone()));
                o.insert("n".to_string(), Json::Num(g.n as f64));
                o.insert("k".to_string(), Json::Num(g.k as f64));
                o.insert("m".to_string(), Json::Num(g.m as f64));
                o.insert("flops".to_string(), Json::Num(g.flops));
                o.insert("naive_st_gflops".to_string(), Json::Num(g.naive_st_gflops));
                o.insert("blocked_gflops".to_string(), Json::Obj(by_threads));
                o.insert("largest".to_string(), Json::Bool(g.largest));
                Json::Obj(o)
            })
            .collect::<Vec<_>>();
        let l = self.largest();
        let mut speedups = std::collections::BTreeMap::new();
        for (t, _) in &l.blocked_gflops {
            if let Some(s) = self.speedup_at(*t) {
                speedups.insert(t.to_string(), Json::Num(s));
            }
        }
        let mut largest = std::collections::BTreeMap::new();
        largest.insert("name".to_string(), Json::Str(l.name.clone()));
        largest.insert("flops".to_string(), Json::Num(l.flops));
        largest.insert("naive_st_gflops".to_string(), Json::Num(l.naive_st_gflops));
        largest.insert("speedup_by_threads".to_string(), Json::Obj(speedups));
        Json::obj(vec![
            ("bench", Json::str("kernels")),
            ("schema_version", Json::num(1.0)),
            ("preset", Json::str(&self.preset)),
            ("threads_available", Json::num(self.threads_available as f64)),
            ("gemm", Json::Arr(gemm)),
            ("largest", Json::Obj(largest)),
            (
                "wall_ms",
                Json::obj(vec![
                    ("forward", Json::num(self.wall_forward_ms)),
                    ("fused", Json::num(self.wall_fused_ms)),
                    ("train_step", Json::num(self.wall_train_ms)),
                ]),
            ),
        ])
    }
}

/// Atomically write the report next to the other `BENCH_*.json` files.
pub fn write_report(path: &Path, report: &Json) -> Result<()> {
    crate::bench::loadgen::write_report(path, report)
}

fn seeded(n: usize, seed: f32) -> Vec<f32> {
    (0..n).map(|i| ((i as f32 + seed) * 0.37).sin() * 0.25).collect()
}

/// Best-of-reps throughput for `f`, which performs `flops` float ops.
fn bench_gflops(flops: f64, min_time: f64, reps: usize, mut f: impl FnMut()) -> f64 {
    f(); // warmup
    let mut best = 0.0f64;
    for _ in 0..reps {
        let mut calls = 0u32;
        let t0 = Instant::now();
        loop {
            f();
            calls += 1;
            if t0.elapsed().as_secs_f64() >= min_time {
                break;
            }
        }
        let gflops = flops * calls as f64 / t0.elapsed().as_secs_f64() / 1e9;
        best = best.max(gflops);
    }
    best
}

/// Minimum wall time per call over `iters` calls of `f`.
fn bench_wall_ms(iters: usize, mut f: impl FnMut() -> Result<()>) -> Result<f64> {
    f()?; // warmup
    let mut best = f64::INFINITY;
    for _ in 0..iters {
        let t0 = Instant::now();
        f()?;
        best = best.min(t0.elapsed().as_secs_f64() * 1e3);
    }
    Ok(best)
}

/// Deterministic non-zero banks for every input group of an executable:
/// parameter groups by role-aware init, data groups by small patterned
/// values. Shared with `tests/backend_parity.rs` so the bench and the
/// parity test exercise identical inputs.
pub fn banks_for(rt: &Runtime, name: &str) -> Result<Vec<Bank>> {
    let spec = rt.manifest.exe(name)?.clone();
    let groups = spec.input_groups();
    let mut out = Vec::with_capacity(groups.len());
    for (gi, group) in groups.iter().enumerate() {
        let range = spec.input_group_range(group)?;
        let param_group =
            matches!(*group, "base" | "frozen" | "trained" | "adapters" | "head");
        if param_group {
            let named = init::init_group(&spec, group, 7 + gi as u64, 1e-2)?;
            out.push(named.to_bank(&spec, group)?);
            continue;
        }
        let bank: Bank = spec.inputs[range]
            .iter()
            .map(|leaf| match (leaf.name.as_str(), leaf.dtype) {
                ("step", _) => Tensor::scalar_i32(1),
                ("lr", _) => Tensor::scalar_f32(1e-3),
                (n, DType::F32) if n.ends_with("attn_mask") => {
                    Tensor::full_f32(&leaf.shape, 1.0)
                }
                (n, DType::F32) if n.ends_with("class_valid") => {
                    let mut v = vec![0.0f32; leaf.elements()];
                    v[0] = 1.0;
                    v[1] = 1.0;
                    Tensor::f32(leaf.shape.clone(), v)
                }
                (n, DType::F32) if n.ends_with("gates") => {
                    Tensor::full_f32(&leaf.shape, 1.0)
                }
                (n, DType::F32) if n.ends_with("weights") => {
                    Tensor::full_f32(&leaf.shape, 1.0)
                }
                (_, DType::F32) => Tensor::zeros(&leaf.shape, DType::F32),
                (n, DType::I32) if n.ends_with("tokens") => Tensor::i32(
                    leaf.shape.clone(),
                    (0..leaf.elements()).map(|i| (i % 11) as i32).collect(),
                ),
                (n, DType::I32) if n.ends_with("labels") => Tensor::i32(
                    leaf.shape.clone(),
                    (0..leaf.elements()).map(|i| (i % 2) as i32).collect(),
                ),
                (_, DType::I32) => Tensor::zeros(&leaf.shape, DType::I32),
            })
            .collect();
        out.push(bank);
    }
    Ok(out)
}

/// A minimal lnonly-style fused bank (identity LayerNorms, random head).
fn demo_bank(dims: &crate::runtime::ModelDims) -> FusedTaskBank {
    let d = dims.d;
    let ln = || LayerLn {
        ln1_g: Tensor::full_f32(&[d], 1.0),
        ln1_b: Tensor::zeros(&[d], DType::F32),
        ln2_g: Tensor::full_f32(&[d], 1.0),
        ln2_b: Tensor::zeros(&[d], DType::F32),
    };
    FusedTaskBank {
        kind: "cls".to_string(),
        n_classes: dims.max_classes,
        embed_ln_g: Tensor::full_f32(&[d], 1.0),
        embed_ln_b: Tensor::zeros(&[d], DType::F32),
        layer_ln: (0..dims.n_layers).map(|_| ln()).collect(),
        adapters: None,
        head_w: Tensor::f32(vec![d, dims.max_classes], seeded(d * dims.max_classes, 9.0)),
        head_b: Tensor::zeros(&[dims.max_classes], DType::F32),
    }
}

/// Run the whole suite.
pub fn run(cfg: &KernelBenchConfig) -> Result<KernelBenchReport> {
    let ps = synth::builtin(&cfg.preset)
        .with_context(|| format!("unknown preset {:?}", cfg.preset))?;
    let d = &ps.dims;
    let r = ps.batch * d.seq;
    let shapes = [
        ("qkv_proj", r, d.d, d.d),
        ("ffn_in", r, d.d, d.ffn),
        ("ffn_out", r, d.ffn, d.d),
        ("cls_head", ps.batch, d.d, d.max_classes),
        ("mlm_logits", ps.batch * d.mlm_positions, d.d, d.vocab),
    ];
    let largest_i = shapes
        .iter()
        .enumerate()
        .max_by_key(|(_, s)| s.1 * s.2 * s.3)
        .map(|(i, _)| i)
        .unwrap();
    let (min_time, reps) = if cfg.quick { (0.02, 2) } else { (0.1, 3) };

    let mut gemm = Vec::new();
    for (i, &(name, n, kk, m)) in shapes.iter().enumerate() {
        let a = seeded(n * kk, 1.0 + i as f32);
        let b = seeded(kk * m, 2.0 + i as f32);
        let flops = 2.0 * n as f64 * kk as f64 * m as f64;
        let naive =
            bench_gflops(flops, min_time, reps, || {
                std::hint::black_box(k::matmul_naive(
                    std::hint::black_box(&a),
                    &b,
                    n,
                    kk,
                    m,
                ));
            });
        let mut blocked = Vec::new();
        let mut out = vec![0.0f32; n * m];
        for &t in &cfg.threads {
            let pool = Pool::new(t);
            let g = bench_gflops(flops, min_time, reps, || {
                k::matmul_into_on(&pool, std::hint::black_box(&a), &b, &mut out, n, kk, m);
                std::hint::black_box(&out);
            });
            blocked.push((t, g));
        }
        gemm.push(GemmBench {
            name: name.to_string(),
            n,
            k: kk,
            m,
            flops,
            naive_st_gflops: naive,
            blocked_gflops: blocked,
            largest: i == largest_i,
        });
    }

    // wall times on the real executables (native backend, synth manifest)
    let rt = Arc::new(Runtime::open_with(
        Path::new("artifacts"),
        &cfg.preset,
        BackendKind::Native,
    )?);
    let iters = if cfg.quick { 2 } else { 5 };
    let fwd_banks = banks_for(&rt, "cls_fwd_adapter_m8")?;
    let fwd_refs: Vec<&Bank> = fwd_banks.iter().collect();
    let fwd = rt.load("cls_fwd_adapter_m8")?;
    let wall_forward_ms = bench_wall_ms(iters, || fwd.run(&fwd_refs).map(|_| ()))?;

    let train_banks = banks_for(&rt, "cls_train_adapter_m8")?;
    let train_refs: Vec<&Bank> = train_banks.iter().collect();
    let train = rt.load("cls_train_adapter_m8")?;
    let wall_train_ms = bench_wall_ms(iters, || train.run(&train_refs).map(|_| ()))?;

    // fused mixed batch: two segments sharing one lnonly-style bank
    let backend = NativeBackend::new(&rt.manifest);
    let fused = backend.fused().context("native backend must support fused")?;
    let base_spec = rt.manifest.exe("cls_fwd_base")?.clone();
    let base = init::init_group(&base_spec, "base", 7, 1e-2)?;
    let bank = Arc::new(demo_bank(&rt.manifest.dims));
    let half = (ps.batch / 2).max(1);
    let segments = vec![
        FusedSegment { bank: Arc::clone(&bank), len: half },
        FusedSegment { bank: Arc::clone(&bank), len: half },
    ];
    let rows = 2 * half;
    let tokens: Vec<i32> =
        (0..rows * d.seq).map(|i| (i % d.vocab) as i32).collect();
    let type_ids = vec![0i32; rows * d.seq];
    let mask = vec![1.0f32; rows * d.seq];
    let wall_fused_ms = bench_wall_ms(iters, || {
        fused
            .fused_forward(&base.map, &segments, &tokens, &type_ids, &mask)
            .map(|_| ())
    })?;

    Ok(KernelBenchReport {
        preset: cfg.preset.clone(),
        threads_available: std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
        gemm,
        wall_forward_ms,
        wall_fused_ms,
        wall_train_ms,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_thread_sweep_is_sorted_and_deduped() {
        let cfg = KernelBenchConfig::default();
        let mut sorted = cfg.threads.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(cfg.threads, sorted);
        assert!(cfg.threads.contains(&1) && cfg.threads.contains(&4));
    }

    #[test]
    fn speedup_reads_the_largest_shape() {
        let report = KernelBenchReport {
            preset: "test".into(),
            threads_available: 2,
            gemm: vec![
                GemmBench {
                    name: "small".into(),
                    n: 1,
                    k: 1,
                    m: 1,
                    flops: 2.0,
                    naive_st_gflops: 1.0,
                    blocked_gflops: vec![(1, 9.0)],
                    largest: false,
                },
                GemmBench {
                    name: "big".into(),
                    n: 8,
                    k: 8,
                    m: 8,
                    flops: 1024.0,
                    naive_st_gflops: 2.0,
                    blocked_gflops: vec![(1, 3.0), (4, 8.0)],
                    largest: true,
                },
            ],
            wall_forward_ms: 1.0,
            wall_fused_ms: 2.0,
            wall_train_ms: 3.0,
        };
        assert_eq!(report.largest().name, "big");
        assert_eq!(report.speedup_at(4), Some(4.0));
        assert_eq!(report.speedup_at(2), None);
        let doc = report.to_json();
        assert_eq!(doc.at("bench").as_str(), Some("kernels"));
        assert_eq!(doc.at("schema_version").as_usize(), Some(1));
        let largest = doc.at("largest");
        assert_eq!(largest.at("name").as_str(), Some("big"));
        assert_eq!(
            largest.at("speedup_by_threads").at("4").as_f64(),
            Some(4.0)
        );
    }
}
