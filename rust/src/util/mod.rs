//! Dependency-free substrates: JSON, RNG, statistics, tensors, timing.
//!
//! The offline build environment only ships the `xla` crate's dependency
//! closure, so everything `serde_json` / `rand` / `criterion` would
//! normally provide is implemented (and tested) here.

pub mod json;
pub mod rng;
pub mod stats;
pub mod tensor;
pub mod timer;
