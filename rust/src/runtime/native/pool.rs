//! Persistent std-only worker pool for the native kernels.
//!
//! The build is fully offline (no rayon), so this is a hand-rolled scoped
//! parallel-for: a fixed set of worker threads parked on a condvar, woken
//! once per [`Pool::parallel_for`] call, pulling task indices from a shared
//! atomic counter until the range is drained. The caller thread
//! participates too, so a pool of size `n` uses `n - 1` spawned workers and
//! `Pool::new(1)` degenerates to a plain serial loop with zero overhead.
//!
//! Scheduling is dynamic (whichever thread is free claims the next index)
//! but the *values* computed are scheduling-independent: kernels partition
//! work so each index owns a disjoint output slice and performs a fixed
//! sequence of float ops, which is what makes N-thread results bitwise
//! equal to 1-thread results (pinned by `tests/kernel_props.rs`).
//!
//! The global pool is sized by `ADAPTERBERT_THREADS` (default: available
//! parallelism) and constructed lazily on first use.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::thread;

use crate::check::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use crate::check::sync::{self, Arc, Condvar, Mutex, OnceLock};
use crate::check::thread::{spawn_named, JoinHandle};

/// One `parallel_for` invocation: the erased closure plus its own claim /
/// completion counters. Counters live *inside* the job so a worker that
/// wakes late for an old epoch can only touch that old job's (drained)
/// counters, never the next call's.
struct Job {
    /// Caller's `&(dyn Fn(usize) + Sync)` with the lifetime erased. Only
    /// dereferenced after a successful claim (`next < tasks`), which
    /// implies the issuing `parallel_for` has not yet returned, so the
    /// borrow is still live.
    f: *const (dyn Fn(usize) + Sync),
    tasks: usize,
    next: AtomicUsize,
    done: AtomicUsize,
    panicked: AtomicBool,
}

// SAFETY: the raw closure pointer is only shared with worker threads while
// the issuing `parallel_for` blocks on `done == tasks`; see `Job::f`.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

impl Job {
    /// Claim and run indices until the range is drained.
    fn work(&self) {
        loop {
            // relaxed: the RMW hands out each index exactly once at any
            // ordering; the claim publishes nothing
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.tasks {
                return;
            }
            // SAFETY: claim succeeded, so the caller is still waiting.
            let f = unsafe { &*self.f };
            if catch_unwind(AssertUnwindSafe(|| f(i))).is_err() {
                // relaxed: sequenced before the Release `done` bump
                // below, which the caller's Acquire `done` loop pairs
                // with — the flag cannot be missed
                self.panicked.store(true, Ordering::Relaxed);
            }
            self.done.fetch_add(1, Ordering::Release);
        }
    }
}

struct State {
    epoch: u64,
    job: Option<Arc<Job>>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    cv: Condvar,
}

/// A fixed-size worker pool; see the module docs.
pub struct Pool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
}

impl Pool {
    /// A pool running `threads` ways in `parallel_for` (the caller counts
    /// as one, so `threads - 1` OS threads are spawned; `0` is clamped
    /// to `1`).
    pub fn new(threads: usize) -> Pool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State { epoch: 0, job: None, shutdown: false }),
            cv: Condvar::new(),
        });
        let workers = (1..threads)
            .map(|i| {
                let shared = Arc::clone(&shared);
                spawn_named(&format!("kernel-worker-{i}"), move || worker_loop(&shared))
                    .expect("spawn kernel worker")
            })
            .collect();
        Pool { shared, workers, threads }
    }

    /// Parallelism degree (including the calling thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0) … f(tasks-1)` across the pool; returns when all are done.
    /// Each index must own a disjoint slice of any shared output.
    pub fn parallel_for(&self, tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        if tasks == 0 {
            return;
        }
        if self.workers.is_empty() || tasks == 1 {
            for i in 0..tasks {
                f(i);
            }
            return;
        }
        // SAFETY: erase the borrow's lifetime; `Job::f` documents why the
        // pointer never outlives this call.
        let f_static = unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(f)
        };
        let job = Arc::new(Job {
            f: f_static as *const (dyn Fn(usize) + Sync),
            tasks,
            next: AtomicUsize::new(0),
            done: AtomicUsize::new(0),
            panicked: AtomicBool::new(false),
        });
        {
            let mut st = self.shared.state.lock().unwrap();
            st.job = Some(Arc::clone(&job));
            st.epoch += 1;
            self.shared.cv.notify_all();
        }
        job.work();
        while job.done.load(Ordering::Acquire) < tasks {
            sync::yield_now();
        }
        // relaxed: the Acquire loop above synchronizes with each task's
        // Release `done` bump, which the panicked store precedes
        if job.panicked.load(Ordering::Relaxed) {
            panic!("kernel pool: a parallel task panicked");
        }
    }
}

impl Drop for Pool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.cv.notify_all();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if st.epoch != seen {
                    seen = st.epoch;
                    if let Some(job) = &st.job {
                        break Arc::clone(job);
                    }
                }
                st = shared.cv.wait(st).unwrap();
            }
        };
        job.work();
    }
}

/// Pool size for the process: `ADAPTERBERT_THREADS` if set (values < 1
/// are clamped, unparseable values fall back to the default), else the
/// machine's available parallelism.
pub fn configured_threads() -> usize {
    let avail = thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    match std::env::var("ADAPTERBERT_THREADS") {
        Ok(v) => v.trim().parse::<usize>().map(|n| n.max(1)).unwrap_or(avail),
        Err(_) => avail,
    }
}

/// The process-wide pool used by the kernel entry points; built on first
/// use with [`configured_threads`] ways.
pub fn global() -> &'static Pool {
    static GLOBAL: OnceLock<Pool> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let n = configured_threads();
        crate::log_debug!("pool", "native worker pool started threads={n}");
        Pool::new(n)
    })
}

/// A `*mut f32` that can cross thread boundaries; used by kernels whose
/// parallel tasks write disjoint regions of one output buffer.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr(pub *mut f32);
// SAFETY: every kernel using SendPtr partitions the output so no two task
// indices touch the same element.
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

impl SendPtr {
    /// The wrapped pointer; caller must respect the disjointness contract.
    #[inline]
    pub fn get(self) -> *mut f32 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_every_index_exactly_once() {
        let pool = Pool::new(4);
        let hits: Vec<AtomicU64> = (0..257).map(|_| AtomicU64::new(0)).collect();
        pool.parallel_for(hits.len(), &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::Relaxed), 1, "index {i}");
        }
    }

    #[test]
    fn serial_pool_runs_inline() {
        let pool = Pool::new(1);
        assert_eq!(pool.threads(), 1);
        let sum = AtomicU64::new(0);
        pool.parallel_for(10, &|i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn reusable_across_calls() {
        let pool = Pool::new(3);
        for round in 0..50 {
            let sum = AtomicU64::new(0);
            pool.parallel_for(round + 1, &|i| {
                sum.fetch_add(i as u64 + 1, Ordering::Relaxed);
            });
            let n = (round + 1) as u64;
            assert_eq!(sum.load(Ordering::Relaxed), n * (n + 1) / 2);
        }
    }

    #[test]
    fn zero_tasks_is_a_noop() {
        Pool::new(2).parallel_for(0, &|_| panic!("must not run"));
    }
}
