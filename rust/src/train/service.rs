//! Background training jobs: a bounded worker pool that trains adapter
//! banks *next to* live serving, with durable, resumable checkpoints.
//!
//! This is the producer side of the paper's continual-service story
//! (§1: "new tasks can be added without revisiting previous ones"):
//! because every task's trainable parameters are independent given the
//! frozen trunk, training jobs for different tasks run concurrently on
//! the same [`Runtime`] (and kernel worker pool) that serves traffic —
//! no second model copy, no process restart.
//!
//! Job lifecycle:
//!
//! ```text
//!   submit ─► queued ─► running ─► completed (installed + store version)
//!                 ▲         │  └──► failed (error recorded)
//!                 └─────────┘ shutdown: checkpoint + back to queued
//! ```
//!
//! Durability: with a checkpoint directory configured (the disk-backed
//! store's `_jobs/` area), a job writes `job_<id>.json` (its full spec)
//! at submit time and `job_<id>.ckpt` (a [`TrainCheckpoint`] — trained
//! bank, Adam moments, cursors, RNG) every `checkpoint_every` epochs and
//! on shutdown, all via atomic tmp+rename. [`TrainService::recover`]
//! re-enqueues any descriptors found on disk; a job with a checkpoint
//! resumes mid-run and produces the *byte-identical* final bank the
//! uninterrupted run would have (see `TrainState`). Only successful
//! completion removes a job's files — failures keep them, both for
//! post-mortem and because the durable state may be valid (a park whose
//! checkpoint write failed, a recover under the wrong preset) and a
//! later process's recover() should retry from it.
//!
//! Completion is delegated to an injected `install` callback so this
//! module stays independent of the serving stack: the gateway wires it
//! to "store append + hot-install into the live coordinator" (see
//! `serve::registry::install_trained`), making a finished job servable
//! with zero restart. A hot install lands in the coordinator's paged
//! bank cache like any other load, so it counts against the byte budget
//! (`--adapter-cache-mb`) and may evict a colder task's bank; the store
//! append precedes the install, so anything evicted — including the new
//! bank itself, later — pages back in on demand.

use std::collections::{BTreeMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::checkpoint::TrainCheckpoint;
use super::r#loop::{TrainConfig, TrainState};
use crate::data::grammar::World;
use crate::data::tasks::{generate, Metric, TaskKind, TaskSpec};
use crate::eval::TaskModel;
use crate::model::params::NamedTensors;
use crate::obs::trace::{self, SpanKind, Stage};
use crate::runtime::Runtime;
use crate::store::{validate_task_name, write_atomic};
use crate::util::json::Json;

/// Where a job is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Waiting for a worker (also the parked state after a shutdown
    /// checkpointed a running job).
    Queued,
    /// A worker is stepping it right now.
    Running,
    /// Trained, installed, servable; `version` holds the store version.
    Completed,
    /// Terminal error; `error` holds the message.
    Failed,
}

impl JobState {
    /// Wire/status name.
    pub fn name(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Completed => "completed",
            JobState::Failed => "failed",
        }
    }
}

/// Everything a job needs to run: the synthetic-task spec (data is
/// regenerated deterministically from it) plus the training config.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub task: TaskSpec,
    pub train: TrainConfig,
}

impl JobSpec {
    /// Class count for registration/serving (0 for reg/span heads).
    pub fn n_classes(&self) -> usize {
        match &self.task.kind {
            TaskKind::Cls { n_classes, .. } => *n_classes,
            _ => 0,
        }
    }
}

/// Live view of one job, cloned out for status reporting.
#[derive(Debug, Clone)]
pub struct JobRecord {
    pub id: u64,
    pub task: String,
    pub n_classes: usize,
    pub state: JobState,
    /// Completed epochs / configured epochs.
    pub epoch: usize,
    pub total_epochs: usize,
    /// Optimizer steps taken / total steps of the run.
    pub step: usize,
    pub total_steps: usize,
    /// Latest train-step loss (`NaN` before the first step).
    pub loss: f64,
    /// Best validation score so far (`NaN` before the first eval).
    pub best_val: f64,
    /// `(epoch, val score)` per evaluated epoch.
    pub val_history: Vec<(usize, f64)>,
    /// Store version assigned on completion.
    pub version: Option<usize>,
    /// Failure message for [`JobState::Failed`].
    pub error: Option<String>,
    /// Wall-clock seconds of the current (or final) run leg.
    pub wall_s: f64,
    /// Training throughput of the current run leg.
    pub steps_per_sec: f64,
    /// True when this leg resumed from an on-disk checkpoint.
    pub resumed: bool,
}

impl JobRecord {
    /// A fresh queued record for `spec` (exposed for wire-type tests).
    pub fn new(id: u64, spec: &JobSpec, total_steps: usize) -> JobRecord {
        JobRecord {
            id,
            task: spec.task.name.clone(),
            n_classes: spec.n_classes(),
            state: JobState::Queued,
            epoch: 0,
            total_epochs: spec.train.epochs,
            step: 0,
            total_steps,
            loss: f64::NAN,
            best_val: f64::NAN,
            val_history: Vec::new(),
            version: None,
            error: None,
            wall_s: 0.0,
            steps_per_sec: 0.0,
            resumed: false,
        }
    }
}

/// Pool sizing and durability knobs.
#[derive(Debug, Clone)]
pub struct ServiceConfig {
    /// Concurrent training jobs (worker threads). Jobs beyond this queue.
    pub workers: usize,
    /// Where job descriptors and checkpoints persist (`None` = jobs are
    /// in-memory only and die with the process).
    pub ckpt_dir: Option<PathBuf>,
    /// Checkpoint cadence in epochs (0 = only on shutdown).
    pub checkpoint_every: usize,
}

impl Default for ServiceConfig {
    fn default() -> Self {
        ServiceConfig { workers: 1, ckpt_dir: None, checkpoint_every: 1 }
    }
}

/// Called when a job finishes: `(task, n_classes, val_score, model)` →
/// assigned store version. The serving stack injects store-append +
/// hot-install here.
pub type InstallFn = dyn Fn(&str, usize, f64, &TaskModel) -> Result<usize> + Send + Sync;

struct ServiceState {
    jobs: BTreeMap<u64, JobRecord>,
    specs: BTreeMap<u64, JobSpec>,
    queue: VecDeque<u64>,
}

struct Inner {
    rt: Arc<Runtime>,
    base: Arc<NamedTensors>,
    world: World,
    cfg: ServiceConfig,
    install: Box<InstallFn>,
    state: Mutex<ServiceState>,
    cv: Condvar,
    next_id: AtomicU64,
    stop: AtomicBool,
}

/// A running training-job pool; shut down with [`TrainService::shutdown`].
pub struct TrainService {
    inner: Arc<Inner>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl TrainService {
    /// Start the pool. `world` must be the same topic world serving/eval
    /// use (job data is regenerated from it); `install` runs on a worker
    /// thread when a job completes.
    pub fn start(
        rt: Arc<Runtime>,
        base: Arc<NamedTensors>,
        world: World,
        cfg: ServiceConfig,
        install: Box<InstallFn>,
    ) -> Result<TrainService> {
        if let Some(dir) = &cfg.ckpt_dir {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating checkpoint dir {dir:?}"))?;
        }
        let inner = Arc::new(Inner {
            rt,
            base,
            world,
            cfg: cfg.clone(),
            install,
            state: Mutex::new(ServiceState {
                jobs: BTreeMap::new(),
                specs: BTreeMap::new(),
                queue: VecDeque::new(),
            }),
            cv: Condvar::new(),
            next_id: AtomicU64::new(1),
            stop: AtomicBool::new(false),
        });
        let mut workers = Vec::new();
        for i in 0..cfg.workers.max(1) {
            let inner = inner.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("ab-train-{i}"))
                    .spawn(move || worker_loop(&inner))?,
            );
        }
        Ok(TrainService { inner, workers })
    }

    /// Enqueue a job. Validates up front — the task name, that the train
    /// executable exists in the manifest, and that the train split is at
    /// least one batch — so a doomed job is an immediate error instead
    /// of a failure discovered minutes later. Returns the job id.
    pub fn submit(&self, spec: JobSpec) -> Result<u64> {
        validate_task_name(&spec.task.name)?;
        let exe = self.inner.rt.manifest.exe(&spec.train.exe)?;
        let steps_per_epoch = spec.task.n_train / exe.batch;
        if steps_per_epoch == 0 {
            bail!(
                "job for task {:?}: {} training examples < batch {} of {} — \
                 the run would take zero optimizer steps",
                spec.task.name,
                spec.task.n_train,
                exe.batch,
                spec.train.exe
            );
        }
        let total_steps = steps_per_epoch * spec.train.epochs;
        let id = self.inner.next_id.fetch_add(1, Ordering::SeqCst);
        // durable first: the descriptor hits disk before the job is
        // visible, so a crash right after submit is still recoverable
        if let Some(dir) = &self.inner.cfg.ckpt_dir {
            write_atomic(
                &desc_path(dir, id),
                job_descriptor_json(id, &spec).to_string().as_bytes(),
            )?;
        }
        let record = JobRecord::new(id, &spec, total_steps);
        let mut st = self.inner.state.lock().unwrap();
        st.jobs.insert(id, record);
        st.specs.insert(id, spec);
        st.queue.push_back(id);
        drop(st);
        self.inner.cv.notify_all();
        Ok(id)
    }

    /// Re-enqueue every job whose descriptor survives in the checkpoint
    /// directory (call once at startup). Jobs with a checkpoint resume
    /// mid-run; descriptor-only jobs start over. Returns how many jobs
    /// were recovered.
    pub fn recover(&self) -> Result<usize> {
        let Some(dir) = self.inner.cfg.ckpt_dir.clone() else {
            return Ok(0);
        };
        let mut found: Vec<(u64, JobSpec)> = Vec::new();
        for f in std::fs::read_dir(&dir)? {
            let p = f?.path();
            let Some(name) = p.file_name().map(|n| n.to_string_lossy().to_string())
            else {
                continue;
            };
            let Some(id) = name
                .strip_prefix("job_")
                .and_then(|r| r.strip_suffix(".json"))
                .and_then(|r| r.parse::<u64>().ok())
            else {
                continue;
            };
            let text = std::fs::read_to_string(&p)
                .with_context(|| format!("reading descriptor {p:?}"))?;
            match Json::parse(&text)
                .map_err(|e| anyhow::anyhow!("{p:?}: {e}"))
                .and_then(|j| job_spec_from_descriptor(&j))
            {
                Ok(spec) => found.push((id, spec)),
                Err(e) => {
                    crate::log_warn!("train", "skipping job descriptor {p:?}: {e:#}")
                }
            }
        }
        found.sort_by_key(|(id, _)| *id);
        let mut recovered = 0;
        let mut st = self.inner.state.lock().unwrap();
        for (id, spec) in found {
            if st.jobs.contains_key(&id) {
                continue;
            }
            self.inner.next_id.fetch_max(id + 1, Ordering::SeqCst);
            let steps_per_epoch = self
                .inner
                .rt
                .manifest
                .exe(&spec.train.exe)
                .map(|e| spec.task.n_train / e.batch)
                .unwrap_or(0);
            let mut record = JobRecord::new(id, &spec, steps_per_epoch * spec.train.epochs);
            record.resumed = ckpt_path(&dir, id).exists();
            st.jobs.insert(id, record);
            st.specs.insert(id, spec);
            st.queue.push_back(id);
            recovered += 1;
        }
        drop(st);
        self.inner.cv.notify_all();
        Ok(recovered)
    }

    /// Snapshot of one job.
    pub fn status(&self, id: u64) -> Option<JobRecord> {
        self.inner.state.lock().unwrap().jobs.get(&id).cloned()
    }

    /// Snapshot of every job, by id.
    pub fn jobs(&self) -> Vec<JobRecord> {
        self.inner.state.lock().unwrap().jobs.values().cloned().collect()
    }

    /// Jobs not yet terminal (queued or running).
    pub fn active_jobs(&self) -> usize {
        self.inner
            .state
            .lock()
            .unwrap()
            .jobs
            .values()
            .filter(|r| matches!(r.state, JobState::Queued | JobState::Running))
            .count()
    }

    /// Stop the pool: running jobs checkpoint (when durable) and park
    /// back to `queued`; workers are joined. Queued durable jobs stay on
    /// disk for the next process's [`TrainService::recover`].
    pub fn shutdown(self) {
        self.inner.stop.store(true, Ordering::SeqCst);
        self.inner.cv.notify_all();
        for h in self.workers {
            let _ = h.join();
        }
    }
}

fn worker_loop(inner: &Arc<Inner>) {
    loop {
        let id = {
            let mut st = inner.state.lock().unwrap();
            loop {
                if inner.stop.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(id) = st.queue.pop_front() {
                    break id;
                }
                let (guard, _) = inner
                    .cv
                    .wait_timeout(st, Duration::from_millis(50))
                    .unwrap();
                st = guard;
            }
        };
        let span = trace::global().begin(SpanKind::TrainJob, format!("job-{id}"));
        {
            let st = inner.state.lock().unwrap();
            if let Some(rec) = st.jobs.get(&id) {
                span.set_task(&rec.task);
            }
        }
        let outcome = run_job(inner, id);
        span.set_status(if outcome.is_ok() { 200 } else { 500 });
        span.mark(Stage::Responded);
        trace::global().record(&span);
        if let Err(e) = outcome {
            let msg = format!("{e:#}");
            crate::log_error!("train", "job {id} failed: {msg}");
            let mut st = inner.state.lock().unwrap();
            if let Some(rec) = st.jobs.get_mut(&id) {
                rec.state = JobState::Failed;
                rec.error = Some(msg);
            }
            // durable state is kept on failure: the descriptor/checkpoint
            // may be perfectly valid (a park whose checkpoint write
            // failed, a recover under the wrong preset) and a later
            // process's recover() retries from them — only successful
            // completion removes job files
        }
    }
}

/// Drive one job to completion (or park it on shutdown).
///
/// Completion semantics are **at-least-once**: the install callback and
/// the job-file cleanup are not atomic, so a crash in the window between
/// them re-runs the job on the next `recover()` and appends another
/// store version of the same bank. That is benign under the append-only
/// store (serving always resolves `latest`, and the re-run is
/// deterministic), and strictly safer than deleting the descriptor
/// first, which would lose the job entirely if the install never ran.
fn run_job(inner: &Arc<Inner>, id: u64) -> Result<()> {
    let spec = inner
        .state
        .lock()
        .unwrap()
        .specs
        .get(&id)
        .cloned()
        .context("job spec missing")?;
    let t0 = Instant::now();
    let data = generate(&inner.world, &spec.task, inner.rt.manifest.dims.seq);
    let ck = load_checkpoint(inner, id);
    let resumed = ck.is_some();
    let mut ts = match &ck {
        Some(c) => TrainState::resume(&inner.rt, &spec.train, &data, &inner.base, c)
            .context("resuming from checkpoint")?,
        None => TrainState::new(&inner.rt, &spec.train, &data, &inner.base)?,
    };
    drop(ck);
    let start_steps = ts.steps_taken();
    {
        let mut st = inner.state.lock().unwrap();
        if let Some(rec) = st.jobs.get_mut(&id) {
            rec.state = JobState::Running;
            rec.resumed = resumed;
            rec.total_epochs = ts.epochs_total();
            rec.total_steps = ts.total_steps();
            rec.epoch = ts.epochs_done();
            rec.step = ts.steps_taken();
            // a resumed run carries its pre-restart progress — surface
            // it so GET /train/<id> doesn't under-report a job that is
            // already several epochs in
            rec.val_history = ts
                .history()
                .iter()
                .filter(|(_, _, v)| !v.is_nan())
                .map(|&(e, _, v)| (e, v))
                .collect();
            if let Some(b) = ts.best_val() {
                rec.best_val = b;
            }
            rec.loss = ts.last_loss();
        }
    }
    while !ts.done() {
        while !ts.epoch_done() {
            if inner.stop.load(Ordering::SeqCst) {
                return park_job(inner, id, &ts);
            }
            let loss = ts.step()?;
            let mut st = inner.state.lock().unwrap();
            if let Some(rec) = st.jobs.get_mut(&id) {
                rec.step = ts.steps_taken();
                rec.loss = loss;
                rec.wall_s = t0.elapsed().as_secs_f64();
                if rec.wall_s > 0.0 {
                    rec.steps_per_sec =
                        (ts.steps_taken() - start_steps) as f64 / rec.wall_s;
                }
            }
        }
        let (epoch, _mean_loss, val) = ts.end_epoch()?;
        {
            let mut st = inner.state.lock().unwrap();
            if let Some(rec) = st.jobs.get_mut(&id) {
                rec.epoch = ts.epochs_done();
                if !val.is_nan() {
                    rec.val_history.push((epoch, val));
                }
                if let Some(b) = ts.best_val() {
                    rec.best_val = b;
                }
            }
        }
        if inner.cfg.checkpoint_every > 0
            && !ts.done()
            && ts.epochs_done() % inner.cfg.checkpoint_every == 0
        {
            save_checkpoint(inner, id, &ts)?;
        }
    }
    let result = ts.finish()?;
    let version = (inner.install)(
        &spec.task.name,
        spec.n_classes(),
        result.val_score,
        &result.model,
    )
    .with_context(|| format!("installing trained bank for {:?}", spec.task.name))?;
    {
        let mut st = inner.state.lock().unwrap();
        if let Some(rec) = st.jobs.get_mut(&id) {
            rec.state = JobState::Completed;
            rec.version = Some(version);
            rec.best_val = result.val_score;
            rec.wall_s = t0.elapsed().as_secs_f64();
        }
    }
    remove_job_files(inner, id);
    Ok(())
}

/// Shutdown hit mid-run: checkpoint (when durable) and put the job back
/// in `queued` so recover/restart continues it.
fn park_job(inner: &Arc<Inner>, id: u64, ts: &TrainState<'_>) -> Result<()> {
    save_checkpoint(inner, id, ts)?;
    let mut st = inner.state.lock().unwrap();
    if let Some(rec) = st.jobs.get_mut(&id) {
        rec.state = JobState::Queued;
    }
    Ok(())
}

fn ckpt_path(dir: &Path, id: u64) -> PathBuf {
    dir.join(format!("job_{id:06}.ckpt"))
}

fn desc_path(dir: &Path, id: u64) -> PathBuf {
    dir.join(format!("job_{id:06}.json"))
}

fn save_checkpoint(inner: &Inner, id: u64, ts: &TrainState<'_>) -> Result<()> {
    let Some(dir) = &inner.cfg.ckpt_dir else { return Ok(()) };
    write_atomic(&ckpt_path(dir, id), &ts.checkpoint().to_bytes())
        .with_context(|| format!("checkpointing job {id}"))
}

/// Best-effort checkpoint read: a missing file starts fresh; an
/// unreadable one warns and starts fresh (the descriptor is the source
/// of truth for *what* to train, the checkpoint only for *where it was*).
fn load_checkpoint(inner: &Inner, id: u64) -> Option<TrainCheckpoint> {
    let dir = inner.cfg.ckpt_dir.as_ref()?;
    let path = ckpt_path(dir, id);
    let bytes = std::fs::read(&path).ok()?;
    match TrainCheckpoint::from_bytes(&bytes) {
        Ok(ck) => Some(ck),
        Err(e) => {
            crate::log_warn!(
                "train",
                "job {id}: unreadable checkpoint {path:?} ({e:#}); \
                 restarting from scratch"
            );
            None
        }
    }
}

fn remove_job_files(inner: &Inner, id: u64) {
    if let Some(dir) = &inner.cfg.ckpt_dir {
        let _ = std::fs::remove_file(ckpt_path(dir, id));
        let _ = std::fs::remove_file(desc_path(dir, id));
    }
}

// ---------------------------------------------------------------------------
// durable job descriptors
// ---------------------------------------------------------------------------

/// Serialize a job's full spec (task generation + training config) for
/// crash recovery. Seeds are exact through JSON for values < 2^53 —
/// far beyond any seed this repo uses.
fn job_descriptor_json(id: u64, spec: &JobSpec) -> Json {
    let kind = match &spec.task.kind {
        TaskKind::Cls { n_classes, pair } => Json::obj(vec![
            ("kind", Json::str("cls")),
            ("n_classes", Json::num(*n_classes as f64)),
            ("pair", Json::Bool(*pair)),
        ]),
        TaskKind::Reg => Json::obj(vec![("kind", Json::str("reg"))]),
        TaskKind::Span => Json::obj(vec![("kind", Json::str("span"))]),
    };
    Json::obj(vec![
        ("id", Json::num(id as f64)),
        ("name", Json::str(&spec.task.name)),
        ("task_kind", kind),
        ("metric", Json::str(spec.task.metric.name())),
        ("n_train", Json::num(spec.task.n_train as f64)),
        ("n_val", Json::num(spec.task.n_val as f64)),
        ("n_test", Json::num(spec.task.n_test as f64)),
        ("purity", Json::num(spec.task.purity)),
        ("noise", Json::num(spec.task.noise)),
        ("data_seed", Json::num(spec.task.seed as f64)),
        ("exe", Json::str(&spec.train.exe)),
        ("lr", Json::num(spec.train.lr)),
        ("epochs", Json::num(spec.train.epochs as f64)),
        ("warmup_frac", Json::num(spec.train.warmup_frac)),
        ("seed", Json::num(spec.train.seed as f64)),
        ("adapter_std", Json::num(spec.train.adapter_std)),
        ("eval_each_epoch", Json::Bool(spec.train.eval_each_epoch)),
    ])
}

/// Inverse of [`job_descriptor_json`].
fn job_spec_from_descriptor(j: &Json) -> Result<JobSpec> {
    let get_num = |key: &str| -> Result<f64> {
        j.get(key)
            .and_then(Json::as_f64)
            .with_context(|| format!("descriptor missing {key:?}"))
    };
    let get_str = |key: &str| -> Result<String> {
        j.get(key)
            .and_then(Json::as_str)
            .map(str::to_string)
            .with_context(|| format!("descriptor missing {key:?}"))
    };
    let kj = j.get("task_kind").context("descriptor missing task_kind")?;
    let kind = match kj.get("kind").and_then(Json::as_str) {
        Some("cls") => TaskKind::Cls {
            n_classes: kj
                .get("n_classes")
                .and_then(Json::as_usize)
                .context("cls kind missing n_classes")?,
            pair: kj.get("pair").and_then(Json::as_bool).unwrap_or(false),
        },
        Some("reg") => TaskKind::Reg,
        Some("span") => TaskKind::Span,
        other => bail!("unknown task kind {other:?}"),
    };
    let metric_name = get_str("metric")?;
    let metric = Metric::from_name(&metric_name)
        .with_context(|| format!("unknown metric {metric_name:?}"))?;
    let task = TaskSpec {
        name: get_str("name")?,
        kind,
        metric,
        n_train: get_num("n_train")? as usize,
        n_val: get_num("n_val")? as usize,
        n_test: get_num("n_test")? as usize,
        purity: get_num("purity")?,
        noise: get_num("noise")?,
        seed: get_num("data_seed")? as u64,
    };
    let train = TrainConfig {
        exe: get_str("exe")?,
        lr: get_num("lr")?,
        epochs: get_num("epochs")? as usize,
        warmup_frac: get_num("warmup_frac")?,
        seed: get_num("seed")? as u64,
        adapter_std: get_num("adapter_std")?,
        eval_each_epoch: j
            .get("eval_each_epoch")
            .and_then(Json::as_bool)
            .unwrap_or(true),
    };
    Ok(JobSpec { task, train })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> JobSpec {
        JobSpec {
            task: TaskSpec {
                name: "jobtask".into(),
                kind: TaskKind::Cls { n_classes: 3, pair: true },
                metric: Metric::Accuracy,
                n_train: 240,
                n_val: 48,
                n_test: 48,
                purity: 0.85,
                noise: 0.0,
                seed: 77,
            },
            train: TrainConfig::new("cls_train_adapter_m4", 1e-3, 4, 9),
        }
    }

    #[test]
    fn descriptor_roundtrip_is_exact() {
        let s = spec();
        let j = job_descriptor_json(5, &s);
        let back = job_spec_from_descriptor(&Json::parse(&j.to_string()).unwrap())
            .unwrap();
        assert_eq!(back.task.name, "jobtask");
        assert_eq!(back.task.kind, TaskKind::Cls { n_classes: 3, pair: true });
        assert_eq!(back.task.metric, Metric::Accuracy);
        assert_eq!(back.task.n_train, 240);
        assert_eq!(back.task.seed, 77);
        assert_eq!(back.task.purity, 0.85);
        assert_eq!(back.train.exe, "cls_train_adapter_m4");
        assert_eq!(back.train.lr, 1e-3);
        assert_eq!(back.train.epochs, 4);
        assert_eq!(back.train.seed, 9);
        assert!(back.train.eval_each_epoch);
    }

    #[test]
    fn descriptor_covers_reg_and_span_kinds() {
        for (kind, metric) in [
            (TaskKind::Reg, Metric::Spearman),
            (TaskKind::Span, Metric::SpanF1),
        ] {
            let mut s = spec();
            s.task.kind = kind.clone();
            s.task.metric = metric;
            let back = job_spec_from_descriptor(
                &Json::parse(&job_descriptor_json(1, &s).to_string()).unwrap(),
            )
            .unwrap();
            assert_eq!(back.task.kind, kind);
            assert_eq!(back.task.metric, metric);
        }
    }

    #[test]
    fn job_state_names_are_stable() {
        assert_eq!(JobState::Queued.name(), "queued");
        assert_eq!(JobState::Running.name(), "running");
        assert_eq!(JobState::Completed.name(), "completed");
        assert_eq!(JobState::Failed.name(), "failed");
    }
}
