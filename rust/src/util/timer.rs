//! Tiny timing/throughput helpers shared by the bench harness and metrics.

use std::time::{Duration, Instant};

/// Collects duration samples; reports mean/percentiles. Used by the micro
/// benches and the serving-latency metrics (no criterion offline).
#[derive(Debug, Default, Clone)]
pub struct Samples {
    pub durs: Vec<Duration>,
}

impl Samples {
    pub fn record(&mut self, d: Duration) {
        self.durs.push(d);
    }

    pub fn time<T>(&mut self, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(t0.elapsed());
        out
    }

    pub fn len(&self) -> usize {
        self.durs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.durs.is_empty()
    }

    fn secs(&self) -> Vec<f64> {
        self.durs.iter().map(|d| d.as_secs_f64()).collect()
    }

    pub fn mean_s(&self) -> f64 {
        super::stats::mean(&self.secs())
    }

    pub fn pctl_s(&self, p: f64) -> f64 {
        super::stats::percentile(&self.secs(), p)
    }

    pub fn total_s(&self) -> f64 {
        self.secs().iter().sum()
    }

    /// "events per second" given one event per sample.
    pub fn throughput(&self) -> f64 {
        self.len() as f64 / self.total_s()
    }

    pub fn summary(&self, unit_per_sample: f64) -> String {
        format!(
            "n={} mean={:.3}ms p50={:.3}ms p95={:.3}ms thpt={:.1}/s",
            self.len(),
            self.mean_s() * 1e3,
            self.pctl_s(50.0) * 1e3,
            self.pctl_s(95.0) * 1e3,
            self.throughput() * unit_per_sample,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut s = Samples::default();
        for ms in [1u64, 2, 3] {
            s.record(Duration::from_millis(ms));
        }
        assert_eq!(s.len(), 3);
        assert!((s.mean_s() - 0.002).abs() < 1e-9);
        assert!((s.pctl_s(50.0) - 0.002).abs() < 1e-9);
        assert!(s.throughput() > 0.0);
    }
}
