//! Dev utility: measure XLA compile time of one artifact.
//!
//! `cargo run --release --example compile_probe -- <exe-name> [preset]`
//! Used for the §Perf calibration in EXPERIMENTS.md.

use std::sync::Arc;
use std::time::Instant;
use adapterbert::runtime::Runtime;
fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap();
    let preset = std::env::args().nth(2).unwrap_or("default".into());
    let rt = Arc::new(Runtime::open(std::path::Path::new("artifacts"), &preset)?);
    let t0 = Instant::now();
    rt.load(&name)?;
    println!("compile {name}: {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
