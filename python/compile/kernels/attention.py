"""L1: VMEM-tiled scaled-dot-product attention Pallas kernel.

Flash-attention-style schedule rethought for TPU (DESIGN.md
§Hardware-Adaptation): instead of a CUDA threadblock per (head, q-tile)
with K/V streamed through shared memory, the grid is (batch*heads,) with
the K/V sequence walked in VMEM-resident blocks using the running-max /
running-denominator recurrence, so the s x s score matrix never
materializes in HBM.

Used by the inference (``*_fwd``) graphs. Training graphs use
:func:`compile.kernels.ref.attention_ref` so XLA autodiff applies.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_K = 64
_NEG = -1e9


def _attn_kernel(q_ref, k_ref, v_ref, mask_ref, o_ref, *, block_k: int):
    """One (batch*head): online-softmax attention over K/V blocks."""
    q = q_ref[0]          # [s, dh]
    k = k_ref[0]          # [s, dh]
    v = v_ref[0]          # [s, dh]
    mask = mask_ref[0]    # [s]
    s, dh = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, q.dtype))
    n_blocks = s // block_k

    def body(j, carry):
        acc, m_run, l_run = carry
        kj = jax.lax.dynamic_slice_in_dim(k, j * block_k, block_k, axis=0)
        vj = jax.lax.dynamic_slice_in_dim(v, j * block_k, block_k, axis=0)
        mj = jax.lax.dynamic_slice_in_dim(mask, j * block_k, block_k, axis=0)
        scores = jnp.dot(q, kj.T, preferred_element_type=jnp.float32) * scale
        scores = jnp.where(mj[None, :] > 0, scores, _NEG)
        m_new = jnp.maximum(m_run, jnp.max(scores, axis=-1))
        alpha = jnp.exp(m_run - m_new)
        p = jnp.exp(scores - m_new[:, None])
        l_new = l_run * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + jnp.dot(
            p, vj, preferred_element_type=jnp.float32
        )
        return acc, m_new, l_new

    acc0 = jnp.zeros((s, dh), jnp.float32)
    m0 = jnp.full((s,), _NEG, jnp.float32)
    l0 = jnp.zeros((s,), jnp.float32)
    acc, _, l_run = jax.lax.fori_loop(0, n_blocks, body, (acc0, m0, l0))
    o_ref[0] = (acc / l_run[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_k",))
def attention_pallas(q, k, v, mask, block_k: int = DEFAULT_BLOCK_K):
    """Batched multi-head attention.

    Args:
      q, k, v: [bh, s, dh]  (batch*heads already folded)
      mask:    [bh, s]      1.0 = valid key position.
    Returns [bh, s, dh].
    """
    bh, s, dh = q.shape
    block_k = min(block_k, s)
    assert s % block_k == 0, "seq len must divide the K block"
    kern = functools.partial(_attn_kernel, block_k=block_k)
    return pl.pallas_call(
        kern,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        interpret=True,
    )(q, k, v, mask)
