//! Parameter-bank plumbing: named tensors, signature-driven packing,
//! split/merge between training and serving layouts, and task-side
//! initializers (σ-sweepable for the Fig. 6 init ablation).

pub mod init;
pub mod params;
