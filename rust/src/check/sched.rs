//! Deterministic cooperative scheduler for model-checking the hand-rolled
//! sync primitives.
//!
//! ## Execution model
//!
//! A model-check *execution* runs a test body plus every thread it spawns
//! through [`spawn`] under a token-passing scheduler: exactly one
//! registered thread runs at a time, and control changes hands only at
//! *yield points* — every operation on a [`crate::check::sync::shim`]
//! primitive (mutex lock/unlock, condvar wait/notify, every atomic op)
//! plus explicit [`yield_now`] calls. At each yield point with more than
//! one runnable thread the scheduler consults its [`Schedule`] to pick who
//! runs next; the sequence of picks *is* the interleaving, so
//!
//! * replaying the same schedule replays the same interleaving exactly,
//! * enumerating schedules enumerates interleavings.
//!
//! The [`Explorer`] does the enumeration: bounded-exhaustive DFS over the
//! choice tree for small op counts, falling back to seeded-random search
//! when the tree outgrows the budget. Any failure (assertion panic in the
//! body, deadlock, lock-order violation) aborts exploration with a panic
//! whose message carries a replay token (`path:…` for DFS schedules,
//! `seed:…` for random ones); re-running with
//! `ADAPTERBERT_MC_REPLAY=<token>` or [`Opts::replay`] reproduces it.
//!
//! ## Blocking and deadlock
//!
//! A thread that model-blocks (mutex held by someone else, condvar wait,
//! join on a live thread) is parked and removed from the runnable set.
//! When the runnable set goes empty while parked threads remain, the
//! scheduler reports a deadlock with the full waits-for table — this is
//! also how *lost wakeups* surface: a waiter nobody will ever notify is a
//! deadlock of one.
//!
//! ## What is and is not explored
//!
//! Interleavings are explored at shim-operation granularity under
//! sequentially-consistent semantics (like `loom`'s coarse mode): plain
//! (non-shim) memory operations between two yield points execute
//! atomically with respect to other threads, and weak-memory reorderings
//! are not modeled. That is the right level for the invariants checked
//! here (single-flight, ring torn-freedom, handoff, state machines),
//! which are all about operation interleavings, not fence placement.
//!
//! ## Degraded (stress) mode
//!
//! Without the `modelcheck` feature the production modules compile
//! against the raw `std::sync` types, so their internals present no yield
//! points and cannot be scheduled cooperatively. [`Explorer::explore`]
//! then degrades to seeded stress iterations: the body still runs, its
//! threads really race, and its invariant assertions still hold — it is
//! just a probabilistic scheduler instead of a controlled one. Suites
//! assert schedule counts only under the feature.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex};

/// Thread id inside one execution; the body's thread is always 0.
pub type Tid = usize;

/// Default per-execution yield-point budget; a schedule that exceeds it
/// is truncated (counted, not failed) so spin loops cannot hang DFS.
pub const DEFAULT_MAX_STEPS: u64 = 20_000;

// ---------------------------------------------------------------------------
// Schedules
// ---------------------------------------------------------------------------

/// One replayable interleaving: either a seed for the xorshift chooser or
/// an explicit DFS choice path.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Schedule {
    /// Seeded-random choices (`seed:<hex>` token).
    Random(u64),
    /// Explicit branch choices at each multi-option yield point
    /// (`path:<c0>.<c1>…` token); choices past the end default to 0.
    Path(Vec<u32>),
}

impl Schedule {
    /// Wire form for panic messages and `ADAPTERBERT_MC_REPLAY`.
    pub fn token(&self) -> String {
        match self {
            Schedule::Random(seed) => format!("seed:{seed:x}"),
            Schedule::Path(p) => {
                let parts: Vec<String> = p.iter().map(|c| c.to_string()).collect();
                format!("path:{}", parts.join("."))
            }
        }
    }

    /// Parse a [`Schedule::token`] back; `None` on malformed input.
    pub fn parse(tok: &str) -> Option<Schedule> {
        if let Some(hex) = tok.strip_prefix("seed:") {
            return u64::from_str_radix(hex.trim(), 16).ok().map(Schedule::Random);
        }
        if let Some(path) = tok.strip_prefix("path:") {
            let path = path.trim();
            if path.is_empty() {
                return Some(Schedule::Path(Vec::new()));
            }
            let mut out = Vec::new();
            for part in path.split('.') {
                out.push(part.parse::<u32>().ok()?);
            }
            return Some(Schedule::Path(out));
        }
        None
    }
}

fn xorshift(state: &mut u64) -> u64 {
    // xorshift64*: tiny, deterministic, plenty for schedule choice
    let mut x = *state;
    x ^= x << 13;
    x ^= x >> 7;
    x ^= x << 17;
    *state = x;
    x.wrapping_mul(0x2545_f491_4f6c_dd1d)
}

// ---------------------------------------------------------------------------
// Scheduler core
// ---------------------------------------------------------------------------

#[derive(Clone, Debug, PartialEq, Eq)]
enum TState {
    /// May be granted the token.
    Runnable,
    /// Parked until the lock frees (waits-for edge: thread → lock).
    Lock(usize),
    /// Parked on a condvar until notified.
    Cond(usize),
    /// Parked until the target thread finishes.
    Join(Tid),
    Finished,
}

struct Core {
    states: Vec<TState>,
    granted: Vec<bool>,
    /// Exclusive lock id → owning thread.
    lock_owner: BTreeMap<usize, Tid>,
    /// Shared (read) holders per rwlock id.
    read_holders: BTreeMap<usize, Vec<Tid>>,
    /// Condvar id → (waiting thread, mutex id to reacquire on wake).
    cv_waiters: BTreeMap<usize, Vec<(Tid, usize)>>,
    /// Human-readable names for ids, for deadlock reports.
    names: BTreeMap<usize, String>,
    schedule: Schedule,
    rng: u64,
    /// `(chosen, n_options)` at every multi-option yield point.
    trace: Vec<(u32, u32)>,
    steps: u64,
    max_steps: u64,
    truncated: bool,
    /// Once set, every shim op falls through to plain `std` behavior and
    /// every parked thread is released, so the execution drains freely.
    abort: bool,
    failure: Option<String>,
}

impl Core {
    fn choose(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        if n == 1 {
            return 0;
        }
        let c = match &self.schedule {
            Schedule::Random(_) => (xorshift(&mut self.rng) % n as u64) as u32,
            Schedule::Path(p) => {
                let i = self.trace.len();
                let c = p.get(i).copied().unwrap_or(0);
                c.min(n as u32 - 1)
            }
        };
        self.trace.push((c, n as u32));
        c as usize
    }

    fn runnable(&self) -> Vec<Tid> {
        (0..self.states.len())
            .filter(|&t| self.states[t] == TState::Runnable)
            .collect()
    }

    fn all_finished(&self) -> bool {
        self.states.iter().all(|s| *s == TState::Finished)
    }

    fn name_of(&self, id: usize) -> String {
        self.names
            .get(&id)
            .cloned()
            .unwrap_or_else(|| format!("{id:#x}"))
    }

    /// Describe every parked thread — the waits-for table of a deadlock.
    fn waits_for_report(&self) -> String {
        let mut lines = Vec::new();
        for (t, s) in self.states.iter().enumerate() {
            match s {
                TState::Lock(l) => {
                    let holder = match self.lock_owner.get(l) {
                        Some(o) => format!("held by thread {o}"),
                        None => match self.read_holders.get(l) {
                            Some(rs) if !rs.is_empty() => {
                                format!("read-held by threads {rs:?}")
                            }
                            _ => "free".to_string(),
                        },
                    };
                    lines.push(format!(
                        "  thread {t} waits for lock {} ({holder})",
                        self.name_of(*l)
                    ));
                }
                TState::Cond(c) => lines.push(format!(
                    "  thread {t} waits on condvar {} (never notified)",
                    self.name_of(*c)
                )),
                TState::Join(j) => {
                    lines.push(format!("  thread {t} joins thread {j}"))
                }
                _ => {}
            }
        }
        lines.join("\n")
    }

    /// Pick the next thread to grant the token to. Returns `false` when
    /// nobody is runnable (deadlock or normal completion).
    fn grant_next(&mut self) -> bool {
        if self.abort {
            return false;
        }
        self.steps += 1;
        if self.steps > self.max_steps {
            self.truncated = true;
            self.abort = true;
            return false;
        }
        let runnable = self.runnable();
        if runnable.is_empty() {
            if !self.all_finished() {
                let parked = self
                    .states
                    .iter()
                    .any(|s| matches!(s, TState::Lock(_) | TState::Cond(_) | TState::Join(_)));
                if parked && self.failure.is_none() {
                    self.failure = Some(format!(
                        "deadlock: no runnable thread\n{}",
                        self.waits_for_report()
                    ));
                }
                self.abort = true;
            }
            return false;
        }
        let k = self.choose(runnable.len());
        self.granted[runnable[k]] = true;
        true
    }
}

/// One execution's scheduler. Shared (via `Arc`) by every thread the
/// execution spawns; the scheduler itself synchronizes with raw `std`
/// primitives — it is the thing *under* the model, not in it.
pub struct Sched {
    core: Mutex<Core>,
    cv: Condvar,
}

thread_local! {
    static CURRENT: RefCell<Option<(Arc<Sched>, Tid)>> = const { RefCell::new(None) };
}

/// The scheduler + tid the current thread is registered with, if any.
pub fn current() -> Option<(Arc<Sched>, Tid)> {
    CURRENT.with(|c| c.borrow().clone())
}

/// Is the current thread inside a live (non-aborted) controlled
/// execution? Shim primitives use this to decide controlled vs
/// pass-through behavior on every operation.
pub fn controlled() -> Option<(Arc<Sched>, Tid)> {
    let (s, t) = current()?;
    if s.aborted() {
        None
    } else {
        Some((s, t))
    }
}

impl Sched {
    fn new(schedule: Schedule, max_steps: u64) -> Sched {
        let rng = match schedule {
            Schedule::Random(seed) => seed | 1,
            Schedule::Path(_) => 1,
        };
        Sched {
            core: Mutex::new(Core {
                states: vec![TState::Runnable],
                granted: vec![true],
                lock_owner: BTreeMap::new(),
                read_holders: BTreeMap::new(),
                cv_waiters: BTreeMap::new(),
                names: BTreeMap::new(),
                schedule,
                rng,
                trace: Vec::new(),
                steps: 0,
                max_steps,
                truncated: false,
                abort: false,
                failure: None,
            }),
            cv: Condvar::new(),
        }
    }

    fn lock_core(&self) -> std::sync::MutexGuard<'_, Core> {
        match self.core.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        }
    }

    pub fn aborted(&self) -> bool {
        self.lock_core().abort
    }

    /// Register a human-readable name for a lock/condvar id (deadlock
    /// reports only).
    pub fn name_resource(&self, id: usize, name: &str) {
        self.lock_core().names.insert(id, name.to_string());
    }

    /// Record a failure and release every thread into pass-through mode.
    pub fn fail(&self, msg: String) {
        let mut core = self.lock_core();
        if core.failure.is_none() {
            core.failure = Some(msg);
        }
        core.abort = true;
        drop(core);
        self.cv.notify_all();
    }

    /// Park until granted the token (or the execution aborts).
    fn wait_granted(&self, tid: Tid) {
        let mut core = self.lock_core();
        while !core.granted[tid] && !core.abort {
            core = match self.cv.wait(core) {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
        }
    }

    /// A plain yield point: hand the token to whoever the schedule picks
    /// (possibly back to the caller).
    pub fn yield_point(&self, tid: Tid) {
        let mut core = self.lock_core();
        if core.abort {
            return;
        }
        core.granted[tid] = false;
        core.grant_next();
        drop(core);
        self.cv.notify_all();
        self.wait_granted(tid);
    }

    /// Register a child thread (spawner keeps the token).
    fn register_child(&self) -> Tid {
        let mut core = self.lock_core();
        core.states.push(TState::Runnable);
        core.granted.push(false);
        core.states.len() - 1
    }

    /// Mark the current thread finished and pass the token on.
    fn finish(&self, tid: Tid) {
        let mut core = self.lock_core();
        core.states[tid] = TState::Finished;
        core.granted[tid] = false;
        // wake joiners
        for t in 0..core.states.len() {
            if core.states[t] == TState::Join(tid) {
                core.states[t] = TState::Runnable;
            }
        }
        core.grant_next();
        drop(core);
        self.cv.notify_all();
    }

    /// Model-join: park until `target` finishes.
    fn join_wait(&self, tid: Tid, target: Tid) {
        let mut core = self.lock_core();
        if core.abort || core.states[target] == TState::Finished {
            return;
        }
        core.states[tid] = TState::Join(target);
        core.granted[tid] = false;
        core.grant_next();
        drop(core);
        self.cv.notify_all();
        self.wait_granted(tid);
    }

    /// Handle an observed abort inside a blocking op. A *failure* abort
    /// (deadlock, body panic) unwinds the thread immediately — falling
    /// through to real blocking could reproduce the detected deadlock on
    /// the OS primitives and hang the harness. A truncation abort (step
    /// budget, no failure) returns normally so threads drain in
    /// pass-through mode.
    fn on_abort(&self, failed: bool) {
        if failed {
            panic!("model-check execution aborted after failure");
        }
    }

    /// Model-acquire an exclusive lock. Returns `false` when the
    /// execution aborted mid-acquire and the caller must fall through to
    /// the real primitive.
    pub fn acquire(&self, tid: Tid, lock: usize) -> bool {
        loop {
            self.yield_point(tid);
            let mut core = self.lock_core();
            if core.abort {
                let failed = core.failure.is_some();
                drop(core);
                self.on_abort(failed);
                return false;
            }
            let read_held = core
                .read_holders
                .get(&lock)
                .map(|v| !v.is_empty())
                .unwrap_or(false);
            if !core.lock_owner.contains_key(&lock) && !read_held {
                core.lock_owner.insert(lock, tid);
                return true;
            }
            if core.lock_owner.get(&lock) == Some(&tid) {
                // re-entrant model-acquire would self-deadlock; report it
                // rather than hang the exploration
                drop(core);
                self.fail(format!(
                    "thread {tid} re-acquired lock it already holds (self-deadlock)"
                ));
                return false;
            }
            core.states[tid] = TState::Lock(lock);
            core.granted[tid] = false;
            core.grant_next();
            drop(core);
            self.cv.notify_all();
            self.wait_granted(tid);
        }
    }

    /// Model-release an exclusive lock; lock waiters become runnable and
    /// re-compete under the schedule's choices.
    pub fn release(&self, tid: Tid, lock: usize) {
        let mut core = self.lock_core();
        if core.lock_owner.get(&lock) == Some(&tid) {
            core.lock_owner.remove(&lock);
        }
        for t in 0..core.states.len() {
            if core.states[t] == TState::Lock(lock) {
                core.states[t] = TState::Runnable;
            }
        }
        drop(core);
        self.cv.notify_all();
        if !self.aborted() {
            self.yield_point(tid);
        }
    }

    /// Model-acquire a read (shared) side of an rwlock.
    pub fn acquire_shared(&self, tid: Tid, lock: usize) -> bool {
        loop {
            self.yield_point(tid);
            let mut core = self.lock_core();
            if core.abort {
                let failed = core.failure.is_some();
                drop(core);
                self.on_abort(failed);
                return false;
            }
            if !core.lock_owner.contains_key(&lock) {
                core.read_holders.entry(lock).or_default().push(tid);
                return true;
            }
            core.states[tid] = TState::Lock(lock);
            core.granted[tid] = false;
            core.grant_next();
            drop(core);
            self.cv.notify_all();
            self.wait_granted(tid);
        }
    }

    /// Release a read hold; writer waiters become runnable.
    pub fn release_shared(&self, tid: Tid, lock: usize) {
        let mut core = self.lock_core();
        if let Some(rs) = core.read_holders.get_mut(&lock) {
            if let Some(pos) = rs.iter().position(|&t| t == tid) {
                rs.swap_remove(pos);
            }
        }
        for t in 0..core.states.len() {
            if core.states[t] == TState::Lock(lock) {
                core.states[t] = TState::Runnable;
            }
        }
        drop(core);
        self.cv.notify_all();
        if !self.aborted() {
            self.yield_point(tid);
        }
    }

    /// Model condvar wait: atomically release `lock` and park on `cv`;
    /// after a notify, re-acquire `lock` before returning. Returns
    /// `false` on abort (the caller re-locks for real and treats the
    /// return as a spurious wakeup).
    pub fn cv_wait(&self, tid: Tid, cv_id: usize, lock: usize) -> bool {
        {
            let mut core = self.lock_core();
            if core.abort {
                return false;
            }
            if core.lock_owner.get(&lock) == Some(&tid) {
                core.lock_owner.remove(&lock);
            }
            for t in 0..core.states.len() {
                if core.states[t] == TState::Lock(lock) {
                    core.states[t] = TState::Runnable;
                }
            }
            core.cv_waiters.entry(cv_id).or_default().push((tid, lock));
            core.states[tid] = TState::Cond(cv_id);
            core.granted[tid] = false;
            core.grant_next();
            drop(core);
            self.cv.notify_all();
        }
        self.wait_granted(tid);
        {
            let core = self.lock_core();
            if core.abort {
                let failed = core.failure.is_some();
                drop(core);
                self.on_abort(failed);
                return false;
            }
        }
        // notified: compete for the mutex again
        self.acquire(tid, lock)
    }

    /// Model notify: wake one waiter (schedule-chosen) or all of them.
    pub fn cv_notify(&self, tid: Tid, cv_id: usize, all: bool) {
        let mut core = self.lock_core();
        if core.abort {
            return;
        }
        if let Some(waiters) = core.cv_waiters.get_mut(&cv_id) {
            if !waiters.is_empty() {
                if all {
                    let woken: Vec<(Tid, usize)> = waiters.drain(..).collect();
                    for (t, _) in woken {
                        core.states[t] = TState::Runnable;
                    }
                } else {
                    let n = waiters.len();
                    let k = core.choose(n);
                    let (t, _) = core
                        .cv_waiters
                        .get_mut(&cv_id)
                        .map(|w| w.swap_remove(k))
                        .unwrap_or((tid, 0));
                    core.states[t] = TState::Runnable;
                }
            }
        }
        drop(core);
        self.cv.notify_all();
        if !self.aborted() {
            self.yield_point(tid);
        }
    }
}

// ---------------------------------------------------------------------------
// Controlled thread spawn/join
// ---------------------------------------------------------------------------

/// Join handle for [`spawn`]: a real `std` handle plus, in controlled
/// mode, the model tid so `join` parks in the model first.
pub struct JoinHandle<T> {
    inner: std::thread::JoinHandle<T>,
    model: Option<(Arc<Sched>, Tid)>,
}

impl<T> JoinHandle<T> {
    pub fn join(self) -> std::thread::Result<T> {
        if let Some((sched, child)) = &self.model {
            if let Some((cur_sched, tid)) = controlled() {
                if Arc::ptr_eq(sched, &cur_sched) {
                    cur_sched.join_wait(tid, *child);
                }
            }
        }
        self.inner.join()
    }

    pub fn is_finished(&self) -> bool {
        self.inner.is_finished()
    }
}

/// Spawn a thread that participates in the current controlled execution
/// (if any); outside an execution this is a plain `std::thread::spawn`.
pub fn spawn<F, T>(f: F) -> JoinHandle<T>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    match spawn_named("mc-worker", f) {
        Ok(h) => h,
        Err(e) => panic!("model-check spawn failed: {e}"),
    }
}

/// [`spawn`] with a thread name (the `thread::Builder` path the worker
/// pool uses).
pub fn spawn_named<F, T>(name: &str, f: F) -> std::io::Result<JoinHandle<T>>
where
    F: FnOnce() -> T + Send + 'static,
    T: Send + 'static,
{
    let builder = std::thread::Builder::new().name(name.to_string());
    match controlled() {
        None => {
            let inner = builder.spawn(f)?;
            Ok(JoinHandle { inner, model: None })
        }
        Some((sched, _parent)) => {
            let child = sched.register_child();
            let sched_t = Arc::clone(&sched);
            let inner = builder.spawn(move || {
                CURRENT.with(|c| {
                    *c.borrow_mut() = Some((Arc::clone(&sched_t), child));
                });
                sched_t.wait_granted(child);
                let r = catch_unwind(AssertUnwindSafe(f));
                if let Err(payload) = &r {
                    sched_t.fail(format!(
                        "thread {child} panicked: {}",
                        panic_message(payload)
                    ));
                }
                sched_t.finish(child);
                CURRENT.with(|c| c.borrow_mut().take());
                match r {
                    Ok(v) => v,
                    Err(payload) => resume_unwind(payload),
                }
            })?;
            Ok(JoinHandle { inner, model: Some((sched, child)) })
        }
    }
}

/// Scheduler-aware yield: a choice point in controlled mode, a plain
/// `std::thread::yield_now` otherwise.
pub fn yield_now() {
    match controlled() {
        Some((sched, tid)) => sched.yield_point(tid),
        None => std::thread::yield_now(),
    }
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

// ---------------------------------------------------------------------------
// Explorer
// ---------------------------------------------------------------------------

/// Exploration options. `Default` gives 1 024 schedules, DFS-first, seed
/// `0xADA97`, step budget [`DEFAULT_MAX_STEPS`].
#[derive(Clone, Debug)]
pub struct Opts {
    /// Total schedule budget (DFS + random combined).
    pub schedules: usize,
    /// Try bounded-exhaustive DFS before seeded-random search.
    pub exhaustive: bool,
    /// Base seed for the random phase (schedule `i` uses `seed + i`).
    pub seed: u64,
    /// Yield-point budget per execution; exceeding it truncates.
    pub max_steps: u64,
    /// Iteration cap in degraded stress mode (no controlled scheduler).
    pub stress_iters: usize,
    /// Run exactly this schedule instead of exploring.
    pub replay: Option<Schedule>,
    /// Force controlled mode even without the `modelcheck` feature. Only
    /// valid for bodies whose *every* shared access goes through the shim
    /// types explicitly (the scheduler self-tests).
    pub force_controlled: bool,
}

impl Default for Opts {
    fn default() -> Opts {
        Opts {
            schedules: 1024,
            exhaustive: true,
            seed: 0xADA97,
            max_steps: DEFAULT_MAX_STEPS,
            stress_iters: 200,
            replay: None,
            force_controlled: false,
        }
    }
}

impl Opts {
    /// Replay one schedule from its failure token.
    pub fn replay(tok: &str) -> Opts {
        Opts {
            replay: Schedule::parse(tok),
            ..Opts::default()
        }
    }
}

/// What an exploration did. Failures do not appear here: the explorer
/// panics on the first one, with the replay token in the message.
#[derive(Clone, Debug)]
pub struct Report {
    /// Executions actually run.
    pub explored: usize,
    /// Executions cut short by the step budget.
    pub truncated: usize,
    /// DFS proved the space exhausted within the budget.
    pub exhausted: bool,
    /// Ran under the controlled scheduler (vs stress mode).
    pub controlled: bool,
}

struct ExecOutcome {
    trace: Vec<(u32, u32)>,
    truncated: bool,
    failure: Option<String>,
}

/// Run `body` once under `schedule`, fully controlled.
fn run_one(schedule: Schedule, max_steps: u64, body: &(dyn Fn() + Sync)) -> ExecOutcome {
    let sched = Arc::new(Sched::new(schedule, max_steps));
    CURRENT.with(|c| {
        *c.borrow_mut() = Some((Arc::clone(&sched), 0));
    });
    let r = catch_unwind(AssertUnwindSafe(body));
    if let Err(payload) = &r {
        sched.fail(format!("body panicked: {}", panic_message(payload)));
    }
    // drain: children the body leaked keep scheduling until done; a child
    // parked forever is a deadlock and fails the schedule
    loop {
        let mut core = sched.lock_core();
        core.states[0] = TState::Finished;
        core.granted[0] = false;
        let others_done = core
            .states
            .iter()
            .enumerate()
            .all(|(t, s)| t == 0 || *s == TState::Finished);
        if others_done || core.abort {
            break;
        }
        core.grant_next();
        let done = core.abort
            || core
                .states
                .iter()
                .enumerate()
                .all(|(t, s)| t == 0 || *s == TState::Finished);
        drop(core);
        sched.cv.notify_all();
        if done {
            break;
        }
        // children are running; wait for the state to move
        std::thread::yield_now();
    }
    // release anything still parked so OS threads can exit
    {
        let mut core = sched.lock_core();
        core.abort = true;
        drop(core);
        sched.cv.notify_all();
    }
    CURRENT.with(|c| c.borrow_mut().take());
    let core = sched.lock_core();
    ExecOutcome {
        trace: core.trace.clone(),
        truncated: core.truncated,
        failure: core.failure.clone(),
    }
}

/// Next DFS path after a run whose trace was `trace`: deepest choice with
/// an untried sibling, bumped; `None` when the tree is exhausted.
fn next_path(trace: &[(u32, u32)]) -> Option<Vec<u32>> {
    for i in (0..trace.len()).rev() {
        let (chosen, n) = trace[i];
        if chosen + 1 < n {
            let mut p: Vec<u32> = trace[..i].iter().map(|&(c, _)| c).collect();
            p.push(chosen + 1);
            return Some(p);
        }
    }
    None
}

/// Explore interleavings of `body` and panic (with a replay token) on the
/// first failing schedule. See the module docs for the exploration
/// strategy; returns what was covered.
pub fn explore(opts: Opts, body: impl Fn() + Sync) -> Report {
    let controlled_mode =
        opts.force_controlled || cfg!(feature = "modelcheck");
    // env replay wins over everything (the printed reproduction recipe)
    let replay = std::env::var("ADAPTERBERT_MC_REPLAY")
        .ok()
        .and_then(|s| Schedule::parse(&s))
        .or_else(|| opts.replay.clone());

    if !controlled_mode {
        let iters = opts.schedules.min(opts.stress_iters).max(1);
        for _ in 0..iters {
            body();
        }
        return Report {
            explored: iters,
            truncated: 0,
            exhausted: false,
            controlled: false,
        };
    }

    if let Some(schedule) = replay {
        let out = run_one(schedule.clone(), opts.max_steps, &body);
        if let Some(msg) = out.failure {
            panic!(
                "model check failed (replay {}): {msg}",
                schedule.token()
            );
        }
        return Report {
            explored: 1,
            truncated: if out.truncated { 1 } else { 0 },
            exhausted: false,
            controlled: true,
        };
    }

    let mut explored = 0usize;
    let mut truncated = 0usize;
    let mut exhausted = false;

    if opts.exhaustive {
        let mut path: Vec<u32> = Vec::new();
        loop {
            if explored >= opts.schedules {
                break;
            }
            let schedule = Schedule::Path(path.clone());
            let out = run_one(schedule.clone(), opts.max_steps, &body);
            explored += 1;
            if out.truncated {
                truncated += 1;
            }
            if let Some(msg) = out.failure {
                panic!(
                    "model check failed under schedule {tok}: {msg}\n\
                     replay with ADAPTERBERT_MC_REPLAY={tok}",
                    tok = schedule.token()
                );
            }
            match next_path(&out.trace) {
                Some(p) => path = p,
                None => {
                    exhausted = true;
                    break;
                }
            }
        }
    }

    if !exhausted {
        while explored < opts.schedules {
            let seed = opts.seed.wrapping_add(explored as u64);
            let schedule = Schedule::Random(seed);
            let out = run_one(schedule.clone(), opts.max_steps, &body);
            explored += 1;
            if out.truncated {
                truncated += 1;
            }
            if let Some(msg) = out.failure {
                panic!(
                    "model check failed under schedule {tok}: {msg}\n\
                     replay with ADAPTERBERT_MC_REPLAY={tok}",
                    tok = schedule.token()
                );
            }
        }
    }

    Report {
        explored,
        truncated,
        exhausted,
        controlled: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn schedule_tokens_round_trip() {
        for s in [
            Schedule::Random(0xdeadbeef),
            Schedule::Path(vec![]),
            Schedule::Path(vec![0, 2, 1, 0]),
        ] {
            assert_eq!(Schedule::parse(&s.token()), Some(s));
        }
        assert_eq!(Schedule::parse("garbage"), None);
        assert_eq!(Schedule::parse("path:1.x"), None);
    }

    #[test]
    fn next_path_walks_the_tree() {
        // trace: two binary choice points, both took 0
        assert_eq!(next_path(&[(0, 2), (0, 2)]), Some(vec![0, 1]));
        assert_eq!(next_path(&[(0, 2), (1, 2)]), Some(vec![1]));
        assert_eq!(next_path(&[(1, 2), (1, 2)]), None);
        assert_eq!(next_path(&[]), None);
    }

    #[test]
    fn controlled_execution_runs_spawned_threads_to_completion() {
        let report = explore(
            Opts {
                schedules: 16,
                force_controlled: true,
                ..Opts::default()
            },
            || {
                let h = spawn(|| 21usize * 2);
                let v = match h.join() {
                    Ok(v) => v,
                    Err(_) => panic!("child failed"),
                };
                assert_eq!(v, 42);
            },
        );
        assert!(report.controlled);
        assert!(report.explored >= 1);
    }
}
