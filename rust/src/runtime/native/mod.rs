//! Native execution backend: pure-Rust kernels, no PJRT plugin required.
//!
//! `kernels` holds the numeric primitives (mirroring
//! `python/compile/kernels/ref.py`) and `graph` evaluates whole manifest
//! executables — forward passes for serving/eval and full train steps
//! (forward + hand-derived backward + Adam) for tuning. "Compilation" is
//! trivial: the interpreter dispatches on the executable's manifest
//! metadata, so no artifacts beyond `manifest.json` are needed, and for the
//! built-in presets even that can be synthesized (see
//! [`crate::runtime::synth`]).
//!
//! Uploaded banks are plain host tensors ([`HostBank`]); `upload_bank` is a
//! cheap clone kept for API parity with the PJRT backend so the serving
//! layer's bank-caching pattern is backend-agnostic.
//!
//! Throughput comes from three pieces (see ARCHITECTURE.md §Native
//! performance): `pool` (a persistent std-only worker pool sized by
//! `ADAPTERBERT_THREADS`), the blocked panel-packed GEMM and fused
//! elementwise kernels in `kernels`, and `workspace` (a per-thread
//! scratch-buffer arena so steady-state execution allocates nothing per
//! op). `bench kernels` pins the resulting speedups in
//! `BENCH_kernels.json`.

pub mod graph;
pub mod kernels;
pub mod pool;
pub mod workspace;

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use super::backend::{ArgTensor, Backend, BackendExec, Bank, BankStorage};
use super::fused::{FusedBackend, FusedSegment, RowOutput};
use super::manifest::{ExeSpec, Manifest, ModelDims};
use crate::util::tensor::{DType, Tensor};

/// The pure-Rust execution backend.
pub struct NativeBackend {
    dims: ModelDims,
}

impl NativeBackend {
    /// Build a backend for the manifest's architecture dims.
    pub fn new(manifest: &Manifest) -> NativeBackend {
        NativeBackend { dims: manifest.dims.clone() }
    }
}

impl Backend for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn compile(
        &self,
        _manifest: &Manifest,
        spec: &ExeSpec,
    ) -> Result<Box<dyn BackendExec>> {
        // validate the dispatch up front so unsupported graphs fail at
        // load time (like an XLA compile error would), not mid-training
        match (spec.kind.as_str(), spec.variant.as_str()) {
            ("mlm", "pretrain")
            | ("embed", "fwd")
            | (_, "adapter")
            | (_, "topk")
            | (_, "lnonly")
            | (_, "fwd_adapter")
            | (_, "fwd_base") => {}
            (kind, variant) => bail!(
                "native backend cannot evaluate {} (kind {kind:?}, variant {variant:?})",
                spec.name
            ),
        }
        Ok(Box::new(NativeExec { dims: self.dims.clone() }))
    }

    fn upload_bank(&self, bank: &Bank) -> Result<Box<dyn BankStorage>> {
        let shapes = bank.iter().map(|t| (t.shape.clone(), t.dtype())).collect();
        Ok(Box::new(HostBank { tensors: bank.clone(), shapes }))
    }

    fn fused(&self) -> Option<&dyn FusedBackend> {
        Some(self)
    }
}

impl FusedBackend for NativeBackend {
    fn fused_forward(
        &self,
        base: &BTreeMap<String, Tensor>,
        segments: &[FusedSegment],
        tokens: &[i32],
        type_ids: &[i32],
        mask: &[f32],
    ) -> Result<Vec<RowOutput>> {
        graph::run_fused(&self.dims, base, segments, tokens, type_ids, mask)
    }
}

/// A "device" bank for the native backend: host tensors held for reuse.
pub struct HostBank {
    tensors: Vec<Tensor>,
    shapes: Vec<(Vec<usize>, DType)>,
}

impl BankStorage for HostBank {
    fn shapes(&self) -> &[(Vec<usize>, DType)] {
        &self.shapes
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }
}

struct NativeExec {
    dims: ModelDims,
}

impl BackendExec for NativeExec {
    fn execute(&self, spec: &ExeSpec, args: &[ArgTensor<'_>]) -> Result<Vec<Tensor>> {
        let flat: Vec<&Tensor> = args
            .iter()
            .map(|arg| match arg {
                ArgTensor::Host(t) => Ok(*t),
                ArgTensor::Stored { bank, index } => {
                    let hb = bank
                        .as_any()
                        .downcast_ref::<HostBank>()
                        .with_context(|| {
                            format!(
                                "{}: device bank was not uploaded via the native backend",
                                spec.name
                            )
                        })?;
                    hb.tensors.get(*index).with_context(|| {
                        format!("{}: bank slot {index} out of range", spec.name)
                    })
                }
            })
            .collect::<Result<_>>()?;
        graph::run(&self.dims, spec, &flat)
    }
}
