//! Kernel-stage profiling hooks (`--features profile`).
//!
//! When the `profile` feature is **off** (the default), every item here
//! is a unit struct or an empty `#[inline]` function — call sites in the
//! kernels compile to nothing, so the hot path pays zero cost.
//!
//! When **on**, executor threads accumulate per-stage wall time in a
//! thread-local table:
//!
//! * leaf kernels open a [`scope`] tagged `"gemm"` / `"attention"` /
//!   `"ln"`; the elapsed time lands in that stage's bucket;
//! * semantic regions in the graph (adapter bottlenecks, head decode)
//!   open a [`ctx`] instead: the *whole region* is timed under the
//!   region's label and leaf scopes inside it become no-ops, so a GEMM
//!   inside an adapter counts as `adapter`, not twice.
//!
//! Kernels measure on the calling thread: the worker pool's
//! `parallel_for` blocks the caller until the range drains, so
//! caller-side timing captures the full wall time of the parallel
//! region without instrumenting pool workers.
//!
//! The executor wraps each batch in [`start_batch`]/[`take_batch`] and
//! attaches the table to the batch's trace spans as `<stage>_s` metadata
//! (see `coordinator::server`), which `GET /trace` and `bench profile`
//! surface.

#[cfg(feature = "profile")]
mod imp {
    use std::cell::RefCell;
    use std::collections::BTreeMap;
    use std::time::Instant;

    thread_local! {
        static STATE: RefCell<State> = RefCell::new(State::default());
    }

    #[derive(Default)]
    struct State {
        ctx_depth: usize,
        totals: BTreeMap<&'static str, f64>,
    }

    /// Times a leaf kernel; no-op while a [`ctx`] region is open.
    pub struct Scope {
        label: &'static str,
        start: Option<Instant>,
    }

    #[inline]
    pub fn scope(label: &'static str) -> Scope {
        let active = STATE.with(|s| s.borrow().ctx_depth == 0);
        Scope { label, start: active.then(Instant::now) }
    }

    impl Drop for Scope {
        fn drop(&mut self) {
            if let Some(t0) = self.start {
                let dt = t0.elapsed().as_secs_f64();
                STATE.with(|s| {
                    *s.borrow_mut().totals.entry(self.label).or_insert(0.0) += dt;
                });
            }
        }
    }

    /// Times a semantic region and suppresses leaf scopes inside it.
    /// Nested regions: the outermost wins (inner `ctx` only bumps the
    /// suppression depth).
    pub struct Ctx {
        label: &'static str,
        start: Option<Instant>,
    }

    #[inline]
    pub fn ctx(label: &'static str) -> Ctx {
        let outermost = STATE.with(|s| {
            let mut s = s.borrow_mut();
            s.ctx_depth += 1;
            s.ctx_depth == 1
        });
        Ctx { label, start: outermost.then(Instant::now) }
    }

    impl Drop for Ctx {
        fn drop(&mut self) {
            STATE.with(|s| {
                let mut s = s.borrow_mut();
                s.ctx_depth -= 1;
                if let Some(t0) = self.start {
                    *s.totals.entry(self.label).or_insert(0.0) += t0.elapsed().as_secs_f64();
                }
            });
        }
    }

    /// Reset this thread's stage table (executor, once per batch).
    pub fn start_batch() {
        STATE.with(|s| s.borrow_mut().totals.clear());
    }

    /// Drain this thread's stage table as `(<stage>_s, seconds)` pairs.
    pub fn take_batch() -> Vec<(String, f64)> {
        STATE.with(|s| {
            s.borrow_mut()
                .totals
                .split_off("")
                .into_iter()
                .map(|(k, v)| (format!("{k}_s"), v))
                .collect()
        })
    }

    pub const ENABLED: bool = true;
}

#[cfg(not(feature = "profile"))]
mod imp {
    /// Unit guard; constructing and dropping it is a no-op.
    pub struct Scope;

    #[inline(always)]
    pub fn scope(_label: &'static str) -> Scope {
        Scope
    }

    /// Unit guard; constructing and dropping it is a no-op.
    pub struct Ctx;

    #[inline(always)]
    pub fn ctx(_label: &'static str) -> Ctx {
        Ctx
    }

    #[inline(always)]
    pub fn start_batch() {}

    #[inline(always)]
    pub fn take_batch() -> Vec<(String, f64)> {
        Vec::new()
    }

    pub const ENABLED: bool = false;
}

pub use imp::{ctx, scope, start_batch, take_batch, Ctx, Scope, ENABLED};

#[cfg(all(test, feature = "profile"))]
mod tests {
    use super::*;

    #[test]
    fn ctx_suppresses_leaf_scopes() {
        start_batch();
        {
            let _c = ctx("adapter");
            let _s = scope("gemm"); // suppressed
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        {
            let _s = scope("gemm");
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        let t = take_batch();
        let keys: Vec<&str> = t.iter().map(|(k, _)| k.as_str()).collect();
        assert!(keys.contains(&"adapter_s"));
        assert!(keys.contains(&"gemm_s"));
        assert_eq!(keys.len(), 2);
    }
}
