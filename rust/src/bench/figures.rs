//! Figure regenerators: Figs. 1, 3, 4, 5, 6 (left/center/right), 7.
//!
//! Every figure is emitted as a long-format CSV under `results/` with the
//! same series the paper plots; stdout gets a compact preview.

use anyhow::Result;

use super::{trained_params_of_exe, Ctx};
use crate::data::tasks::{self, TaskKind};
use crate::eval::{evaluate, evaluate_with_gates};
use crate::report::{Series, Table};
use crate::train::TrainConfig;
use crate::util::stats;

fn adapter_sizes(ctx: &Ctx) -> Vec<usize> {
    let mut ms: Vec<usize> = ctx
        .rt
        .manifest
        .find("cls", "adapter")
        .iter()
        .filter_map(|e| e.m)
        .collect();
    ms.sort_unstable();
    if ctx.quick {
        ms.retain(|m| [1, 4, 16, 64].contains(m));
    }
    ms
}

fn topk_range(ctx: &Ctx) -> Vec<usize> {
    let mut ks: Vec<usize> = ctx
        .rt
        .manifest
        .find("cls", "topk")
        .iter()
        .filter_map(|e| e.k)
        .collect();
    ks.sort_unstable();
    if ctx.quick {
        ks.retain(|k| [1, 2, 4, 6].contains(k));
    }
    ks
}

/// Figs. 1 & 3 (GLUE panel): normalized accuracy vs trained parameters,
/// 20/50/80th percentiles across tasks, adapters vs top-k fine-tuning.
///
/// For each task: train at every size/k, normalize by the task's full-FT
/// score (paper Fig. 3 caption), then take percentiles across tasks at
/// each x.
pub fn fig1_fig3(ctx: &Ctx) -> Result<()> {
    let task_names: Vec<&str> = if ctx.quick {
        vec!["cola_s", "sst_s", "rte_s", "qnli_s", "mrpc_s"]
    } else {
        vec!["cola_s", "sst_s", "rte_s", "qnli_s", "mrpc_s", "qqp_s", "mnli_s"]
    };
    let ms = adapter_sizes(ctx);
    let ks = topk_range(ctx);
    let full_k = ctx.rt.manifest.dims.n_layers;

    // per (curve point) → normalized deltas across tasks
    let mut adapter_pts: Vec<(usize, Vec<f64>)> =
        ms.iter().map(|_| (0usize, Vec::new())).collect();
    let mut topk_pts: Vec<(usize, Vec<f64>)> =
        ks.iter().map(|_| (0usize, Vec::new())).collect();

    for name in &task_names {
        let spec = tasks::find_spec(name).unwrap();
        let data = ctx.gen(&spec);
        let epochs = ctx.epochs_for(&data);
        println!("[fig3] {name}");
        let ft = ctx.train_once(
            &data,
            &format!("cls_train_topk_k{full_k}"),
            ctx.ft_lr(),
            epochs,
            0,
        )?;
        let ft_score = ft.2;
        for (i, m) in ms.iter().enumerate() {
            let exe = format!("cls_train_adapter_m{m}");
            let (_, _, test) =
                ctx.train_once(&data, &exe, ctx.adapter_lr(), epochs, 0)?;
            adapter_pts[i].0 = trained_params_of_exe(&ctx.rt, &exe);
            adapter_pts[i].1.push(test - ft_score);
        }
        for (i, k) in ks.iter().enumerate() {
            let exe = format!("cls_train_topk_k{k}");
            let (_, _, test) =
                ctx.train_once(&data, &exe, ctx.ft_lr(), epochs, 0)?;
            topk_pts[i].0 = trained_params_of_exe(&ctx.rt, &exe);
            topk_pts[i].1.push(test - ft_score);
        }
    }

    let mut s = Series::new(&["curve", "trained_params", "p20", "p50", "p80"]);
    let mut emit = |label: &str, pts: &[(usize, Vec<f64>)]| {
        for (params, deltas) in pts {
            s.push(vec![
                label.into(),
                params.to_string(),
                format!("{:.4}", stats::percentile(deltas, 20.0)),
                format!("{:.4}", stats::percentile(deltas, 50.0)),
                format!("{:.4}", stats::percentile(deltas, 80.0)),
            ]);
        }
    };
    emit("adapters", &adapter_pts);
    emit("finetune_topk", &topk_pts);
    s.save("fig3_glue_tradeoff")?;
    // stdout preview
    let mut t = Table::new(
        "Fig. 1/3 — GLUE trade-off (normalized vs full FT; median across tasks)",
        &["curve", "trained params", "p50 Δ"],
    );
    for (params, deltas) in &adapter_pts {
        t.row(vec![
            "adapters".into(),
            params.to_string(),
            format!("{:+.3}", stats::percentile(deltas, 50.0)),
        ]);
    }
    for (params, deltas) in &topk_pts {
        t.row(vec![
            "topk FT".into(),
            params.to_string(),
            format!("{:+.3}", stats::percentile(deltas, 50.0)),
        ]);
    }
    t.print();
    Ok(())
}

/// Fig. 3 right panel — the additional-tasks suite trade-off.
pub fn fig3_extra(ctx: &Ctx) -> Result<()> {
    let names: Vec<String> = if ctx.quick {
        // a representative slice of the 17 (diverse sizes/classes)
        ["news20_s", "cf_corporate_s", "cf_warming_s", "cf_prog_opinion_s",
         "sms_spam_s"]
            .iter()
            .map(|s| s.to_string())
            .collect()
    } else {
        tasks::extra_suite().iter().map(|s| s.name.clone()).collect()
    };
    let ms = adapter_sizes(ctx);
    let ks = topk_range(ctx);
    let full_k = ctx.rt.manifest.dims.n_layers;
    let mut s = Series::new(&["curve", "trained_params", "task", "delta"]);
    for name in &names {
        let spec = tasks::find_spec(name).unwrap();
        let data = ctx.gen(&spec);
        let epochs = ctx.epochs_for(&data);
        println!("[fig3-extra] {name}");
        let ft =
            ctx.train_once(&data, &format!("cls_train_topk_k{full_k}"),
                           ctx.ft_lr(), epochs, 0)?;
        for m in &ms {
            let exe = format!("cls_train_adapter_m{m}");
            let (_, _, test) = ctx.train_once(&data, &exe, ctx.adapter_lr(),
                                              epochs, 0)?;
            s.push(vec![
                "adapters".into(),
                trained_params_of_exe(&ctx.rt, &exe).to_string(),
                name.clone(),
                format!("{:.4}", test - ft.2),
            ]);
        }
        for k in &ks {
            let exe = format!("cls_train_topk_k{k}");
            let (_, _, test) =
                ctx.train_once(&data, &exe, ctx.ft_lr(), epochs, 0)?;
            s.push(vec![
                "finetune_topk".into(),
                trained_params_of_exe(&ctx.rt, &exe).to_string(),
                name.clone(),
                format!("{:.4}", test - ft.2),
            ]);
        }
    }
    s.save("fig3_extra_tradeoff")?;
    Ok(())
}

/// Fig. 4 — MNLI-like and CoLA-like detail curves: adapters across sizes,
/// top-k fine-tuning, and LayerNorm-only, with ±sem over seeds.
pub fn fig4(ctx: &Ctx) -> Result<()> {
    let seeds: Vec<u64> = if ctx.quick { vec![0, 1] } else { vec![0, 1, 2] };
    let ms = adapter_sizes(ctx);
    let ks = topk_range(ctx);
    let mut s = Series::new(&[
        "task", "curve", "trained_params", "mean_val", "sem",
    ]);
    for name in ["mnli_s", "cola_s"] {
        let spec = tasks::find_spec(name).unwrap();
        let data = ctx.gen(&spec);
        let epochs = ctx.epochs_for(&data);
        println!("[fig4] {name}");
            let mut run_curve = |curve: &str, exe: String, lr: f64| -> Result<()> {
            let mut vals = Vec::new();
            for &seed in &seeds {
                // Fig. 4 reports *validation* accuracy
                let (_, val, _) = ctx.train_once(&data, &exe, lr, epochs, seed)?;
                vals.push(val);
            }
            s.push(vec![
                name.into(),
                curve.into(),
                trained_params_of_exe(&ctx.rt, &exe).to_string(),
                format!("{:.4}", stats::mean(&vals)),
                format!("{:.4}", stats::sem(&vals)),
            ]);
            Ok(())
        };
        for m in &ms {
            run_curve("adapters", format!("cls_train_adapter_m{m}"),
                      ctx.adapter_lr())?;
        }
        for k in &ks {
            run_curve("finetune_topk", format!("cls_train_topk_k{k}"),
                      ctx.ft_lr())?;
        }
        run_curve("layernorm_only", "cls_train_lnonly".into(),
                  ctx.adapter_lr())?;
    }
    s.save("fig4_detail")?;
    Ok(())
}

/// Fig. 5 — SQuAD stand-in: span F1 vs trained params.
pub fn fig5(ctx: &Ctx) -> Result<()> {
    let spec = tasks::span_task();
    let data = ctx.gen(&spec);
    let epochs = ctx.epochs_for(&data);
    let mut ms: Vec<usize> = ctx
        .rt
        .manifest
        .find("span", "adapter")
        .iter()
        .filter_map(|e| e.m)
        .collect();
    ms.sort_unstable();
    let mut ks: Vec<usize> = ctx
        .rt
        .manifest
        .find("span", "topk")
        .iter()
        .filter_map(|e| e.k)
        .collect();
    ks.sort_unstable();
    let mut s = Series::new(&["curve", "trained_params", "val_f1"]);
    for m in &ms {
        let exe = format!("span_train_adapter_m{m}");
        println!("[fig5] {exe}");
        let (_, val, _) = ctx.train_once(&data, &exe, ctx.adapter_lr(), epochs, 0)?;
        s.push(vec![
            "adapters".into(),
            trained_params_of_exe(&ctx.rt, &exe).to_string(),
            format!("{val:.4}"),
        ]);
    }
    for k in &ks {
        let exe = format!("span_train_topk_k{k}");
        println!("[fig5] {exe}");
        let (_, val, _) = ctx.train_once(&data, &exe, ctx.ft_lr(), epochs, 0)?;
        s.push(vec![
            "finetune_topk".into(),
            trained_params_of_exe(&ctx.rt, &exe).to_string(),
            format!("{val:.4}"),
        ]);
    }
    s.save("fig5_squad")?;
    Ok(())
}

/// Fig. 6 left/center — adapter-span ablation heatmap: train once at a
/// fixed size, then re-evaluate with adapters disabled on every contiguous
/// layer span (no retraining — the gates are a runtime input).
pub fn fig6_heatmap(ctx: &Ctx) -> Result<()> {
    let n_layers = ctx.rt.manifest.dims.n_layers;
    let m = ctx.pick_size("cls", 16);
    let mut s = Series::new(&["task", "first", "last", "rel_delta"]);
    for name in ["mnli_s", "cola_s"] {
        let spec = tasks::find_spec(name).unwrap();
        let data = ctx.gen(&spec);
        let n_classes = ctx.n_classes(&spec);
        let epochs = ctx.epochs_for(&data);
        println!("[fig6] training {name} (m={m})");
        let (model, _, _) = ctx.train_once(
            &data,
            &format!("cls_train_adapter_m{m}"),
            ctx.adapter_lr(),
            epochs,
            0,
        )?;
        let full = evaluate(&ctx.rt, &model, &ctx.base, &data.val, n_classes,
                            spec.metric)?;
        for first in 0..n_layers {
            for last in first..n_layers {
                let mut gates = vec![1.0f32; n_layers * 2];
                for l in first..=last {
                    gates[l * 2] = 0.0;
                    gates[l * 2 + 1] = 0.0;
                }
                let score = evaluate_with_gates(
                    &ctx.rt, &model, &ctx.base, &data.val, n_classes,
                    spec.metric, &gates,
                )?;
                s.push(vec![
                    name.into(),
                    first.to_string(),
                    last.to_string(),
                    format!("{:.4}", score - full),
                ]);
            }
        }
        // the "all ablated" corner ≈ majority class (paper: 37% MNLI / 69% CoLA)
        let all_off = vec![0.0f32; n_layers * 2];
        let floor = evaluate_with_gates(
            &ctx.rt, &model, &ctx.base, &data.val, n_classes, spec.metric,
            &all_off,
        )?;
        println!(
            "  {name}: full={full:.3}, all-ablated={floor:.3} (majority floor \
             {:.3})",
            super::tables::majority_floor(&data.val.labels)
        );
    }
    s.save("fig6_heatmap")?;
    Ok(())
}

/// Fig. 6 right — robustness to the adapter init σ ∈ [1e-7, 1].
pub fn fig6_init(ctx: &Ctx) -> Result<()> {
    let stds: Vec<f64> = if ctx.quick {
        vec![1e-7, 1e-4, 1e-2, 1e-1, 1.0]
    } else {
        vec![1e-7, 1e-6, 1e-5, 1e-4, 1e-3, 1e-2, 1e-1, 1.0]
    };
    let seeds: Vec<u64> = if ctx.quick { vec![0, 1] } else { vec![0, 1, 2] };
    let mut s = Series::new(&["task", "std", "mean_val", "sem"]);
    for name in ["mnli_s", "cola_s"] {
        let spec = tasks::find_spec(name).unwrap();
        let data = ctx.gen(&spec);
        let epochs = ctx.epochs_for(&data);
        for &std in &stds {
            let mut vals = Vec::new();
            for &seed in &seeds {
                    let exe = format!("cls_train_adapter_m{}", ctx.pick_size("cls", 16));
                let mut cfg =
                    TrainConfig::new(&exe, ctx.adapter_lr(), epochs, seed);
                cfg.adapter_std = std;
                let res = crate::train::train_task(&ctx.rt, &cfg, &data,
                                                   &ctx.base)?;
                vals.push(res.val_score);
            }
            println!("[fig6-init] {name} σ={std:.0e}: {:.3}", stats::mean(&vals));
            s.push(vec![
                name.into(),
                format!("{std:e}"),
                format!("{:.4}", stats::mean(&vals)),
                format!("{:.4}", stats::sem(&vals)),
            ]);
        }
    }
    s.save("fig6_init_scale")?;
    Ok(())
}

/// Fig. 7 — learning-rate robustness: best adapters vs best fine-tuning at
/// each lr in [2e-5, 1e-3] (we extend to 3e-3 — adapters' optimum sits
/// higher, as the paper also finds).
pub fn fig7(ctx: &Ctx) -> Result<()> {
    let lrs = [3e-5, 1e-4, 3e-4, 1e-3, 3e-3];
    let seeds: Vec<u64> = if ctx.quick { vec![0] } else { vec![0, 1, 2] };
    let mut s = Series::new(&["task", "method", "lr", "mean_val", "sem"]);
    for name in ["cola_s", "rte_s"] {
        let spec = tasks::find_spec(name).unwrap();
        let data = ctx.gen(&spec);
        let epochs = ctx.epochs_for(&data);
        for &lr in &lrs {
            for (method, exe) in [
                ("adapters", format!("cls_train_adapter_m{}", ctx.pick_size("cls", 16))),
                (
                    "finetune",
                    format!("cls_train_topk_k{}", ctx.rt.manifest.dims.n_layers),
                ),
            ] {
                let mut vals = Vec::new();
                for &seed in &seeds {
                    let (_, val, _) = ctx.train_once(&data, &exe, lr, epochs,
                                                     seed)?;
                    vals.push(val);
                }
                println!("[fig7] {name} {method} lr={lr:.0e}: {:.3}",
                         stats::mean(&vals));
                s.push(vec![
                    name.into(),
                    method.into(),
                    format!("{lr:e}"),
                    format!("{:.4}", stats::mean(&vals)),
                    format!("{:.4}", stats::sem(&vals)),
                ]);
            }
        }
    }
    s.save("fig7_lr_robustness")?;
    Ok(())
}

/// §3.6 size-robustness note: mean val accuracy across tasks per size.
pub fn size_robustness(ctx: &Ctx) -> Result<()> {
    let names = ["cola_s", "sst_s", "rte_s", "qnli_s"];
    let ms = adapter_sizes(ctx);
    let mut s = Series::new(&["m", "mean_val_acc"]);
    for m in &ms {
        let mut vals = Vec::new();
        for name in names {
            let spec = tasks::find_spec(name).unwrap();
            // accuracy metric for comparability (as the paper does)
            let mut spec = spec;
            spec.metric = tasks::Metric::Accuracy;
            let data = ctx.gen(&spec);
            let epochs = ctx.epochs_for(&data);
            let (_, val, _) = ctx.train_once(
                &data,
                &format!("cls_train_adapter_m{m}"),
                ctx.adapter_lr(),
                epochs,
                0,
            )?;
            vals.push(val);
        }
        println!("[size-robustness] m={m}: {:.3}", stats::mean(&vals));
        s.push(vec![m.to_string(), format!("{:.4}", stats::mean(&vals))]);
    }
    s.save("size_robustness")?;
    Ok(())
}

#[allow(unused)]
fn unused_taskkind_guard(k: &TaskKind) {}
