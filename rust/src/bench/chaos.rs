//! `bench chaos`: deterministic fault schedule against an in-process
//! cluster → `BENCH_chaos.json`.
//!
//! Everything runs in-process on ephemeral ports: one shared runtime and
//! one shared in-memory `AdapterStore` (behind a fault-injectable
//! [`BankSource`] wrapper) back two `Gateway` replicas behind one
//! `cluster::Router`. The schedule is fixed and seeded — which faults
//! fire, in what order, against which tenant — so two runs exercise the
//! same code paths even though wall-clock timings differ:
//!
//! * **baseline** — well-behaved closed-loop traffic; its p99 anchors
//!   the flood-phase SLO;
//! * **slow_replica** — a byte-pump TCP proxy in front of replica 0
//!   delays every response chunk past the router's upstream read
//!   timeout: the replica is alive (accepts, eventually answers) but
//!   useless. The router's circuit breaker must trip from passive
//!   forward failures and traffic must converge on the healthy replica;
//! * **stalled_store** — the shared store stalls every bank fetch for a
//!   cold tenant far past that tenant's deadline budget: its requests
//!   must die by deadline (never a post-deadline `200`), and resident
//!   tenants must keep serving;
//! * **flood** — one tenant floods with short budgets while the rest
//!   run normally: the brownout controller sheds the hog, expired rows
//!   never reach the engine (counter-verified), and the well-behaved
//!   p99 stays within `p99_ratio_limit ×` baseline;
//! * **kill_owner** — the replica owning the flooded tenant is shut
//!   down mid-traffic; the tail after the kill must stay busy.
//!
//! The report is schema-pinned (v1) and carries an `slo` block CI gates
//! on: zero post-deadline `200`s across every phase, bounded shed rate,
//! and the flood-phase p99 ratio.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use super::loadgen;
use crate::cluster::{
    HashRing, HealthPolicy, Router, RouterConfig, RouterReport, DEFAULT_VNODES,
};
use crate::coordinator::{FlushPolicy, Server, ServerConfig};
use crate::data::grammar::World;
use crate::data::tasks::{self, Metric, TaskKind, TaskSpec};
use crate::eval::TaskModel;
use crate::model::params::NamedTensors;
use crate::runtime::Runtime;
use crate::serve::{
    Client, ClientConfig, Gateway, GatewayConfig, GatewayReport, HttpConfig,
    PredictRequest,
};
use crate::store::{AdapterStore, BankMeta, BankSource};
use crate::train::{self, PretrainConfig, TrainConfig};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// A `200` counts as *late* only when it lands this far past the
/// client's own deadline — absorbs scheduler jitter between the last
/// socket read and the clock check.
const LATE_SLACK: Duration = Duration::from_millis(50);

/// Flood-phase p99 may be at most this multiple of the baseline p99.
const P99_RATIO_LIMIT: f64 = 3.0;

/// Harness knobs.
#[derive(Debug, Clone)]
pub struct ChaosBenchConfig {
    pub preset: String,
    /// Well-behaved tenant tasks trained into the shared store (one
    /// extra cold tenant is always trained on top for the stalled-store
    /// phase).
    pub tenants: usize,
    /// Adapter size for the tenants.
    pub m: usize,
    /// MLM pre-training steps when no cached base exists.
    pub pretrain_steps: usize,
    /// Closed-loop well-behaved client threads per phase.
    pub concurrency: usize,
    /// Budget minted by well-behaved clients.
    pub deadline: Duration,
    /// Budget minted by the flooding tenant (and the cold tenant).
    pub flood_deadline: Duration,
    /// Flooding client threads during the flood phase.
    pub flood_workers: usize,
    /// Traffic window per phase.
    pub phase_duration: Duration,
    /// Injected per-chunk response delay for the slow replica.
    pub slow_delay: Duration,
    /// Injected stall per bank fetch for the cold tenant.
    pub stall: Duration,
    /// Schedule seed (task/text choices in the drivers).
    pub seed: u64,
}

impl Default for ChaosBenchConfig {
    fn default() -> Self {
        ChaosBenchConfig {
            preset: "test".to_string(),
            tenants: 4,
            m: 8,
            pretrain_steps: 120,
            concurrency: 4,
            deadline: Duration::from_millis(2000),
            flood_deadline: Duration::from_millis(400),
            flood_workers: 12,
            phase_duration: Duration::from_millis(2500),
            slow_delay: Duration::from_millis(600),
            stall: Duration::from_millis(900),
            seed: 7,
        }
    }
}

/// Client-observed outcome counts for one phase (or one worker class
/// within a phase).
#[derive(Debug, Clone, Default)]
pub struct PhaseStats {
    pub name: String,
    pub requests: u64,
    /// `200`s.
    pub ok: u64,
    /// `200`s that landed after the client's own deadline (+slack) —
    /// the headline SLO is that this stays zero everywhere.
    pub late_ok: u64,
    /// `503`s (brownout shed, admission window, draining, no replica).
    pub shed: u64,
    /// `504`s (deadline exceeded / reply timeout).
    pub deadline_504: u64,
    /// Transport errors (client-side read timeouts, resets).
    pub errors: u64,
    pub p50_ms: f64,
    pub p99_ms: f64,
}

impl PhaseStats {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("requests", Json::num(self.requests as f64)),
            ("ok", Json::num(self.ok as f64)),
            ("late_ok", Json::num(self.late_ok as f64)),
            ("shed", Json::num(self.shed as f64)),
            ("deadline_504", Json::num(self.deadline_504 as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p99_ms", Json::num(self.p99_ms)),
        ])
    }
}

/// Router-side counters summed over every phase's router.
#[derive(Debug, Clone, Default)]
pub struct RouterTotals {
    pub breaker_trips: u64,
    pub breaker_fast_fails: u64,
    pub deadline_rejected: u64,
    pub reroutes: u64,
    pub ejections: u64,
}

impl RouterTotals {
    fn absorb(&mut self, r: &RouterReport) {
        self.breaker_trips += r.breaker_trips;
        self.breaker_fast_fails += r.breaker_fast_fails;
        self.deadline_rejected += r.deadline_rejected;
        self.reroutes += r.reroutes;
        self.ejections += r.ejections;
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("breaker_trips", Json::num(self.breaker_trips as f64)),
            ("breaker_fast_fails", Json::num(self.breaker_fast_fails as f64)),
            ("deadline_rejected", Json::num(self.deadline_rejected as f64)),
            ("reroutes", Json::num(self.reroutes as f64)),
            ("ejections", Json::num(self.ejections as f64)),
        ])
    }
}

/// Coordinator-side deadline counters summed over every replica that
/// served a phase — the "engine never executed an expired row" evidence.
#[derive(Debug, Clone, Default)]
pub struct CoordinatorTotals {
    /// Rows the engine executed.
    pub requests: u64,
    /// Expired rows purged from the batch queues.
    pub expired_queue: u64,
    /// Expired rows dropped at the pre-execution partition.
    pub expired_exec: u64,
    /// Executed rows whose reply was suppressed past the deadline.
    pub late_replies: u64,
}

impl CoordinatorTotals {
    fn absorb(&mut self, g: &GatewayReport) {
        self.requests += g.server.requests;
        self.expired_queue += g.server.expired_queue;
        self.expired_exec += g.server.expired_exec;
        self.late_replies += g.server.late_replies;
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::num(self.requests as f64)),
            ("expired_queue", Json::num(self.expired_queue as f64)),
            ("expired_exec", Json::num(self.expired_exec as f64)),
            ("late_replies", Json::num(self.late_replies as f64)),
        ])
    }
}

/// The whole run.
#[derive(Debug)]
pub struct ChaosReport {
    /// One row per schedule phase, in schedule order.
    pub phases: Vec<PhaseStats>,
    /// Well-behaved tenants' p99 during the flood, and its ratio to the
    /// baseline p99.
    pub flood_well_p99_ms: f64,
    pub p99_ratio: f64,
    pub router: RouterTotals,
    pub coordinator: CoordinatorTotals,
}

impl ChaosReport {
    fn late_ok_total(&self) -> u64 {
        self.phases.iter().map(|p| p.late_ok).sum()
    }

    fn shed_rate(&self) -> f64 {
        let (shed, reqs): (u64, u64) = self
            .phases
            .iter()
            .fold((0, 0), |(s, r), p| (s + p.shed, r + p.requests));
        if reqs == 0 {
            0.0
        } else {
            shed as f64 / reqs as f64
        }
    }

    /// The `BENCH_chaos.json` document (schema v1). The `slo` block is
    /// what CI gates on.
    pub fn to_json(&self, cfg: &ChaosBenchConfig) -> Json {
        let zero_late = self.late_ok_total() == 0;
        let p99_ok = self.p99_ratio <= P99_RATIO_LIMIT;
        let shed_rate = self.shed_rate();
        // "bounded": shedding may be heavy under deliberate overload but
        // must never drown the run — some traffic always gets through
        let shed_bounded = shed_rate < 0.95;
        Json::obj(vec![
            ("bench", Json::str("chaos")),
            ("schema_version", Json::num(1.0)),
            (
                "config",
                Json::obj(vec![
                    ("preset", Json::str(&cfg.preset)),
                    ("tenants", Json::num(cfg.tenants as f64)),
                    ("m", Json::num(cfg.m as f64)),
                    ("concurrency", Json::num(cfg.concurrency as f64)),
                    ("flood_workers", Json::num(cfg.flood_workers as f64)),
                    ("deadline_ms", Json::num(cfg.deadline.as_secs_f64() * 1e3)),
                    (
                        "flood_deadline_ms",
                        Json::num(cfg.flood_deadline.as_secs_f64() * 1e3),
                    ),
                    (
                        "phase_duration_ms",
                        Json::num(cfg.phase_duration.as_secs_f64() * 1e3),
                    ),
                    ("seed", Json::num(cfg.seed as f64)),
                ]),
            ),
            ("phases", Json::arr(self.phases.iter().map(PhaseStats::to_json))),
            (
                "flood",
                Json::obj(vec![
                    ("well_p99_ms", Json::num(self.flood_well_p99_ms)),
                    ("p99_ratio", Json::num(self.p99_ratio)),
                ]),
            ),
            ("router", self.router.to_json()),
            ("coordinator", self.coordinator.to_json()),
            (
                "slo",
                Json::obj(vec![
                    ("late_ok_total", Json::num(self.late_ok_total() as f64)),
                    ("zero_late", Json::Bool(zero_late)),
                    ("p99_ratio", Json::num(self.p99_ratio)),
                    ("p99_ratio_limit", Json::num(P99_RATIO_LIMIT)),
                    ("p99_ok", Json::Bool(p99_ok)),
                    ("shed_rate", Json::num(shed_rate)),
                    ("shed_bounded", Json::Bool(shed_bounded)),
                    ("pass", Json::Bool(zero_late && p99_ok && shed_bounded)),
                ]),
            ),
        ])
    }
}

// ---------------------------------------------------------------------------
// fault seams
// ---------------------------------------------------------------------------

/// [`BankSource`] wrapper over the shared store with an injectable
/// per-task fetch stall — the "remote store hung" fault. Metadata probes
/// stay healthy (the fault models the expensive read, not the
/// directory), matching the production failure mode of a slow blob
/// store behind a fast index.
struct ChaosStore {
    inner: Arc<AdapterStore>,
    stalls: Mutex<BTreeMap<String, Duration>>,
}

impl ChaosStore {
    fn new(inner: Arc<AdapterStore>) -> Arc<ChaosStore> {
        Arc::new(ChaosStore { inner, stalls: Mutex::new(BTreeMap::new()) })
    }

    fn stall(&self, task: &str, d: Duration) {
        self.stalls.lock().unwrap().insert(task.to_string(), d);
    }

    fn heal(&self, task: &str) {
        self.stalls.lock().unwrap().remove(task);
    }
}

impl BankSource for ChaosStore {
    fn fetch_latest(&self, task: &str) -> Result<Option<(BankMeta, Arc<TaskModel>)>> {
        let stall = self.stalls.lock().unwrap().get(task).copied();
        if let Some(d) = stall {
            thread::sleep(d);
        }
        self.inner.fetch_latest(task)
    }

    fn latest_meta(&self, task: &str) -> Option<BankMeta> {
        self.inner.latest_meta(task)
    }

    fn latest_bank_bytes(&self, task: &str) -> Option<u64> {
        self.inner.latest_bank_bytes(task)
    }

    fn task_names(&self) -> Vec<String> {
        self.inner.task_names()
    }
}

/// A byte-pump TCP proxy that delays every upstream→client chunk by a
/// settable amount: the "slow but alive" replica. The request direction
/// passes verbatim, so the replica really does the work — it just
/// answers too late for the router's upstream read timeout.
struct SlowProxy {
    addr: String,
    delay_ms: Arc<AtomicU64>,
    stop: Arc<AtomicBool>,
    accept: Option<thread::JoinHandle<()>>,
}

fn pump(mut from: TcpStream, mut to: TcpStream, delay_ms: Option<Arc<AtomicU64>>) {
    let mut buf = [0u8; 4096];
    loop {
        let n = match from.read(&mut buf) {
            Ok(0) | Err(_) => break,
            Ok(n) => n,
        };
        if let Some(d) = &delay_ms {
            let ms = d.load(Ordering::Relaxed);
            if ms > 0 {
                thread::sleep(Duration::from_millis(ms));
            }
        }
        if to.write_all(&buf[..n]).is_err() {
            break;
        }
    }
    let _ = to.shutdown(Shutdown::Write);
    let _ = from.shutdown(Shutdown::Read);
}

impl SlowProxy {
    fn start(upstream: String) -> Result<SlowProxy> {
        let listener =
            TcpListener::bind("127.0.0.1:0").context("binding slow proxy")?;
        let addr = listener.local_addr()?.to_string();
        let delay_ms = Arc::new(AtomicU64::new(0));
        let stop = Arc::new(AtomicBool::new(false));
        let (d, s) = (delay_ms.clone(), stop.clone());
        let accept = thread::spawn(move || {
            for conn in listener.incoming() {
                if s.load(Ordering::Relaxed) {
                    break;
                }
                let Ok(client) = conn else { continue };
                let Ok(server) = TcpStream::connect(&upstream) else { continue };
                let (Ok(c2), Ok(s2)) = (client.try_clone(), server.try_clone())
                else {
                    continue;
                };
                // request direction verbatim; response direction delayed.
                // Pump threads die with their sockets when either side
                // closes, so only the accept loop needs explicit stop.
                thread::spawn(move || pump(c2, s2, None));
                let d2 = d.clone();
                thread::spawn(move || pump(server, client, Some(d2)));
            }
        });
        Ok(SlowProxy { addr, delay_ms, stop, accept: Some(accept) })
    }

    fn set_delay(&self, d: Duration) {
        self.delay_ms.store(d.as_millis() as u64, Ordering::Relaxed);
    }

    fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        // wake the accept loop so it observes the flag
        let _ = TcpStream::connect(&self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

// ---------------------------------------------------------------------------
// fixture + replicas
// ---------------------------------------------------------------------------

/// Shared fixture: runtime, base, tenants in one in-memory store.
struct Fixture {
    rt: Arc<Runtime>,
    base: NamedTensors,
    store: Arc<AdapterStore>,
    /// Well-behaved tenants, registered with every replica at startup.
    tenants: Vec<String>,
    /// Registered in the store only — every replica's first predict for
    /// it goes through `admit_from_store` + a cold fetch, which the
    /// stalled-store phase hangs.
    cold_tenant: String,
    classes: BTreeMap<String, usize>,
}

fn tenant_spec(name: &str, seed: u64) -> TaskSpec {
    TaskSpec {
        name: name.to_string(),
        kind: TaskKind::Cls { n_classes: 2, pair: false },
        metric: Metric::Accuracy,
        n_train: 240,
        n_val: 48,
        n_test: 48,
        purity: 0.85,
        noise: 0.0,
        seed,
    }
}

fn setup(cfg: &ChaosBenchConfig) -> Result<Fixture> {
    let rt = Arc::new(Runtime::open(Path::new("artifacts"), &cfg.preset)?);
    let world = World::new(rt.manifest.dims.vocab, 0);
    let base = train::load_or_pretrain(
        &rt,
        &world,
        &PretrainConfig { steps: cfg.pretrain_steps, ..Default::default() },
        Path::new(&format!("runs/base_{}.bank", cfg.preset)),
    )?;
    let store = Arc::new(AdapterStore::in_memory());
    let exe = format!("cls_train_adapter_m{}", cfg.m);
    let mut tenants = Vec::new();
    let mut classes = BTreeMap::new();
    let cold_tenant = "coldstore".to_string();
    let mut names: Vec<String> =
        (0..cfg.tenants.max(2)).map(|k| format!("chaos{k:02}")).collect();
    names.push(cold_tenant.clone());
    for (k, name) in names.iter().enumerate() {
        let data =
            tasks::generate(&world, &tenant_spec(name, 700 + k as u64), rt.manifest.dims.seq);
        let res = train::train_task(&rt, &TrainConfig::new(&exe, 1e-3, 3, 0), &data, &base)?;
        store.register_with_classes(name, &res.model, 2, res.val_score)?;
        if *name != cold_tenant {
            classes.insert(name.clone(), 2usize);
            tenants.push(name.clone());
        }
        println!("  tenant {name}: val {:.3}", res.val_score);
    }
    Ok(Fixture { rt, base, store, tenants, cold_tenant, classes })
}

/// One gateway replica over the (fault-injectable) source. A single
/// executor serializes the trunk so the flood phase builds a real
/// queue, the brownout knobs are bench-tight so sustained overload
/// flips the controller within the phase window, and the HTTP pool is
/// widened so threads wedged in a stalled cold fetch can't starve the
/// well-behaved tenants on the same replica.
fn start_replica(fx: &Fixture, source: &Arc<ChaosStore>) -> Result<Gateway> {
    let server = Server::start_with_source(
        fx.rt.clone(),
        source.clone(),
        &fx.base,
        &fx.classes,
        ServerConfig {
            flush: FlushPolicy {
                max_batch: fx.rt.manifest.batch,
                max_delay: Duration::from_millis(2),
            },
            executors: 1,
            // lazy residency (generous budget, no eviction pressure):
            // with `None` startup eagerly resolves every store task and
            // the stalled-store phase would have no cold fetch to stall
            cache_budget: Some(64 * 1024 * 1024),
            ..Default::default()
        },
    )?;
    Gateway::start(
        fx.rt.clone(),
        fx.store.clone(),
        server,
        GatewayConfig {
            addr: "127.0.0.1:0".to_string(),
            // a predict holds its HTTP worker while awaiting the reply,
            // so the pool size caps outstanding coordinator rows — wide
            // enough that the flood can actually build a queue (and
            // wedged stall threads can't starve resident tenants)
            http: HttpConfig { workers: 16, ..Default::default() },
            brownout_target: Duration::from_millis(5),
            brownout_window: Duration::from_millis(100),
            ..Default::default()
        },
    )
}

/// Bench-speed router: fast health ejection, no dial retries (the
/// preference walk is the retry mechanism). `upstream_read` is
/// per-phase: the slow-replica phase pins it *below* the injected
/// delay so a slow-but-alive replica surfaces as forward errors the
/// breaker can count, instead of slow successes nothing acts on.
fn router_config(upstream_read: Duration) -> RouterConfig {
    RouterConfig {
        health: HealthPolicy {
            interval: Duration::from_millis(100),
            timeout: Duration::from_millis(500),
            fail_after: 2,
            pass_after: 2,
        },
        upstream: ClientConfig {
            connect_timeout: Duration::from_millis(500),
            read_timeout: Some(upstream_read),
            retries: 0,
            backoff: Duration::from_millis(10),
            deadline: None,
        },
        ..Default::default()
    }
}

/// Poll the router's `/health` until `healthy` reaches `want`.
fn wait_healthy(addr: &str, want: usize, timeout: Duration) -> Result<()> {
    let deadline = Instant::now() + timeout;
    loop {
        if let Ok(mut c) = Client::connect(addr) {
            if let Ok((status, j)) = c.roundtrip("GET", "/health", None) {
                if status == 200 && j.get("healthy").and_then(Json::as_usize) == Some(want)
                {
                    return Ok(());
                }
            }
        }
        if Instant::now() > deadline {
            bail!("router at {addr} never reported {want} healthy replica(s)");
        }
        thread::sleep(Duration::from_millis(50));
    }
}

// ---------------------------------------------------------------------------
// traffic driver
// ---------------------------------------------------------------------------

/// One closed-loop worker's brief: which tasks to hit, with what budget.
#[derive(Clone)]
struct WorkerSpec {
    tasks: Vec<String>,
    deadline: Duration,
}

/// Raw per-worker outcome; callers merge by worker class.
#[derive(Default)]
struct DriveOutcome {
    requests: u64,
    ok: u64,
    late_ok: u64,
    shed: u64,
    deadline_504: u64,
    errors: u64,
    /// Latency (seconds) of each `200`.
    lat: Vec<f64>,
}

fn merge(name: &str, outs: &[DriveOutcome]) -> PhaseStats {
    let mut lat: Vec<f64> = outs.iter().flat_map(|o| o.lat.iter().copied()).collect();
    lat.sort_by(|a, b| a.total_cmp(b));
    PhaseStats {
        name: name.to_string(),
        requests: outs.iter().map(|o| o.requests).sum(),
        ok: outs.iter().map(|o| o.ok).sum(),
        late_ok: outs.iter().map(|o| o.late_ok).sum(),
        shed: outs.iter().map(|o| o.shed).sum(),
        deadline_504: outs.iter().map(|o| o.deadline_504).sum(),
        errors: outs.iter().map(|o| o.errors).sum(),
        p50_ms: pctl_ms(&lat, 0.50),
        p99_ms: pctl_ms(&lat, 0.99),
    }
}

fn pctl_ms(sorted_s: &[f64], q: f64) -> f64 {
    if sorted_s.is_empty() {
        return 0.0;
    }
    let i = ((q * sorted_s.len() as f64).ceil() as usize).clamp(1, sorted_s.len());
    sorted_s[i - 1] * 1e3
}

const PHRASES: [&str; 4] = [
    "moresa zu kari letu",
    "kari letu moresa zu",
    "zu zu letu moresa kari",
    "letu kari moresa zu vanto",
];

/// Closed-loop drive: one thread per spec, each hammering its task list
/// until `stop` flips (the caller owns phase timing and mid-phase
/// events like kills). Returns one outcome per spec, in order.
fn drive(
    addr: &str,
    specs: &[WorkerSpec],
    stop: &AtomicBool,
    seed: u64,
) -> Vec<DriveOutcome> {
    thread::scope(|scope| {
        let mut handles = Vec::new();
        for (w, spec) in specs.iter().enumerate() {
            handles.push(scope.spawn(move || {
                let mut out = DriveOutcome::default();
                let mut rng = Rng::new(seed ^ (w as u64).wrapping_mul(0x9E37));
                let ccfg = ClientConfig {
                    connect_timeout: Duration::from_secs(1),
                    read_timeout: Some(Duration::from_secs(10)),
                    retries: 0,
                    backoff: Duration::from_millis(10),
                    deadline: Some(spec.deadline),
                };
                let Ok(mut client) = Client::connect_with(addr, ccfg) else {
                    return out;
                };
                while !stop.load(Ordering::Relaxed) {
                    let task = &spec.tasks[rng.below(spec.tasks.len())];
                    let text = PHRASES[rng.below(PHRASES.len())];
                    let body = PredictRequest::text(task, text).to_json();
                    let t0 = Instant::now();
                    out.requests += 1;
                    match client.roundtrip("POST", "/predict", Some(&body)) {
                        Ok((200, _)) => {
                            let el = t0.elapsed();
                            out.ok += 1;
                            out.lat.push(el.as_secs_f64());
                            if el > spec.deadline + LATE_SLACK {
                                out.late_ok += 1;
                            }
                        }
                        Ok((503, _)) => {
                            out.shed += 1;
                            // minimal client politeness: without this a
                            // shed answer (which costs the server ~no
                            // work) turns the flood into a tight loop
                            // that measures the driver, not the server
                            thread::sleep(Duration::from_millis(5));
                        }
                        Ok((504, _)) => out.deadline_504 += 1,
                        Ok(_) => out.errors += 1,
                        Err(_) => {
                            // client-side deadline/read timeout or reset:
                            // the connection state is unknown, redial
                            out.errors += 1;
                            let _ = client.reconnect();
                        }
                    }
                }
                out
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap_or_default()).collect()
    })
}

/// Run `specs` against a fresh 2-replica cluster for `dur`, with
/// `mid_phase` called once the traffic is flowing (fault injection /
/// kills happen there, against the live replica set).
#[allow(clippy::type_complexity)]
fn phase(
    fx: &Fixture,
    source: &Arc<ChaosStore>,
    specs: &[WorkerSpec],
    dur: Duration,
    seed: u64,
    upstream_read: Duration,
    proxy_first: bool,
    mid_phase: &mut dyn FnMut(&mut Vec<Gateway>, &[String], Option<&SlowProxy>, &str),
) -> Result<(Vec<DriveOutcome>, RouterReport, Vec<GatewayReport>)> {
    let mut gateways: Vec<Gateway> =
        (0..2).map(|_| start_replica(fx, source)).collect::<Result<_>>()?;
    let real_addrs: Vec<String> =
        gateways.iter().map(|g| g.local_addr().to_string()).collect();
    // the slow-replica phase fronts replica 0 with the byte-pump proxy;
    // the router only ever sees the proxy address
    let proxy = if proxy_first {
        Some(SlowProxy::start(real_addrs[0].clone())?)
    } else {
        None
    };
    let mut router_addrs = real_addrs.clone();
    if let Some(p) = &proxy {
        router_addrs[0] = p.addr.clone();
    }
    let router = Router::start(router_addrs.clone(), router_config(upstream_read))?;
    let raddr = router.local_addr().to_string();
    wait_healthy(&raddr, 2, Duration::from_secs(10))?;

    let stop = AtomicBool::new(false);
    let outs = thread::scope(|scope| {
        let driver = scope.spawn(|| drive(&raddr, specs, &stop, seed));
        // let traffic flow before injecting the fault, so every phase
        // has a healthy head the SLOs can lean on
        thread::sleep(dur.mul_f64(0.25));
        mid_phase(&mut gateways, &router_addrs, proxy.as_ref(), &raddr);
        thread::sleep(dur.mul_f64(0.75));
        stop.store(true, Ordering::Relaxed);
        driver.join().unwrap_or_default()
    });
    let rrep = router.shutdown();
    if let Some(p) = proxy {
        p.shutdown();
    }
    let mut greps = Vec::new();
    for g in gateways {
        greps.push(g.shutdown()?);
    }
    Ok((outs, rrep, greps))
}

// ---------------------------------------------------------------------------
// the schedule
// ---------------------------------------------------------------------------

/// Run the full fault schedule.
pub fn run(cfg: &ChaosBenchConfig) -> Result<ChaosReport> {
    ensure!(cfg.tenants >= 2, "need at least two well-behaved tenants");
    let fx = setup(cfg).context("chaos bench fixture")?;
    let source = ChaosStore::new(fx.store.clone());

    let well = |deadline: Duration| WorkerSpec { tasks: fx.tenants.clone(), deadline };
    // generous upstream reads everywhere except the slow-replica phase:
    // there the read cap sits below the injected delay so slowness
    // surfaces as breaker-countable forward errors
    let upstream_read = Duration::from_secs(3);
    let mut phases: Vec<PhaseStats> = Vec::new();
    let mut router = RouterTotals::default();
    let mut coord = CoordinatorTotals::default();

    // ---- baseline --------------------------------------------------------
    println!("  phase baseline: {} workers …", cfg.concurrency);
    let specs: Vec<WorkerSpec> =
        (0..cfg.concurrency).map(|_| well(cfg.deadline)).collect();
    let (outs, rrep, greps) = phase(
        &fx,
        &source,
        &specs,
        cfg.phase_duration,
        cfg.seed,
        upstream_read,
        false,
        &mut |_, _, _, _| {},
    )?;
    let baseline = merge("baseline", &outs);
    ensure!(baseline.ok > 0, "baseline phase produced no 200s");
    // floor tiny baselines so the flood ratio is not hostage to a few
    // milliseconds of noise on an unloaded box
    let baseline_p99_ms = baseline.p99_ms.max(10.0);
    router.absorb(&rrep);
    greps.iter().for_each(|g| coord.absorb(g));
    println!("    {} ok, p99 {:.1}ms", baseline.ok, baseline.p99_ms);
    phases.push(baseline);

    // ---- slow replica ----------------------------------------------------
    println!("  phase slow_replica: +{:?} per response chunk …", cfg.slow_delay);
    let specs: Vec<WorkerSpec> =
        (0..cfg.concurrency).map(|_| well(cfg.deadline)).collect();
    let slow = cfg.slow_delay;
    // read cap below the injected delay: every forward through the
    // proxy times out and feeds the breaker; the healthy replica
    // answers well inside it
    let slow_read = cfg.slow_delay.mul_f64(0.5).max(Duration::from_millis(100));
    let (outs, rrep, greps) = phase(
        &fx,
        &source,
        &specs,
        cfg.phase_duration.mul_f64(1.5),
        cfg.seed + 1,
        slow_read,
        true,
        &mut |_, addrs, proxy, raddr| {
            if let Some(p) = proxy {
                p.set_delay(slow);
            }
            // Ring placement of the tenant names over two ephemeral-port
            // addresses is luck; make the breaker test deterministic: find
            // a key the ring provably routes to the proxied replica
            // (index 0) and fire a concurrent burst at it. All forwards
            // start before the first failure records, so the breaker sees
            // enough consecutive failures to trip even though the health
            // view ejects the replica after two. The bodies name an
            // unregistered task — the healthy successor answers each with
            // a cheap 4xx that never touches the phase's client stats.
            let ring = HashRing::new(addrs, DEFAULT_VNODES);
            let key = (0..u64::MAX)
                .map(|k| format!("breakerprobe{k}"))
                .find(|k| ring.route(k) == Some(0))
                .expect("some key routes to the proxied replica");
            thread::scope(|s| {
                for _ in 0..4 {
                    let key = &key;
                    s.spawn(move || {
                        let ccfg = ClientConfig {
                            connect_timeout: Duration::from_secs(1),
                            read_timeout: Some(Duration::from_secs(5)),
                            retries: 0,
                            backoff: Duration::from_millis(10),
                            deadline: None,
                        };
                        if let Ok(mut c) = Client::connect_with(raddr, ccfg) {
                            let body = PredictRequest::text(key, "trip").to_json();
                            let _ = c.roundtrip("POST", "/predict", Some(&body));
                        }
                    });
                }
            });
        },
    )?;
    let row = merge("slow_replica", &outs);
    ensure!(row.ok > 0, "no 200s while one replica was slow");
    router.absorb(&rrep);
    greps.iter().for_each(|g| coord.absorb(g));
    println!("    {} ok / {} shed / {} err", row.ok, row.shed, row.errors);
    phases.push(row);

    // ---- stalled store ---------------------------------------------------
    println!(
        "  phase stalled_store: {:?} stall on cold tenant {:?} …",
        cfg.stall, fx.cold_tenant
    );
    source.stall(&fx.cold_tenant, cfg.stall);
    let mut specs: Vec<WorkerSpec> =
        (0..cfg.concurrency).map(|_| well(cfg.deadline)).collect();
    // one cold-tenant worker: each of its attempts wedges a gateway
    // thread for the stall duration, and the widened HTTP pool has to
    // absorb that without starving the resident tenants
    specs.push(WorkerSpec {
        tasks: vec![fx.cold_tenant.clone()],
        deadline: cfg.flood_deadline,
    });
    let (outs, rrep, greps) = phase(
        &fx,
        &source,
        &specs,
        cfg.phase_duration,
        cfg.seed + 2,
        upstream_read,
        false,
        &mut |_, _, _, _| {},
    )?;
    source.heal(&fx.cold_tenant);
    let row = merge("stalled_store", &outs);
    let well_row = merge("stalled_store_well", &outs[..cfg.concurrency]);
    ensure!(well_row.ok > 0, "resident tenants starved during the store stall");
    router.absorb(&rrep);
    greps.iter().for_each(|g| coord.absorb(g));
    println!(
        "    {} ok / {} late / {} 504 (well-behaved ok {})",
        row.ok, row.late_ok, row.deadline_504, well_row.ok
    );
    phases.push(row);

    // ---- flood -----------------------------------------------------------
    println!(
        "  phase flood: {} workers on {:?} at {:?} budget …",
        cfg.flood_workers, fx.tenants[0], cfg.flood_deadline
    );
    let mut specs: Vec<WorkerSpec> = (0..cfg.flood_workers)
        .map(|_| WorkerSpec {
            tasks: vec![fx.tenants[0].clone()],
            deadline: cfg.flood_deadline,
        })
        .collect();
    let others: Vec<String> = fx.tenants[1..].to_vec();
    for _ in 0..cfg.concurrency {
        specs.push(WorkerSpec { tasks: others.clone(), deadline: cfg.deadline });
    }
    let (outs, rrep, greps) = phase(
        &fx,
        &source,
        &specs,
        cfg.phase_duration.mul_f64(1.5),
        cfg.seed + 3,
        upstream_read,
        false,
        &mut |_, _, _, _| {},
    )?;
    let row = merge("flood", &outs);
    let well_row = merge("flood_well", &outs[cfg.flood_workers..]);
    ensure!(well_row.ok > 0, "well-behaved tenants starved during the flood");
    let flood_well_p99_ms = well_row.p99_ms;
    let p99_ratio = flood_well_p99_ms / baseline_p99_ms;
    router.absorb(&rrep);
    greps.iter().for_each(|g| coord.absorb(g));
    println!(
        "    flood: {} req / {} shed / {} 504 | well-behaved p99 {:.1}ms ({:.2}x baseline)",
        row.requests, row.shed, row.deadline_504, flood_well_p99_ms, p99_ratio
    );
    phases.push(row);

    // ---- kill owner ------------------------------------------------------
    println!("  phase kill_owner: shut down the owner of {:?} mid-traffic …", fx.tenants[0]);
    let specs: Vec<WorkerSpec> =
        (0..cfg.concurrency.max(2)).map(|_| well(cfg.deadline)).collect();
    let target = fx.tenants[0].clone();
    let (outs, rrep, greps) = phase(
        &fx,
        &source,
        &specs,
        cfg.phase_duration.mul_f64(1.5),
        cfg.seed + 4,
        upstream_read,
        false,
        &mut |gateways, addrs, _, _| {
            let ring = HashRing::new(addrs, DEFAULT_VNODES);
            let victim = ring.route(&target).expect("non-empty ring");
            let dead = gateways.swap_remove(victim);
            let _ = dead.shutdown();
        },
    )?;
    let row = merge("kill_owner", &outs);
    ensure!(row.ok > 0, "no 200s survived the owner kill");
    router.absorb(&rrep);
    greps.iter().for_each(|g| coord.absorb(g));
    println!("    {} ok / {} shed / {} err", row.ok, row.shed, row.errors);
    phases.push(row);

    Ok(ChaosReport { phases, flood_well_p99_ms, p99_ratio, router, coordinator: coord })
}

/// Atomically persist the report (same contract as the other benches).
pub fn write_report(path: &Path, report: &Json) -> Result<()> {
    loadgen::write_report(path, report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_report() -> ChaosReport {
        let p = |name: &str, ok: u64, late: u64, shed: u64| PhaseStats {
            name: name.to_string(),
            requests: ok + shed + 10,
            ok,
            late_ok: late,
            shed,
            deadline_504: 4,
            errors: 1,
            p50_ms: 6.0,
            p99_ms: 18.0,
        };
        ChaosReport {
            phases: vec![
                p("baseline", 200, 0, 0),
                p("slow_replica", 150, 0, 3),
                p("stalled_store", 140, 0, 0),
                p("flood", 300, 0, 120),
                p("kill_owner", 160, 0, 5),
            ],
            flood_well_p99_ms: 21.0,
            p99_ratio: 21.0 / 18.0,
            router: RouterTotals {
                breaker_trips: 2,
                breaker_fast_fails: 9,
                deadline_rejected: 3,
                reroutes: 11,
                ejections: 1,
            },
            coordinator: CoordinatorTotals {
                requests: 900,
                expired_queue: 12,
                expired_exec: 5,
                late_replies: 2,
            },
        }
    }

    /// Pins the BENCH_chaos.json v1 schema CI validates against.
    #[test]
    fn report_json_schema() {
        let report = sample_report();
        let cfg = ChaosBenchConfig::default();
        let back = Json::parse(&report.to_json(&cfg).to_string()).unwrap();
        assert_eq!(back.at("bench").as_str(), Some("chaos"));
        assert_eq!(back.at("schema_version").as_usize(), Some(1));
        assert_eq!(back.at("config").at("tenants").as_usize(), Some(4));
        let rows = back.at("phases").as_arr().unwrap();
        assert_eq!(rows.len(), 5);
        let names: Vec<&str> =
            rows.iter().filter_map(|r| r.at("name").as_str()).collect();
        assert_eq!(
            names,
            ["baseline", "slow_replica", "stalled_store", "flood", "kill_owner"]
        );
        for row in rows {
            assert!(row.at("ok").as_usize().unwrap() > 0);
            assert_eq!(row.at("late_ok").as_usize(), Some(0));
            assert!(row.at("p99_ms").as_f64().is_some());
        }
        assert!(back.at("router").at("breaker_trips").as_usize().unwrap() > 0);
        assert!(back.at("coordinator").at("expired_queue").as_usize().is_some());
        let slo = back.at("slo");
        assert_eq!(slo.at("late_ok_total").as_usize(), Some(0));
        assert_eq!(slo.at("zero_late").as_bool(), Some(true));
        assert_eq!(slo.at("p99_ok").as_bool(), Some(true));
        assert_eq!(slo.at("shed_bounded").as_bool(), Some(true));
        assert_eq!(slo.at("pass").as_bool(), Some(true));
        assert!(slo.at("p99_ratio_limit").as_f64().unwrap() >= 3.0 - 1e-9);
    }

    /// A late 200 anywhere, or a flood p99 blowout, fails the gate.
    #[test]
    fn slo_gate_trips_on_late_replies_and_p99() {
        let cfg = ChaosBenchConfig::default();
        let mut late = sample_report();
        late.phases[3].late_ok = 1;
        let j = late.to_json(&cfg);
        assert_eq!(j.at("slo").at("zero_late").as_bool(), Some(false));
        assert_eq!(j.at("slo").at("pass").as_bool(), Some(false));

        let mut slow = sample_report();
        slow.p99_ratio = 4.2;
        let j = slow.to_json(&cfg);
        assert_eq!(j.at("slo").at("p99_ok").as_bool(), Some(false));
        assert_eq!(j.at("slo").at("pass").as_bool(), Some(false));
    }

    #[test]
    fn percentiles_from_sorted_seconds() {
        let lat = [0.001, 0.002, 0.003, 0.010];
        assert_eq!(pctl_ms(&lat, 0.50), 2.0);
        assert_eq!(pctl_ms(&lat, 0.99), 10.0);
        assert_eq!(pctl_ms(&[], 0.99), 0.0);
    }

    #[test]
    fn shed_rate_is_bounded_by_construction() {
        let r = sample_report();
        assert!(r.shed_rate() > 0.0 && r.shed_rate() < 0.95);
    }
}
