"""AOT pipeline: lower every executable to HLO *text* + write the manifest.

Run once per preset (``make artifacts``); Python never appears on the Rust
request path afterwards.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the published
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

The manifest (``artifacts/<preset>/manifest.json``) is the Rust runtime's
source of truth: for every executable it records the *flattened* input and
output leaves — group (top-level argument name), path, shape, dtype — in
the exact positional order of the HLO entry computation, plus the model
config. Rust packs parameter banks positionally from this.

Caching: each executable records a content hash of (compiler sources,
config, batch). Unchanged entries are skipped on re-run; ``make artifacts``
is a no-op when nothing changed.
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import re
import sys
import time
from typing import Any, Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp
import jax.tree_util as tu

from . import model as M
from . import steps

# ---------------------------------------------------------------------------
# artifact registry
# ---------------------------------------------------------------------------

# adapter-size sweeps (paper: Fig. 4 uses 2^0..2^9; GLUE uses {8,64,256};
# the additional suite uses {2..64}; SQuAD uses {2,8,64,256})
# sized for the reproduction's d=64 MiniBERT: m=1 trains ~0.7% of the base,
# m=64 ~30% — the same two-orders-of-magnitude spread as the paper's Fig. 4
CLS_ADAPTER_SIZES = {
    "default": [1, 2, 4, 8, 16, 32, 64],
    "test": [4, 8],
}
REG_ADAPTER_SIZES = {"default": [4, 16, 64], "test": [8]}
SPAN_ADAPTER_SIZES = {"default": [1, 4, 16, 64], "test": [8]}
TOPK_RANGE = {"default": list(range(1, 7)), "test": [1, 2]}
REG_SPAN_TOPK = {"default": [1, 2, 4, 6], "test": [1, 2]}
BATCH = {"default": 16, "test": 8}


@dataclasses.dataclass
class Artifact:
    name: str
    fn: Callable
    args: Tuple
    arg_names: List[str]
    meta: Dict[str, Any]


def build_registry(preset: str) -> List[Artifact]:
    cfg0 = M.PRESETS[preset]
    b = BATCH[preset]
    arts: List[Artifact] = []

    train_names = ["frozen", "trained", "opt_m", "opt_v", "step", "batch", "lr"]
    pretrain_names = [
        "base", "opt_m", "opt_v", "step", "tokens", "segments", "attn_mask",
        "positions", "targets", "weights", "lr",
    ]
    fwd_ad_names = [
        "base", "adapters", "head", "gates", "tokens", "segments", "attn_mask",
    ]
    fwd_base_names = ["base", "head", "tokens", "segments", "attn_mask"]

    arts.append(Artifact(
        "pretrain_step", steps.make_pretrain_step(cfg0),
        steps.example_args_pretrain(cfg0, b), pretrain_names,
        {"kind": "mlm", "variant": "pretrain", "batch": b},
    ))
    arts.append(Artifact(
        "embed_fwd", steps.make_embed_fwd(cfg0),
        steps.example_args_embed_fwd(cfg0, b),
        ["tok_embed", "tokens", "attn_mask"],
        {"kind": "embed", "variant": "fwd", "batch": b},
    ))

    def add_family(kind, adapter_sizes, topk_list, lnonly):
        for m in adapter_sizes:
            cfg = dataclasses.replace(cfg0, adapter_size=m)
            arts.append(Artifact(
                f"{kind}_train_adapter_m{m}",
                steps.make_train_adapter_step(cfg, kind),
                steps.example_args_train(cfg, kind, "adapter", b),
                train_names,
                {"kind": kind, "variant": "adapter", "m": m, "batch": b},
            ))
            arts.append(Artifact(
                f"{kind}_fwd_adapter_m{m}",
                steps.make_fwd_adapter(cfg, kind),
                steps.example_args_fwd_adapter(cfg, kind, b),
                fwd_ad_names,
                {"kind": kind, "variant": "fwd_adapter", "m": m, "batch": b},
            ))
        for k in topk_list:
            arts.append(Artifact(
                f"{kind}_train_topk_k{k}",
                steps.make_train_topk_step(cfg0, kind, k),
                steps.example_args_train(cfg0, kind, "topk", b, k=k),
                train_names,
                {"kind": kind, "variant": "topk", "k": k, "batch": b},
            ))
        if lnonly:
            arts.append(Artifact(
                f"{kind}_train_lnonly",
                steps.make_train_lnonly_step(cfg0, kind),
                steps.example_args_train(cfg0, kind, "lnonly", b),
                train_names,
                {"kind": kind, "variant": "lnonly", "batch": b},
            ))
        arts.append(Artifact(
            f"{kind}_fwd_base",
            steps.make_fwd_base(cfg0, kind),
            steps.example_args_fwd_base(cfg0, kind, b),
            fwd_base_names,
            {"kind": kind, "variant": "fwd_base", "batch": b},
        ))

    add_family("cls", CLS_ADAPTER_SIZES[preset], TOPK_RANGE[preset], lnonly=True)
    add_family("reg", REG_ADAPTER_SIZES[preset], REG_SPAN_TOPK[preset], lnonly=True)
    add_family("span", SPAN_ADAPTER_SIZES[preset], REG_SPAN_TOPK[preset], lnonly=False)
    return arts


# ---------------------------------------------------------------------------
# lowering
# ---------------------------------------------------------------------------


def to_hlo_text(lowered) -> str:
    from jax._src.lib import xla_client as xc

    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


_DTYPE_NAMES = {"float32": "f32", "int32": "i32", "float64": "f64", "int64": "i64"}


def _leaf_entries(tree, arg_names):
    """Flatten a tuple of pytrees into manifest leaf entries, in HLO order."""
    entries = []
    for idx, (arg, name) in enumerate(zip(tree, arg_names)):
        leaves = tu.tree_flatten_with_path(arg)[0]
        for path, leaf in leaves:
            p = name + "".join(_fmt_key(k) for k in path)
            entries.append({
                "name": p,
                "group": name,
                "shape": list(leaf.shape),
                "dtype": _DTYPE_NAMES[str(leaf.dtype)],
            })
    return entries


def _out_entries(out_tree):
    leaves = tu.tree_flatten_with_path(out_tree)[0]
    entries = []
    for path, leaf in leaves:
        p = "out" + "".join(_fmt_key(k) for k in path)
        entries.append({
            "name": p,
            "group": _out_group(path),
            "shape": list(leaf.shape),
            "dtype": _DTYPE_NAMES[str(leaf.dtype)],
        })
    return entries


def _out_group(path) -> str:
    """Top-level tuple index of the output — Rust splits results by it."""
    if path and hasattr(path[0], "idx"):
        return f"out{path[0].idx}"
    return "out0"


def _fmt_key(k) -> str:
    if hasattr(k, "key"):
        return f"/{k.key}"
    if hasattr(k, "idx"):
        return f"/{k.idx}"
    return f"/{k}"


def _source_hash() -> str:
    here = os.path.dirname(__file__)
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        if "__pycache__" in root:
            continue
        for f in sorted(files):
            if f.endswith(".py"):
                h.update(open(os.path.join(root, f), "rb").read())
    return h.hexdigest()[:16]


def lower_all(preset: str, out_dir: str, only: str | None = None,
              force: bool = False) -> None:
    cfg = M.PRESETS[preset]
    os.makedirs(out_dir, exist_ok=True)
    manifest_path = os.path.join(out_dir, "manifest.json")
    old: Dict[str, Any] = {}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            old = {e["name"]: e for e in json.load(f).get("executables", [])}

    src_hash = _source_hash()
    registry = build_registry(preset)
    entries = []
    n_lowered = 0
    for art in registry:
        if only and not re.search(only, art.name):
            if art.name in old:
                entries.append(old[art.name])
            continue
        file_name = f"{art.name}.hlo.txt"
        file_path = os.path.join(out_dir, file_name)
        content_key = hashlib.sha256(
            json.dumps([src_hash, dataclasses.asdict(cfg), art.meta],
                       sort_keys=True).encode()
        ).hexdigest()[:16]
        prev = old.get(art.name)
        if (not force and prev and prev.get("content_key") == content_key
                and os.path.exists(file_path)):
            entries.append(prev)
            continue
        t0 = time.time()
        # keep_unused=True: the manifest promises a 1:1 positional mapping
        # between flattened example args and HLO ENTRY parameters, so jit
        # must not DCE inputs that a particular graph ignores (e.g. the
        # fwd graphs never read ``mlm_bias``).
        lowered = jax.jit(art.fn, keep_unused=True).lower(*art.args)
        text = to_hlo_text(lowered)
        with open(file_path, "w") as f:
            f.write(text)
        out_shapes = jax.eval_shape(art.fn, *art.args)
        entry = {
            "name": art.name,
            "file": file_name,
            "content_key": content_key,
            "meta": art.meta,
            "inputs": _leaf_entries(art.args, art.arg_names),
            "outputs": _out_entries(out_shapes),
        }
        entries.append(entry)
        n_lowered += 1
        print(f"  lowered {art.name:32s} {time.time()-t0:6.2f}s "
              f"{len(text)/1e6:6.2f} MB", flush=True)

    manifest = {
        "preset": preset,
        "config": dataclasses.asdict(cfg),
        "batch": BATCH[preset],
        "adam": {"b1": M.ADAM_B1, "b2": M.ADAM_B2, "eps": M.ADAM_EPS},
        "executables": entries,
    }
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"{preset}: {n_lowered} lowered, {len(entries) - n_lowered} cached "
          f"-> {manifest_path}")


def kernel_report(preset: str) -> None:
    """Structural VMEM/roofline estimates for the Pallas kernels.

    interpret=True gives CPU-numpy timings only, so TPU viability is
    argued from footprints and arithmetic intensity (EXPERIMENTS.md §Perf).
    """
    cfg = M.PRESETS[preset]
    d = cfg.d
    b = BATCH[preset]
    rows = b * cfg.seq
    block_rows = min(128, rows)
    print(f"preset {preset}: d={d} seq={cfg.seq} batch={b} "
          f"(rows/block={block_rows})")
    print(f"{'kernel':28} {'VMEM/block':>12} {'FLOPs/block':>12} "
          f"{'bytes/block':>12} {'intensity':>10}")
    for m in CLS_ADAPTER_SIZES[preset]:
        # fused adapter: x block + W1 + W2 + biases + h scratch
        vmem = 4 * (block_rows * d + d * m + m * d + m + d + block_rows * m)
        flops = 2 * block_rows * (d * m + m * d)
        # HBM traffic: x in, y out, weights once (amortized over blocks)
        traffic = 4 * (2 * block_rows * d + 2 * d * m + m + d)
        print(f"adapter m={m:<4} fwd           {vmem:>11,}B {flops:>12,} "
              f"{traffic:>11,}B {flops/traffic:>9.2f}")
    # attention: per (batch*head): q,k,v,o + running stats
    s_len, dh = cfg.seq, cfg.d // cfg.n_heads
    vmem = 4 * (4 * s_len * dh + 3 * s_len)
    flops = 2 * 2 * s_len * s_len * dh
    traffic = 4 * 4 * s_len * dh
    print(f"attention (per head)         {vmem:>11,}B {flops:>12,} "
          f"{traffic:>11,}B {flops/traffic:>9.2f}")
    print("\nall adapter weight sets fit VMEM whole (<= "
          f"{4*(d*max(CLS_ADAPTER_SIZES[preset])*2)/1024:.0f} KiB vs 16 MiB); "
          "the adapter is bandwidth-bound (intensity < ~10), so fusing away "
          "2 of 3 activation round-trips is the available win.")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="artifacts root")
    ap.add_argument("--preset", default="all", choices=["default", "test", "all"])
    ap.add_argument("--only", default=None, help="regex filter on artifact names")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true", help="list artifacts and exit")
    ap.add_argument("--report", action="store_true",
                    help="print kernel VMEM/roofline estimates and exit")
    args = ap.parse_args()

    if args.report:
        for p in (["default", "test"] if args.preset == "all" else [args.preset]):
            kernel_report(p)
        return

    presets = ["default", "test"] if args.preset == "all" else [args.preset]
    if args.list:
        for p in presets:
            for a in build_registry(p):
                print(f"{p}/{a.name}")
        return
    for p in presets:
        lower_all(p, os.path.join(args.out, p), only=args.only, force=args.force)


if __name__ == "__main__":
    main()
