"""L1: fused LayerNorm Pallas kernel (row-parallel, one VMEM pass).

Used in the inference (``*_fwd``) graphs; the training graphs use the jnp
reference (:func:`compile.kernels.ref.layernorm_ref`) so XLA autodiff
differentiates it (LayerNorm parameters are trained per task — paper §2.1).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 128
EPS = 1e-6


def _ln_kernel(x_ref, gamma_ref, beta_ref, o_ref):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + EPS)
    o_ref[...] = (x - mu) * inv * gamma_ref[...][None, :] + beta_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def layernorm_pallas(x, gamma, beta, block_rows: int = DEFAULT_BLOCK_ROWS):
    """LayerNorm over the last dim. x: [rows, d]."""
    rows, d = x.shape
    pad = (-rows) % block_rows
    xp = jnp.concatenate([x, jnp.zeros((pad, d), x.dtype)], axis=0) if pad else x
    out = pl.pallas_call(
        _ln_kernel,
        grid=(xp.shape[0] // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=True,
    )(xp, gamma, beta)
    return out[:rows]


def layernorm_nd(x, gamma, beta):
    """LayerNorm over arbitrary leading dims: x [..., d]."""
    d = x.shape[-1]
    return layernorm_pallas(x.reshape((-1, d)), gamma, beta).reshape(x.shape)
