//! Model-check regression corpus for the hand-rolled sync primitives
//! (`check::sched` + `check::sync`).
//!
//! Two tiers live here:
//!
//! - **Scheduler self-tests** drive the explorer over `check::sync::shim`
//!   types explicitly (`force_controlled`), so they exercise the full
//!   controlled scheduler in *every* build: a seeded race is found and
//!   replayed from its token, an ABBA deadlock and a lost wakeup are both
//!   reported with the waits-for table.
//! - **Production suites** run the ported primitives — `PagedCache`
//!   single-flight, the `Recorder` ring, the kernel `Pool` handoff, the
//!   `Breaker` and `ClusterView` state machines — under `Opts::default()`.
//!   With `--features modelcheck` that explores ≥1000 schedules each and
//!   any failure panics with an `ADAPTERBERT_MC_REPLAY=` token; in a plain
//!   build the same bodies run as seeded stress iterations, so this file
//!   stays green (and useful) under tier-1 `cargo test`.
//!
//! Every assertion below is schedule-independent: it must hold on *any*
//! legal interleaving, which is what makes exploration sound.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

use adapterbert::check::sched::{self, explore, Opts, Schedule};
use adapterbert::check::sync::shim;
use adapterbert::cluster::{Breaker, BreakerPolicy, ClusterView, HealthPolicy};
use adapterbert::coordinator::PagedCache;
use adapterbert::obs::trace::{Recorder, SpanKind};
use adapterbert::runtime::native::pool::Pool;
use anyhow::bail;

/// Stringify a panic payload (the explorer panics with `String`).
fn panic_text(p: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic".to_string()
    }
}

/// Run `body` expecting the explorer to find a failure; returns the
/// explorer's panic message. The default panic hook is silenced for the
/// duration — these panics are the test's expected outcome, not noise.
/// (The hook is process-global, so a concurrent test failing inside the
/// window loses its backtrace print, not its failure.)
fn expect_failure(opts: Opts, body: impl Fn() + Sync) -> String {
    let hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let r = catch_unwind(AssertUnwindSafe(|| explore(opts, body)));
    std::panic::set_hook(hook);
    match r {
        Ok(_) => panic!("exploration was expected to find a failure"),
        Err(p) => panic_text(p),
    }
}

/// The replay token out of an explorer failure message.
fn replay_token(msg: &str) -> String {
    let key = "ADAPTERBERT_MC_REPLAY=";
    let at = msg.rfind(key).unwrap_or_else(|| {
        panic!("failure message carries no replay token: {msg}")
    });
    msg[at + key.len()..].trim().to_string()
}

/// Under `modelcheck` the suites must actually explore the schedule
/// budget the issue pins (≥1000); plain builds run the degraded stress
/// mode and only need to have run at all.
fn assert_coverage(report: &sched::Report) {
    assert!(report.explored > 0);
    if cfg!(feature = "modelcheck") {
        assert!(report.controlled, "modelcheck build must run controlled");
        assert!(
            report.explored >= 1000,
            "expected >=1000 schedules, explored {}",
            report.explored
        );
    }
}

// ---------------------------------------------------------------------------
// Scheduler self-tests (controlled in every build)
// ---------------------------------------------------------------------------

/// A classic lost update: two threads do load-then-store increments on a
/// shared shim atomic. Any schedule that interleaves the two loads
/// before either store drops an increment.
fn racy_increment_body() {
    let n = Arc::new(shim::AtomicUsize::new(0));
    let workers: Vec<_> = (0..2)
        .map(|_| {
            let n = Arc::clone(&n);
            sched::spawn(move || {
                let v = n.load(Ordering::SeqCst);
                n.store(v + 1, Ordering::SeqCst);
            })
        })
        .collect();
    for w in workers {
        w.join().unwrap();
    }
    assert_eq!(n.load(Ordering::SeqCst), 2, "lost update");
}

#[test]
fn explorer_finds_racy_increment_and_replays_it() {
    let opts = Opts { schedules: 4096, force_controlled: true, ..Opts::default() };
    let msg = expect_failure(opts, racy_increment_body);
    assert!(msg.contains("lost update"), "wrong failure: {msg}");

    // the token must parse and — since DFS runs first and is
    // deterministic — be a path token, stable across runs
    let tok = replay_token(&msg);
    assert!(tok.starts_with("path:"), "DFS should find this race: {tok}");
    assert!(Schedule::parse(&tok).is_some(), "unparseable token: {tok}");

    // pinned replay: the exact failing schedule must still fail
    let replay = Opts {
        replay: Schedule::parse(&tok),
        force_controlled: true,
        ..Opts::default()
    };
    let msg2 = expect_failure(replay, racy_increment_body);
    assert!(msg2.contains("replay"), "replay failure not flagged: {msg2}");
    assert!(msg2.contains("lost update"), "replay found a different bug: {msg2}");
}

#[test]
fn explorer_reports_abba_deadlock_with_waits_for_table() {
    let body = || {
        let a = Arc::new(shim::Mutex::new(()));
        let b = Arc::new(shim::Mutex::new(()));
        let (a2, b2) = (Arc::clone(&a), Arc::clone(&b));
        let t = sched::spawn(move || {
            let _ga = a2.lock().unwrap();
            let _gb = b2.lock().unwrap();
        });
        {
            let _gb = b.lock().unwrap();
            let _ga = a.lock().unwrap();
        }
        let _ = t.join();
    };
    let opts = Opts { schedules: 8192, force_controlled: true, ..Opts::default() };
    let msg = expect_failure(opts, body);
    assert!(
        msg.contains("deadlock: no runnable thread"),
        "expected a deadlock report: {msg}"
    );
    assert!(msg.contains("ADAPTERBERT_MC_REPLAY="), "no replay token: {msg}");
}

#[test]
fn explorer_catches_lost_wakeup() {
    // the notifier signals without ever establishing the predicate, so
    // the waiter parks forever — the drain loop reports it as a deadlock
    let body = || {
        let gate = Arc::new((shim::Mutex::new(false), shim::Condvar::new()));
        let g2 = Arc::clone(&gate);
        let t = sched::spawn(move || {
            g2.1.notify_one();
        });
        let (lock, cv) = &*gate;
        let mut ready = lock.lock().unwrap();
        while !*ready {
            ready = cv.wait(ready).unwrap();
        }
        drop(ready);
        let _ = t.join();
    };
    let opts = Opts { schedules: 64, force_controlled: true, ..Opts::default() };
    let msg = expect_failure(opts, body);
    assert!(
        msg.contains("deadlock: no runnable thread"),
        "expected the parked waiter to be reported: {msg}"
    );
}

// ---------------------------------------------------------------------------
// PagedCache: single-flight cold loads
// ---------------------------------------------------------------------------

/// Three concurrent `get_or_load`s of one cold key: exactly one runs the
/// loader, the others join its gate (or hit afterwards). Holds on any
/// schedule because the loader installs the value *before* removing the
/// gate.
fn single_flight_body() {
    let cache: Arc<PagedCache<u32>> = Arc::new(PagedCache::new(None));
    let loads = Arc::new(AtomicUsize::new(0));
    let workers: Vec<_> = (0..2)
        .map(|_| {
            let cache = Arc::clone(&cache);
            let loads = Arc::clone(&loads);
            sched::spawn(move || {
                cache
                    .get_or_load("bank", || {
                        loads.fetch_add(1, Ordering::SeqCst);
                        Ok((7u32, 64))
                    })
                    .unwrap()
            })
        })
        .collect();
    let mine = cache
        .get_or_load("bank", || {
            loads.fetch_add(1, Ordering::SeqCst);
            Ok((7u32, 64))
        })
        .unwrap();
    assert_eq!(mine, 7);
    for w in workers {
        assert_eq!(w.join().unwrap(), 7);
    }
    assert_eq!(loads.load(Ordering::SeqCst), 1, "double-fetch");
    let snap = cache.snapshot();
    assert_eq!(snap.misses, 1, "only the loader counts a miss");
    assert_eq!(snap.hits, 2, "both waiters resolve via a hit");
    assert_eq!(snap.load_errors, 0);
    assert_eq!(snap.cold_loads, 1);
}

#[test]
fn paged_cache_single_flight_loads_once() {
    let report = explore(Opts::default(), single_flight_body);
    assert_coverage(&report);
}

#[test]
fn paged_cache_failed_load_releases_gate() {
    let report = explore(Opts::default(), || {
        let cache: Arc<PagedCache<u32>> = Arc::new(PagedCache::new(None));
        let calls = Arc::new(AtomicUsize::new(0));
        // first loader run fails; whoever loads next succeeds
        let workers: Vec<_> = (0..2)
            .map(|_| {
                let cache = Arc::clone(&cache);
                let calls = Arc::clone(&calls);
                sched::spawn(move || {
                    cache.get_or_load("bank", || {
                        if calls.fetch_add(1, Ordering::SeqCst) == 0 {
                            bail!("injected cold-load failure");
                        }
                        Ok((7u32, 64))
                    })
                })
            })
            .collect();
        let mut oks = 0;
        let mut errs = 0;
        for w in workers {
            match w.join().unwrap() {
                Ok(v) => {
                    assert_eq!(v, 7);
                    oks += 1;
                }
                Err(_) => errs += 1,
            }
        }
        // the failure surfaces to exactly one caller; the gate reopens so
        // the other caller's retry loads for real (no stuck gate, no
        // poisoned key)
        assert_eq!((oks, errs), (1, 1));
        assert!(cache.contains("bank"));
        let snap = cache.snapshot();
        assert_eq!(snap.load_errors, 1);
        assert_eq!(snap.misses, 2, "one failed + one successful loader run");
        // a late reader must hit without ever invoking its loader
        let v = cache
            .get_or_load("bank", || bail!("resident key must not reload"))
            .unwrap();
        assert_eq!(v, 7);
    });
    assert_coverage(&report);
}

// ---------------------------------------------------------------------------
// obs::trace: recorder ring under wraparound
// ---------------------------------------------------------------------------

#[test]
fn trace_ring_snapshots_stay_consistent_under_wraparound() {
    let report = explore(Opts::default(), || {
        let rec = Arc::new(Recorder::new(2)); // capacity 2 < 3 writers
        rec.set_enabled(true);
        let workers: Vec<_> = (0..2)
            .map(|i| {
                let rec = Arc::clone(&rec);
                sched::spawn(move || {
                    let h = rec.begin(SpanKind::Request, format!("r{i}"));
                    rec.record(&h);
                })
            })
            .collect();
        let h = rec.begin(SpanKind::Request, "r2");
        rec.record(&h);
        // mid-flight snapshot: racing with the writers, it may see any
        // subset, but never a torn span and never more than capacity
        let mid = rec.snapshot();
        assert!(mid.len() <= rec.capacity());
        for s in &mid {
            assert_eq!(s.kind, SpanKind::Request);
            assert!(matches!(s.rid.as_str(), "r0" | "r1" | "r2"), "torn rid {}", s.rid);
            assert!(s.start_us() > 0);
        }
        for w in workers {
            w.join().unwrap();
        }
        // quiescent: 3 claims over 2 slots — full ring, total preserved
        assert_eq!(rec.recorded(), 3);
        let fin = rec.snapshot();
        assert_eq!(fin.len(), 2);
        for s in &fin {
            assert!(matches!(s.rid.as_str(), "r0" | "r1" | "r2"));
        }
    });
    assert_coverage(&report);
}

// ---------------------------------------------------------------------------
// runtime::native::pool: wake/handoff protocol
// ---------------------------------------------------------------------------

#[test]
fn pool_handoff_covers_every_index_exactly_once() {
    // the caller's completion wait is a yield loop, which makes DFS
    // prefixes degenerate (it enumerates spin iterations); random
    // schedules probe the wake/claim races without that blowup
    let opts = Opts { exhaustive: false, ..Opts::default() };
    let report = explore(opts, || {
        let pool = Pool::new(3);
        let hits: Vec<AtomicUsize> = (0..8).map(|_| AtomicUsize::new(0)).collect();
        // back-to-back calls reuse the parked workers (epoch bump): a
        // lost wakeup on the second call would strand its panels
        for _ in 0..2 {
            pool.parallel_for(hits.len(), &|i| {
                hits[i].fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(pool); // joins workers; a hung worker deadlocks the schedule
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 2, "index {i} lost or repeated");
        }
    });
    assert_coverage(&report);
}

// ---------------------------------------------------------------------------
// cluster::breaker: trip-once and half-open admission
// ---------------------------------------------------------------------------

#[test]
fn breaker_trips_once_and_admits_exactly_one_trial() {
    let report = explore(Opts::default(), || {
        let policy = BreakerPolicy { open_after: 2, cooldown: Duration::ZERO };
        let b = Arc::new(Breaker::new(1, policy));
        // two racing failure reports: the streak reaches 2 exactly once,
        // so the circuit trips exactly once (no double-trip, no lost trip)
        let workers: Vec<_> = (0..2)
            .map(|_| {
                let b = Arc::clone(&b);
                sched::spawn(move || b.record_failure(0))
            })
            .collect();
        for w in workers {
            w.join().unwrap();
        }
        assert!(b.is_open(0), "stuck closed after open_after failures");
        assert_eq!(b.trips(), 1);
        // cooldown elapsed (zero): racing callers get exactly one
        // half-open trial between them, never two, never zero
        let allows: Vec<_> = (0..2)
            .map(|_| {
                let b = Arc::clone(&b);
                sched::spawn(move || b.allow(0))
            })
            .collect();
        let mut granted = 0;
        for w in allows {
            if w.join().unwrap() {
                granted += 1;
            }
        }
        assert_eq!(granted, 1, "exactly one trial through a half-open circuit");
        // the trial's success closes the circuit — no stuck-open
        b.record_success(0);
        assert!(b.allow(0));
        assert!(!b.is_open(0));
        assert_eq!(b.trips(), 1, "half-open transitions are not trips");
        assert_eq!(b.fast_fails(), 1, "the losing racer fast-failed");
    });
    assert_coverage(&report);
}

// ---------------------------------------------------------------------------
// cluster::health: eject/readmit flap accounting
// ---------------------------------------------------------------------------

#[test]
fn cluster_view_flap_counters_balance() {
    let report = explore(Opts::default(), || {
        let policy = HealthPolicy { fail_after: 1, pass_after: 1, ..HealthPolicy::default() };
        let view =
            Arc::new(ClusterView::new(vec!["a".into(), "b".into()], &policy));
        // a prober and a forward-error reporter flap node 0 as fast as
        // the hysteresis allows, in any order
        let v1 = Arc::clone(&view);
        let failer = sched::spawn(move || {
            v1.record_fail(0);
            v1.record_fail(0);
        });
        let v2 = Arc::clone(&view);
        let passer = sched::spawn(move || {
            v2.record_pass(0);
            v2.record_pass(0);
        });
        failer.join().unwrap();
        passer.join().unwrap();
        // every counted ejection is a true→false edge and every counted
        // readmission a false→true edge, so on any interleaving the
        // ledger reconciles with the final liveness bit
        let ej = view.ejections.load(Ordering::SeqCst);
        let re = view.readmissions.load(Ordering::SeqCst);
        if view.is_alive(0) {
            assert_eq!(ej, re, "alive node with unbalanced flap ledger");
        } else {
            assert_eq!(ej, re + 1, "dead node must hold one open ejection");
        }
        assert!(view.is_alive(1), "untouched node ejected");
        let mask = view.alive_mask();
        assert_eq!(view.healthy_count(), mask.iter().filter(|b| **b).count());
    });
    assert_coverage(&report);
}

// ---------------------------------------------------------------------------
// Pinned schedules: known-good seeds/paths replayed on every run
// ---------------------------------------------------------------------------

/// Regression pins: schedules that once explored the single-flight suite
/// and must keep passing. (Failing schedules pin themselves via
/// `explorer_finds_racy_increment_and_replays_it`.)
const PINNED_GOOD: &[&str] = &[
    "seed:1",
    "seed:ada97",
    "seed:deadbeef",
    "path:0",
    "path:1.0.1",
];

#[test]
fn pinned_schedules_still_pass() {
    for tok in PINNED_GOOD {
        let schedule = Schedule::parse(tok);
        assert!(schedule.is_some(), "pinned token no longer parses: {tok}");
        let opts = Opts { replay: schedule, stress_iters: 2, ..Opts::default() };
        let report = explore(opts, single_flight_body);
        if cfg!(feature = "modelcheck") {
            assert_eq!(report.explored, 1, "replay runs exactly one schedule");
        }
    }
}
