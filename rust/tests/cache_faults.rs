//! Fault injection on the store→coordinator fetch seam (PR 6).
//!
//! The paged bank cache turned "every bank is resident" into a fallible
//! fetch: a predict for an evicted task streams its bank back in from
//! the durable store, and that read can be slow, interrupted, or find
//! the file gone. These tests wrap the store in a [`FaultStore`] (a
//! test-only [`BankSource`]) and inject exactly those failures:
//!
//! * resident tasks keep serving — correctly and without blocking —
//!   while another task's cold load is slow or failing;
//! * a failing cold load answers `503` with a descriptive error, and a
//!   retry after the fault heals succeeds;
//! * a herd of concurrent requests for one cold task runs a single
//!   store fetch (single-flight);
//! * a bank file deleted mid-serving surfaces the store's own error and
//!   heals when the file comes back (real disk store, no wrapper);
//! * the same request trace through an unbounded cache and a budget
//!   forcing evictions produces byte-identical predictions, in both
//!   per-task and fused execution modes.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::Result;

use adapterbert::coordinator::server::{Prediction, Request};
use adapterbert::coordinator::{
    ExecMode, FlushPolicy, Server, ServerConfig,
};
use adapterbert::data::grammar::World;
use adapterbert::data::tasks::{self, TaskKind, TaskSpec};
use adapterbert::eval::{predict_split, Predictions, TaskModel};
use adapterbert::model::params::NamedTensors;
use adapterbert::obs::trace::TraceHandle;
use adapterbert::runtime::Runtime;
use adapterbert::serve::{Client, Gateway, GatewayConfig, PredictRequest};
use adapterbert::store::{AdapterStore, BankMeta, BankSource};
use adapterbert::train::{self, PretrainConfig, TrainConfig};
use adapterbert::util::rng::Rng;

fn runtime() -> Arc<Runtime> {
    Arc::new(
        Runtime::open(
            Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")),
            "test",
        )
        .expect("open test preset (built-in presets synthesize their manifest)"),
    )
}

fn world(rt: &Runtime) -> World {
    World::new(rt.manifest.dims.vocab, 0)
}

fn pretrained_base(rt: &Arc<Runtime>) -> NamedTensors {
    static BASE: OnceLock<NamedTensors> = OnceLock::new();
    BASE.get_or_init(|| {
        train::load_or_pretrain(
            rt,
            &world(rt),
            &PretrainConfig { steps: 3000, log_every: 0, ..Default::default() },
            Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/runs/base_test.bank")),
        )
        .unwrap()
    })
    .clone()
}

fn cls_spec(name: &str, seed: u64) -> TaskSpec {
    TaskSpec {
        name: name.to_string(),
        kind: TaskKind::Cls { n_classes: 2, pair: false },
        metric: tasks::Metric::Accuracy,
        n_train: 240,
        n_val: 48,
        n_test: 48,
        purity: 0.85,
        noise: 0.0,
        seed,
    }
}

/// Three distinct trained adapters shared by every test in this file
/// (training dominates the suite's runtime; the faults don't care which
/// model they interrupt).
fn fixture(rt: &Arc<Runtime>) -> &'static Vec<(TaskModel, tasks::TaskData)> {
    static FIX: OnceLock<Vec<(TaskModel, tasks::TaskData)>> = OnceLock::new();
    FIX.get_or_init(|| {
        let base = pretrained_base(rt);
        (0..3u64)
            .map(|i| {
                let spec = cls_spec(&format!("fault{i}"), 61 + i);
                let data = tasks::generate(&world(rt), &spec, rt.manifest.dims.seq);
                let cfg = TrainConfig::new("cls_train_adapter_m4", 1e-3, 3, 0);
                let model = train::train_task(rt, &cfg, &data, &base).unwrap().model;
                (model, data)
            })
            .collect()
    })
}

fn class_preds(
    rt: &Arc<Runtime>,
    model: &TaskModel,
    base: &NamedTensors,
    split: &tasks::Split,
) -> Vec<usize> {
    match predict_split(rt, model, base, split, 2, None).unwrap() {
        Predictions::Class(v) => v,
        other => panic!("expected class predictions, got {other:?}"),
    }
}

/// One blocking prediction straight through the coordinator (no HTTP).
fn serve_one(
    server: &Server,
    rt: &Arc<Runtime>,
    task: &str,
    split: &tasks::Split,
    row: usize,
) -> Prediction {
    let seq = rt.manifest.dims.seq;
    let tokens: Vec<i32> = split.row_tokens(row).to_vec();
    let attn_mask: Vec<f32> =
        tokens.iter().map(|&t| if t == 0 { 0.0 } else { 1.0 }).collect();
    let (reply, rx) = mpsc::channel();
    server
        .submit_blocking(Request {
            task: task.to_string(),
            tokens,
            segments: vec![0; seq],
            attn_mask,
            reply,
            submitted: Instant::now(),
            deadline: None,
            trace: TraceHandle::none(),
        })
        .unwrap();
    rx.recv_timeout(Duration::from_secs(60)).unwrap().prediction
}

fn server_cfg(mode: ExecMode, cache_budget: Option<u64>) -> ServerConfig {
    ServerConfig {
        flush: FlushPolicy { max_batch: 4, max_delay: Duration::from_millis(2) },
        executors: 2,
        queue_capacity: 256,
        mode,
        cache_budget,
    }
}

// ---------------------------------------------------------------------------
// FaultStore: the injection seam
// ---------------------------------------------------------------------------

/// Per-task fault on the bank-fetch path. Metadata probes stay healthy —
/// faults model the expensive read, not the directory.
enum Fault {
    /// Sleep before delegating (slow disk, remote store).
    Slow(Duration),
    /// Fail every fetch with this message until healed.
    Fail(String),
    /// Fail the next `n` fetches, then pass — the transient-I/O class
    /// (`ErrorKind::Interrupted`, short reads that heal on retry).
    FailTimes(usize, String),
}

/// Test-only [`BankSource`] wrapping a real [`AdapterStore`]: delegates
/// everything, with injectable faults and a fetch counter on
/// [`BankSource::fetch_latest`].
struct FaultStore {
    inner: Arc<AdapterStore>,
    faults: Mutex<BTreeMap<String, Fault>>,
    fetches: Mutex<BTreeMap<String, u64>>,
}

impl FaultStore {
    fn new(inner: Arc<AdapterStore>) -> Arc<FaultStore> {
        Arc::new(FaultStore {
            inner,
            faults: Mutex::new(BTreeMap::new()),
            fetches: Mutex::new(BTreeMap::new()),
        })
    }

    fn inject(&self, task: &str, fault: Fault) {
        self.faults.lock().unwrap().insert(task.to_string(), fault);
    }

    fn heal(&self, task: &str) {
        self.faults.lock().unwrap().remove(task);
    }

    fn fetch_count(&self, task: &str) -> u64 {
        *self.fetches.lock().unwrap().get(task).unwrap_or(&0)
    }
}

impl BankSource for FaultStore {
    fn fetch_latest(
        &self,
        task: &str,
    ) -> Result<Option<(BankMeta, Arc<TaskModel>)>> {
        *self.fetches.lock().unwrap().entry(task.to_string()).or_default() += 1;
        // decide under the lock, act (sleep/fail) outside it
        enum Act {
            Sleep(Duration),
            Fail(String),
            Pass,
        }
        let act = {
            let mut faults = self.faults.lock().unwrap();
            match faults.get_mut(task) {
                Some(Fault::Slow(d)) => Act::Sleep(*d),
                Some(Fault::Fail(msg)) => Act::Fail(msg.clone()),
                Some(Fault::FailTimes(n, msg)) => {
                    if *n > 0 {
                        *n -= 1;
                        Act::Fail(msg.clone())
                    } else {
                        faults.remove(task);
                        Act::Pass
                    }
                }
                None => Act::Pass,
            }
        };
        match act {
            Act::Sleep(d) => std::thread::sleep(d),
            Act::Fail(msg) => {
                anyhow::bail!("injected fault reading bank for {task:?}: {msg}")
            }
            Act::Pass => {}
        }
        self.inner.fetch_latest(task)
    }

    fn latest_meta(&self, task: &str) -> Option<BankMeta> {
        self.inner.latest_meta(task)
    }

    fn latest_bank_bytes(&self, task: &str) -> Option<u64> {
        self.inner.latest_bank_bytes(task)
    }

    fn task_names(&self) -> Vec<String> {
        self.inner.task_names()
    }
}

// ---------------------------------------------------------------------------
// Scenarios
// ---------------------------------------------------------------------------

/// Resident tasks never block or 5xx while another task's cold load is
/// failing or slow; the failing task answers a descriptive 503 and heals.
#[test]
fn resident_tasks_unaffected_while_cold_load_fails_and_heals() {
    let rt = runtime();
    let base = pretrained_base(&rt);
    let fix = fixture(&rt);

    let store = Arc::new(AdapterStore::in_memory());
    let mut classes = BTreeMap::new();
    for (name, (model, _)) in ["fa", "fb", "fc"].iter().zip(fix) {
        store.register(name, model, 0.9).unwrap();
        classes.insert(name.to_string(), 2);
    }
    let exp: Vec<Vec<usize>> = fix
        .iter()
        .map(|(model, data)| class_preds(&rt, model, &base, &data.test))
        .collect();

    let faults = FaultStore::new(store.clone());
    // a budget makes startup lazy: every task starts cold
    let server = Server::start_with_source(
        rt.clone(),
        faults.clone(),
        &base,
        &classes,
        server_cfg(ExecMode::PerTask, Some(1 << 30)),
    )
    .unwrap();
    let gw = Gateway::start(
        rt.clone(),
        store.clone(),
        server,
        GatewayConfig { addr: "127.0.0.1:0".to_string(), ..Default::default() },
    )
    .unwrap();
    let addr = gw.local_addr().to_string();

    // warm fa and fb into residency (their own cold loads, fault-free)
    let mut client = Client::connect(&addr).unwrap();
    for (name, fx, exp) in [("fa", &fix[0], &exp[0]), ("fb", &fix[1], &exp[1])] {
        let resp = client.predict_ids(name, fx.1.test.row_tokens(0)).unwrap();
        assert_eq!(resp.pred_class, Some(exp[0]), "{name} warm-up");
    }

    // phase 1: fc's bank read fails hard — exactly two attempts
    faults.inject("fc", Fault::Fail("disk gone".into()));
    for attempt in 0..2 {
        let req = PredictRequest::ids("fc", fix[2].1.test.row_tokens(0).to_vec());
        let (status, j) = client
            .roundtrip("POST", "/predict_ids", Some(&req.to_json()))
            .unwrap();
        assert_eq!(status, 503, "attempt {attempt}: faulty cold load must 503");
        let msg = j.get("error").and_then(|e| e.as_str().map(String::from));
        let msg = msg.expect("503 carries an error message");
        assert!(
            msg.contains("cold load failed") && msg.contains("injected fault"),
            "attempt {attempt}: error not descriptive: {msg}"
        );
    }
    // resident tasks answer correctly straight through the fault
    for (name, fx, exp) in [("fa", &fix[0], &exp[0]), ("fb", &fix[1], &exp[1])] {
        let resp = client.predict_ids(name, fx.1.test.row_tokens(1)).unwrap();
        assert_eq!(resp.pred_class, Some(exp[1]), "{name} during fault");
    }

    // phase 2: heal, make the reload slow instead; resident traffic must
    // keep flowing while fc's cold load sleeps in the gateway worker
    faults.heal("fc");
    faults.inject("fc", Fault::Slow(Duration::from_millis(600)));
    let done = AtomicBool::new(false);
    let served_during_load = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        let done = &done;
        let served = &served_during_load;
        let addr = &addr;
        let exp = &exp;
        scope.spawn(move || {
            let mut slow_client = Client::connect(addr).unwrap();
            let t0 = Instant::now();
            let resp = slow_client
                .predict_ids("fc", fix[2].1.test.row_tokens(0))
                .unwrap();
            assert!(
                t0.elapsed() >= Duration::from_millis(600),
                "fc's cold load should have slept"
            );
            assert_eq!(resp.pred_class, Some(exp[2][0]), "fc after heal");
            done.store(true, Ordering::SeqCst);
        });
        // spin on the resident tasks until the slow load completes (the
        // deadline only matters if the slow request dies — the scope join
        // then reports its panic instead of hanging here)
        let deadline = Instant::now() + Duration::from_secs(30);
        let mut i = 1usize;
        while !done.load(Ordering::SeqCst) && Instant::now() < deadline {
            let row = i % 8;
            for (name, fx, exp) in
                [("fa", &fix[0], &exp[0]), ("fb", &fix[1], &exp[1])]
            {
                let resp =
                    client.predict_ids(name, fx.1.test.row_tokens(row)).unwrap();
                assert_eq!(resp.pred_class, Some(exp[row]), "{name} row {row}");
                if !done.load(Ordering::SeqCst) {
                    served.fetch_add(1, Ordering::SeqCst);
                }
            }
            i += 1;
        }
    });
    assert!(
        served_during_load.load(Ordering::SeqCst) >= 2,
        "resident tasks were starved during a 600ms cold load"
    );
    faults.heal("fc");

    // the cache counters tell the same story over /metrics
    let metrics = client.metrics().unwrap();
    let cache = metrics.at("cache");
    assert_eq!(cache.at("load_errors").as_usize(), Some(2));
    assert_eq!(cache.at("resident").as_usize(), Some(3), "all three resident now");
    assert_eq!(
        cache.at("misses").as_usize(),
        Some(5),
        "3 successful cold loads + 2 failed attempts"
    );
    assert_eq!(cache.at("cold_loads").as_usize(), Some(3));
    assert_eq!(faults.fetch_count("fc"), 3, "2 failures + 1 success");

    gw.shutdown().unwrap();
}

/// Transient faults (the interrupted-syscall / short-read class): each
/// failed load releases the single-flight gate without poisoning the
/// key, so plain retries succeed once the fault clears.
#[test]
fn transient_fetch_faults_heal_on_retry() {
    let rt = runtime();
    let base = pretrained_base(&rt);
    let fix = fixture(&rt);

    let store = Arc::new(AdapterStore::in_memory());
    store.register("ft", &fix[0].0, 0.9).unwrap();
    let mut classes = BTreeMap::new();
    classes.insert("ft".to_string(), 2);

    let faults = FaultStore::new(store.clone());
    faults.inject(
        "ft",
        Fault::FailTimes(2, "read interrupted: short read on bank".into()),
    );
    let server = Server::start_with_source(
        rt.clone(),
        faults.clone(),
        &base,
        &classes,
        server_cfg(ExecMode::PerTask, Some(1 << 30)),
    )
    .unwrap();

    for attempt in 0..2 {
        let err = server.prefetch("ft").unwrap_err();
        assert!(
            format!("{err:#}").contains("short read"),
            "attempt {attempt}: {err:#}"
        );
        assert!(!server.is_resident("ft"));
    }
    server.prefetch("ft").unwrap();
    assert!(server.is_resident("ft"));
    let snap = server.cache_stats();
    assert_eq!(snap.load_errors, 2);
    assert_eq!(snap.misses, 3);
    assert_eq!(snap.cold_loads, 1);

    // and the reloaded bank actually serves
    let pred = serve_one(&server, &rt, "ft", &fix[0].1.test, 0);
    let exp = class_preds(&rt, &fix[0].0, &base, &fix[0].1.test);
    assert_eq!(pred, Prediction::Class(exp[0]));

    server.drain();
    server.shutdown();
}

/// A herd of threads hitting one cold task runs the store fetch once:
/// one loader, everyone else waits on the gate and hits.
#[test]
fn cold_herd_is_single_flight() {
    let rt = runtime();
    let base = pretrained_base(&rt);
    let fix = fixture(&rt);

    let store = Arc::new(AdapterStore::in_memory());
    store.register("herd", &fix[1].0, 0.9).unwrap();
    let mut classes = BTreeMap::new();
    classes.insert("herd".to_string(), 2);

    let faults = FaultStore::new(store.clone());
    // slow enough that all 8 threads pile up behind the first
    faults.inject("herd", Fault::Slow(Duration::from_millis(200)));
    let server = Server::start_with_source(
        rt.clone(),
        faults.clone(),
        &base,
        &classes,
        server_cfg(ExecMode::PerTask, Some(1 << 30)),
    )
    .unwrap();
    assert!(!server.is_resident("herd"));

    std::thread::scope(|scope| {
        for _ in 0..8 {
            let server = &server;
            scope.spawn(move || server.prefetch("herd").unwrap());
        }
    });

    assert_eq!(faults.fetch_count("herd"), 1, "herd ran more than one fetch");
    let snap = server.cache_stats();
    assert_eq!(snap.misses, 1);
    assert_eq!(snap.hits, 7);
    assert_eq!(snap.load_errors, 0);
    assert!(server.is_resident("herd"));

    server.drain();
    server.shutdown();
}

/// Real disk store, no wrapper: delete the bank file under a cold task,
/// get the store's own descriptive error over HTTP, put the file back,
/// and watch the task heal.
#[test]
fn midload_bank_deletion_surfaces_and_heals() {
    let rt = runtime();
    let base = pretrained_base(&rt);
    let fix = fixture(&rt);

    let dir = std::env::temp_dir()
        .join(format!("abcache_del_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(AdapterStore::at(&dir).unwrap());
    store.register("da", &fix[0].0, 0.9).unwrap();
    store.register("db", &fix[1].0, 0.9).unwrap();
    let mut classes = BTreeMap::new();
    classes.insert("da".to_string(), 2);
    classes.insert("db".to_string(), 2);
    let exp_a = class_preds(&rt, &fix[0].0, &base, &fix[0].1.test);
    let exp_b = class_preds(&rt, &fix[1].0, &base, &fix[1].1.test);

    let server = Server::start_with_source(
        rt.clone(),
        store.clone(),
        &base,
        &classes,
        server_cfg(ExecMode::PerTask, Some(1 << 30)),
    )
    .unwrap();
    let gw = Gateway::start(
        rt.clone(),
        store.clone(),
        server,
        GatewayConfig { addr: "127.0.0.1:0".to_string(), ..Default::default() },
    )
    .unwrap();
    let mut client = Client::connect(&gw.local_addr().to_string()).unwrap();

    // da pages in from disk and serves
    let resp = client.predict_ids("da", fix[0].1.test.row_tokens(0)).unwrap();
    assert_eq!(resp.pred_class, Some(exp_a[0]));

    // db's bank file vanishes before its first request
    let bank = dir.join("db").join("v001.bank");
    let saved = std::fs::read(&bank).unwrap();
    std::fs::remove_file(&bank).unwrap();
    let req = PredictRequest::ids("db", fix[1].1.test.row_tokens(0).to_vec());
    let (status, j) = client
        .roundtrip("POST", "/predict_ids", Some(&req.to_json()))
        .unwrap();
    assert_eq!(status, 503);
    let msg = j
        .get("error")
        .and_then(|e| e.as_str().map(String::from))
        .expect("error message");
    assert!(
        msg.contains("cold load failed") && msg.contains("bank"),
        "missing-bank error not descriptive: {msg}"
    );
    // da is untouched; db still lists (directory is metadata-only)
    let resp = client.predict_ids("da", fix[0].1.test.row_tokens(1)).unwrap();
    assert_eq!(resp.pred_class, Some(exp_a[1]));
    let names: Vec<String> =
        client.tasks().unwrap().into_iter().map(|t| t.task).collect();
    assert_eq!(names, vec!["da".to_string(), "db".to_string()]);

    // the file comes back (operator restores from backup) — db heals
    std::fs::write(&bank, &saved).unwrap();
    let resp = client.predict_ids("db", fix[1].1.test.row_tokens(0)).unwrap();
    assert_eq!(resp.pred_class, Some(exp_b[0]), "db after restore");

    gw.shutdown().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// The headline parity check: one request trace, two servers — unbounded
/// cache vs. a budget sized to half the working set (constant eviction
/// churn) — must produce identical predictions row for row, in both
/// execution modes.
#[test]
fn eviction_parity_with_unbounded_cache() {
    let rt = runtime();
    let base = pretrained_base(&rt);
    let fix = fixture(&rt);

    // six tenants over three distinct adapters: evicting "p4" and
    // reloading it must bring back p4's bytes, not its twin's
    let store = Arc::new(AdapterStore::in_memory());
    let mut classes = BTreeMap::new();
    let names: Vec<String> = (0..6).map(|i| format!("p{i}")).collect();
    for (i, name) in names.iter().enumerate() {
        store.register(name, &fix[i % 3].0, 0.9).unwrap();
        classes.insert(name.clone(), 2);
    }

    // deterministic skewed trace (hot head, cold tail → real reloads)
    let mut rng = Rng::new(9);
    let trace: Vec<(usize, usize)> =
        (0..96).map(|_| (rng.zipf(6, 1.1), rng.below(8))).collect();

    let run_trace = |mode: ExecMode,
                     budget: Option<u64>|
     -> (Vec<Prediction>, adapterbert::coordinator::CacheSnapshot) {
        let server = Server::start_with_source(
            rt.clone(),
            store.clone(),
            &base,
            &classes,
            server_cfg(mode, budget),
        )
        .unwrap();
        let mut preds = Vec::with_capacity(trace.len());
        for (i, &(ti, row)) in trace.iter().enumerate() {
            preds.push(serve_one(
                &server,
                &rt,
                &names[ti],
                &fix[ti % 3].1.test,
                row,
            ));
            if let Some(b) = budget {
                if i % 8 == 0 {
                    let bytes = server.cache_stats().resident_bytes;
                    assert!(
                        bytes <= b,
                        "request {i}: resident {bytes} bytes over budget {b}"
                    );
                }
            }
        }
        let snap = server.cache_stats();
        server.drain();
        server.shutdown();
        (preds, snap)
    };

    for mode in [ExecMode::PerTask, ExecMode::Fused] {
        let (unbounded, full) = run_trace(mode, None);
        // half the eagerly-built working set forces ~50% of the banks out
        let budget = full.resident_bytes / 2;
        assert!(budget > 0, "working set measured as empty");
        let (bounded, snap) = run_trace(mode, Some(budget));

        assert_eq!(
            unbounded, bounded,
            "mode {mode:?}: predictions diverged under eviction"
        );
        assert!(
            snap.evictions > 0,
            "mode {mode:?}: budget {budget} evicted nothing"
        );
        assert!(snap.resident_bytes <= budget, "mode {mode:?}: over budget");
        assert!(
            snap.misses > 6,
            "mode {mode:?}: no reloads — eviction pressure never materialized"
        );
        assert_eq!(snap.load_errors, 0, "mode {mode:?}");
    }
}
