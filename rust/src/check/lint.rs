//! `adapterbert lint` — token-level static checks for repo invariants.
//!
//! A deliberately small, dependency-free pass over `rust/src`: each file
//! is split line-by-line into *code* and *comment* halves by a scanner
//! that understands nested block comments, (raw) string literals, and
//! char-vs-lifetime quotes, and five rules run over the halves:
//!
//! | rule | invariant |
//! |------|-----------|
//! | `unsafe-no-safety` | every `unsafe` carries a `// SAFETY:` comment on the same line or within the 4 lines above |
//! | `unwrap-request-path` | no `.unwrap()` / `.expect(` in request-path modules (`serve/`, `coordinator/`, `cluster/`, `fuse/`); lock-poisoning unwraps (chained to `.lock()`/`.read()`/`.write()`/`.wait(`) are exempt |
//! | `print-outside-log` | no `println!`-family macros outside `main.rs`, `obs/log.rs`, `bench/`, `report/`, and this file |
//! | `timing-in-kernel` | no `Instant::now` / `SystemTime::now` / `thread::sleep` in the deterministic kernel paths under `runtime/native/` |
//! | `relaxed-no-justify` | every `Ordering::Relaxed` in the audited concurrency modules carries a `// relaxed:` justification within 3 lines |
//!
//! `#[cfg(test)] mod` bodies are skipped for the unwrap and print rules
//! (tests may be loud and may unwrap); `unsafe` must be documented even
//! in tests. Findings can be waived in `rust/lint-allow.txt` — one
//! `rule path-substring [snippet-substring]` per line — and the report
//! serializes to JSON for CI.
//!
//! The `relaxed-no-justify` rule is scoped to [`RELAXED_AUDITED`]: the
//! modules whose atomics have been audited (PR 10). Add a module to the
//! list when it joins the `check::sync` facade.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::fs;
use std::path::{Path, PathBuf};

/// Modules whose `Ordering::Relaxed` uses must carry a `// relaxed:`
/// justification comment. Grows as modules are audited.
pub const RELAXED_AUDITED: &[&str] = &[
    "coordinator/cache.rs",
    "obs/trace.rs",
    "runtime/native/pool.rs",
    "cluster/breaker.rs",
    "cluster/health.rs",
];

/// Request-path module prefixes for the unwrap/expect ban.
const REQUEST_PATH: &[&str] = &["serve/", "coordinator/", "cluster/", "fuse/"];

/// Files allowed to print to stdout/stderr directly.
const PRINT_ALLOWED: &[&str] = &["main.rs", "obs/log.rs", "check/lint.rs"];
const PRINT_ALLOWED_DIRS: &[&str] = &["bench/", "report/"];

#[derive(Clone, Debug)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub snippet: String,
}

#[derive(Debug, Default)]
pub struct LintReport {
    pub files_scanned: usize,
    pub findings: Vec<Finding>,
    /// Findings waived by the allowlist (count only).
    pub allowed: usize,
}

impl LintReport {
    pub fn to_json(&self, root: &str) -> Json {
        let mut counts: std::collections::BTreeMap<&str, usize> = Default::default();
        for f in &self.findings {
            *counts.entry(f.rule).or_insert(0) += 1;
        }
        Json::obj(vec![
            ("schema_version", Json::num(1)),
            ("tool", Json::str("adapterbert-lint")),
            ("root", Json::str(root)),
            ("files_scanned", Json::num(self.files_scanned as f64)),
            (
                "findings",
                Json::arr(self.findings.iter().map(|f| {
                    Json::obj(vec![
                        ("rule", Json::str(f.rule)),
                        ("file", Json::str(&f.file)),
                        ("line", Json::num(f.line as f64)),
                        ("snippet", Json::str(&f.snippet)),
                    ])
                })),
            ),
            ("allowed", Json::num(self.allowed as f64)),
            (
                "counts",
                Json::obj(
                    counts
                        .iter()
                        .map(|(k, v)| (*k, Json::num(*v as f64)))
                        .collect(),
                ),
            ),
        ])
    }
}

// ---------------------------------------------------------------------------
// Line scanner: split source into code / comment halves
// ---------------------------------------------------------------------------

#[derive(Default)]
struct Scanner {
    /// Nesting depth of `/* */` (Rust block comments nest).
    block_depth: usize,
    /// Inside a normal `"…"` string continuing from a previous line.
    in_str: bool,
    /// Inside a raw string; the value is the `#` count of its delimiter.
    in_raw: Option<usize>,
}

impl Scanner {
    /// Split one line into (code, comment). Literal contents are dropped
    /// from the code half; comment text (without the `//`/`/*` markers'
    /// interior structure) lands in the comment half.
    fn split(&mut self, line: &str) -> (String, String) {
        let b = line.as_bytes();
        let mut code = String::new();
        let mut comment = String::new();
        let mut i = 0usize;
        while i < b.len() {
            if self.block_depth > 0 {
                if b[i] == b'*' && i + 1 < b.len() && b[i + 1] == b'/' {
                    self.block_depth -= 1;
                    i += 2;
                } else if b[i] == b'/' && i + 1 < b.len() && b[i + 1] == b'*' {
                    self.block_depth += 1;
                    i += 2;
                } else {
                    comment.push(b[i] as char);
                    i += 1;
                }
                continue;
            }
            if self.in_str {
                if b[i] == b'\\' {
                    i += 2;
                } else if b[i] == b'"' {
                    self.in_str = false;
                    code.push('"');
                    i += 1;
                } else {
                    i += 1;
                }
                continue;
            }
            if let Some(h) = self.in_raw {
                if b[i] == b'"' && i + h < b.len() && b[i + 1..].iter().take(h).all(|&c| c == b'#')
                {
                    self.in_raw = None;
                    code.push('"');
                    i += 1 + h;
                } else {
                    i += 1;
                }
                continue;
            }
            match b[i] {
                b'/' if i + 1 < b.len() && b[i + 1] == b'/' => {
                    comment.push_str(&line[i + 2..]);
                    break;
                }
                b'/' if i + 1 < b.len() && b[i + 1] == b'*' => {
                    self.block_depth = 1;
                    i += 2;
                }
                b'"' => {
                    self.in_str = true;
                    code.push('"');
                    i += 1;
                }
                b'r' | b'b' if !prev_is_ident(b, i) => {
                    // raw-string opener `(b?)r#*"` or byte string `b"`/`b'`
                    let mut j = i;
                    if b[j] == b'b' && j + 1 < b.len() && b[j + 1] == b'r' {
                        j += 1;
                    }
                    if b[j] == b'r' || b[i] == b'b' {
                        let mut k = j + 1;
                        let mut hashes = 0usize;
                        while b.get(k) == Some(&b'#') && b[j] == b'r' {
                            hashes += 1;
                            k += 1;
                        }
                        if b.get(k) == Some(&b'"') && (b[j] == b'r' || hashes == 0) {
                            if b[j] == b'r' {
                                self.in_raw = Some(hashes);
                            } else {
                                self.in_str = true;
                            }
                            code.push('"');
                            i = k + 1;
                            continue;
                        }
                        if b[i] == b'b' && b.get(i + 1) == Some(&b'\'') {
                            i = skip_char_literal(b, i + 1);
                            continue;
                        }
                    }
                    code.push(b[i] as char);
                    i += 1;
                }
                b'\'' => {
                    let j = skip_char_literal(b, i);
                    if j == i + 1 {
                        // lifetime: keep the tick so code stays parseable-ish
                        code.push('\'');
                    }
                    i = j.max(i + 1);
                }
                c => {
                    code.push(c as char);
                    i += 1;
                }
            }
        }
        (code, comment)
    }
}

fn prev_is_ident(b: &[u8], i: usize) -> bool {
    i > 0 && (b[i - 1].is_ascii_alphanumeric() || b[i - 1] == b'_')
}

/// At `b[i] == '\''`: return the index just past a char literal, or
/// `i + 1` when this tick starts a lifetime.
fn skip_char_literal(b: &[u8], i: usize) -> usize {
    if b.get(i + 1) == Some(&b'\\') {
        // escaped char: find the closing tick
        let mut j = i + 2;
        if j < b.len() {
            j += 1; // the escaped character itself
        }
        while j < b.len() && b[j] != b'\'' {
            j += 1;
        }
        return (j + 1).min(b.len());
    }
    // `'x'` — exactly one (possibly multi-byte) char then a tick; ASCII
    // fast path covers real code, multibyte falls back to lifetime-skip
    if i + 2 < b.len() && b[i + 2] == b'\'' && b[i + 1] != b'\'' {
        return i + 3;
    }
    i + 1
}

// ---------------------------------------------------------------------------
// Rules
// ---------------------------------------------------------------------------

fn find_token(code: &str, token: &str) -> bool {
    let b = code.as_bytes();
    let mut start = 0usize;
    while let Some(pos) = code[start..].find(token) {
        let at = start + pos;
        let before_ok = at == 0 || !(b[at - 1].is_ascii_alphanumeric() || b[at - 1] == b'_');
        let end = at + token.len();
        let after_ok =
            end >= b.len() || !(b[end].is_ascii_alphanumeric() || b[end] == b'_');
        if before_ok && after_ok {
            return true;
        }
        start = at + 1;
    }
    false
}

fn in_request_path(rel: &str) -> bool {
    REQUEST_PATH.iter().any(|p| rel.starts_with(p))
}

fn print_allowed(rel: &str) -> bool {
    PRINT_ALLOWED.contains(&rel) || PRINT_ALLOWED_DIRS.iter().any(|d| rel.starts_with(d))
}

fn has_print_macro(code: &str) -> bool {
    // longest-first so `print!` does not fire inside `eprintln!`
    let mut masked = code.to_string();
    for name in ["eprintln!", "println!", "eprint!", "print!"] {
        let b = masked.clone();
        let bytes = b.as_bytes();
        let mut start = 0usize;
        while let Some(pos) = b[start..].find(name) {
            let at = start + pos;
            let before_ok =
                at == 0 || !(bytes[at - 1].is_ascii_alphanumeric() || bytes[at - 1] == b'_');
            if before_ok {
                return true;
            }
            // identifier prefix (e.g. `reprint!` — mask and move on)
            masked.replace_range(at..at + name.len(), &" ".repeat(name.len()));
            start = at + name.len();
        }
    }
    false
}

/// Lock-poisoning unwrap idiom: `.unwrap()` chained (possibly across a
/// formatted multi-line call) onto `.lock()` / `.read()` / `.write()` /
/// `.wait(`.
fn is_poison_unwrap(code: &str, prev_code: &[String]) -> bool {
    let hit = |s: &str| {
        s.contains(".lock(") || s.contains(".read(") || s.contains(".write(") || s.contains(".wait(")
    };
    if hit(code) {
        return true;
    }
    prev_code.iter().rev().take(2).any(|l| hit(l))
}

/// Scan one file's source. `rel` is the path relative to the lint root,
/// with forward slashes.
pub fn scan_source(rel: &str, src: &str) -> Vec<Finding> {
    let mut findings = Vec::new();
    let mut scanner = Scanner::default();
    let mut comments: Vec<String> = Vec::new();
    let mut codes: Vec<String> = Vec::new();
    let mut depth: i64 = 0;
    let mut test_region_floor: Option<i64> = None;
    let mut pending_cfg_test = false;

    let relaxed_audited = RELAXED_AUDITED.contains(&rel);
    let request_path = in_request_path(rel);
    let printing_ok = print_allowed(rel);
    let kernel_path = rel.starts_with("runtime/native/");

    for (idx, raw_line) in src.lines().enumerate() {
        let lineno = idx + 1;
        let (code, comment) = scanner.split(raw_line);
        let trimmed = code.trim();

        // -- cfg(test) region tracking --------------------------------
        if test_region_floor.is_none() {
            if trimmed.contains("#[cfg(test)]") || trimmed.contains("#[cfg(all(test") {
                if find_token(trimmed, "mod") {
                    test_region_floor = Some(depth);
                } else {
                    pending_cfg_test = true;
                }
            } else if pending_cfg_test && !trimmed.is_empty() {
                if find_token(trimmed, "mod") {
                    test_region_floor = Some(depth);
                    pending_cfg_test = false;
                } else if !trimmed.starts_with("#[") {
                    pending_cfg_test = false;
                }
            }
        }
        let in_test = test_region_floor.is_some();

        let snippet = || {
            let t = raw_line.trim();
            if t.len() > 120 {
                let mut cut = 120;
                while cut > 0 && !t.is_char_boundary(cut) {
                    cut -= 1;
                }
                format!("{}…", &t[..cut])
            } else {
                t.to_string()
            }
        };

        // -- rule: unsafe-no-safety (applies everywhere) --------------
        if find_token(&code, "unsafe") {
            // `SAFETY:` block comments and rustdoc `# Safety` sections
            // both count
            let has = |c: &str| c.to_ascii_lowercase().contains("safety");
            let documented =
                has(&comment) || comments.iter().rev().take(4).any(|c| has(c));
            if !documented {
                findings.push(Finding {
                    rule: "unsafe-no-safety",
                    file: rel.to_string(),
                    line: lineno,
                    snippet: snippet(),
                });
            }
        }

        // -- rule: unwrap-request-path --------------------------------
        if request_path && !in_test && (code.contains(".unwrap()") || code.contains(".expect(")) {
            if !is_poison_unwrap(&code, &codes) {
                findings.push(Finding {
                    rule: "unwrap-request-path",
                    file: rel.to_string(),
                    line: lineno,
                    snippet: snippet(),
                });
            }
        }

        // -- rule: print-outside-log ----------------------------------
        if !printing_ok && !in_test && has_print_macro(&code) {
            findings.push(Finding {
                rule: "print-outside-log",
                file: rel.to_string(),
                line: lineno,
                snippet: snippet(),
            });
        }

        // -- rule: timing-in-kernel -----------------------------------
        if kernel_path
            && !in_test
            && (code.contains("Instant::now")
                || code.contains("SystemTime::now")
                || code.contains("thread::sleep"))
        {
            findings.push(Finding {
                rule: "timing-in-kernel",
                file: rel.to_string(),
                line: lineno,
                snippet: snippet(),
            });
        }

        // -- rule: relaxed-no-justify ---------------------------------
        if relaxed_audited && !in_test && code.contains("Ordering::Relaxed") {
            let justified = comment.contains("relaxed:")
                || comments.iter().rev().take(3).any(|c| c.contains("relaxed:"));
            if !justified {
                findings.push(Finding {
                    rule: "relaxed-no-justify",
                    file: rel.to_string(),
                    line: lineno,
                    snippet: snippet(),
                });
            }
        }

        // -- bookkeeping ----------------------------------------------
        for ch in code.bytes() {
            match ch {
                b'{' => depth += 1,
                b'}' => depth -= 1,
                _ => {}
            }
        }
        if let Some(floor) = test_region_floor {
            if depth <= floor {
                test_region_floor = None;
            }
        }
        comments.push(comment);
        if !trimmed.is_empty() {
            codes.push(code);
        }
    }
    findings
}

// ---------------------------------------------------------------------------
// Allowlist + driver
// ---------------------------------------------------------------------------

struct AllowEntry {
    rule: String,
    path_sub: String,
    snippet_sub: Option<String>,
}

fn parse_allowlist(text: &str) -> Vec<AllowEntry> {
    let mut out = Vec::new();
    for line in text.lines() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        let mut parts = line.split_whitespace();
        let (Some(rule), Some(path_sub)) = (parts.next(), parts.next()) else {
            continue;
        };
        let rest: Vec<&str> = parts.collect();
        out.push(AllowEntry {
            rule: rule.to_string(),
            path_sub: path_sub.to_string(),
            snippet_sub: if rest.is_empty() { None } else { Some(rest.join(" ")) },
        });
    }
    out
}

fn allowed(entry: &[AllowEntry], f: &Finding) -> bool {
    entry.iter().any(|e| {
        e.rule == f.rule
            && f.file.contains(&e.path_sub)
            && e.snippet_sub
                .as_ref()
                .map(|s| f.snippet.contains(s.as_str()))
                .unwrap_or(true)
    })
}

fn collect_rs_files(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    let rd = fs::read_dir(dir).with_context(|| format!("read_dir {}", dir.display()))?;
    let mut entries: Vec<PathBuf> = Vec::new();
    for e in rd {
        entries.push(e.with_context(|| format!("read_dir entry in {}", dir.display()))?.path());
    }
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs_files(&p, out)?;
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
    Ok(())
}

/// Run the lint over `root` (typically `rust/src`), waiving findings
/// listed in `allow_path` if it exists.
pub fn run(root: &Path, allow_path: &Path) -> Result<LintReport> {
    let allow = match fs::read_to_string(allow_path) {
        Ok(text) => parse_allowlist(&text),
        Err(_) => Vec::new(),
    };
    let mut files = Vec::new();
    collect_rs_files(root, &mut files)?;
    let mut report = LintReport::default();
    for path in &files {
        let src =
            fs::read_to_string(path).with_context(|| format!("read {}", path.display()))?;
        let rel = path
            .strip_prefix(root)
            .unwrap_or(path)
            .to_string_lossy()
            .replace('\\', "/");
        report.files_scanned += 1;
        for f in scan_source(&rel, &src) {
            if allowed(&allow, &f) {
                report.allowed += 1;
            } else {
                report.findings.push(f);
            }
        }
    }
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(rel: &str, src: &str) -> Vec<&'static str> {
        scan_source(rel, src).into_iter().map(|f| f.rule).collect()
    }

    #[test]
    fn unsafe_requires_safety_comment() {
        assert_eq!(rules("runtime/x.rs", "unsafe { foo() }"), vec!["unsafe-no-safety"]);
        assert_eq!(rules("runtime/x.rs", "// SAFETY: disjoint\nunsafe { foo() }"), Vec::<&str>::new());
        assert_eq!(
            rules("runtime/x.rs", "let x = 1; // SAFETY: fine\nlet y = 2;\nunsafe { foo() }"),
            Vec::<&str>::new()
        );
        // `unsafe` in a string or comment is not a finding
        assert_eq!(rules("runtime/x.rs", "let s = \"unsafe\";"), Vec::<&str>::new());
        assert_eq!(rules("runtime/x.rs", "// unsafe is scary"), Vec::<&str>::new());
    }

    #[test]
    fn safety_comment_window_is_four_lines() {
        let src = "// SAFETY: too far\nlet a = 1;\nlet b = 2;\nlet c = 3;\nlet d = 4;\nunsafe { foo() }";
        assert_eq!(rules("runtime/x.rs", src), vec!["unsafe-no-safety"]);
    }

    #[test]
    fn unwrap_banned_in_request_path_only() {
        assert_eq!(rules("serve/x.rs", "let v = maybe.unwrap();"), vec!["unwrap-request-path"]);
        assert_eq!(rules("serve/x.rs", "let v = maybe.expect(\"msg\");"), vec!["unwrap-request-path"]);
        assert_eq!(rules("train/x.rs", "let v = maybe.unwrap();"), Vec::<&str>::new());
        // unwrap_or is not unwrap
        assert_eq!(rules("serve/x.rs", "let v = maybe.unwrap_or(0);"), Vec::<&str>::new());
    }

    #[test]
    fn poison_unwrap_carveout() {
        assert_eq!(rules("serve/x.rs", "let g = m.lock().unwrap();"), Vec::<&str>::new());
        assert_eq!(rules("serve/x.rs", "let g = m.read().unwrap();"), Vec::<&str>::new());
        // multi-line chain: `.unwrap()` within 2 lines of the `.lock(`
        let src = "let g = m\n    .lock()\n    .unwrap();";
        assert_eq!(rules("serve/x.rs", src), Vec::<&str>::new());
    }

    #[test]
    fn unwrap_allowed_in_test_mod() {
        let src = "#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\nfn f() { y.unwrap(); }";
        assert_eq!(rules("serve/x.rs", src), vec!["unwrap-request-path"]);
    }

    #[test]
    fn prints_flagged_outside_allowed_files() {
        assert_eq!(rules("serve/x.rs", "println!(\"hi\");"), vec!["print-outside-log"]);
        assert_eq!(rules("serve/x.rs", "eprintln!(\"hi\");"), vec!["print-outside-log"]);
        assert_eq!(rules("main.rs", "println!(\"hi\");"), Vec::<&str>::new());
        assert_eq!(rules("obs/log.rs", "eprintln!(\"hi\");"), Vec::<&str>::new());
        assert_eq!(rules("bench/x.rs", "println!(\"hi\");"), Vec::<&str>::new());
        assert_eq!(rules("report/mod.rs", "println!(\"hi\");"), Vec::<&str>::new());
        // inside a string: fine
        assert_eq!(rules("serve/x.rs", "let s = \"println!\";"), Vec::<&str>::new());
    }

    #[test]
    fn timing_banned_in_kernel_paths() {
        assert_eq!(
            rules("runtime/native/kernels.rs", "let t = Instant::now();"),
            vec!["timing-in-kernel"]
        );
        assert_eq!(
            rules("runtime/native/pool.rs", "thread::sleep(d);"),
            vec!["timing-in-kernel"]
        );
        assert_eq!(rules("obs/trace.rs", "let t = Instant::now();"), Vec::<&str>::new());
    }

    #[test]
    fn relaxed_needs_justification_in_audited_files() {
        assert_eq!(
            rules("obs/trace.rs", "x.load(Ordering::Relaxed);"),
            vec!["relaxed-no-justify"]
        );
        assert_eq!(
            rules("obs/trace.rs", "// relaxed: plain counter\nx.load(Ordering::Relaxed);"),
            Vec::<&str>::new()
        );
        assert_eq!(
            rules("obs/trace.rs", "x.load(Ordering::Relaxed); // relaxed: counter"),
            Vec::<&str>::new()
        );
        // unaudited file: no requirement
        assert_eq!(rules("serve/x.rs", "x.load(Ordering::Relaxed);"), Vec::<&str>::new());
    }

    #[test]
    fn scanner_handles_block_comments_and_raw_strings() {
        let src = "/* unsafe\n   println! */ let ok = 1;\nlet r = r#\"println!(\"x\")\"#;";
        assert_eq!(rules("serve/x.rs", src), Vec::<&str>::new());
        // nested block comments
        let src2 = "/* outer /* inner */ still comment: x.unwrap() */ let y = 2;";
        assert_eq!(rules("serve/x.rs", src2), Vec::<&str>::new());
    }

    #[test]
    fn char_literals_do_not_confuse_the_scanner() {
        // a '"' char literal must not open a string
        let src = "let q = '\"';\nlet v = x.unwrap();";
        assert_eq!(rules("serve/x.rs", src), vec!["unwrap-request-path"]);
        // lifetimes pass through
        assert_eq!(rules("serve/x.rs", "fn f<'a>(x: &'a str) {}"), Vec::<&str>::new());
    }

    #[test]
    fn allowlist_waives_matching_findings() {
        let entries = parse_allowlist(
            "# comment\nunwrap-request-path serve/x.rs\nprint-outside-log cluster/ debug dump\n",
        );
        let f1 = Finding {
            rule: "unwrap-request-path",
            file: "serve/x.rs".into(),
            line: 1,
            snippet: "x.unwrap()".into(),
        };
        let f2 = Finding {
            rule: "print-outside-log",
            file: "cluster/y.rs".into(),
            line: 2,
            snippet: "println!(\"debug dump\");".into(),
        };
        let f3 = Finding {
            rule: "print-outside-log",
            file: "cluster/y.rs".into(),
            line: 3,
            snippet: "println!(\"other\");".into(),
        };
        assert!(allowed(&entries, &f1));
        assert!(allowed(&entries, &f2));
        assert!(!allowed(&entries, &f3));
    }

    #[test]
    fn report_serializes_to_json() {
        let mut r = LintReport::default();
        r.files_scanned = 2;
        r.findings.push(Finding {
            rule: "unsafe-no-safety",
            file: "a.rs".into(),
            line: 7,
            snippet: "unsafe { x }".into(),
        });
        let j = r.to_json("rust/src");
        let parsed = crate::util::json::Json::parse(&j.to_string()).expect("valid json");
        assert_eq!(parsed.at("schema_version").as_usize(), Some(1));
        assert_eq!(
            parsed.at("findings").as_arr().map(|a| a.len()),
            Some(1)
        );
    }

    #[test]
    fn repo_is_lint_clean() {
        // cargo test runs with CWD = package root
        let root = Path::new("rust/src");
        if !root.is_dir() {
            return; // running from an unexpected CWD; CI runs the CLI too
        }
        let report = run(root, Path::new("rust/lint-allow.txt")).expect("lint run");
        assert!(report.files_scanned > 30, "suspiciously few files scanned");
        let msgs: Vec<String> = report
            .findings
            .iter()
            .map(|f| format!("{}:{} [{}] {}", f.file, f.line, f.rule, f.snippet))
            .collect();
        assert!(msgs.is_empty(), "lint findings:\n{}", msgs.join("\n"));
    }
}
