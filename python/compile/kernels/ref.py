"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: pytest (and hypothesis sweeps)
assert that each ``pallas_call`` (interpret=True) matches the corresponding
function here to tight tolerances, and that the custom VJP of the fused
adapter kernel matches ``jax.grad`` of :func:`adapter_ref`.

Everything is written in plain ``jax.numpy`` so that JAX's own autodiff can
differentiate it — that is what makes these usable as gradient oracles.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "gelu",
    "adapter_ref",
    "layernorm_ref",
    "attention_ref",
    "softmax_xent_ref",
]


def gelu(x: jnp.ndarray) -> jnp.ndarray:
    """tanh-approximation GELU (the BERT variant).

    Matches the kernel exactly (both use the tanh form), so comparisons are
    not polluted by erf-vs-tanh differences.
    """
    c = jnp.sqrt(2.0 / jnp.pi).astype(x.dtype)
    return 0.5 * x * (1.0 + jnp.tanh(c * (x + 0.044715 * x**3)))


def adapter_ref(x, w_down, b_down, w_up, b_up):
    """Houlsby bottleneck adapter: ``y = x + GELU(x @ W1 + b1) @ W2 + b2``.

    Args:
      x:      [rows, d]  sub-layer output (after the projection back to d).
      w_down: [d, m]     down-projection.
      b_down: [m]
      w_up:   [m, d]     up-projection.
      b_up:   [d]

    The internal skip-connection is the paper's near-identity mechanism:
    with w/b ~ 0 the module is the identity.
    """
    h = gelu(x @ w_down + b_down)
    return x + h @ w_up + b_up


def layernorm_ref(x, gamma, beta, eps: float = 1e-6):
    """Row-wise LayerNorm over the last dim with learned scale/shift."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * gamma + beta


def attention_ref(q, k, v, mask):
    """Single-head scaled dot-product attention.

    Args:
      q, k, v: [s, dh]
      mask:    [s]  1.0 for valid key positions, 0.0 for padding.

    Returns [s, dh].
    """
    dh = q.shape[-1]
    scores = (q @ k.T) / jnp.sqrt(jnp.asarray(dh, q.dtype))
    neg = jnp.asarray(-1e9, q.dtype)
    scores = jnp.where(mask[None, :] > 0, scores, neg)
    p = jax.nn.softmax(scores, axis=-1)
    return p @ v


def softmax_xent_ref(logits, labels, valid_mask):
    """Mean masked softmax cross-entropy.

    Args:
      logits:     [b, c]
      labels:     [b] int32
      valid_mask: [c] 1.0 where the class id is in-use for this task
                  (heads are padded to a fixed ``max_classes``).
    """
    neg = jnp.asarray(-1e9, logits.dtype)
    masked = jnp.where(valid_mask[None, :] > 0, logits, neg)
    logp = jax.nn.log_softmax(masked, axis=-1)
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(nll)
