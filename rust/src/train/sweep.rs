//! Hyper-parameter sweeps with best-on-validation selection (paper §3.1:
//! "for each dataset and algorithm, we run a hyperparameter sweep and
//! select the best model according to accuracy on the validation set",
//! plus the 5-random-seed re-runs for instability).
//!
//! Jobs fan out over a scoped thread pool sharing one `Runtime` (PJRT's
//! CPU client is thread-safe; the compile cache de-duplicates work).

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::r#loop::{train_task, TrainConfig, TrainResult};
use crate::data::tasks::TaskData;
use crate::model::params::NamedTensors;
use crate::runtime::Runtime;

/// Grid definition for one task + method.
#[derive(Debug, Clone)]
pub struct SweepGrid {
    /// train executables to try (e.g. adapter sizes: one exe per size)
    pub exes: Vec<String>,
    pub lrs: Vec<f64>,
    pub epochs: Vec<usize>,
    pub seeds: Vec<u64>,
    /// adapter init σ (usually just [1e-2]; Fig. 6-right sweeps it)
    pub stds: Vec<f64>,
}

impl SweepGrid {
    pub fn configs(&self) -> Vec<TrainConfig> {
        let mut out = Vec::new();
        for exe in &self.exes {
            for &lr in &self.lrs {
                for &ep in &self.epochs {
                    for &seed in &self.seeds {
                        for &std in &self.stds {
                            let mut c = TrainConfig::new(exe, lr, ep, seed);
                            c.adapter_std = std;
                            out.push(c);
                        }
                    }
                }
            }
        }
        out
    }
}

/// All runs of a sweep plus the winner (best validation score).
#[derive(Debug)]
pub struct SweepOutcome {
    pub best: TrainResult,
    pub best_config: TrainConfig,
    pub runs: Vec<(TrainConfig, TrainResult)>,
}

/// Run `grid` for `task`, using up to `threads` workers.
pub fn run_sweep(
    rt: &Arc<Runtime>,
    task: &TaskData,
    base: &NamedTensors,
    grid: &SweepGrid,
    threads: usize,
) -> Result<SweepOutcome> {
    let configs = grid.configs();
    let queue: Mutex<VecDeque<TrainConfig>> = Mutex::new(configs.into());
    let results: Mutex<Vec<(TrainConfig, TrainResult)>> = Mutex::new(Vec::new());
    let errors: Mutex<Vec<anyhow::Error>> = Mutex::new(Vec::new());

    std::thread::scope(|scope| {
        for _ in 0..threads.max(1) {
            scope.spawn(|| loop {
                let cfg = match queue.lock().unwrap().pop_front() {
                    Some(c) => c,
                    None => return,
                };
                match train_task(rt, &cfg, task, base) {
                    Ok(res) => results.lock().unwrap().push((cfg, res)),
                    Err(e) => errors.lock().unwrap().push(e),
                }
            });
        }
    });

    let errors = errors.into_inner().unwrap();
    if let Some(e) = errors.into_iter().next() {
        return Err(e);
    }
    let mut runs = results.into_inner().unwrap();
    // deterministic ordering regardless of thread interleaving
    runs.sort_by(|a, b| {
        (&a.0.exe, a.0.seed, a.0.lr.total_cmp(&b.0.lr))
            .partial_cmp(&(&b.0.exe, b.0.seed, std::cmp::Ordering::Equal))
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let (best_config, best) = runs
        .iter()
        .max_by(|a, b| a.1.val_score.total_cmp(&b.1.val_score))
        .map(|(c, r)| (c.clone(), clone_result(r)))
        .expect("sweep produced no runs");
    Ok(SweepOutcome { best, best_config, runs })
}

fn clone_result(r: &TrainResult) -> TrainResult {
    TrainResult {
        model: r.model.clone(),
        val_score: r.val_score,
        steps: r.steps,
        final_loss: r.final_loss,
        history: r.history.clone(),
    }
}

/// The paper's GLUE adapter sweep (§3.2), scaled: lr grid, epochs grid,
/// seeds for instability re-runs. `quick` trims to a CPU-budget subset.
pub fn adapter_grid(kind: &str, sizes: &[usize], quick: bool) -> SweepGrid {
    let exes = sizes
        .iter()
        .map(|m| format!("{kind}_train_adapter_m{m}"))
        .collect();
    if quick {
        SweepGrid {
            exes,
            lrs: vec![1e-3],
            epochs: vec![6],
            seeds: vec![0],
            stds: vec![1e-2],
        }
    } else {
        SweepGrid {
            exes,
            lrs: vec![3e-4, 1e-3, 3e-3],
            epochs: vec![6, 12],
            seeds: vec![0, 1, 2],
            stds: vec![1e-2],
        }
    }
}

pub fn topk_grid(kind: &str, ks: &[usize], quick: bool) -> SweepGrid {
    let exes = ks.iter().map(|k| format!("{kind}_train_topk_k{k}")).collect();
    if quick {
        SweepGrid {
            exes,
            lrs: vec![1e-4],
            epochs: vec![6],
            seeds: vec![0],
            stds: vec![1e-2],
        }
    } else {
        SweepGrid {
            exes,
            lrs: vec![3e-5, 1e-4, 3e-4],
            epochs: vec![6, 12],
            seeds: vec![0, 1, 2],
            stds: vec![1e-2],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_cross_product() {
        let g = SweepGrid {
            exes: vec!["a".into(), "b".into()],
            lrs: vec![1e-3, 1e-4],
            epochs: vec![3],
            seeds: vec![0, 1, 2],
            stds: vec![1e-2],
        };
        assert_eq!(g.configs().len(), 2 * 2 * 1 * 3);
    }

    #[test]
    fn paper_grids_have_expected_shape() {
        let g = adapter_grid("cls", &[8, 64, 256], false);
        assert_eq!(g.exes.len(), 3);
        assert_eq!(g.lrs.len(), 3);
        assert_eq!(g.seeds.len(), 3);
        let q = adapter_grid("cls", &[8], true);
        assert_eq!(q.configs().len(), 1);
    }
}
