//! Gateway integration tests (test preset, native backend, real sockets).
//!
//! The acceptance path for the networked serving layer: start the
//! gateway on an ephemeral port, serve concurrent traffic for two tasks,
//! hot-register a third task over `POST /tasks` **mid-traffic**, and
//! verify (a) the new task serves correctly (vs. offline eval on the
//! same rows), (b) in-flight and subsequent requests for the prior tasks
//! are unaffected, (c) `/metrics` reports per-task p50/p99 — then drive
//! the closed-loop load generator over the same socket and check the
//! `BENCH_serve.json` it writes is schema-valid.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use adapterbert::bench::loadgen;
use adapterbert::coordinator::server::{Prediction, Request};
use adapterbert::coordinator::{
    FlushPolicy, Server, ServerConfig, StreamConfig, TaskStream,
};
use adapterbert::data::grammar::World;
use adapterbert::data::tasks::{self, TaskKind, TaskSpec};
use adapterbert::eval::{predict_split, Predictions, TaskModel};
use adapterbert::model::params::NamedTensors;
use adapterbert::obs::trace::TraceHandle;
use adapterbert::runtime::Runtime;
use adapterbert::serve::{Client, Gateway, GatewayConfig, RegisterRequest};
use adapterbert::store::AdapterStore;
use adapterbert::train::{self, PretrainConfig, TrainConfig};
use adapterbert::util::json::Json;

fn runtime() -> Arc<Runtime> {
    Arc::new(
        Runtime::open(
            Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")),
            "test",
        )
        .expect("open test preset (built-in presets synthesize their manifest)"),
    )
}

fn world(rt: &Runtime) -> World {
    World::new(rt.manifest.dims.vocab, 0)
}

fn pretrained_base(rt: &Arc<Runtime>) -> NamedTensors {
    static BASE: std::sync::OnceLock<NamedTensors> = std::sync::OnceLock::new();
    BASE.get_or_init(|| {
        train::load_or_pretrain(
            rt,
            &world(rt),
            &PretrainConfig { steps: 3000, log_every: 0, ..Default::default() },
            Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/runs/base_test.bank")),
        )
        .unwrap()
    })
    .clone()
}

fn cls_spec(name: &str, seed: u64) -> TaskSpec {
    TaskSpec {
        name: name.to_string(),
        kind: TaskKind::Cls { n_classes: 2, pair: false },
        metric: tasks::Metric::Accuracy,
        n_train: 240,
        n_val: 48,
        n_test: 48,
        purity: 0.85,
        noise: 0.0,
        seed,
    }
}

fn train_cls(
    rt: &Arc<Runtime>,
    base: &NamedTensors,
    name: &str,
    seed: u64,
) -> (TaskModel, tasks::TaskData, f64) {
    let spec = cls_spec(name, seed);
    let data = tasks::generate(&world(rt), &spec, rt.manifest.dims.seq);
    let cfg = TrainConfig::new("cls_train_adapter_m4", 1e-3, 5, 0);
    let res = train::train_task(rt, &cfg, &data, base).unwrap();
    (res.model, data, res.val_score)
}

fn class_preds(
    rt: &Arc<Runtime>,
    model: &TaskModel,
    base: &NamedTensors,
    split: &tasks::Split,
) -> Vec<usize> {
    match predict_split(rt, model, base, split, 2, None).unwrap() {
        Predictions::Class(v) => v,
        other => panic!("expected class predictions, got {other:?}"),
    }
}

fn quick_server(
    rt: &Arc<Runtime>,
    store: &Arc<AdapterStore>,
    base: &NamedTensors,
    classes: &BTreeMap<String, usize>,
) -> Server {
    Server::start(
        rt.clone(),
        store,
        base,
        classes,
        ServerConfig {
            flush: FlushPolicy {
                max_batch: 4,
                max_delay: Duration::from_millis(2),
            },
            executors: 2,
            queue_capacity: 256,
            ..Default::default()
        },
    )
    .unwrap()
}

/// The headline test: hot registration mid-traffic, per-task metrics,
/// loadgen → schema-valid BENCH_serve.json.
#[test]
fn gateway_hot_registration_mid_traffic() {
    let rt = runtime();
    let base = pretrained_base(&rt);
    let (model_a, data_a, val_a) = train_cls(&rt, &base, "gwa", 21);
    let (model_b, data_b, val_b) = train_cls(&rt, &base, "gwb", 22);
    let (model_c, data_c, _val_c) = train_cls(&rt, &base, "gwc", 23);

    let store = Arc::new(AdapterStore::in_memory());
    store.register("gwa", &model_a, val_a).unwrap();
    store.register("gwb", &model_b, val_b).unwrap();
    let mut classes = BTreeMap::new();
    classes.insert("gwa".to_string(), 2);
    classes.insert("gwb".to_string(), 2);
    let server = quick_server(&rt, &store, &base, &classes);
    let gw = Gateway::start(
        rt.clone(),
        store.clone(),
        server,
        GatewayConfig { addr: "127.0.0.1:0".to_string(), ..Default::default() },
    )
    .unwrap();
    let addr = gw.local_addr().to_string();

    // ground truth: offline predictions over the same rows the clients send
    let exp_a = class_preds(&rt, &model_a, &base, &data_a.test);
    let exp_b = class_preds(&rt, &model_b, &base, &data_b.test);
    let exp_c = class_preds(&rt, &model_c, &base, &data_c.test);
    let rows = 16usize.min(data_a.test.n).min(data_b.test.n);

    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let stop = &stop;
        let addr = &addr;
        // concurrent traffic on the two pre-registered tasks — every
        // response must match offline eval, before, during and after the
        // hot registration
        for (task, data, exp) in
            [("gwa", &data_a, &exp_a), ("gwb", &data_b, &exp_b)]
        {
            scope.spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                let mut i = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    let row = i % rows;
                    let resp =
                        client.predict_ids(task, data.test.row_tokens(row)).unwrap();
                    assert_eq!(resp.kind, "cls", "{task} row {row}");
                    assert_eq!(
                        resp.pred_class,
                        Some(exp[row]),
                        "{task} row {row}: served prediction diverged"
                    );
                    i += 1;
                }
                assert!(i > 0, "worker for {task} made no requests");
            });
        }

        let mut client = Client::connect(addr).unwrap();
        let health = client.health().unwrap();
        assert_eq!(health.status, "ok");
        assert_eq!(health.tasks, 2);
        assert_eq!(health.seq, rt.manifest.dims.seq);

        // before registration the third task 404s
        assert!(client.predict_ids("gwc", data_c.test.row_tokens(0)).is_err());

        // let traffic flow, then hot-register mid-stream
        std::thread::sleep(Duration::from_millis(150));
        let reg = RegisterRequest::from_model("gwc", 2, 0.9, &model_c);
        let reg_resp = client.register_task(&reg).unwrap();
        assert_eq!(reg_resp.task, "gwc");
        assert_eq!(reg_resp.version, 1);

        // (a) the new task serves correctly, immediately
        for row in 0..16usize.min(data_c.test.n) {
            let resp =
                client.predict_ids("gwc", data_c.test.row_tokens(row)).unwrap();
            assert_eq!(
                resp.pred_class,
                Some(exp_c[row]),
                "hot-registered task row {row}"
            );
        }
        let listing = client.tasks().unwrap();
        let names: Vec<&str> = listing.iter().map(|t| t.task.as_str()).collect();
        assert_eq!(names, vec!["gwa", "gwb", "gwc"]);

        // (b) keep prior-task traffic flowing a little longer post-swap
        std::thread::sleep(Duration::from_millis(150));
        stop.store(true, Ordering::Relaxed);
    });

    // (c) per-task latency quantiles for all three tasks
    let mut client = Client::connect(&addr).unwrap();
    let metrics = client.metrics().unwrap();
    for task in ["gwa", "gwb", "gwc"] {
        let h = metrics.at("tasks").at(task);
        assert!(h.at("count").as_usize().unwrap() > 0, "{task} count");
        let p50 = h.at("p50_ms").as_f64().unwrap();
        let p99 = h.at("p99_ms").as_f64().unwrap();
        assert!(p50 > 0.0, "{task} p50");
        assert!(p99 >= p50, "{task} p99 >= p50");
    }
    drop(client);

    // closed-loop load generator over the same socket
    let cfg = loadgen::LoadgenConfig {
        addr: addr.clone(),
        tasks: vec!["gwa".into(), "gwb".into(), "gwc".into()],
        concurrency: 3,
        requests: 60,
        words_per_request: 8,
        seed: 3,
        ..Default::default()
    };
    let report = loadgen::run(&cfg).unwrap();
    assert_eq!(report.requests, 60, "every loadgen request answered");
    assert_eq!(report.errors, 0);
    assert_eq!(report.per_task.len(), 3);

    // BENCH_serve.json: written at the repo root, schema-valid
    let out = Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/BENCH_serve.json"));
    loadgen::write_report(out, &report.to_json(&cfg)).unwrap();
    let text = std::fs::read_to_string(out).unwrap();
    let j = Json::parse(text.trim()).unwrap();
    assert_eq!(j.at("bench").as_str(), Some("serve"));
    assert_eq!(j.at("schema_version").as_usize(), Some(2));
    assert_eq!(j.at("totals").at("requests").as_usize(), Some(60));
    assert!(j.at("totals").at("throughput_rps").as_f64().unwrap() > 0.0);
    for key in ["mean", "p50", "p95", "p99", "max"] {
        assert!(
            j.at("totals").at("latency_ms").at(key).as_f64().is_some(),
            "totals.latency_ms.{key}"
        );
    }
    // schema v2: batch-size histogram + server occupancy window
    assert!(
        j.at("totals").at("batch_size_hist").as_obj().is_some(),
        "totals.batch_size_hist missing"
    );
    assert_eq!(j.at("server").at("exec_mode").as_str(), Some("per_task"));
    assert!(j.at("server").at("mean_occupancy").as_f64().is_some());
    for task in ["gwa", "gwb", "gwc"] {
        let t = j.at("per_task").at(task);
        assert!(t.at("requests").as_usize().unwrap() > 0, "{task} in per_task");
    }

    // graceful drain: everything accepted was answered
    let final_report = gw.shutdown().unwrap();
    assert!(final_report.served >= 60, "served {}", final_report.served);
    assert_eq!(final_report.timeouts, 0);
    assert_eq!(
        final_report.server.requests,
        final_report.server.latencies.len() as u64
    );
}

/// PR 6 regression: `/metrics` is assembled from one atomic coordinator
/// snapshot (`Server::metrics_snapshot`), never from piecemeal lock
/// acquisitions. Hammer it from two connections while tasks hot-register,
/// and the cache section must be internally consistent on every poll:
/// the resident count matches the resident task list, residency never
/// exceeds the registered directory, and the cold-load counter always
/// reconciles with misses and load errors.
#[test]
fn metrics_stay_consistent_under_hot_registration() {
    let rt = runtime();
    let base = pretrained_base(&rt);
    let (model, _data, val) = train_cls(&rt, &base, "gwm0", 24);
    let store = Arc::new(AdapterStore::in_memory());
    store.register("gwm0", &model, val).unwrap();
    let mut classes = BTreeMap::new();
    classes.insert("gwm0".to_string(), 2);
    let server = quick_server(&rt, &store, &base, &classes);
    let gw = Gateway::start(
        rt.clone(),
        store.clone(),
        server,
        GatewayConfig { addr: "127.0.0.1:0".to_string(), ..Default::default() },
    )
    .unwrap();
    let addr = gw.local_addr().to_string();

    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let stop = &stop;
        let addr = &addr;
        for _ in 0..2 {
            scope.spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                let mut polls = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    let m = client.metrics().unwrap();
                    let cache = m.at("cache");
                    let resident = cache.at("resident").as_usize().unwrap();
                    let tasks = cache.at("resident_tasks").as_arr().unwrap();
                    assert_eq!(
                        resident,
                        tasks.len(),
                        "resident count vs resident task list (poll {polls})"
                    );
                    let registered = cache.at("registered").as_usize().unwrap();
                    assert!(
                        resident <= registered,
                        "poll {polls}: resident {resident} > registered {registered}"
                    );
                    let misses = cache.at("misses").as_usize().unwrap();
                    let errors = cache.at("load_errors").as_usize().unwrap();
                    assert_eq!(
                        cache.at("cold_loads").as_usize().unwrap(),
                        misses - errors,
                        "poll {polls}: cold_loads out of step"
                    );
                    polls += 1;
                }
                assert!(polls > 0, "metrics poller never ran");
            });
        }
        // hot-register eight more tasks while /metrics is being polled
        // (same trained bank under new names — the churn is the point)
        let mut client = Client::connect(addr).unwrap();
        for i in 1..9 {
            let name = format!("gwm{i}");
            let reg = RegisterRequest::from_model(&name, 2, 0.9, &model);
            let resp = client.register_task(&reg).unwrap();
            assert_eq!(resp.task, name);
            std::thread::sleep(Duration::from_millis(20));
        }
        stop.store(true, Ordering::Relaxed);
    });

    // all nine registered and (unbounded budget) resident
    let mut client = Client::connect(&addr).unwrap();
    let m = client.metrics().unwrap();
    assert_eq!(m.at("cache").at("registered").as_usize(), Some(9));
    assert_eq!(m.at("cache").at("resident").as_usize(), Some(9));
    drop(client);
    gw.shutdown().unwrap();
}

/// The gateway serves all three head kinds: wire a regression and a span
/// task through and check payloads against offline eval, row by row.
#[test]
fn gateway_serves_reg_and_span_heads() {
    let rt = runtime();
    let base = pretrained_base(&rt);
    let seq = rt.manifest.dims.seq;

    let reg_spec = TaskSpec {
        name: "gwreg".to_string(),
        kind: TaskKind::Reg,
        metric: tasks::Metric::Spearman,
        n_train: 160,
        n_val: 32,
        n_test: 32,
        purity: 0.5,
        noise: 0.0,
        seed: 31,
    };
    let span_spec = TaskSpec {
        name: "gwspan".to_string(),
        kind: TaskKind::Span,
        metric: tasks::Metric::SpanF1,
        n_train: 160,
        n_val: 32,
        n_test: 32,
        purity: 0.9,
        noise: 0.0,
        seed: 32,
    };
    let reg_data = tasks::generate(&world(&rt), &reg_spec, seq);
    let span_data = tasks::generate(&world(&rt), &span_spec, seq);
    let reg_model = train::train_task(
        &rt,
        &TrainConfig::new("reg_train_adapter_m8", 1e-3, 2, 0),
        &reg_data,
        &base,
    )
    .unwrap()
    .model;
    let span_model = train::train_task(
        &rt,
        &TrainConfig::new("span_train_adapter_m8", 1e-3, 2, 0),
        &span_data,
        &base,
    )
    .unwrap()
    .model;

    let exp_reg = match predict_split(&rt, &reg_model, &base, &reg_data.test, 0, None)
        .unwrap()
    {
        Predictions::Score(v) => v,
        other => panic!("expected scores, got {other:?}"),
    };
    let exp_span =
        match predict_split(&rt, &span_model, &base, &span_data.test, 0, None).unwrap()
        {
            Predictions::Span(v) => v,
            other => panic!("expected spans, got {other:?}"),
        };

    let store = Arc::new(AdapterStore::in_memory());
    store.register("gwreg", &reg_model, 0.5).unwrap();
    store.register("gwspan", &span_model, 0.5).unwrap();
    let mut classes = BTreeMap::new();
    classes.insert("gwreg".to_string(), 0);
    classes.insert("gwspan".to_string(), 0);
    let server = quick_server(&rt, &store, &base, &classes);
    let gw = Gateway::start(
        rt.clone(),
        store.clone(),
        server,
        GatewayConfig { addr: "127.0.0.1:0".to_string(), ..Default::default() },
    )
    .unwrap();
    let mut client = Client::connect(&gw.local_addr().to_string()).unwrap();

    for row in 0..8usize.min(reg_data.test.n) {
        let resp = client
            .predict_ids("gwreg", reg_data.test.row_tokens(row))
            .unwrap();
        assert_eq!(resp.kind, "reg", "row {row}");
        let served = resp.score.expect("reg response carries a score");
        assert!(
            (served - exp_reg[row]).abs() < 1e-5,
            "row {row}: served {served} vs offline {}",
            exp_reg[row]
        );
        assert!(resp.pred_class.is_none());
    }
    for row in 0..8usize.min(span_data.test.n) {
        let resp = client
            .predict_ids("gwspan", span_data.test.row_tokens(row))
            .unwrap();
        assert_eq!(resp.kind, "span", "row {row}");
        assert_eq!(resp.span, Some(exp_span[row]), "row {row}");
    }

    gw.shutdown().unwrap();
}

/// The in-process seam: a `TaskStream` wired to a live server via
/// `set_on_register` + `register_live` — train-and-serve with no restart.
#[test]
fn stream_hot_installs_into_live_server() {
    let rt = runtime();
    let base = pretrained_base(&rt);
    let store = Arc::new(AdapterStore::in_memory());
    let server = Arc::new(quick_server(&rt, &store, &base, &BTreeMap::new()));
    assert!(server.tasks().is_empty());

    let cfg = StreamConfig {
        adapter_sizes: vec![4],
        lrs: vec![1e-3],
        epochs: 3,
        seeds: vec![0],
        threads: 1,
    };
    let mut stream =
        TaskStream::new(rt.clone(), base.clone(), store.clone(), world(&rt), cfg);
    let srv = server.clone();
    stream.set_on_register(move |task, n_classes, model| {
        srv.register_live(task, n_classes, model).unwrap();
    });
    let spec = cls_spec("streamed", 41);
    let report = stream.run(std::slice::from_ref(&spec)).unwrap();
    assert!(!report.forgetting_detected);
    drop(stream); // releases the server Arc held by the callback

    // the server picked the task up live
    assert_eq!(server.tasks(), vec!["streamed".to_string()]);
    assert_eq!(server.task_info("streamed"), Some(("cls".to_string(), 2)));

    // and it answers requests
    let data = tasks::generate(&world(&rt), &spec, rt.manifest.dims.seq);
    let (reply, rx) = mpsc::channel();
    let row: Vec<i32> = data.test.row_tokens(0).to_vec();
    let seq = rt.manifest.dims.seq;
    server
        .submit_blocking(Request {
            task: "streamed".to_string(),
            tokens: row.clone(),
            segments: vec![0; seq],
            attn_mask: row
                .iter()
                .map(|&t| if t == 0 { 0.0 } else { 1.0 })
                .collect(),
            reply,
            submitted: Instant::now(),
            trace: TraceHandle::none(),
        })
        .unwrap();
    let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
    assert!(matches!(resp.prediction, Prediction::Class(_)));

    // drain refuses new work but the accepted request above was answered
    server.drain();
    let (reply2, _rx2) = mpsc::channel();
    assert!(server
        .submit(Request {
            task: "streamed".to_string(),
            tokens: row,
            segments: vec![0; seq],
            attn_mask: vec![1.0; seq],
            reply: reply2,
            submitted: Instant::now(),
            trace: TraceHandle::none(),
        })
        .is_err());
    let server = Arc::try_unwrap(server).ok().expect("no other refs");
    let metrics = server.shutdown();
    assert_eq!(metrics.requests, 1);
}

/// PR 8 drain semantics through the wire: flipping `Server::drain` under
/// concurrent traffic never hangs or corrupts a response — every request
/// either completes with the correct prediction (accepted before the
/// flip, or in flight across it) or is refused with the draining 503;
/// late arrivals are refused, and `/health` reports `draining`.
#[test]
fn gateway_drain_completes_inflight_and_refuses_late_arrivals() {
    let rt = runtime();
    let base = pretrained_base(&rt);
    let (model, data, val) = train_cls(&rt, &base, "gwdrain", 26);
    let store = Arc::new(AdapterStore::in_memory());
    store.register("gwdrain", &model, val).unwrap();
    let mut classes = BTreeMap::new();
    classes.insert("gwdrain".to_string(), 2);
    let server = quick_server(&rt, &store, &base, &classes);
    let gw = Gateway::start(
        rt.clone(),
        store.clone(),
        server,
        GatewayConfig { addr: "127.0.0.1:0".to_string(), ..Default::default() },
    )
    .unwrap();
    let addr = gw.local_addr().to_string();
    let exp = class_preds(&rt, &model, &base, &data.test);
    let rows = 16usize.min(data.test.n);

    let stop = AtomicBool::new(false);
    let answered = AtomicUsize::new(0);
    let refused = AtomicUsize::new(0);
    std::thread::scope(|scope| {
        let (stop, answered, refused) = (&stop, &answered, &refused);
        let (addr, data, exp) = (&addr, &data, &exp);
        for _ in 0..2 {
            scope.spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                let mut i = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    let row = i % rows;
                    i += 1;
                    match client.predict_ids("gwdrain", data.test.row_tokens(row))
                    {
                        Ok(resp) => {
                            // anything answered must be answered correctly
                            assert_eq!(
                                resp.pred_class,
                                Some(exp[row]),
                                "row {row} corrupted around drain"
                            );
                            answered.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(e) => {
                            // the only legitimate refusal is the drain 503,
                            // on a connection that stays usable
                            assert!(
                                format!("{e:#}").contains("server draining"),
                                "unexpected error around drain: {e:#}"
                            );
                            refused.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
            });
        }
        // let traffic flow, flip the switch with requests in flight, then
        // keep the workers hammering the draining gateway for a while
        std::thread::sleep(Duration::from_millis(150));
        gw.server().drain();
        std::thread::sleep(Duration::from_millis(150));
        stop.store(true, Ordering::Relaxed);
    });
    assert!(answered.load(Ordering::Relaxed) > 0, "no request ever answered");
    assert!(refused.load(Ordering::Relaxed) > 0, "drain refused nothing");

    // late arrivals on a fresh connection are refused too…
    let mut client = Client::connect(&addr).unwrap();
    let err = client
        .predict_ids("gwdrain", data.test.row_tokens(0))
        .expect_err("draining gateway must refuse new work");
    assert!(format!("{err:#}").contains("server draining"), "{err:#}");
    // …and the health document says so (the cluster prober keys off this)
    let health = client.health().unwrap();
    assert!(health.draining, "health must advertise draining");
    assert_eq!(health.status, "ok");
    drop(client);

    // drain-then-shutdown answers everything it accepted
    let report = gw.shutdown().unwrap();
    assert_eq!(report.server.requests, report.server.latencies.len() as u64);
}

/// PR 7 observability: request ids are honored/minted and echoed on every
/// response (including error shapes), traced requests land in the span
/// ring with complete stage chains at `GET /trace`, and the Prometheus
/// text exposition at `GET /metrics?format=prometheus` passes the
/// line-format check.
#[test]
fn gateway_observability_surfaces() {
    use std::io::Write as _;

    use adapterbert::obs::prom;
    use adapterbert::serve::http::read_client_response;

    let rt = runtime();
    let base = pretrained_base(&rt);
    let (model, data, val) = train_cls(&rt, &base, "gwobs", 25);
    let store = Arc::new(AdapterStore::in_memory());
    store.register("gwobs", &model, val).unwrap();
    let mut classes = BTreeMap::new();
    classes.insert("gwobs".to_string(), 2);
    let server = quick_server(&rt, &store, &base, &classes);
    let gw = Gateway::start(
        rt.clone(),
        store.clone(),
        server,
        GatewayConfig {
            addr: "127.0.0.1:0".to_string(),
            trace: true,
            ..Default::default()
        },
    )
    .unwrap();
    let addr = gw.local_addr().to_string();

    // raw socket so the request headers are under test control
    let stream = std::net::TcpStream::connect(&addr).unwrap();
    let mut reader = std::io::BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;

    // a client-supplied X-Request-Id echoes back verbatim — on errors too
    for (path, want) in [("/health", 200u16), ("/no_such_route", 404)] {
        write!(
            writer,
            "GET {path} HTTP/1.1\r\nhost: t\r\nx-request-id: rid-echo-7\r\n\
             content-length: 0\r\nconnection: keep-alive\r\n\r\n"
        )
        .unwrap();
        writer.flush().unwrap();
        let resp = read_client_response(&mut reader).unwrap();
        assert_eq!(resp.status, want, "{path}");
        assert_eq!(resp.header("x-request-id"), Some("rid-echo-7"), "{path}");
    }
    // without the header the gateway mints a non-empty id
    write!(
        writer,
        "GET /health HTTP/1.1\r\nhost: t\r\ncontent-length: 0\r\n\
         connection: keep-alive\r\n\r\n"
    )
    .unwrap();
    writer.flush().unwrap();
    let resp = read_client_response(&mut reader).unwrap();
    let minted = resp.header("x-request-id").expect("gateway mints an id");
    assert!(!minted.trim().is_empty(), "minted id must be non-empty");
    drop(reader);
    drop(writer);

    // traced traffic → spans with complete stage chains at GET /trace
    let mut client = Client::connect(&addr).unwrap();
    let rows = 8usize.min(data.test.n);
    for row in 0..rows {
        client.predict_ids("gwobs", data.test.row_tokens(row)).unwrap();
    }
    let t = client.trace().unwrap();
    assert_eq!(t.at("enabled").as_bool(), Some(true));
    let spans = t.at("spans").as_arr().unwrap();
    // the ring is process-global, so other tests' spans may interleave —
    // judge only this test's task
    let mine: Vec<&Json> = spans
        .iter()
        .filter(|s| {
            s.at("task").as_str() == Some("gwobs")
                && s.at("kind").as_str() == Some("request")
                && s.at("status").as_usize() == Some(200)
        })
        .collect();
    assert!(mine.len() >= rows, "{} spans for {rows} requests", mine.len());
    for sp in &mine {
        assert_eq!(sp.at("complete").as_f64(), Some(1.0), "complete chain");
        assert!(!sp.at("rid").as_str().unwrap_or("").is_empty(), "span rid");
        let total = sp.at("total_us").as_f64().unwrap();
        let stages = sp.at("stages_us").as_obj().unwrap();
        assert_eq!(stages.len(), 5, "all five stages present");
        let sum: f64 = stages.values().map(|v| v.as_f64().unwrap()).sum();
        assert_eq!(sum, total, "stage durations tile the span end-to-end");
    }

    // Prometheus text exposition parses and carries the core families
    let body = client.metrics_prometheus().unwrap();
    if let Err(e) = prom::check_exposition(&body) {
        panic!("exposition rejected: {e}");
    }
    for needle in [
        "# TYPE adapterbert_requests_served_total counter",
        "adapterbert_request_duration_seconds_bucket",
        "adapterbert_trace_spans_total",
    ] {
        assert!(body.contains(needle), "missing {needle:?} in exposition");
    }

    drop(client);
    gw.shutdown().unwrap();
}
