"""L1: the fused Houlsby bottleneck-adapter kernel (forward + backward).

The adapter is the hot spot the paper *adds* to the Transformer: two skinny
GEMMs (d->m, m->d with m << d), a GELU, and the internal skip-connection,
executed twice per layer. A naive implementation materializes the
bottleneck activation ``h`` in HBM three times (once per op); the fused
kernel streams a row-block of ``x`` into VMEM once, keeps ``W_down/W_up``
pinned in VMEM (they always fit: m <= 512), and never round-trips ``h``.

TPU mapping (see DESIGN.md §Hardware-Adaptation):
  * grid = row blocks (token-parallel), analogue of CUDA threadblocks;
  * BlockSpec pins the weight operands whole (index_map -> block 0) so the
    pipeline only streams activations;
  * row block defaults to 128 to align with the 128x128 MXU.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and interpret mode lowers to plain HLO so the AOT artifacts
run anywhere. Correctness is pinned to :mod:`.ref` by pytest/hypothesis.

The public entry point :func:`adapter` carries a custom VJP whose backward
pass is itself a Pallas kernel (recompute-in-VMEM + gradient accumulation
across row blocks), so the *training* artifacts also run the fused path.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_ROWS = 128

_C = 0.7978845608028654  # sqrt(2/pi)
_A = 0.044715


def _gelu(x):
    return 0.5 * x * (1.0 + jnp.tanh(_C * (x + _A * x * x * x)))


def _gelu_grad(x):
    t = jnp.tanh(_C * (x + _A * x * x * x))
    return 0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * _C * (1.0 + 3.0 * _A * x * x)


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------


def _fwd_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    """One row-block: o = x + GELU(x @ W1 + b1) @ W2 + b2."""
    x = x_ref[...]
    h = _gelu(
        jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
        + b1_ref[...][None, :]
    )
    o_ref[...] = (
        x
        + jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
        + b2_ref[...][None, :]
    )


def _pad_rows(x, block_rows):
    rows = x.shape[0]
    pad = (-rows) % block_rows
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad, x.shape[1]), x.dtype)], axis=0)
    return x, rows


@functools.partial(jax.jit, static_argnames=("block_rows",))
def adapter_fwd_pallas(x, w1, b1, w2, b2, block_rows: int = DEFAULT_BLOCK_ROWS):
    """Fused adapter forward. x: [rows, d] -> [rows, d]."""
    xp, rows = _pad_rows(x, block_rows)
    d = x.shape[1]
    m = w1.shape[1]
    n_blocks = xp.shape[0] // block_rows
    out = pl.pallas_call(
        _fwd_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d, m), lambda i: (0, 0)),  # pinned whole in VMEM
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, x.dtype),
        interpret=True,
    )(xp, w1, b1, w2, b2)
    return out[:rows]


# ---------------------------------------------------------------------------
# backward
# ---------------------------------------------------------------------------


def _bwd_kernel(
    x_ref, w1_ref, w2_ref, b1_ref, g_ref,
    dx_ref, dw1_ref, db1_ref, dw2_ref, db2_ref,
):
    """One row-block of the adapter VJP, recomputing ``h`` in VMEM.

    Weight/bias gradients are accumulated across grid steps into output
    blocks that map to the same (0, 0) block every iteration — the Pallas
    revisiting-accumulator pattern (grid is sequential on TPU/interpret).
    """
    i = pl.program_id(0)
    x = x_ref[...]
    g = g_ref[...]
    w1 = w1_ref[...]
    w2 = w2_ref[...]
    pre = jnp.dot(x, w1, preferred_element_type=jnp.float32) + b1_ref[...][None, :]
    h = _gelu(pre)
    dh = jnp.dot(g, w2.T, preferred_element_type=jnp.float32)
    dpre = dh * _gelu_grad(pre)
    dx_ref[...] = g + jnp.dot(dpre, w1.T, preferred_element_type=jnp.float32)

    @pl.when(i == 0)
    def _init():
        dw1_ref[...] = jnp.zeros_like(dw1_ref)
        db1_ref[...] = jnp.zeros_like(db1_ref)
        dw2_ref[...] = jnp.zeros_like(dw2_ref)
        db2_ref[...] = jnp.zeros_like(db2_ref)

    dw1_ref[...] += jnp.dot(x.T, dpre, preferred_element_type=jnp.float32)
    db1_ref[...] += jnp.sum(dpre, axis=0)
    dw2_ref[...] += jnp.dot(h.T, g, preferred_element_type=jnp.float32)
    db2_ref[...] += jnp.sum(g, axis=0)


@functools.partial(jax.jit, static_argnames=("block_rows",))
def adapter_bwd_pallas(x, w1, b1, w2, g, block_rows: int = DEFAULT_BLOCK_ROWS):
    """Fused adapter backward: returns (dx, dw1, db1, dw2, db2)."""
    xp, rows = _pad_rows(x, block_rows)
    gp, _ = _pad_rows(g, block_rows)
    d = x.shape[1]
    m = w1.shape[1]
    n_blocks = xp.shape[0] // block_rows
    outs = pl.pallas_call(
        _bwd_kernel,
        grid=(n_blocks,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d, m), lambda i: (0, 0)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d, m), lambda i: (0, 0)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((m, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(xp.shape, x.dtype),
            jax.ShapeDtypeStruct((d, m), x.dtype),
            jax.ShapeDtypeStruct((m,), x.dtype),
            jax.ShapeDtypeStruct((m, d), x.dtype),
            jax.ShapeDtypeStruct((d,), x.dtype),
        ],
        interpret=True,
    )(xp, w1, w2, b1, gp)
    dx, dw1, db1, dw2, db2 = outs
    return dx[:rows], dw1, db1, dw2, db2


# ---------------------------------------------------------------------------
# public op with custom VJP
# ---------------------------------------------------------------------------


@jax.custom_vjp
def adapter(x, w1, b1, w2, b2):
    """Fused bottleneck adapter ``y = x + GELU(x @ W1 + b1) @ W2 + b2``.

    Differentiable: the VJP runs :func:`adapter_bwd_pallas`. Shapes:
    x [rows, d], w1 [d, m], b1 [m], w2 [m, d], b2 [d].
    """
    return adapter_fwd_pallas(x, w1, b1, w2, b2)


def _adapter_fwd_rule(x, w1, b1, w2, b2):
    return adapter_fwd_pallas(x, w1, b1, w2, b2), (x, w1, b1, w2)


def _adapter_bwd_rule(res, g):
    x, w1, b1, w2 = res
    dx, dw1, db1, dw2, db2 = adapter_bwd_pallas(x, w1, b1, w2, g)
    return dx, dw1, db1, dw2, db2


adapter.defvjp(_adapter_fwd_rule, _adapter_bwd_rule)


def adapter_nd(x, w1, b1, w2, b2):
    """Adapter over arbitrary leading dims: x [..., d]."""
    d = x.shape[-1]
    flat = x.reshape((-1, d))
    return adapter(flat, w1, b1, w2, b2).reshape(x.shape)
