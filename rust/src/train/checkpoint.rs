//! Durable training-job state: everything a [`crate::train::TrainState`]
//! needs to continue a run after a crash, byte for byte.
//!
//! A checkpoint is a full snapshot of the host-side training loop —
//! optimizer moments (`opt_m`/`opt_v`), the current trained bank, the
//! best-on-validation bank so far, the step/epoch cursors, the shuffled
//! epoch order and the raw RNG state — so resuming replays *exactly* the
//! remaining steps the uninterrupted run would have taken. The binary
//! layout is versioned and self-delimiting (magic + version header,
//! length-prefixed sections, [`Tensor::write_to`] for tensors) and the
//! originating [`TrainConfig`](crate::train::TrainConfig) is echoed in
//! full, so resuming under a different configuration fails loudly instead
//! of silently diverging.

use anyhow::{bail, Context, Result};

use crate::runtime::Bank;
use crate::util::tensor::Tensor;

/// File magic for serialized checkpoints (`ABTC` = AdapterBert Train
/// Checkpoint).
const MAGIC: &[u8; 4] = b"ABTC";
/// Current serialization version.
const VERSION: u32 = 1;

/// A serializable snapshot of one training run.
///
/// Produced by [`crate::train::TrainState::checkpoint`] and consumed by
/// [`crate::train::TrainState::resume`]. The config fields (`exe` … `eval_each_epoch`)
/// echo the `TrainConfig` the run started with; resume validates them.
#[derive(Debug, Clone)]
pub struct TrainCheckpoint {
    // -- config echo (validated on resume) ---------------------------------
    pub exe: String,
    pub lr: f64,
    pub epochs: usize,
    pub warmup_frac: f64,
    pub seed: u64,
    pub adapter_std: f64,
    pub eval_each_epoch: bool,
    // -- loop cursors ------------------------------------------------------
    /// Optimizer steps taken so far.
    pub step: usize,
    /// Completed epochs.
    pub epoch: usize,
    /// Cursor into `order` (start of the next batch of the current epoch).
    pub pos: usize,
    /// Whether `order` has been shuffled for the current epoch yet.
    pub shuffled: bool,
    /// Raw [`crate::util::rng::Rng`] state (epoch shuffling).
    pub rng_state: u64,
    /// Loss of the last executed step (`NaN` before the first).
    pub final_loss: f64,
    /// The current epoch's (possibly shuffled) row order.
    pub order: Vec<usize>,
    /// Per-step losses accumulated inside the current epoch.
    pub epoch_losses: Vec<f64>,
    /// `(epoch, mean train loss, val score)` rows so far.
    pub history: Vec<(usize, f64, f64)>,
    // -- numeric state -----------------------------------------------------
    /// Current trained bank (positional, train-exe `trained` order).
    pub trained: Bank,
    /// Adam first moments.
    pub opt_m: Bank,
    /// Adam second moments.
    pub opt_v: Bank,
    /// Best-on-validation snapshot so far: `(val score, trained bank)`.
    pub best: Option<(f64, Bank)>,
}

impl TrainCheckpoint {
    /// Serialize to the versioned binary layout.
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend(MAGIC);
        out.extend(VERSION.to_le_bytes());
        put_str(&mut out, &self.exe);
        put_f64(&mut out, self.lr);
        put_u64(&mut out, self.epochs as u64);
        put_f64(&mut out, self.warmup_frac);
        put_u64(&mut out, self.seed);
        put_f64(&mut out, self.adapter_std);
        out.push(self.eval_each_epoch as u8);
        put_u64(&mut out, self.step as u64);
        put_u64(&mut out, self.epoch as u64);
        put_u64(&mut out, self.pos as u64);
        out.push(self.shuffled as u8);
        put_u64(&mut out, self.rng_state);
        put_f64(&mut out, self.final_loss);
        put_u64(&mut out, self.order.len() as u64);
        for &i in &self.order {
            put_u64(&mut out, i as u64);
        }
        put_u64(&mut out, self.epoch_losses.len() as u64);
        for &l in &self.epoch_losses {
            put_f64(&mut out, l);
        }
        put_u64(&mut out, self.history.len() as u64);
        for &(e, loss, val) in &self.history {
            put_u64(&mut out, e as u64);
            put_f64(&mut out, loss);
            put_f64(&mut out, val);
        }
        put_bank(&mut out, &self.trained);
        put_bank(&mut out, &self.opt_m);
        put_bank(&mut out, &self.opt_v);
        match &self.best {
            None => out.push(0),
            Some((val, bank)) => {
                out.push(1);
                put_f64(&mut out, *val);
                put_bank(&mut out, bank);
            }
        }
        out
    }

    /// Parse a checkpoint previously produced by [`Self::to_bytes`].
    pub fn from_bytes(buf: &[u8]) -> Result<TrainCheckpoint> {
        let mut pos = 0usize;
        let magic = take(buf, &mut pos, 4)?;
        if magic != MAGIC {
            bail!("not a training checkpoint (bad magic {magic:?})");
        }
        let version = u32::from_le_bytes(take(buf, &mut pos, 4)?.try_into().unwrap());
        if version != VERSION {
            bail!("unsupported checkpoint version {version} (expected {VERSION})");
        }
        let exe = get_str(buf, &mut pos)?;
        let lr = get_f64(buf, &mut pos)?;
        let epochs = get_u64(buf, &mut pos)? as usize;
        let warmup_frac = get_f64(buf, &mut pos)?;
        let seed = get_u64(buf, &mut pos)?;
        let adapter_std = get_f64(buf, &mut pos)?;
        let eval_each_epoch = get_bool(buf, &mut pos)?;
        let step = get_u64(buf, &mut pos)? as usize;
        let epoch = get_u64(buf, &mut pos)? as usize;
        let cursor = get_u64(buf, &mut pos)? as usize;
        let shuffled = get_bool(buf, &mut pos)?;
        let rng_state = get_u64(buf, &mut pos)?;
        let final_loss = get_f64(buf, &mut pos)?;
        let n = get_u64(buf, &mut pos)? as usize;
        if n > buf.len() {
            bail!("implausible order length {n}");
        }
        let mut order = Vec::with_capacity(n);
        for _ in 0..n {
            order.push(get_u64(buf, &mut pos)? as usize);
        }
        let n = get_u64(buf, &mut pos)? as usize;
        if n > buf.len() {
            bail!("implausible loss count {n}");
        }
        let mut epoch_losses = Vec::with_capacity(n);
        for _ in 0..n {
            epoch_losses.push(get_f64(buf, &mut pos)?);
        }
        let n = get_u64(buf, &mut pos)? as usize;
        if n > buf.len() {
            bail!("implausible history length {n}");
        }
        let mut history = Vec::with_capacity(n);
        for _ in 0..n {
            let e = get_u64(buf, &mut pos)? as usize;
            let loss = get_f64(buf, &mut pos)?;
            let val = get_f64(buf, &mut pos)?;
            history.push((e, loss, val));
        }
        let trained = get_bank(buf, &mut pos)?;
        let opt_m = get_bank(buf, &mut pos)?;
        let opt_v = get_bank(buf, &mut pos)?;
        let best = match take(buf, &mut pos, 1)?[0] {
            0 => None,
            1 => {
                let val = get_f64(buf, &mut pos)?;
                let bank = get_bank(buf, &mut pos)?;
                Some((val, bank))
            }
            other => bail!("bad best-bank tag {other}"),
        };
        if pos != buf.len() {
            bail!("trailing bytes in checkpoint ({} of {})", pos, buf.len());
        }
        Ok(TrainCheckpoint {
            exe,
            lr,
            epochs,
            warmup_frac,
            seed,
            adapter_std,
            eval_each_epoch,
            step,
            epoch,
            pos: cursor,
            shuffled,
            rng_state,
            final_loss,
            order,
            epoch_losses,
            history,
            trained,
            opt_m,
            opt_v,
            best,
        })
    }
}

// ---------------------------------------------------------------------------
// little-endian section primitives
// ---------------------------------------------------------------------------

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend(v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend(v.to_le_bytes());
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u64(out, s.len() as u64);
    out.extend(s.as_bytes());
}

fn put_bank(out: &mut Vec<u8>, bank: &Bank) {
    put_u64(out, bank.len() as u64);
    for t in bank {
        t.write_to(out);
    }
}

fn take<'a>(buf: &'a [u8], pos: &mut usize, n: usize) -> Result<&'a [u8]> {
    if *pos + n > buf.len() {
        bail!("truncated checkpoint at byte {pos}");
    }
    let s = &buf[*pos..*pos + n];
    *pos += n;
    Ok(s)
}

fn get_u64(buf: &[u8], pos: &mut usize) -> Result<u64> {
    Ok(u64::from_le_bytes(take(buf, pos, 8)?.try_into().unwrap()))
}

fn get_f64(buf: &[u8], pos: &mut usize) -> Result<f64> {
    Ok(f64::from_le_bytes(take(buf, pos, 8)?.try_into().unwrap()))
}

fn get_bool(buf: &[u8], pos: &mut usize) -> Result<bool> {
    match take(buf, pos, 1)?[0] {
        0 => Ok(false),
        1 => Ok(true),
        other => bail!("bad bool tag {other}"),
    }
}

fn get_str(buf: &[u8], pos: &mut usize) -> Result<String> {
    let n = get_u64(buf, pos)? as usize;
    if n > buf.len() {
        bail!("implausible string length {n}");
    }
    String::from_utf8(take(buf, pos, n)?.to_vec()).context("non-utf8 string")
}

fn get_bank(buf: &[u8], pos: &mut usize) -> Result<Bank> {
    let n = get_u64(buf, pos)? as usize;
    if n > buf.len() {
        bail!("implausible bank length {n}");
    }
    let mut bank = Vec::with_capacity(n);
    for _ in 0..n {
        bank.push(Tensor::read_from(buf, pos)?);
    }
    Ok(bank)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> TrainCheckpoint {
        TrainCheckpoint {
            exe: "cls_train_adapter_m8".into(),
            lr: 1e-3,
            epochs: 6,
            warmup_frac: 0.1,
            seed: 7,
            adapter_std: 1e-2,
            eval_each_epoch: true,
            step: 42,
            epoch: 2,
            pos: 16,
            shuffled: true,
            rng_state: 0xDEAD_BEEF_CAFE_F00D,
            final_loss: 0.625,
            order: vec![3, 1, 2, 0],
            epoch_losses: vec![0.9, 0.8],
            history: vec![(0, 1.2, 0.5), (1, 0.9, f64::NAN)],
            trained: vec![Tensor::f32(vec![2, 2], vec![1.0, -2.0, 0.5, 0.25])],
            opt_m: vec![Tensor::f32(vec![2, 2], vec![0.0; 4])],
            opt_v: vec![Tensor::f32(vec![2, 2], vec![0.1; 4])],
            best: Some((0.75, vec![Tensor::f32(vec![2, 2], vec![9.0; 4])])),
        }
    }

    #[test]
    fn roundtrip_is_lossless() {
        let ck = sample();
        let back = TrainCheckpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert_eq!(back.exe, ck.exe);
        assert_eq!(back.step, ck.step);
        assert_eq!(back.epoch, ck.epoch);
        assert_eq!(back.pos, ck.pos);
        assert_eq!(back.shuffled, ck.shuffled);
        assert_eq!(back.rng_state, ck.rng_state);
        assert_eq!(back.order, ck.order);
        assert_eq!(back.epoch_losses, ck.epoch_losses);
        assert_eq!(back.trained, ck.trained);
        assert_eq!(back.opt_m, ck.opt_m);
        assert_eq!(back.opt_v, ck.opt_v);
        let (val, bank) = back.best.unwrap();
        assert_eq!(val, 0.75);
        assert_eq!(bank, ck.best.as_ref().unwrap().1);
        // NaN survives (history row without an eval)
        assert!(back.history[1].2.is_nan());
        assert_eq!(back.history.len(), 2);
    }

    #[test]
    fn rejects_corruption() {
        let ck = sample();
        let bytes = ck.to_bytes();
        // bad magic
        let mut bad = bytes.clone();
        bad[0] = b'X';
        assert!(TrainCheckpoint::from_bytes(&bad).is_err());
        // truncation anywhere must error, never panic
        for cut in [5, 20, bytes.len() / 2, bytes.len() - 1] {
            assert!(
                TrainCheckpoint::from_bytes(&bytes[..cut]).is_err(),
                "cut at {cut} accepted"
            );
        }
        // trailing garbage
        let mut long = bytes.clone();
        long.push(0);
        assert!(TrainCheckpoint::from_bytes(&long).is_err());
        // wrong version
        let mut vbad = bytes;
        vbad[4] = 99;
        assert!(TrainCheckpoint::from_bytes(&vbad).is_err());
    }

    #[test]
    fn no_best_bank_roundtrips() {
        let mut ck = sample();
        ck.best = None;
        let back = TrainCheckpoint::from_bytes(&ck.to_bytes()).unwrap();
        assert!(back.best.is_none());
    }
}
