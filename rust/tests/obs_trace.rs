//! Concurrency and bounds tests for the trace recorder
//! (`obs::trace::Recorder`): writers must never block request threads,
//! memory must stay within the configured span budget, snapshot reads
//! must be torn-free, and stage timestamps must be monotonic per request.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use adapterbert::obs::trace::{Recorder, SpanKind, Stage};

const ALL_STAGES: [Stage; 5] = [
    Stage::Submitted,
    Stage::Flushed,
    Stage::ExecStart,
    Stage::Replied,
    Stage::Responded,
];

/// Drive one request span through its full lifecycle and record it.
fn record_one(r: &Recorder, rid: String) {
    let h = r.begin(SpanKind::Request, rid);
    h.set_task("task_x");
    for s in ALL_STAGES {
        h.mark(s);
    }
    h.set_status(200);
    h.set_batch_rows(4);
    r.record(&h);
}

/// Many writer threads hammering one small ring: everything completes
/// (no deadlock, no blocking on a global lock), every span is counted,
/// and retention never exceeds the configured capacity.
#[test]
fn concurrent_writers_never_block_and_stay_within_budget() {
    const THREADS: usize = 8;
    const PER_THREAD: usize = 500;
    const CAP: usize = 64;

    let r = Arc::new(Recorder::new(CAP));
    r.set_enabled(true);
    let start = Instant::now();
    std::thread::scope(|s| {
        for t in 0..THREADS {
            let r = Arc::clone(&r);
            s.spawn(move || {
                for i in 0..PER_THREAD {
                    record_one(&r, format!("req-{t}-{i}"));
                }
            });
        }
    });
    // Generous bound: 4000 records of pure pointer swaps take well under
    // a second even on a loaded CI box; hitting this means writers
    // serialized on something they shouldn't have.
    assert!(
        start.elapsed() < Duration::from_secs(30),
        "writers took {:?} — recorder is blocking request threads",
        start.elapsed()
    );
    assert_eq!(r.recorded(), (THREADS * PER_THREAD) as u64);
    let spans = r.snapshot();
    assert_eq!(spans.len(), CAP, "ring must retain exactly its capacity");
}

/// Snapshots taken *while* writers are recording must be torn-free:
/// because only finished spans enter the ring, every observed span has
/// all six timestamps stamped and in order, and its stage durations sum
/// exactly to its end-to-end duration.
#[test]
fn snapshots_during_writes_are_torn_free_and_monotonic() {
    let r = Arc::new(Recorder::new(32));
    r.set_enabled(true);
    let stop = Arc::new(AtomicBool::new(false));

    std::thread::scope(|s| {
        for t in 0..4 {
            let r = Arc::clone(&r);
            let stop = Arc::clone(&stop);
            s.spawn(move || {
                let mut i = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    record_one(&r, format!("req-{t}-{i}"));
                    i += 1;
                }
            });
        }
        let reader = {
            let r = Arc::clone(&r);
            let stop = Arc::clone(&stop);
            s.spawn(move || {
                let mut seen = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    for sp in r.snapshot() {
                        seen += 1;
                        assert!(
                            sp.complete_chain(),
                            "torn span observed: rid={} t={:?}",
                            sp.rid,
                            sp.t
                        );
                        let sum: u64 = (0..5).map(|i| sp.stage_us(i).unwrap()).sum();
                        assert_eq!(
                            sum,
                            sp.total_us(),
                            "stages must tile the lifetime (rid={})",
                            sp.rid
                        );
                        assert_eq!(sp.status, 200);
                        assert_eq!(sp.task, "task_x");
                    }
                }
                seen
            })
        };
        std::thread::sleep(Duration::from_millis(200));
        stop.store(true, Ordering::Relaxed);
        let seen = reader.join().unwrap();
        assert!(seen > 0, "reader never observed a span");
    });
    assert!(r.recorded() > 0);
}

/// Stage boundaries marked in lifecycle order produce non-decreasing
/// timestamps per request, and `complete_chain` rejects gaps and
/// out-of-order chains.
#[test]
fn stage_ordering_is_monotonic_per_request() {
    let r = Recorder::new(8);
    r.set_enabled(true);

    // Full chain, marked in order, with real delays between boundaries.
    let h = r.begin(SpanKind::Request, "req-mono");
    for s in ALL_STAGES {
        std::thread::sleep(Duration::from_millis(1));
        h.mark(s);
    }
    r.record(&h);

    // Error path: admission fails, only the final boundary is stamped.
    let e = r.begin(SpanKind::Request, "req-404");
    e.set_status(404);
    e.mark(Stage::Responded);
    r.record(&e);

    let spans = r.snapshot();
    assert_eq!(spans.len(), 2);
    for sp in &spans {
        match sp.rid.as_str() {
            "req-mono" => {
                assert!(sp.complete_chain());
                assert!(
                    sp.t.windows(2).all(|w| w[0] <= w[1]),
                    "timestamps regressed: {:?}",
                    sp.t
                );
                // each stage saw a real delay, so each is strictly set
                for i in 0..5 {
                    assert!(sp.stage_us(i).unwrap() > 0);
                }
            }
            "req-404" => {
                assert!(!sp.complete_chain(), "gappy chain must not count");
                assert_eq!(sp.status, 404);
                assert!(sp.total_us() > 0 || sp.end_us() >= sp.start_us());
            }
            other => panic!("unexpected rid {other}"),
        }
    }
}

/// Request ids minted concurrently are unique.
#[test]
fn generated_request_ids_are_unique_across_threads() {
    let r = Arc::new(Recorder::new(4));
    let mut all = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..4)
            .map(|_| {
                let r = Arc::clone(&r);
                s.spawn(move || {
                    (0..200).map(|_| r.gen_rid()).collect::<Vec<_>>()
                })
            })
            .collect();
        for h in handles {
            all.extend(h.join().unwrap());
        }
    });
    let unique: std::collections::BTreeSet<_> = all.iter().collect();
    assert_eq!(unique.len(), all.len(), "duplicate request ids minted");
}

/// A disabled recorder costs nothing and retains nothing, even under
/// the same concurrent load — the off-path contract for serving.
#[test]
fn disabled_recorder_retains_nothing_under_load() {
    let r = Arc::new(Recorder::new(16));
    // not enabled
    std::thread::scope(|s| {
        for t in 0..4 {
            let r = Arc::clone(&r);
            s.spawn(move || {
                for i in 0..100 {
                    record_one(&r, format!("req-{t}-{i}"));
                }
            });
        }
    });
    assert_eq!(r.recorded(), 0);
    assert!(r.snapshot().is_empty());
}
