//! Online task stream — the paper's continual setting (§1): "tasks arrive
//! in a stream … the model has perfect memory of previous tasks".
//!
//! For each arriving task: run a (configurable) sweep, register the best
//! bank in the store, then *re-evaluate every previously registered task*
//! and assert its score is bit-identical to the score at registration —
//! the frozen base + immutable banks make this exact, not approximate.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::data::grammar::World;
use crate::data::tasks::{generate, TaskKind, TaskSpec};
use crate::eval::{evaluate, TaskModel};
use crate::model::params::NamedTensors;
use crate::runtime::Runtime;
use crate::store::AdapterStore;
use crate::train::{run_sweep, SweepGrid};

/// Per-arrival sweep budget for the online task stream.
#[derive(Debug, Clone)]
pub struct StreamConfig {
    /// adapter sizes offered to each task's sweep
    pub adapter_sizes: Vec<usize>,
    /// Learning rates in the sweep grid.
    pub lrs: Vec<f64>,
    /// Training epochs per run.
    pub epochs: usize,
    /// Seeds re-run per configuration (instability control).
    pub seeds: Vec<u64>,
    /// Sweep worker threads.
    pub threads: usize,
}

impl Default for StreamConfig {
    fn default() -> Self {
        StreamConfig {
            adapter_sizes: vec![8],
            lrs: vec![1e-3],
            epochs: 6,
            seeds: vec![0],
            threads: 2,
        }
    }
}

/// Outcome of one task's arrival: scores, chosen config, memory audit.
#[derive(Debug)]
pub struct ArrivalReport {
    /// The arriving task's name.
    pub task: String,
    /// Best validation score across the sweep.
    pub val_score: f64,
    /// Held-out test score of the registered bank.
    pub test_score: f64,
    /// The winning train executable (encodes method + size).
    pub chosen_exe: String,
    /// Trained parameters excluding the head (paper accounting).
    pub trained_params_no_head: usize,
    /// (old task, score at its registration, score now) — must match
    pub memory_checks: Vec<(String, f64, f64)>,
}

/// Whole-stream summary.
#[derive(Debug)]
pub struct StreamReport {
    /// One report per arrived task, in order.
    pub arrivals: Vec<ArrivalReport>,
    /// Store-wide parameter multiple vs. one base (Table 1 column).
    pub total_params_ratio: f64,
    /// True when any memory check moved (must stay false).
    pub forgetting_detected: bool,
}

/// Processes tasks one at a time against a shared frozen base.
pub struct TaskStream {
    rt: Arc<Runtime>,
    base: NamedTensors,
    store: Arc<AdapterStore>,
    world: World,
    cfg: StreamConfig,
    /// test-time scores recorded at registration (task → score)
    registered_scores: BTreeMap<String, f64>,
    task_data_cache: BTreeMap<String, crate::data::tasks::TaskData>,
    /// called after each registration: (task, n_classes, model) — the
    /// hot-swap seam that lets a live [`super::Server`] start serving the
    /// task immediately (via [`super::Server::register_live`])
    on_register: Option<Box<dyn Fn(&str, usize, &TaskModel) + Send>>,
}

impl TaskStream {
    /// A stream over a shared frozen base, registering into `store`.
    pub fn new(
        rt: Arc<Runtime>,
        base: NamedTensors,
        store: Arc<AdapterStore>,
        world: World,
        cfg: StreamConfig,
    ) -> Self {
        TaskStream {
            rt,
            base,
            store,
            world,
            cfg,
            registered_scores: BTreeMap::new(),
            task_data_cache: BTreeMap::new(),
            on_register: None,
        }
    }

    /// The backing adapter store.
    pub fn store(&self) -> &Arc<AdapterStore> {
        &self.store
    }

    /// Install a post-registration callback. Typical use: hot-install the
    /// newly trained bank into a running server so task N+1 is servable
    /// the moment it registers, with tasks 1…N untouched.
    pub fn set_on_register<F>(&mut self, f: F)
    where
        F: Fn(&str, usize, &TaskModel) + Send + 'static,
    {
        self.on_register = Some(Box::new(f));
    }

    /// Handle one arriving task end-to-end.
    pub fn arrive(&mut self, spec: &TaskSpec) -> Result<ArrivalReport> {
        let seq = self.rt.manifest.dims.seq;
        let data = generate(&self.world, spec, seq);
        let kind = spec.kind.artifact_kind();
        let grid = SweepGrid {
            exes: self
                .cfg
                .adapter_sizes
                .iter()
                .map(|m| format!("{kind}_train_adapter_m{m}"))
                .collect(),
            lrs: self.cfg.lrs.clone(),
            epochs: vec![self.cfg.epochs],
            seeds: self.cfg.seeds.clone(),
            stds: vec![1e-2],
        };
        let outcome = run_sweep(&self.rt, &data, &self.base, &grid, self.cfg.threads)?;
        let n_classes = match &spec.kind {
            TaskKind::Cls { n_classes, .. } => *n_classes,
            _ => 0,
        };
        let test_score = evaluate(
            &self.rt,
            &outcome.best.model,
            &self.base,
            &data.test,
            n_classes,
            spec.metric,
        )?;
        self.store
            .register(&spec.name, &outcome.best.model, outcome.best.val_score)?;
        if let Some(cb) = &self.on_register {
            cb(&spec.name, n_classes, &outcome.best.model);
        }
        self.registered_scores.insert(spec.name.clone(), test_score);
        self.task_data_cache.insert(spec.name.clone(), data);

        // continual-learning invariant: all older tasks unchanged
        let mut memory_checks = Vec::new();
        for (old, &old_score) in &self.registered_scores {
            if old == &spec.name {
                continue;
            }
            let (_, model) = self.store.latest(old).context("store lost a task")?;
            let od = &self.task_data_cache[old];
            let on = match &od.spec.kind {
                TaskKind::Cls { n_classes, .. } => *n_classes,
                _ => 0,
            };
            let now =
                evaluate(&self.rt, &model, &self.base, &od.test, on, od.spec.metric)?;
            memory_checks.push((old.clone(), old_score, now));
        }

        Ok(ArrivalReport {
            task: spec.name.clone(),
            val_score: outcome.best.val_score,
            test_score,
            chosen_exe: outcome.best_config.exe.clone(),
            trained_params_no_head: outcome.best.model.trained_param_count_no_head(),
            memory_checks,
        })
    }

    /// Process a whole stream and summarize.
    pub fn run(&mut self, specs: &[TaskSpec]) -> Result<StreamReport> {
        let mut arrivals = Vec::new();
        let mut forgetting = false;
        for spec in specs {
            let rep = self.arrive(spec)?;
            for (old, was, now) in &rep.memory_checks {
                if (was - now).abs() > 1e-12 {
                    crate::log_warn!(
                        "stream",
                        "FORGETTING: task {old} score moved {was} -> {now}"
                    );
                    forgetting = true;
                }
            }
            arrivals.push(rep);
        }
        let ratio = self
            .store
            .total_params_ratio(self.rt.manifest.base_param_count());
        Ok(StreamReport {
            arrivals,
            total_params_ratio: ratio,
            forgetting_detected: forgetting,
        })
    }
}
