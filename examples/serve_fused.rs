//! The fused multi-task engine, end to end: many tenants at modest
//! per-task traffic — the paper's serving regime — first on classic
//! per-task batching, then on `ExecMode::Fused`, printing the occupancy
//! and throughput the cross-task batches buy.
//!
//! Per-task mode pads every 1–2-row flush to the artifact batch shape and
//! pays one trunk forward per task; fused mode packs rows from all tasks
//! into one shared-trunk forward with per-segment LN/adapter/head gather.
//! Served predictions are checked to agree across both modes, row by row.
//!
//! Run: `cargo run --release --example serve_fused [-- --preset test]`

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use adapterbert::coordinator::server::Request;
use adapterbert::coordinator::{ExecMode, FlushPolicy, Server, ServerConfig};
use adapterbert::data::grammar::World;
use adapterbert::data::tasks::{self, TaskKind};
use adapterbert::runtime::Runtime;
use adapterbert::store::AdapterStore;
use adapterbert::tokenizer::Tokenizer;
use adapterbert::train::{self, PretrainConfig, TrainConfig};

const TENANTS: [&str; 4] = ["rte_s", "cola_s", "mrpc_s", "qnli_s"];

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let preset = args
        .iter()
        .position(|a| a == "--preset")
        .and_then(|i| args.get(i + 1))
        .map(|s| s.as_str())
        .unwrap_or("test")
        .to_string();

    let rt = Arc::new(Runtime::open(Path::new("artifacts"), &preset)?);
    let dims = rt.manifest.dims.clone();
    let world = World::new(dims.vocab, 0);
    let base = train::load_or_pretrain(
        &rt,
        &world,
        &PretrainConfig::default(),
        Path::new(&format!("runs/base_{preset}.bank")),
    )?;

    // many tenants, each with its own adapter bank on the shared trunk
    let store = Arc::new(AdapterStore::in_memory());
    let mut task_classes = BTreeMap::new();
    for name in TENANTS {
        let spec = tasks::find_spec(name).unwrap();
        let data = tasks::generate(&world, &spec, dims.seq);
        let res = train::train_task(
            &rt,
            &TrainConfig::new("cls_train_adapter_m8", 1e-3, 3, 0),
            &data,
            &base,
        )?;
        println!("tenant {name}: val {:.3}", res.val_score);
        store.register(name, &res.model, res.val_score)?;
        if let TaskKind::Cls { n_classes, .. } = spec.kind {
            task_classes.insert(name.to_string(), n_classes);
        }
    }

    // the low-rate trace: waves of one request per task — the worst case
    // for per-task batching, the natural case for fused batching
    let tok = Tokenizer::new(dims.vocab);
    let mut rng = adapterbert::util::rng::Rng::new(11);
    let waves = 64usize;
    let mut trace: Vec<(String, Vec<i32>, Vec<f32>)> = Vec::new();
    for _ in 0..waves {
        for name in TENANTS {
            let words: Vec<String> = (0..10)
                .map(|_| tok.word(4 + rng.below(dims.vocab - 8) as i32).to_string())
                .collect();
            let (tokens, mask) = tok.encode_for_cls(&words.join(" "), dims.seq);
            trace.push((name.to_string(), tokens, mask));
        }
    }

    let mut results: Vec<(ExecMode, f64, f64, Vec<Option<usize>>)> = Vec::new();
    for mode in [ExecMode::PerTask, ExecMode::Fused] {
        let server = Server::start(
            rt.clone(),
            &store,
            &base,
            &task_classes,
            ServerConfig {
                flush: FlushPolicy {
                    max_batch: TENANTS.len() * 2,
                    max_delay: Duration::from_millis(3),
                },
                executors: 1,
                queue_capacity: 1024,
                mode,
                ..Default::default()
            },
        )?;
        let (reply_tx, reply_rx) = mpsc::channel();
        let t0 = Instant::now();
        // one request per tenant per wave, waves spaced past max_delay —
        // per-task queues never hold more than one row
        for wave in trace.chunks(TENANTS.len()) {
            for (task, tokens, mask) in wave {
                server.submit_blocking(Request {
                    task: task.clone(),
                    tokens: tokens.clone(),
                    segments: vec![0; dims.seq],
                    attn_mask: mask.clone(),
                    reply: reply_tx.clone(),
                    submitted: Instant::now(),
                })?;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        drop(reply_tx);
        let mut preds: Vec<Option<usize>> = Vec::new();
        while let Ok(resp) = reply_rx.recv() {
            preds.push(resp.prediction.class());
            if preds.len() == trace.len() {
                break;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let metrics = server.shutdown();
        // per-task batches pad to the artifact batch shape; fused batches
        // run exactly their real rows
        let row_slots = if mode == ExecMode::Fused {
            metrics.requests as usize
        } else {
            metrics.batches * rt.manifest.batch
        };
        println!(
            "\n[{}] {} requests in {wall:.2}s | {} trunk forwards \
             ({} fused) | {} row-slots computed | mean occupancy {:.2}",
            mode.name(),
            preds.len(),
            metrics.batches,
            metrics.fused_batches,
            row_slots,
            metrics.mean_occupancy()
        );
        results.push((mode, row_slots as f64, metrics.mean_occupancy(), preds));
    }

    // responses arrive in batch-completion order, so compare sorted
    // prediction multisets per mode — both modes must agree
    let (_, per_task_slots, per_task_occ, mut a) = results.remove(0);
    let (_, fused_slots, fused_occ, mut b) = results.remove(0);
    a.sort_unstable();
    b.sort_unstable();
    anyhow::ensure!(a == b, "fused and per-task served different predictions");
    println!(
        "\nfused vs per-task: {:.1}× less trunk compute, occupancy \
         {per_task_occ:.2} → {fused_occ:.2} (identical predictions)",
        per_task_slots / fused_slots,
    );
    Ok(())
}
