"""L2: artifact entry points — whole training/inference steps as pure fns.

Each function here lowers to exactly one HLO executable (see
:mod:`compile.aot`). Conventions shared with the Rust runtime:

  * every argument / result is a pytree of arrays; the manifest records the
    flattened leaf order (``jax.tree_util`` default ordering) so Rust can
    pack parameter banks positionally;
  * parameter *values* are runtime inputs — nothing task- or seed-specific
    is baked into the graph;
  * the learning rate is a runtime scalar: the warmup/decay schedule of the
    paper (§3.1) is computed host-side in Rust;
  * ``step`` is the 1-based Adam step (bias correction);
  * classification heads are padded to ``cfg.max_classes`` and masked with
    ``class_valid`` so one artifact serves tasks with any class count.

Training steps use the *reference* (autodiff-friendly) encoder path except
the adapter, which always runs the fused Pallas kernel through its custom
VJP. Inference (``*_fwd``) steps run the full Pallas path.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from . import model as M


# ---------------------------------------------------------------------------
# pre-training (MLM)
# ---------------------------------------------------------------------------


def make_pretrain_step(cfg: M.ModelConfig):
    """MLM step over the full base: the repo's own "pre-trained BERT"."""

    def pretrain_step(base, opt_m, opt_v, step, tokens, segments, attn_mask,
                      positions, targets, weights, lr):
        def loss_fn(b):
            hidden = M.encode(cfg, b, tokens, segments, attn_mask)
            return M.mlm_loss(cfg, b, hidden, positions, targets, weights)

        loss, grads = jax.value_and_grad(loss_fn)(base)
        new, opt_m2, opt_v2 = M.adam_update(base, grads, opt_m, opt_v, step, lr)
        return new, opt_m2, opt_v2, loss

    return pretrain_step


# ---------------------------------------------------------------------------
# task heads: shared plumbing
# ---------------------------------------------------------------------------


def _task_forward(cfg, kind, base, adapters, gates, head, tokens, segments,
                  attn_mask, inference_kernels):
    hidden = M.encode(
        cfg, base, tokens, segments, attn_mask,
        adapters=adapters, adapter_gates=gates,
        inference_kernels=inference_kernels,
    )
    if kind == "cls":
        return M.cls_logits(cfg, head, hidden)
    if kind == "reg":
        return M.reg_prediction(cfg, head, hidden)
    if kind == "span":
        return M.span_logits(cfg, head, hidden, attn_mask)
    raise ValueError(kind)


def _task_loss_and_metric(cfg, kind, out, batch):
    if kind == "cls":
        loss = M.cls_loss(cfg, out, batch["labels"], batch["class_valid"])
        metric = M.cls_accuracy(cfg, out, batch["labels"], batch["class_valid"])
    elif kind == "reg":
        loss = M.reg_loss(cfg, out, batch["targets"])
        metric = -loss  # host computes Spearman from fwd preds; this is a proxy
    else:  # span
        start, end = out
        loss = M.span_loss(cfg, start, end, batch["spans"])
        hit_s = jnp.argmax(start, -1) == batch["spans"][:, 0]
        hit_e = jnp.argmax(end, -1) == batch["spans"][:, 1]
        metric = jnp.mean((hit_s & hit_e).astype(jnp.float32))
    return loss, metric


def _batch_tree(cfg, kind, b):
    """Example batch pytree for lowering. ``b`` = batch size."""
    t = {
        "tokens": jnp.zeros((b, cfg.seq), jnp.int32),
        "segments": jnp.zeros((b, cfg.seq), jnp.int32),
        "attn_mask": jnp.ones((b, cfg.seq), jnp.float32),
    }
    if kind == "cls":
        t["labels"] = jnp.zeros((b,), jnp.int32)
        t["class_valid"] = jnp.ones((cfg.max_classes,), jnp.float32)
    elif kind == "reg":
        t["targets"] = jnp.zeros((b,), jnp.float32)
    else:
        t["spans"] = jnp.zeros((b, 2), jnp.int32)
    return t


# ---------------------------------------------------------------------------
# task training steps (one per trained-parameter partition)
# ---------------------------------------------------------------------------


def make_train_adapter_step(cfg: M.ModelConfig, kind: str):
    """Adapter tuning: train adapters + LayerNorms + head (paper §2.1).

    trained = {"adapters", "base_ln", "head"}; frozen = base minus its LNs.
    """

    def step_fn(frozen, trained, opt_m, opt_v, step, batch, lr):
        def loss_fn(tr):
            base = M.merge_adapter_base(cfg, tr["base_ln"], frozen)
            gates = jnp.ones((cfg.n_layers, 2), jnp.float32)
            out = _task_forward(
                cfg, kind, base, tr["adapters"], gates, tr["head"],
                batch["tokens"], batch["segments"], batch["attn_mask"],
                inference_kernels=False,  # adapters still run the Pallas VJP
            )
            return _task_loss_and_metric(cfg, kind, out, batch)

        (loss, metric), grads = jax.value_and_grad(loss_fn, has_aux=True)(trained)
        new, m2, v2 = M.adam_update(trained, grads, opt_m, opt_v, step, lr)
        return new, m2, v2, loss, metric

    return step_fn


def make_train_topk_step(cfg: M.ModelConfig, kind: str, k: int):
    """(Variable) fine-tuning: train the top-k layers + head.

    trained = {"base_top", "head"}; frozen = {"base_rest"}. k = n_layers is
    full fine-tuning (embeddings included). No adapters in the graph.
    """

    def step_fn(frozen, trained, opt_m, opt_v, step, batch, lr):
        def loss_fn(tr):
            base = M.merge_topk(cfg, tr["base_top"], frozen)
            out = _task_forward(
                cfg, kind, base, None, None, tr["head"],
                batch["tokens"], batch["segments"], batch["attn_mask"],
                inference_kernels=False,
            )
            return _task_loss_and_metric(cfg, kind, out, batch)

        (loss, metric), grads = jax.value_and_grad(loss_fn, has_aux=True)(trained)
        new, m2, v2 = M.adam_update(trained, grads, opt_m, opt_v, step, lr)
        return new, m2, v2, loss, metric

    return step_fn


def make_train_lnonly_step(cfg: M.ModelConfig, kind: str):
    """LayerNorm-only tuning (Fig. 4 green baseline)."""

    def step_fn(frozen, trained, opt_m, opt_v, step, batch, lr):
        def loss_fn(tr):
            base = M.merge_ln(cfg, tr["base_ln"], frozen)
            out = _task_forward(
                cfg, kind, base, None, None, tr["head"],
                batch["tokens"], batch["segments"], batch["attn_mask"],
                inference_kernels=False,
            )
            return _task_loss_and_metric(cfg, kind, out, batch)

        (loss, metric), grads = jax.value_and_grad(loss_fn, has_aux=True)(trained)
        new, m2, v2 = M.adam_update(trained, grads, opt_m, opt_v, step, lr)
        return new, m2, v2, loss, metric

    return step_fn


# ---------------------------------------------------------------------------
# inference steps (serving / evaluation; full Pallas path)
# ---------------------------------------------------------------------------


def make_fwd_adapter(cfg: M.ModelConfig, kind: str):
    """Forward with adapters. ``base`` is the *merged* base (Rust patches the
    task's trained LayerNorms in); ``gates`` is the Fig. 6 ablation mask."""

    def fwd(base, adapters, head, gates, tokens, segments, attn_mask):
        return _task_forward(
            cfg, kind, base, adapters, gates, head,
            tokens, segments, attn_mask, inference_kernels=True,
        )

    return fwd


def make_fwd_base(cfg: M.ModelConfig, kind: str):
    """Forward without adapters (serves all fine-tuning variants; Rust
    merges trained layers back into the base before upload)."""

    def fwd(base, head, tokens, segments, attn_mask):
        return _task_forward(
            cfg, kind, base, None, None, head,
            tokens, segments, attn_mask, inference_kernels=True,
        )

    return fwd


def make_embed_fwd(cfg: M.ModelConfig):
    """Mean-pooled token embeddings — feature extractor for the Rust
    no-BERT baseline (Table 2 first column)."""

    def fwd(tok_embed, tokens, attn_mask):
        emb = tok_embed[tokens]  # [B,S,d]
        w = attn_mask[:, :, None]
        return jnp.sum(emb * w, axis=1) / jnp.maximum(jnp.sum(w, axis=1), 1.0)

    return fwd


# ---------------------------------------------------------------------------
# example-argument builders (shapes only; values irrelevant to lowering)
# ---------------------------------------------------------------------------


def example_args_pretrain(cfg: M.ModelConfig, batch: int):
    key = jax.random.PRNGKey(0)
    base = init_shapes(M.init_base_params(cfg, key))
    m, v = M.adam_init(base)
    return (
        base, m, v, jnp.int32(1),
        jnp.zeros((batch, cfg.seq), jnp.int32),
        jnp.zeros((batch, cfg.seq), jnp.int32),
        jnp.ones((batch, cfg.seq), jnp.float32),
        jnp.zeros((batch, cfg.mlm_positions), jnp.int32),
        jnp.zeros((batch, cfg.mlm_positions), jnp.int32),
        jnp.ones((batch, cfg.mlm_positions), jnp.float32),
        jnp.float32(1e-4),
    )


def init_shapes(tree):
    """Zero-valued copy (lowering only cares about shapes/dtypes)."""
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def trained_tree_adapter(cfg: M.ModelConfig, kind: str):
    key = jax.random.PRNGKey(0)
    base = M.init_base_params(cfg, key)
    base_ln, _ = M.split_base_for_adapter(cfg, base)
    return {
        "adapters": init_shapes(M.init_adapter_params(cfg, key)),
        "base_ln": init_shapes(base_ln),
        "head": init_shapes(M.init_head_params(cfg, key, kind)),
    }


def frozen_tree_adapter(cfg: M.ModelConfig):
    key = jax.random.PRNGKey(0)
    base = M.init_base_params(cfg, key)
    _, frozen = M.split_base_for_adapter(cfg, base)
    return init_shapes(frozen)


def trained_tree_topk(cfg: M.ModelConfig, kind: str, k: int):
    key = jax.random.PRNGKey(0)
    base = M.init_base_params(cfg, key)
    top, _ = M.split_base_for_topk(cfg, base, k)
    return {
        "base_top": init_shapes(top),
        "head": init_shapes(M.init_head_params(cfg, key, kind)),
    }


def frozen_tree_topk(cfg: M.ModelConfig, k: int):
    key = jax.random.PRNGKey(0)
    base = M.init_base_params(cfg, key)
    _, rest = M.split_base_for_topk(cfg, base, k)
    return init_shapes(rest)


def trained_tree_lnonly(cfg: M.ModelConfig, kind: str):
    key = jax.random.PRNGKey(0)
    base = M.init_base_params(cfg, key)
    ln, _ = M.split_base_for_ln(cfg, base)
    return {
        "base_ln": init_shapes(ln),
        "head": init_shapes(M.init_head_params(cfg, key, kind)),
    }


def frozen_tree_lnonly(cfg: M.ModelConfig):
    key = jax.random.PRNGKey(0)
    base = M.init_base_params(cfg, key)
    _, frozen = M.split_base_for_ln(cfg, base)
    return init_shapes(frozen)


def example_args_train(cfg: M.ModelConfig, kind: str, variant: str, batch: int,
                       k: int = 0):
    if variant == "adapter":
        frozen = frozen_tree_adapter(cfg)
        trained = trained_tree_adapter(cfg, kind)
    elif variant == "topk":
        frozen = frozen_tree_topk(cfg, k)
        trained = trained_tree_topk(cfg, kind, k)
    elif variant == "lnonly":
        frozen = frozen_tree_lnonly(cfg)
        trained = trained_tree_lnonly(cfg, kind)
    else:
        raise ValueError(variant)
    m, v = M.adam_init(trained)
    return (
        frozen, trained, m, v, jnp.int32(1),
        _batch_tree(cfg, kind, batch), jnp.float32(1e-4),
    )


def example_args_fwd_adapter(cfg: M.ModelConfig, kind: str, batch: int):
    key = jax.random.PRNGKey(0)
    return (
        init_shapes(M.init_base_params(cfg, key)),
        init_shapes(M.init_adapter_params(cfg, key)),
        init_shapes(M.init_head_params(cfg, key, kind)),
        jnp.ones((cfg.n_layers, 2), jnp.float32),
        jnp.zeros((batch, cfg.seq), jnp.int32),
        jnp.zeros((batch, cfg.seq), jnp.int32),
        jnp.ones((batch, cfg.seq), jnp.float32),
    )


def example_args_fwd_base(cfg: M.ModelConfig, kind: str, batch: int):
    key = jax.random.PRNGKey(0)
    return (
        init_shapes(M.init_base_params(cfg, key)),
        init_shapes(M.init_head_params(cfg, key, kind)),
        jnp.zeros((batch, cfg.seq), jnp.int32),
        jnp.zeros((batch, cfg.seq), jnp.int32),
        jnp.ones((batch, cfg.seq), jnp.float32),
    )


def example_args_embed_fwd(cfg: M.ModelConfig, batch: int):
    return (
        jnp.zeros((cfg.vocab, cfg.d), jnp.float32),
        jnp.zeros((batch, cfg.seq), jnp.int32),
        jnp.ones((batch, cfg.seq), jnp.float32),
    )
