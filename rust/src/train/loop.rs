//! Training-loop driver (paper §3.1's procedure, host-side).
//!
//! The whole numeric step (fwd + bwd + Adam) is one AOT executable; Rust
//! owns everything around it: the linear-warmup/linear-decay learning-rate
//! schedule (warmup over the first 10% of steps, as in the paper), epoch
//! shuffling, per-epoch validation, and best-on-validation model selection
//! (the paper re-runs with several seeds and keeps the best val model —
//! `sweep` drives that loop).

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::data::batcher::EpochIter;
use crate::data::tasks::{TaskData, TaskKind};
use crate::eval::{evaluate, TaskModel};
use crate::model::init;
use crate::model::params::NamedTensors;
use crate::runtime::{Bank, Runtime};
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;

/// One training run's configuration.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    /// train executable, e.g. "cls_train_adapter_m8"
    pub exe: String,
    pub lr: f64,
    pub epochs: usize,
    /// fraction of total steps spent in linear warmup (paper: 0.1)
    pub warmup_frac: f64,
    pub seed: u64,
    /// adapter-init σ (Fig. 6 right sweeps this; default 1e-2)
    pub adapter_std: f64,
    /// evaluate on the validation split after each epoch and keep the best
    pub eval_each_epoch: bool,
}

impl TrainConfig {
    pub fn new(exe: &str, lr: f64, epochs: usize, seed: u64) -> Self {
        TrainConfig {
            exe: exe.to_string(),
            lr,
            epochs,
            warmup_frac: 0.1,
            seed,
            adapter_std: 1e-2,
            eval_each_epoch: true,
        }
    }
}

/// Outcome of one run.
#[derive(Debug, Clone)]
pub struct TrainResult {
    pub model: TaskModel,
    pub val_score: f64,
    pub steps: usize,
    pub final_loss: f64,
    /// (epoch, mean train loss, val score) per epoch
    pub history: Vec<(usize, f64, f64)>,
}

/// Linear warmup to `lr`, then linear decay to zero (paper §3.1).
pub fn lr_at(step: usize, total: usize, peak: f64, warmup_frac: f64) -> f64 {
    let warmup = ((total as f64 * warmup_frac).ceil() as usize).max(1);
    if step < warmup {
        peak * (step + 1) as f64 / warmup as f64
    } else if total <= warmup {
        peak
    } else {
        let rest = (total - step) as f64 / (total - warmup).max(1) as f64;
        peak * rest.max(0.0)
    }
}

/// Train one task with one configuration. `pretrained_base` is the shared
/// frozen base in relpath form (from the pre-training checkpoint).
pub fn train_task(
    rt: &Arc<Runtime>,
    cfg: &TrainConfig,
    task: &TaskData,
    pretrained_base: &NamedTensors,
) -> Result<TrainResult> {
    let exe = rt.load(&cfg.exe)?;
    let spec = exe.spec.clone();
    let n_layers = rt.manifest.dims.n_layers;
    let max_classes = rt.manifest.dims.max_classes;
    let n_classes = match &task.spec.kind {
        TaskKind::Cls { n_classes, .. } => *n_classes,
        _ => 0,
    };

    // --- initialize banks -------------------------------------------------
    let (frozen_named, trained_named) =
        init::init_trained(&spec, pretrained_base, n_layers, cfg.seed, cfg.adapter_std)?;
    // full fine-tuning has no frozen group at all (see params.rs)
    let has_frozen = spec.input_group_range("frozen").is_ok();
    let frozen: Bank = if has_frozen {
        frozen_named.to_bank(&spec, "frozen")?
    } else {
        Vec::new()
    };
    let mut trained: Bank = trained_named.to_bank(&spec, "trained")?;
    let zeros = |b: &Bank| -> Bank {
        b.iter().map(|t| Tensor::zeros(&t.shape, t.dtype())).collect()
    };
    let mut opt_m = zeros(&trained);
    let mut opt_v = zeros(&trained);

    // --- step loop ---------------------------------------------------------
    let batch = spec.batch;
    let steps_per_epoch = task.train.n / batch;
    let total_steps = (steps_per_epoch * cfg.epochs).max(1);
    let mut rng = Rng::new(cfg.seed ^ 0x7EA1);
    let mut step = 0usize;
    let mut best: Option<(f64, Bank)> = None;
    let mut history = Vec::new();
    let mut final_loss = f64::NAN;

    for epoch in 0..cfg.epochs {
        let mut epoch_losses = Vec::new();
        for b in EpochIter::new(&task.train, batch, &mut rng) {
            let lr = lr_at(step, total_steps, cfg.lr, cfg.warmup_frac);
            let batch_bank = b.to_train_bank(&spec, n_classes, max_classes)?;
            let step_bank = vec![Tensor::scalar_i32(step as i32 + 1)];
            let lr_bank = vec![Tensor::scalar_f32(lr as f32)];
            let mut banks: Vec<&Bank> = Vec::with_capacity(7);
            if has_frozen {
                banks.push(&frozen);
            }
            banks.extend([
                &trained, &opt_m, &opt_v, &step_bank, &batch_bank, &lr_bank,
            ]);
            let mut out = exe.run(&banks).context("train step")?;
            // outputs: trained', m', v', loss, metric
            let metric_bank = out.pop().unwrap();
            let loss_bank = out.pop().unwrap();
            opt_v = out.pop().unwrap();
            opt_m = out.pop().unwrap();
            trained = out.pop().unwrap();
            let _ = metric_bank;
            let loss = loss_bank[0].scalar_value_f32() as f64;
            epoch_losses.push(loss);
            final_loss = loss;
            step += 1;
        }
        let mean_loss = crate::util::stats::mean(&epoch_losses);
        if cfg.eval_each_epoch || epoch + 1 == cfg.epochs {
            let model = make_model(&spec, &trained)?;
            let val = evaluate(
                rt, &model, pretrained_base, &task.val, n_classes, task.spec.metric,
            )?;
            history.push((epoch, mean_loss, val));
            if best.as_ref().map(|(b, _)| val > *b).unwrap_or(true) {
                best = Some((val, trained.clone()));
            }
        } else {
            history.push((epoch, mean_loss, f64::NAN));
        }
    }

    let (val_score, best_bank) = best.context("no validation evaluation ran")?;
    let model = make_model(&spec, &best_bank)?;
    Ok(TrainResult { model, val_score, steps: step, final_loss, history })
}

/// Wrap a positional trained bank into a serveable `TaskModel`.
fn make_model(
    spec: &crate::runtime::ExeSpec,
    trained: &Bank,
) -> Result<TaskModel> {
    Ok(TaskModel {
        variant: spec.variant.clone(),
        m: spec.m,
        k: spec.k,
        kind: spec.kind.clone(),
        trained: NamedTensors::from_bank(spec, "trained", trained)?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lr_schedule_shape() {
        let total = 100;
        // warmup: first 10 steps rise to peak
        assert!(lr_at(0, total, 1.0, 0.1) > 0.0);
        assert!(lr_at(4, total, 1.0, 0.1) < 1.0);
        assert!((lr_at(9, total, 1.0, 0.1) - 1.0).abs() < 1e-9);
        // decay to zero at the end
        assert!(lr_at(50, total, 1.0, 0.1) < 1.0);
        assert!(lr_at(99, total, 1.0, 0.1) < 0.02);
        // monotone decay after warmup
        let a = lr_at(20, total, 1.0, 0.1);
        let b = lr_at(60, total, 1.0, 0.1);
        assert!(a > b);
    }

    #[test]
    fn lr_schedule_tiny_runs() {
        // pathological sizes must stay finite and positive
        for total in [1usize, 2, 3] {
            for s in 0..total {
                let lr = lr_at(s, total, 3e-4, 0.1);
                assert!(lr.is_finite() && lr >= 0.0);
            }
        }
    }
}
