//! Per-replica circuit breakers for the router's forward path.
//!
//! The health monitor catches *dead* replicas (probe fails → eject),
//! but a slow-but-alive replica passes every `/health` probe while each
//! forward to it eats the full upstream read timeout. The breaker
//! closes that gap from passive signals: `open_after` consecutive
//! forward failures open the circuit, and while it is open the router
//! skips the replica instantly and walks the preference list to its
//! successor — fast-fail inside the caller's remaining budget instead
//! of a wire timeout per request. After `cooldown`, exactly one trial
//! request is let through (half-open); its outcome closes or re-opens
//! the circuit.
//!
//! ```text
//!   Closed ── open_after consecutive failures ──► Open
//!     ▲                                            │ cooldown elapses
//!     │ trial succeeds                             ▼
//!     └─────────────────────────────────────── HalfOpen
//!                    trial fails ── back to Open (fresh cooldown)
//! ```

use std::time::{Duration, Instant};

use crate::check::sync::atomic::{AtomicU64, Ordering};
use crate::check::sync::Mutex;

/// Breaker tuning.
#[derive(Debug, Clone)]
pub struct BreakerPolicy {
    /// Consecutive forward failures that open the circuit.
    pub open_after: usize,
    /// How long an open circuit rejects before letting one trial through.
    pub cooldown: Duration,
}

impl Default for BreakerPolicy {
    fn default() -> Self {
        BreakerPolicy { open_after: 3, cooldown: Duration::from_secs(2) }
    }
}

enum CircuitState {
    Closed { fails: usize },
    Open { since: Instant },
    /// One trial is in flight; everyone else is still rejected.
    HalfOpen,
}

/// One replica set's worth of breakers.
pub struct Breaker {
    policy: BreakerPolicy,
    circuits: Vec<Mutex<CircuitState>>,
    /// Forwards skipped because a circuit was open.
    fast_fails: AtomicU64,
    /// Closed → Open transitions.
    trips: AtomicU64,
}

impl Breaker {
    pub fn new(n: usize, policy: BreakerPolicy) -> Breaker {
        Breaker {
            policy,
            circuits: (0..n)
                .map(|_| Mutex::new(CircuitState::Closed { fails: 0 }))
                .collect(),
            fast_fails: AtomicU64::new(0),
            trips: AtomicU64::new(0),
        }
    }

    /// May a forward go to replica `i` right now? An open circuit past
    /// its cooldown admits exactly one caller as the half-open trial.
    pub fn allow(&self, i: usize) -> bool {
        let mut c = self.circuits[i].lock().unwrap();
        match *c {
            CircuitState::Closed { .. } => true,
            CircuitState::Open { since } => {
                if since.elapsed() >= self.policy.cooldown {
                    *c = CircuitState::HalfOpen;
                    true
                } else {
                    // relaxed: monotonic metrics counter
                    self.fast_fails.fetch_add(1, Ordering::Relaxed);
                    false
                }
            }
            CircuitState::HalfOpen => {
                // relaxed: monotonic metrics counter
                self.fast_fails.fetch_add(1, Ordering::Relaxed);
                false
            }
        }
    }

    /// A forward to replica `i` completed cleanly.
    pub fn record_success(&self, i: usize) {
        let mut c = self.circuits[i].lock().unwrap();
        *c = CircuitState::Closed { fails: 0 };
    }

    /// A forward to replica `i` failed (wire error or upstream timeout).
    pub fn record_failure(&self, i: usize) {
        let mut c = self.circuits[i].lock().unwrap();
        match *c {
            CircuitState::Closed { fails } => {
                let fails = fails + 1;
                if fails >= self.policy.open_after {
                    *c = CircuitState::Open { since: Instant::now() };
                    // relaxed: monotonic metrics counter; the state
                    // transition itself is ordered by the circuit mutex
                    self.trips.fetch_add(1, Ordering::Relaxed);
                } else {
                    *c = CircuitState::Closed { fails };
                }
            }
            // the half-open trial failed: back to a fresh cooldown
            CircuitState::HalfOpen => {
                *c = CircuitState::Open { since: Instant::now() };
                // relaxed: monotonic metrics counter
                self.trips.fetch_add(1, Ordering::Relaxed);
            }
            CircuitState::Open { .. } => {}
        }
    }

    /// Is replica `i`'s circuit currently open (rejecting)?
    pub fn is_open(&self, i: usize) -> bool {
        matches!(
            *self.circuits[i].lock().unwrap(),
            CircuitState::Open { .. } | CircuitState::HalfOpen
        )
    }

    /// Forwards skipped on an open circuit since start.
    pub fn fast_fails(&self) -> u64 {
        // relaxed: metrics read
        self.fast_fails.load(Ordering::Relaxed)
    }

    /// Closed/half-open → Open transitions since start.
    pub fn trips(&self) -> u64 {
        // relaxed: metrics read
        self.trips.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fast_policy() -> BreakerPolicy {
        BreakerPolicy { open_after: 2, cooldown: Duration::from_millis(30) }
    }

    #[test]
    fn opens_after_consecutive_failures_only() {
        let b = Breaker::new(2, fast_policy());
        b.record_failure(0);
        assert!(b.allow(0), "one failure must not trip");
        b.record_success(0); // success resets the streak
        b.record_failure(0);
        assert!(b.allow(0));
        b.record_failure(0);
        assert!(!b.allow(0), "two consecutive failures trip the circuit");
        assert!(b.is_open(0));
        assert_eq!(b.trips(), 1);
        // the other replica's circuit is independent
        assert!(b.allow(1));
    }

    #[test]
    fn cooldown_admits_one_trial_then_outcome_decides() {
        let b = Breaker::new(1, fast_policy());
        b.record_failure(0);
        b.record_failure(0);
        assert!(!b.allow(0));
        std::thread::sleep(Duration::from_millis(40));
        assert!(b.allow(0), "cooldown elapsed: one trial goes through");
        assert!(!b.allow(0), "only one trial while half-open");
        b.record_failure(0);
        assert!(!b.allow(0), "failed trial re-opens with a fresh cooldown");
        std::thread::sleep(Duration::from_millis(40));
        assert!(b.allow(0));
        b.record_success(0);
        assert!(b.allow(0), "successful trial closes the circuit");
        assert!(b.allow(0), "closed circuit admits everyone");
        assert!(!b.is_open(0));
    }

    #[test]
    fn fast_fails_count_rejected_forwards() {
        let b = Breaker::new(1, fast_policy());
        b.record_failure(0);
        b.record_failure(0);
        for _ in 0..5 {
            let _ = b.allow(0);
        }
        assert_eq!(b.fast_fails(), 5);
    }
}
