//! Online train-and-serve integration (test preset, native backend,
//! real sockets).
//!
//! The acceptance path for the training service: a gateway serving two
//! tasks accepts `POST /train` for a third while live traffic flows, the
//! job runs on the shared runtime, the task hot-installs and answers
//! predictions that match the offline `train_task` for the same seed —
//! and in-flight predictions never error during the install. Plus the
//! durability half: a checkpoint taken mid-run (`TrainState` level and
//! service level, through a kill/park + recover cycle) resumes to a
//! byte-identical final bank.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use adapterbert::coordinator::{FlushPolicy, Server, ServerConfig};
use adapterbert::data::grammar::World;
use adapterbert::data::tasks::{self, Metric, TaskKind, TaskSpec};
use adapterbert::eval::{predict_split, Predictions, TaskModel};
use adapterbert::model::params::NamedTensors;
use adapterbert::runtime::Runtime;
use adapterbert::serve::{
    self, Client, Gateway, GatewayConfig, TrainJobRequest,
};
use adapterbert::store::AdapterStore;
use adapterbert::train::{
    self, JobSpec, PretrainConfig, ServiceConfig, TrainCheckpoint, TrainConfig,
    TrainService, TrainState,
};

fn runtime() -> Arc<Runtime> {
    Arc::new(
        Runtime::open(
            Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")),
            "test",
        )
        .expect("open test preset (built-in presets synthesize their manifest)"),
    )
}

fn world(rt: &Runtime) -> World {
    World::new(rt.manifest.dims.vocab, 0)
}

fn pretrained_base(rt: &Arc<Runtime>) -> NamedTensors {
    static BASE: std::sync::OnceLock<NamedTensors> = std::sync::OnceLock::new();
    BASE.get_or_init(|| {
        train::load_or_pretrain(
            rt,
            &world(rt),
            &PretrainConfig { steps: 3000, log_every: 0, ..Default::default() },
            Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/runs/base_test.bank")),
        )
        .unwrap()
    })
    .clone()
}

fn cls_spec(name: &str, n_train: usize, seed: u64) -> TaskSpec {
    TaskSpec {
        name: name.to_string(),
        kind: TaskKind::Cls { n_classes: 2, pair: false },
        metric: Metric::Accuracy,
        n_train,
        n_val: 48,
        n_test: 48,
        purity: 0.85,
        noise: 0.0,
        seed,
    }
}

fn train_cls(
    rt: &Arc<Runtime>,
    base: &NamedTensors,
    name: &str,
    seed: u64,
) -> (TaskModel, tasks::TaskData, f64) {
    let spec = cls_spec(name, 240, seed);
    let data = tasks::generate(&world(rt), &spec, rt.manifest.dims.seq);
    let cfg = TrainConfig::new("cls_train_adapter_m4", 1e-3, 4, 0);
    let res = train::train_task(rt, &cfg, &data, base).unwrap();
    (res.model, data, res.val_score)
}

fn class_preds(
    rt: &Arc<Runtime>,
    model: &TaskModel,
    base: &NamedTensors,
    split: &tasks::Split,
) -> Vec<usize> {
    match predict_split(rt, model, base, split, 2, None).unwrap() {
        Predictions::Class(v) => v,
        other => panic!("expected class predictions, got {other:?}"),
    }
}

fn quick_server(
    rt: &Arc<Runtime>,
    store: &Arc<AdapterStore>,
    base: &NamedTensors,
    classes: &BTreeMap<String, usize>,
) -> Server {
    Server::start(
        rt.clone(),
        store,
        base,
        classes,
        ServerConfig {
            flush: FlushPolicy {
                max_batch: 4,
                max_delay: Duration::from_millis(2),
            },
            executors: 2,
            queue_capacity: 256,
            ..Default::default()
        },
    )
    .unwrap()
}

/// `TrainState` can checkpoint mid-epoch and resume to the byte-identical
/// final bank the uninterrupted run produces; resuming under a different
/// config is refused.
#[test]
fn checkpoint_resume_reproduces_final_bank_byte_identically() {
    let rt = runtime();
    let base = pretrained_base(&rt);
    let spec = cls_spec("ckpt_task", 240, 31);
    let data = tasks::generate(&world(&rt), &spec, rt.manifest.dims.seq);
    let cfg = TrainConfig::new("cls_train_adapter_m4", 1e-3, 3, 1);
    let reference = train::train_task(&rt, &cfg, &data, &base).unwrap();
    let batch = rt.manifest.exe("cls_train_adapter_m4").unwrap().batch;
    let steps_per_epoch = 240 / batch;

    // run one full epoch plus a few steps of the next, then snapshot
    let mut st = TrainState::new(&rt, &cfg, &data, &base).unwrap();
    while !st.epoch_done() {
        st.step().unwrap();
    }
    st.end_epoch().unwrap();
    for _ in 0..5 {
        assert!(!st.epoch_done());
        st.step().unwrap();
    }
    let bytes = st.checkpoint().to_bytes();
    drop(st); // the "crash"

    let ck = TrainCheckpoint::from_bytes(&bytes).unwrap();
    // wrong config must be refused, not silently diverge
    let mut other = cfg.clone();
    other.lr = 5e-4;
    assert!(TrainState::resume(&rt, &other, &data, &base, &ck).is_err());

    let mut st2 = TrainState::resume(&rt, &cfg, &data, &base, &ck).unwrap();
    assert_eq!(st2.steps_taken(), steps_per_epoch + 5);
    assert_eq!(st2.epochs_done(), 1);
    while !st2.done() {
        while !st2.epoch_done() {
            st2.step().unwrap();
        }
        st2.end_epoch().unwrap();
    }
    let resumed = st2.finish().unwrap();
    assert_eq!(resumed.val_score, reference.val_score);
    assert_eq!(resumed.steps, reference.steps);
    assert_eq!(
        resumed.model.trained.to_bytes(),
        reference.model.trained.to_bytes(),
        "resumed run diverged from the uninterrupted run"
    );
    assert_eq!(resumed.history.len(), reference.history.len());
    for (a, b) in resumed.history.iter().zip(&reference.history) {
        assert_eq!(a.0, b.0);
        assert_eq!(a.1, b.1);
        assert_eq!(a.2, b.2);
    }
}

/// A train split smaller than the batch is a descriptive error, not a
/// silent zero-step run returning an untrained model.
#[test]
fn too_small_dataset_errors_instead_of_silent_zero_step_training() {
    let rt = runtime();
    let base = pretrained_base(&rt);
    let batch = rt.manifest.exe("cls_train_adapter_m4").unwrap().batch;
    let spec = cls_spec("tiny_task", batch - 1, 41);
    let data = tasks::generate(&world(&rt), &spec, rt.manifest.dims.seq);
    let cfg = TrainConfig::new("cls_train_adapter_m4", 1e-3, 3, 0);
    let err = train::train_task(&rt, &cfg, &data, &base).unwrap_err();
    let msg = format!("{err:#}");
    assert!(
        msg.contains("steps_per_epoch") && msg.contains(&format!("batch {batch}")),
        "unhelpful error: {msg}"
    );
    // exactly one batch of data is the smallest run that trains
    let spec = cls_spec("tiny_ok", batch, 42);
    let data = tasks::generate(&world(&rt), &spec, rt.manifest.dims.seq);
    let res = train::train_task(&rt, &cfg, &data, &base).unwrap();
    assert_eq!(res.steps, 3, "one step per epoch over 3 epochs");
}

/// The headline acceptance test: gateway serving two tasks takes
/// `POST /train` for a third mid-traffic; the job trains on the shared
/// runtime, hot-installs, and the new task's predictions (and stored
/// bank bytes) match the offline `train_task` for the same seed.
/// In-flight predictions for the existing tasks never error.
#[test]
fn gateway_train_job_end_to_end_with_live_traffic() {
    let rt = runtime();
    let base = pretrained_base(&rt);
    let (model_a, data_a, val_a) = train_cls(&rt, &base, "tja", 61);
    let (model_b, data_b, val_b) = train_cls(&rt, &base, "tjb", 62);

    let store = Arc::new(AdapterStore::in_memory());
    store.register("tja", &model_a, val_a).unwrap();
    store.register("tjb", &model_b, val_b).unwrap();
    let mut classes = BTreeMap::new();
    classes.insert("tja".to_string(), 2);
    classes.insert("tjb".to_string(), 2);
    let server = Arc::new(quick_server(&rt, &store, &base, &classes));

    let store_t = store.clone();
    let server_t = server.clone();
    let install = move |task: &str, n_classes: usize, val: f64, model: &TaskModel| {
        serve::install_trained(&store_t, &server_t, task, n_classes, val, model)
            .map(|meta| meta.version)
    };
    let trainer = Arc::new(
        TrainService::start(
            rt.clone(),
            Arc::new(base.clone()),
            world(&rt),
            ServiceConfig::default(),
            Box::new(install),
        )
        .unwrap(),
    );
    let gw = Gateway::start_with_trainer(
        rt.clone(),
        store.clone(),
        server,
        Some(trainer),
        GatewayConfig { addr: "127.0.0.1:0".to_string(), ..Default::default() },
    )
    .unwrap();
    let addr = gw.local_addr().to_string();

    let exp_a = class_preds(&rt, &model_a, &base, &data_a.test);
    let exp_b = class_preds(&rt, &model_b, &base, &data_b.test);
    let rows = 16usize.min(data_a.test.n).min(data_b.test.n);

    let stop = AtomicBool::new(false);
    std::thread::scope(|scope| {
        let stop = &stop;
        let addr = &addr;
        // live traffic on the two existing tasks for the whole job
        // lifetime — every response must be correct, none may error
        for (task, data, exp) in
            [("tja", &data_a, &exp_a), ("tjb", &data_b, &exp_b)]
        {
            scope.spawn(move || {
                let mut client = Client::connect(addr).unwrap();
                let mut i = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    let row = i % rows;
                    let resp = client
                        .predict_ids(task, data.test.row_tokens(row))
                        .unwrap_or_else(|e| {
                            panic!("{task} errored during train-and-serve: {e:#}")
                        });
                    assert_eq!(resp.pred_class, Some(exp[row]), "{task} row {row}");
                    i += 1;
                }
                assert!(i > 0, "worker for {task} made no requests");
            });
        }

        let mut client = Client::connect(addr).unwrap();
        // before the job, the third task 404s
        assert!(client.predict_text("hotjob", "zu kari").is_err());

        let mut req = TrainJobRequest::new("hotjob");
        req.m = Some(4);
        req.epochs = Some(3);
        req.seed = Some(0);
        req.n_train = Some(240);
        req.n_val = Some(48);
        req.purity = Some(0.85);
        req.noise = Some(0.0);
        req.data_seed = Some(77);
        let sub = client.submit_train(&req).unwrap();
        assert_eq!(sub.task, "hotjob");
        assert!(
            matches!(sub.status.as_str(), "queued" | "running"),
            "{}",
            sub.status
        );
        let id = sub.job_id;
        // the listing knows the job
        assert!(client.train_jobs().unwrap().iter().any(|j| j.job_id == id));
        // a bad id 404s, a malformed one 400s
        assert!(client.train_status(id + 999).is_err());

        // poll to completion while traffic flows
        let deadline = Instant::now() + Duration::from_secs(300);
        let fin = loop {
            let s = client.train_status(id).unwrap();
            assert_ne!(s.status, "failed", "job failed: {:?}", s.error);
            if s.status == "completed" {
                break s;
            }
            assert!(Instant::now() < deadline, "job never finished");
            std::thread::sleep(Duration::from_millis(50));
        };
        assert_eq!(fin.version, Some(1), "store version assigned");
        assert_eq!(fin.total_epochs, 3);
        assert_eq!(fin.epoch, 3);
        assert_eq!(fin.val_history.len(), 3, "eval each epoch");
        assert!(fin.steps_per_sec > 0.0);
        assert!(fin.wall_s > 0.0);

        // keep prior-task traffic flowing a little longer post-install
        std::thread::sleep(Duration::from_millis(100));
        stop.store(true, Ordering::Relaxed);
    });

    // offline mirror of the exact job the service resolved
    let spec = cls_spec("hotjob", 240, 77);
    let data = tasks::generate(&world(&rt), &spec, rt.manifest.dims.seq);
    let cfg = TrainConfig::new("cls_train_adapter_m4", 1e-3, 3, 0);
    let offline = train::train_task(&rt, &cfg, &data, &base).unwrap();
    // the job's stored bank is byte-identical to the offline run's
    let (meta, stored) = store.latest("hotjob").unwrap();
    assert_eq!(meta.version, 1);
    assert_eq!(
        stored.trained.to_bytes(),
        offline.model.trained.to_bytes(),
        "online job diverged from offline train_task"
    );

    // and the served predictions match offline eval row by row
    let exp = class_preds(&rt, &offline.model, &base, &data.test);
    let mut client = Client::connect(&addr).unwrap();
    for row in 0..16usize.min(data.test.n) {
        let resp = client.predict_ids("hotjob", data.test.row_tokens(row)).unwrap();
        assert_eq!(resp.pred_class, Some(exp[row]), "hot-trained task row {row}");
    }
    let names: Vec<String> =
        client.tasks().unwrap().into_iter().map(|t| t.task).collect();
    assert_eq!(names, vec!["hotjob", "tja", "tjb"]);
    drop(client);
    gw.shutdown().unwrap();
}

/// Service-level durability: shutdown parks a running job (checkpoint +
/// queued), a fresh service recovers it from disk, resumes mid-run, and
/// the final bank is byte-identical to an uninterrupted offline run.
#[test]
fn service_parks_on_shutdown_and_recovers_byte_identically() {
    let rt = runtime();
    let base = pretrained_base(&rt);
    let dir = std::env::temp_dir().join(format!("ab_jobs_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let store = Arc::new(AdapterStore::in_memory());

    let spec = cls_spec("parked", 480, 91);
    let train_cfg = TrainConfig::new("cls_train_adapter_m4", 1e-3, 6, 5);
    let data = tasks::generate(&world(&rt), &spec, rt.manifest.dims.seq);
    let reference = train::train_task(&rt, &train_cfg, &data, &base).unwrap();
    let job = JobSpec { task: spec, train: train_cfg };

    let svc_cfg = ServiceConfig {
        workers: 1,
        ckpt_dir: Some(dir.clone()),
        checkpoint_every: 1,
    };
    fn install_into(store: Arc<AdapterStore>) -> Box<adapterbert::train::InstallFn> {
        Box::new(move |task, _n_classes, val, model| {
            Ok(store.register(task, model, val)?.version)
        })
    }

    // leg 1: start the job, shut down mid-run → checkpoint + park
    let svc = TrainService::start(
        rt.clone(),
        Arc::new(base.clone()),
        world(&rt),
        svc_cfg.clone(),
        install_into(store.clone()),
    )
    .unwrap();
    let id = svc.submit(job.clone()).unwrap();
    assert_eq!(svc.active_jobs(), 1);
    let deadline = Instant::now() + Duration::from_secs(120);
    loop {
        let r = svc.status(id).unwrap();
        if r.step >= 10 {
            break;
        }
        assert!(Instant::now() < deadline, "job made no progress");
        std::thread::sleep(Duration::from_millis(5));
    }
    svc.shutdown();
    assert!(store.latest("parked").is_none(), "job must not have completed");
    let desc = dir.join(format!("job_{id:06}.json"));
    let ckpt = dir.join(format!("job_{id:06}.ckpt"));
    assert!(desc.exists(), "descriptor survives shutdown");
    assert!(ckpt.exists(), "checkpoint written on park");

    // leg 2: a fresh service (fresh process, conceptually) recovers it
    let svc2 = TrainService::start(
        rt.clone(),
        Arc::new(base.clone()),
        world(&rt),
        svc_cfg,
        install_into(store.clone()),
    )
    .unwrap();
    assert_eq!(svc2.recover().unwrap(), 1, "one job recovered from disk");
    let deadline = Instant::now() + Duration::from_secs(300);
    let rec = loop {
        let r = svc2.status(id).unwrap();
        assert_ne!(
            r.state,
            adapterbert::train::JobState::Failed,
            "recovered job failed: {:?}",
            r.error
        );
        if r.state == adapterbert::train::JobState::Completed {
            break r;
        }
        assert!(Instant::now() < deadline, "recovered job never finished");
        std::thread::sleep(Duration::from_millis(50));
    };
    assert!(rec.resumed, "job must resume from the checkpoint, not restart");
    assert_eq!(rec.version, Some(1));
    svc2.shutdown();

    // kill + resume == uninterrupted, byte for byte
    let (_, stored) = store.latest("parked").unwrap();
    assert_eq!(
        stored.trained.to_bytes(),
        reference.model.trained.to_bytes(),
        "kill/resume diverged from the uninterrupted run"
    );
    // terminal jobs clean up their durable state
    assert!(!desc.exists() && !ckpt.exists(), "job files not cleaned up");
    std::fs::remove_dir_all(&dir).unwrap();
}

/// Gateways without a training service answer `/train` with 503 instead
/// of panicking or 404-ing.
#[test]
fn train_routes_503_without_a_training_service() {
    let rt = runtime();
    let base = pretrained_base(&rt);
    let store = Arc::new(AdapterStore::in_memory());
    let server = quick_server(&rt, &store, &base, &BTreeMap::new());
    let gw = Gateway::start(
        rt.clone(),
        store.clone(),
        server,
        GatewayConfig { addr: "127.0.0.1:0".to_string(), ..Default::default() },
    )
    .unwrap();
    let mut client = Client::connect(&gw.local_addr().to_string()).unwrap();
    let err = client.submit_train(&TrainJobRequest::new("x")).unwrap_err();
    assert!(format!("{err:#}").contains("503"), "{err:#}");
    assert!(client.train_jobs().is_err());
    assert!(client.train_status(1).is_err());
    drop(client);
    gw.shutdown().unwrap();
}
