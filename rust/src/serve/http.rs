//! Minimal HTTP/1.1 over `std::net` — the transport under the gateway.
//!
//! The environment is offline (no tokio/hyper), so this mirrors the
//! std-threads choice in `coordinator/server.rs`: a non-blocking accept
//! loop feeds a **bounded** connection queue (overflow is answered with
//! `503` and closed — backpressure, not an unbounded backlog), and a
//! fixed worker pool round-robins over keep-alive connections at request
//! granularity (no connection ever pins a worker). Parsing is the
//! small subset the wire protocol needs: request line, headers,
//! `Content-Length` bodies (no chunked encoding), with hard limits on
//! header and body size so a bad client cannot balloon memory.
//!
//! Both sides of the protocol live here: [`read_request`]/
//! [`write_response`] for the server, [`write_request`]/
//! [`read_client_response`] for `serve::client` and the load generator.

use std::io::{self, BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Request line + headers must fit in this many bytes.
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Bodies larger than this are refused (covers hot-registration banks).
pub const MAX_BODY_BYTES: usize = 32 * 1024 * 1024;

/// One parsed request. Header names are lowercased at parse time.
#[derive(Debug)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// Header value by (case-insensitive) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(k, _)| *k == name)
            .map(|(_, v)| v.as_str())
    }

    /// HTTP/1.1 keep-alive semantics: persistent unless `Connection: close`.
    pub fn keep_alive(&self) -> bool {
        !self
            .header("connection")
            .map(|v| v.eq_ignore_ascii_case("close"))
            .unwrap_or(false)
    }

    /// Body parsed as JSON (`400`-shaped error text on failure).
    pub fn json_body(&self) -> Result<Json> {
        let text = std::str::from_utf8(&self.body).context("body is not utf-8")?;
        if text.trim().is_empty() {
            bail!("empty body (expected a JSON object)");
        }
        Json::parse(text).map_err(|e| anyhow::anyhow!("bad json body: {e}"))
    }
}

/// One response to serialize. `application/json` unless a `content-type`
/// entry in `headers` overrides it (the Prometheus endpoint does).
#[derive(Debug)]
pub struct HttpResponse {
    pub status: u16,
    pub body: Vec<u8>,
    /// Extra headers written verbatim (e.g. `x-request-id`); a
    /// `content-type` entry replaces the JSON default.
    pub headers: Vec<(String, String)>,
}

impl HttpResponse {
    pub fn json(status: u16, j: &Json) -> HttpResponse {
        HttpResponse { status, body: j.to_string().into_bytes(), headers: Vec::new() }
    }

    /// A non-JSON body (Prometheus text exposition).
    pub fn text(status: u16, content_type: &str, body: String) -> HttpResponse {
        HttpResponse {
            status,
            body: body.into_bytes(),
            headers: vec![("content-type".to_string(), content_type.to_string())],
        }
    }

    /// `{"error": msg}` with the given status.
    pub fn error(status: u16, msg: &str) -> HttpResponse {
        Self::json(status, &Json::obj(vec![("error", Json::str(msg))]))
    }

    /// Append a header (builder style).
    pub fn with_header(mut self, name: &str, value: &str) -> HttpResponse {
        self.headers.push((name.to_ascii_lowercase(), value.to_string()));
        self
    }
}

/// Canonical reason phrase for the status codes this protocol uses.
pub fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        500 => "Internal Server Error",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

// ---------------------------------------------------------------------------
// wire reading/writing
// ---------------------------------------------------------------------------

/// Outcome of trying to read one request off a keep-alive connection.
pub enum ReadOutcome {
    Request(HttpRequest),
    /// Peer closed the connection between requests.
    Eof,
    /// Read timed out with no bytes received — idle keep-alive; the
    /// caller may check its stop flag and retry.
    Idle,
}

enum LineOutcome {
    Line(Vec<u8>),
    Eof,
    Idle,
}

fn read_line(r: &mut impl BufRead, max: usize) -> Result<LineOutcome> {
    let mut buf = Vec::new();
    // cap the read itself (not just the result): an endless line without
    // a newline must fail at `max`, not balloon memory first
    let mut limited = Read::take(&mut *r, max as u64 + 1);
    match limited.read_until(b'\n', &mut buf) {
        Ok(0) => {
            if buf.is_empty() {
                Ok(LineOutcome::Eof)
            } else {
                bail!("connection closed mid-line")
            }
        }
        Ok(_) => {
            if buf.len() > max {
                bail!("header line over {max} bytes");
            }
            if buf.last() != Some(&b'\n') {
                bail!("connection closed mid-line");
            }
            while matches!(buf.last(), Some(b'\n' | b'\r')) {
                buf.pop();
            }
            Ok(LineOutcome::Line(buf))
        }
        Err(e)
            if e.kind() == io::ErrorKind::WouldBlock
                || e.kind() == io::ErrorKind::TimedOut =>
        {
            if buf.is_empty() {
                Ok(LineOutcome::Idle)
            } else {
                bail!("read timed out mid-request")
            }
        }
        Err(e) => Err(e).context("socket read"),
    }
}

/// Read one request (server side). `Idle`/`Eof` are not errors — they let
/// the worker poll its stop flag on quiet keep-alive connections.
pub fn read_request(r: &mut impl BufRead) -> Result<ReadOutcome> {
    let start = match read_line(r, MAX_HEAD_BYTES)? {
        LineOutcome::Eof => return Ok(ReadOutcome::Eof),
        LineOutcome::Idle => return Ok(ReadOutcome::Idle),
        LineOutcome::Line(l) => String::from_utf8(l).context("request line not utf-8")?,
    };
    let mut parts = start.split_whitespace();
    let method = parts
        .next()
        .context("missing method")?
        .to_ascii_uppercase();
    let path = parts.next().context("missing path")?.to_string();
    let version = parts.next().unwrap_or("HTTP/1.1");
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported protocol version {version:?}");
    }
    let mut headers = Vec::new();
    let mut head_bytes = start.len();
    loop {
        let line = match read_line(r, MAX_HEAD_BYTES)? {
            LineOutcome::Line(l) => l,
            _ => bail!("connection closed inside headers"),
        };
        if line.is_empty() {
            break;
        }
        head_bytes += line.len();
        if head_bytes > MAX_HEAD_BYTES {
            bail!("headers over {MAX_HEAD_BYTES} bytes");
        }
        let text = String::from_utf8(line).context("header not utf-8")?;
        let (name, value) = text
            .split_once(':')
            .with_context(|| format!("malformed header {text:?}"))?;
        headers.push((
            name.trim().to_ascii_lowercase(),
            value.trim().to_string(),
        ));
    }
    let mut req = HttpRequest { method, path, headers, body: Vec::new() };
    if req.header("transfer-encoding").is_some() {
        bail!("chunked transfer encoding is not supported");
    }
    let content_length = match req.header("content-length") {
        Some(v) => v.parse::<usize>().context("bad content-length")?,
        None => 0,
    };
    if content_length > MAX_BODY_BYTES {
        bail!("body of {content_length} bytes over limit {MAX_BODY_BYTES}");
    }
    if content_length > 0 {
        let mut body = vec![0u8; content_length];
        r.read_exact(&mut body).context("reading body")?;
        req.body = body;
    }
    Ok(ReadOutcome::Request(req))
}

/// Serialize a response (server side).
pub fn write_response(
    w: &mut impl Write,
    resp: &HttpResponse,
    keep_alive: bool,
) -> io::Result<()> {
    write!(w, "HTTP/1.1 {} {}\r\n", resp.status, status_text(resp.status))?;
    let custom_ct = resp
        .headers
        .iter()
        .find(|(k, _)| k.eq_ignore_ascii_case("content-type"))
        .map(|(_, v)| v.as_str());
    write!(w, "content-type: {}\r\n", custom_ct.unwrap_or("application/json"))?;
    for (k, v) in &resp.headers {
        if !k.eq_ignore_ascii_case("content-type") {
            write!(w, "{k}: {v}\r\n")?;
        }
    }
    write!(w, "content-length: {}\r\n", resp.body.len())?;
    let conn = if keep_alive { "keep-alive" } else { "close" };
    write!(w, "connection: {conn}\r\n\r\n")?;
    w.write_all(&resp.body)?;
    w.flush()
}

/// Serialize a request (client side).
pub fn write_request(
    w: &mut impl Write,
    method: &str,
    path: &str,
    body: Option<&[u8]>,
) -> io::Result<()> {
    write_request_with_headers(w, method, path, body, &[])
}

/// Serialize a request with extra headers — the router's forwarding path
/// uses this to carry the inbound `X-Request-Id` onto the upstream hop.
pub fn write_request_with_headers(
    w: &mut impl Write,
    method: &str,
    path: &str,
    body: Option<&[u8]>,
    extra: &[(&str, &str)],
) -> io::Result<()> {
    write!(w, "{method} {path} HTTP/1.1\r\n")?;
    write!(w, "host: adapterbert\r\n")?;
    if body.is_some() {
        write!(w, "content-type: application/json\r\n")?;
    }
    write!(w, "content-length: {}\r\n", body.map_or(0, <[u8]>::len))?;
    for (name, value) in extra {
        write!(w, "{}: {value}\r\n", name.to_ascii_lowercase())?;
    }
    write!(w, "connection: keep-alive\r\n\r\n")?;
    if let Some(b) = body {
        w.write_all(b)?;
    }
    w.flush()
}

/// A client-side view of one response: status + headers + body.
#[derive(Debug)]
pub struct ClientResponse {
    pub status: u16,
    /// Response headers, names lowercased.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl ClientResponse {
    /// Header value by (case-insensitive) name.
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter().find(|(k, _)| *k == name).map(|(_, v)| v.as_str())
    }
}

/// Read one response (client side).
pub fn read_client_response(r: &mut impl BufRead) -> Result<ClientResponse> {
    let status_line = match read_line(r, MAX_HEAD_BYTES)? {
        LineOutcome::Line(l) => String::from_utf8(l).context("status line not utf-8")?,
        LineOutcome::Idle => bail!("read timed out waiting for response"),
        LineOutcome::Eof => bail!("connection closed before response"),
    };
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .with_context(|| format!("malformed status line {status_line:?}"))?
        .parse()
        .context("bad status code")?;
    let mut content_length = 0usize;
    let mut headers = Vec::new();
    loop {
        let line = match read_line(r, MAX_HEAD_BYTES)? {
            LineOutcome::Line(l) => l,
            _ => bail!("connection closed inside response headers"),
        };
        if line.is_empty() {
            break;
        }
        let text = String::from_utf8(line).context("header not utf-8")?;
        if let Some((name, value)) = text.split_once(':') {
            let name = name.trim().to_ascii_lowercase();
            let value = value.trim().to_string();
            if name == "content-length" {
                content_length = value.parse().context("bad content-length")?;
            }
            headers.push((name, value));
        }
    }
    if content_length > MAX_BODY_BYTES {
        bail!("response body over limit");
    }
    let mut body = vec![0u8; content_length];
    r.read_exact(&mut body).context("reading response body")?;
    Ok(ClientResponse { status, headers, body })
}

// ---------------------------------------------------------------------------
// server plumbing: bounded accept loop + worker pool
// ---------------------------------------------------------------------------

/// What the gateway (or any user of this layer) plugs into the pool.
pub trait Handler: Send + Sync + 'static {
    fn handle(&self, req: &HttpRequest) -> HttpResponse;
}

/// Transport knobs, separate from the gateway's serving policy.
#[derive(Debug, Clone)]
pub struct HttpConfig {
    /// Worker threads serving connections. Connections do **not** pin a
    /// worker: the pool round-robins at request granularity (see
    /// [`HttpServer::start`]), so more concurrent keep-alive connections
    /// than workers still all make progress.
    pub workers: usize,
    /// Bounded connection queue (accepted + requeued-between-requests);
    /// overflow at accept time is answered `503`.
    pub max_queued_connections: usize,
    /// How long a worker waits for a dequeued connection's next request
    /// before putting it back in the rotation — also bounds how fast
    /// workers observe the stop flag.
    pub read_timeout: Duration,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            workers: 4,
            max_queued_connections: 64,
            read_timeout: Duration::from_millis(100),
        }
    }
}

/// A running HTTP front end; `stop()` joins every thread.
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept_handle: Option<std::thread::JoinHandle<()>>,
    worker_handles: Vec<std::thread::JoinHandle<()>>,
    /// Connections accepted into the queue.
    pub accepted: Arc<AtomicU64>,
    /// Connections refused with `503` because the queue was full.
    pub refused: Arc<AtomicU64>,
}

impl HttpServer {
    /// Bind `addr` (port 0 = ephemeral; see [`HttpServer::local_addr`])
    /// and start the accept loop + worker pool.
    pub fn start(addr: &str, cfg: HttpConfig, handler: Arc<dyn Handler>) -> Result<HttpServer> {
        let listener =
            TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local = listener.local_addr().context("local_addr")?;
        listener
            .set_nonblocking(true)
            .context("listener nonblocking")?;
        let stop = Arc::new(AtomicBool::new(false));
        let accepted = Arc::new(AtomicU64::new(0));
        let refused = Arc::new(AtomicU64::new(0));
        let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(cfg.max_queued_connections);
        let conn_rx = Arc::new(Mutex::new(conn_rx));

        // Workers round-robin over connections at REQUEST granularity: a
        // worker dequeues a connection, serves at most one request (plus
        // any bytes already pipelined), and puts the connection back in
        // the queue. A keep-alive connection therefore never pins a
        // worker, so `connections > workers` all make progress — the
        // closed-loop load harness depends on this.
        let mut worker_handles = Vec::new();
        for i in 0..cfg.workers.max(1) {
            let conn_rx = conn_rx.clone();
            let conn_tx = conn_tx.clone();
            let handler = handler.clone();
            let stop = stop.clone();
            let read_timeout = cfg.read_timeout;
            let handle = std::thread::Builder::new()
                .name(format!("ab-http-{i}"))
                .spawn(move || loop {
                    // recv_timeout (not recv): workers hold conn_tx
                    // clones for requeueing, so the channel never
                    // disconnects — the stop flag is the exit signal
                    let conn = {
                        let rx = conn_rx.lock().unwrap();
                        rx.recv_timeout(Duration::from_millis(50))
                    };
                    match conn {
                        Ok(stream) => {
                            match serve_turn(stream, &*handler, &stop, read_timeout) {
                                Ok(ConnTurn::Requeue(s)) => {
                                    // queue full ⇒ drop the connection —
                                    // bounded state beats silent backlog
                                    let _ = conn_tx.try_send(s);
                                }
                                Ok(ConnTurn::Done) => {}
                                Err(e) => {
                                    // connection-level failures are the
                                    // client's problem — log and move on
                                    crate::log_warn!("http", "connection error err={e:#}");
                                }
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => {
                            if stop.load(Ordering::SeqCst) {
                                return;
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected) => return,
                    }
                })?;
            worker_handles.push(handle);
        }

        let stop_a = stop.clone();
        let accepted_a = accepted.clone();
        let refused_a = refused.clone();
        let accept_handle = std::thread::Builder::new()
            .name("ab-http-accept".into())
            .spawn(move || {
                loop {
                    if stop_a.load(Ordering::SeqCst) {
                        break;
                    }
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            // accepted sockets may inherit the listener's
                            // non-blocking flag on some platforms
                            let _ = stream.set_nonblocking(false);
                            match conn_tx.try_send(stream) {
                                Ok(()) => {
                                    accepted_a.fetch_add(1, Ordering::Relaxed);
                                }
                                Err(mpsc::TrySendError::Full(s)) => {
                                    refused_a.fetch_add(1, Ordering::Relaxed);
                                    busy_reject(s);
                                }
                                Err(mpsc::TrySendError::Disconnected(_)) => break,
                            }
                        }
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                            std::thread::sleep(Duration::from_millis(2));
                        }
                        Err(_) => std::thread::sleep(Duration::from_millis(5)),
                    }
                }
                // workers hold their own conn_tx clones for requeueing,
                // so they exit via the stop flag, not channel disconnect
            })?;

        Ok(HttpServer {
            addr: local,
            stop,
            accept_handle: Some(accept_handle),
            worker_handles,
            accepted,
            refused,
        })
    }

    /// The bound address (resolves port 0 to the ephemeral port chosen).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, finish in-flight requests, join every thread.
    pub fn stop(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(h) = self.accept_handle.take() {
            let _ = h.join();
        }
        for h in self.worker_handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn busy_reject(mut stream: TcpStream) {
    let _ = stream.set_nonblocking(false);
    let resp = HttpResponse::error(503, "connection queue full");
    let _ = write_response(&mut stream, &resp, false);
}

/// What one worker turn on a connection decided.
enum ConnTurn {
    /// Keep-alive connection with no buffered data — rotate it back into
    /// the queue so this worker can serve someone else.
    Requeue(TcpStream),
    /// Connection finished (EOF, `Connection: close`, or stop).
    Done,
}

/// Serve one request on `stream` (plus any already-pipelined ones), then
/// yield. `Idle` (request not yet arrived within `read_timeout`) also
/// yields, so slow or quiet connections cost a worker at most one
/// timeout slice per rotation.
fn serve_turn(
    stream: TcpStream,
    handler: &dyn Handler,
    stop: &AtomicBool,
    read_timeout: Duration,
) -> Result<ConnTurn> {
    let _ = stream.set_nodelay(true);
    stream
        .set_read_timeout(Some(read_timeout))
        .context("set_read_timeout")?;
    let mut reader = BufReader::new(stream.try_clone().context("clone stream")?);
    let mut writer = stream;
    loop {
        if stop.load(Ordering::SeqCst) {
            return Ok(ConnTurn::Done);
        }
        match read_request(&mut reader) {
            Ok(ReadOutcome::Eof) => return Ok(ConnTurn::Done),
            // `Idle` guarantees the BufReader holds no bytes (the read
            // timed out with nothing consumed), so dropping `reader` and
            // requeueing the raw stream loses nothing
            Ok(ReadOutcome::Idle) => return Ok(ConnTurn::Requeue(writer)),
            Ok(ReadOutcome::Request(req)) => {
                let keep = req.keep_alive();
                let resp = handler.handle(&req);
                write_response(&mut writer, &resp, keep).context("writing response")?;
                if !keep {
                    return Ok(ConnTurn::Done);
                }
                if reader.buffer().is_empty() {
                    // fair rotation: one request per turn; any bytes
                    // that arrive from here on wait in the socket buffer
                    return Ok(ConnTurn::Requeue(writer));
                }
                // the client pipelined — the next request is already in
                // our BufReader, which cannot be requeued; serve it now
            }
            Err(e) => {
                // malformed request: answer 400 if the socket still
                // works, then drop the connection either way
                let resp = HttpResponse::error(400, &format!("{e:#}"));
                let _ = write_response(&mut writer, &resp, false);
                return Err(e);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &str) -> Result<ReadOutcome> {
        read_request(&mut Cursor::new(raw.as_bytes().to_vec()))
    }

    #[test]
    fn parses_request_with_body() {
        let raw = "POST /predict HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd";
        let ReadOutcome::Request(req) = parse(raw).unwrap() else {
            panic!("expected request");
        };
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/predict");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.body, b"abcd");
        assert!(req.keep_alive());
    }

    #[test]
    fn connection_close_disables_keep_alive() {
        let raw = "GET /health HTTP/1.1\r\nConnection: close\r\n\r\n";
        let ReadOutcome::Request(req) = parse(raw).unwrap() else {
            panic!("expected request");
        };
        assert!(!req.keep_alive());
        assert!(req.body.is_empty());
    }

    #[test]
    fn eof_between_requests_is_clean() {
        assert!(matches!(parse("").unwrap(), ReadOutcome::Eof));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("NOT-HTTP\r\n\r\n").is_err()); // no path
        assert!(parse("GET /x SPDY/3\r\n\r\n").is_err()); // bad version
        assert!(parse("GET /x HTTP/1.1\r\nbroken header\r\n\r\n").is_err());
        assert!(
            parse("POST /x HTTP/1.1\r\nContent-Length: 10\r\n\r\nshort").is_err(),
            "truncated body"
        );
        let huge = format!("POST /x HTTP/1.1\r\nContent-Length: {}\r\n\r\n", MAX_BODY_BYTES + 1);
        assert!(parse(&huge).is_err(), "oversized body refused up front");
    }

    #[test]
    fn endless_header_line_fails_at_the_cap() {
        // a request line with no newline must error at MAX_HEAD_BYTES,
        // not accumulate the whole stream
        let endless = "G".repeat(MAX_HEAD_BYTES + 64);
        assert!(parse(&endless).is_err());
        let long_header = format!(
            "GET /x HTTP/1.1\r\nx-pad: {}\r\n\r\n",
            "y".repeat(MAX_HEAD_BYTES)
        );
        assert!(parse(&long_header).is_err());
    }

    #[test]
    fn response_roundtrip() {
        let resp = HttpResponse::json(200, &Json::obj(vec![("ok", Json::Bool(true))]));
        let mut wire = Vec::new();
        write_response(&mut wire, &resp, true).unwrap();
        let parsed = read_client_response(&mut Cursor::new(wire)).unwrap();
        assert_eq!(parsed.status, 200);
        assert_eq!(parsed.body, br#"{"ok":true}"#);
    }

    #[test]
    fn request_roundtrip() {
        let mut wire = Vec::new();
        write_request(&mut wire, "POST", "/tasks", Some(br#"{"a":1}"#)).unwrap();
        let ReadOutcome::Request(req) =
            read_request(&mut Cursor::new(wire)).unwrap()
        else {
            panic!("expected request");
        };
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/tasks");
        assert_eq!(req.body, br#"{"a":1}"#);
    }

    #[test]
    fn request_with_extra_headers_roundtrip() {
        let mut wire = Vec::new();
        write_request_with_headers(
            &mut wire,
            "POST",
            "/predict",
            Some(br#"{"task":"t"}"#),
            &[("X-Request-Id", "req-7-9")],
        )
        .unwrap();
        let ReadOutcome::Request(req) =
            read_request(&mut Cursor::new(wire)).unwrap()
        else {
            panic!("expected request");
        };
        assert_eq!(req.header("x-request-id"), Some("req-7-9"));
        assert_eq!(req.body, br#"{"task":"t"}"#);
    }

    #[test]
    fn custom_headers_roundtrip() {
        let resp = HttpResponse::json(200, &Json::obj(vec![("ok", Json::Bool(true))]))
            .with_header("X-Request-Id", "req-1-2");
        let mut wire = Vec::new();
        write_response(&mut wire, &resp, true).unwrap();
        let parsed = read_client_response(&mut Cursor::new(wire)).unwrap();
        assert_eq!(parsed.header("x-request-id"), Some("req-1-2"));
        assert_eq!(parsed.header("X-REQUEST-ID"), Some("req-1-2"));

        let prom = HttpResponse::text(200, "text/plain; version=0.0.4", "up 1\n".into());
        let mut wire = Vec::new();
        write_response(&mut wire, &prom, false).unwrap();
        let s = String::from_utf8(wire).unwrap();
        assert!(s.contains("content-type: text/plain; version=0.0.4"));
        assert!(!s.contains("application/json"));
    }

    #[test]
    fn error_response_shape() {
        let resp = HttpResponse::error(503, "over capacity");
        assert_eq!(resp.status, 503);
        let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(j.at("error").as_str(), Some("over capacity"));
    }
}
