//! Dev utility: step-time and RSS profile of the pre-training loop.
//!
//! `cargo run --release --example leak_probe -- [preset] [steps]`
//! This is the probe that exposed the vendored xla crate's input-buffer
//! leak (EXPERIMENTS.md §Perf #1) and calibrated the preset sizes.

use std::sync::Arc;
use adapterbert::{data::grammar::World, runtime::Runtime, train};
fn rss_mb() -> f64 {
    let s = std::fs::read_to_string("/proc/self/status").unwrap();
    let line = s.lines().find(|l| l.starts_with("VmRSS")).unwrap();
    line.split_whitespace().nth(1).unwrap().parse::<f64>().unwrap() / 1024.0
}
fn main() -> anyhow::Result<()> {
    let preset = std::env::args().nth(1).unwrap_or("test".into());
    let steps: usize = std::env::args().nth(2).unwrap_or("200".into()).parse()?;
    let rt = Arc::new(Runtime::open(std::path::Path::new("artifacts"), &preset)?);
    let world = World::new(rt.manifest.dims.vocab, 0);
    println!("rss before: {:.0} MB", rss_mb());
    let cfg = train::PretrainConfig { steps, lr: 1e-3, warmup_frac: 0.1, seed: 0, log_every: 0 };
    let t0 = std::time::Instant::now();
    let res = train::pretrain(&rt, &world, &cfg)?;
    println!("{} steps in {:.1}s ({:.0} ms/step), loss {:.3} -> {:.3}, rss after: {:.0} MB",
        steps, t0.elapsed().as_secs_f64(), t0.elapsed().as_secs_f64()*1000.0/steps as f64,
        res.initial_loss, res.final_loss, rss_mb());
    Ok(())
}
