//! Minimal JSON parser/serializer.
//!
//! `serde_json` is unreachable in the offline build environment, so the
//! manifest (written by `python/compile/aot.py`), run configs and result
//! files go through this hand-rolled implementation. It supports the full
//! JSON grammar minus exotic number forms; numbers are kept as `f64`
//! (plenty for shapes, hyper-parameters and metrics).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse failure with the byte offset where it occurred (`thiserror` is
/// unreachable offline, so `Display`/`Error` are hand-implemented).
#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` chained access; panics with a useful message.
    pub fn at(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("json: missing key {key:?} in {self:.60?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // -- builders ---------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num<N: Into<f64>>(n: N) -> Json {
        Json::Num(n.into())
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}

impl fmt::Display for Json {
    /// Compact serialization (valid JSON; floats use shortest round-trip).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // surrogate pairs: rare in our data; combine if present
                            if (0xD800..0xDC00).contains(&cp) {
                                self.pos += 5;
                                if self.peek() != Some(b'\\') {
                                    return Err(self.err("lone surrogate"));
                                }
                                self.pos += 1;
                                if self.peek() != Some(b'u') {
                                    return Err(self.err("lone surrogate"));
                                }
                                let hex2 = std::str::from_utf8(
                                    &self.b[self.pos + 1..self.pos + 5],
                                )
                                .map_err(|_| self.err("bad \\u escape"))?;
                                let lo = u32::from_str_radix(hex2, 16)
                                    .map_err(|_| self.err("bad \\u escape"))?;
                                let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                out.push(
                                    char::from_u32(c).ok_or_else(|| self.err("bad cp"))?,
                                );
                                self.pos += 4; // final advance below adds 1
                            } else {
                                out.push(
                                    char::from_u32(cp)
                                        .ok_or_else(|| self.err("bad cp"))?,
                                );
                                self.pos += 4;
                            }
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // copy one UTF-8 char
                    let s = std::str::from_utf8(&self.b[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.at("a").as_arr().unwrap().len(), 3);
        assert_eq!(j.at("a").as_arr().unwrap()[2].at("b").as_str(), Some("x"));
    }

    #[test]
    fn roundtrip_display_parse() {
        let src = r#"{"name":"cls_train_adapter_m8","shape":[32,64],"lr":0.0003,"ok":true,"s":"q\"uote\\n"}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(
            Json::parse(r#""é😀""#).unwrap(),
            Json::Str("é😀".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{,}").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"open").is_err());
        assert!(Json::parse("12 34").is_err());
    }

    #[test]
    fn float_display_roundtrip() {
        for v in [0.1, 3e-5, 1.0, -2.5, 1e15, 0.0003] {
            let s = Json::Num(v).to_string();
            assert_eq!(s.parse::<f64>().unwrap(), v, "{s}");
        }
    }
}
