//! Closed-loop load generator for the serving gateway.
//!
//! Drives `serve::Gateway` over real sockets: N worker threads, each with
//! its own keep-alive connection, issue predict-by-text requests against
//! a configurable task mix until a request budget or deadline runs out
//! (closed loop: a worker sends its next request only after the previous
//! response lands, so concurrency == open requests). The report — total
//! and per-task throughput and latency quantiles — serializes to
//! `BENCH_serve.json`, the serving entry in the repo's perf trajectory.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::serve::Client;
use crate::tokenizer::Tokenizer;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::timer::Samples;

/// What to fire at the gateway.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Gateway address (`host:port`).
    pub addr: String,
    /// Task mix, cycled round-robin; empty = every task the gateway lists.
    pub tasks: Vec<String>,
    /// Closed-loop worker threads (= open requests at any moment).
    pub concurrency: usize,
    /// Total request budget (0 = unlimited, stop on `duration`).
    pub requests: u64,
    /// Optional wall-clock cap.
    pub duration: Option<Duration>,
    /// Words of random text per request.
    pub words_per_request: usize,
    /// RNG seed for the request text.
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: String::new(),
            tasks: Vec::new(),
            concurrency: 4,
            requests: 200,
            duration: None,
            words_per_request: 12,
            seed: 7,
        }
    }
}

/// Per-task slice of the report.
#[derive(Debug, Default, Clone)]
pub struct TaskLoad {
    pub requests: u64,
    pub errors: u64,
    pub latencies: Samples,
}

/// The whole run.
#[derive(Debug)]
pub struct LoadReport {
    /// Resolved task mix (after discovery).
    pub tasks: Vec<String>,
    pub wall_s: f64,
    pub requests: u64,
    pub errors: u64,
    pub per_task: BTreeMap<String, TaskLoad>,
    /// All successful request latencies.
    pub all: Samples,
}

impl LoadReport {
    pub fn throughput_rps(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.requests as f64 / self.wall_s
        }
    }

    /// The `BENCH_serve.json` document (see `write_report`).
    pub fn to_json(&self, cfg: &LoadgenConfig) -> Json {
        let per_task = Json::Obj(
            self.per_task
                .iter()
                .map(|(task, t)| {
                    (
                        task.clone(),
                        Json::obj(vec![
                            ("requests", Json::num(t.requests as f64)),
                            ("errors", Json::num(t.errors as f64)),
                            ("latency_ms", latency_json(&t.latencies)),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("bench", Json::str("serve")),
            ("schema_version", Json::num(1.0)),
            (
                "config",
                Json::obj(vec![
                    ("concurrency", Json::num(cfg.concurrency as f64)),
                    ("requests", Json::num(cfg.requests as f64)),
                    (
                        "duration_s",
                        cfg.duration
                            .map(|d| Json::num(d.as_secs_f64()))
                            .unwrap_or(Json::Null),
                    ),
                    ("words_per_request", Json::num(cfg.words_per_request as f64)),
                    (
                        "tasks",
                        Json::arr(self.tasks.iter().map(|t| Json::str(t))),
                    ),
                ]),
            ),
            (
                "totals",
                Json::obj(vec![
                    ("requests", Json::num(self.requests as f64)),
                    ("errors", Json::num(self.errors as f64)),
                    ("wall_s", Json::num(self.wall_s)),
                    ("throughput_rps", Json::num(self.throughput_rps())),
                    ("latency_ms", latency_json(&self.all)),
                ]),
            ),
            ("per_task", per_task),
        ])
    }
}

/// `{mean, p50, p95, p99, max}` in milliseconds (zeros when empty — JSON
/// has no NaN).
fn latency_json(s: &Samples) -> Json {
    let (mean, p50, p95, p99, max) = if s.is_empty() {
        (0.0, 0.0, 0.0, 0.0, 0.0)
    } else {
        (
            s.mean_s() * 1e3,
            s.pctl_s(50.0) * 1e3,
            s.pctl_s(95.0) * 1e3,
            s.pctl_s(99.0) * 1e3,
            s.pctl_s(100.0) * 1e3,
        )
    };
    Json::obj(vec![
        ("mean", Json::num(mean)),
        ("p50", Json::num(p50)),
        ("p95", Json::num(p95)),
        ("p99", Json::num(p99)),
        ("max", Json::num(max)),
    ])
}

/// Run the closed loop and aggregate.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadReport> {
    if cfg.requests == 0 && cfg.duration.is_none() {
        bail!("loadgen needs a request budget or a duration");
    }
    let mut probe = Client::connect(&cfg.addr)?;
    let health = probe.health().context("gateway health check")?;
    let tasks: Vec<String> = if cfg.tasks.is_empty() {
        probe
            .tasks()
            .context("task discovery")?
            .into_iter()
            .map(|t| t.task)
            .collect()
    } else {
        cfg.tasks.clone()
    };
    if tasks.is_empty() {
        bail!("gateway serves no tasks and none were given");
    }
    // close the discovery connection before the closed loop starts, so
    // the gateway's worker rotation only carries live load connections
    drop(probe);
    let tok = Tokenizer::new(health.vocab);
    let word_ids = health.vocab.saturating_sub(4).max(1);

    let issued = AtomicU64::new(0);
    let deadline = cfg.duration.map(|d| Instant::now() + d);
    let t0 = Instant::now();
    let mut worker_stats: Vec<Result<BTreeMap<String, TaskLoad>>> = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for w in 0..cfg.concurrency.max(1) {
            let tasks = &tasks;
            let tok = &tok;
            let issued = &issued;
            handles.push(scope.spawn(move || {
                worker_loop(cfg, w as u64, tasks, tok, word_ids, issued, deadline)
            }));
        }
        for h in handles {
            worker_stats.push(match h.join() {
                Ok(r) => r,
                Err(_) => Err(anyhow::anyhow!("loadgen worker panicked")),
            });
        }
    });

    let wall_s = t0.elapsed().as_secs_f64();
    let mut per_task: BTreeMap<String, TaskLoad> = BTreeMap::new();
    for stats in worker_stats {
        for (task, t) in stats? {
            let agg = per_task.entry(task).or_default();
            agg.requests += t.requests;
            agg.errors += t.errors;
            agg.latencies.durs.extend(t.latencies.durs);
        }
    }
    let mut all = Samples::default();
    let mut requests = 0;
    let mut errors = 0;
    for t in per_task.values() {
        requests += t.requests;
        errors += t.errors;
        all.durs.extend(t.latencies.durs.iter().copied());
    }
    Ok(LoadReport { tasks, wall_s, requests, errors, per_task, all })
}

fn worker_loop(
    cfg: &LoadgenConfig,
    worker: u64,
    tasks: &[String],
    tok: &Tokenizer,
    word_ids: usize,
    issued: &AtomicU64,
    deadline: Option<Instant>,
) -> Result<BTreeMap<String, TaskLoad>> {
    let mut client = Client::connect(&cfg.addr)?;
    let mut rng = Rng::new(cfg.seed ^ (worker.wrapping_mul(0x9E37_79B9)));
    let mut stats: BTreeMap<String, TaskLoad> = BTreeMap::new();
    let mut consecutive_errors = 0usize;
    loop {
        let i = issued.fetch_add(1, Ordering::Relaxed);
        if cfg.requests > 0 && i >= cfg.requests {
            break;
        }
        if let Some(d) = deadline {
            if Instant::now() >= d {
                break;
            }
        }
        let task = &tasks[(i as usize) % tasks.len()];
        let words: Vec<&str> = (0..cfg.words_per_request.max(1))
            .map(|_| tok.word(4 + rng.below(word_ids) as i32))
            .collect();
        let text = words.join(" ");
        let t0 = Instant::now();
        let entry = stats.entry(task.clone()).or_default();
        match client.predict_text(task, &text) {
            Ok(_) => {
                entry.requests += 1;
                entry.latencies.record(t0.elapsed());
                consecutive_errors = 0;
            }
            Err(e) => {
                entry.errors += 1;
                consecutive_errors += 1;
                if consecutive_errors > 50 {
                    return Err(e).context("worker giving up after 50 straight errors");
                }
                // connection may be poisoned (timeout mid-response); redial
                let _ = client.reconnect();
            }
        }
    }
    Ok(stats)
}

/// Atomically (write + rename) persist the report document.
pub fn write_report(path: &Path, report: &Json) -> Result<()> {
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, format!("{report}\n"))
        .with_context(|| format!("writing {tmp:?}"))?;
    std::fs::rename(&tmp, path).with_context(|| format!("renaming into {path:?}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_schema() {
        let mut per_task = BTreeMap::new();
        let mut lat = Samples::default();
        lat.record(Duration::from_millis(3));
        per_task.insert(
            "rte_s".to_string(),
            TaskLoad { requests: 10, errors: 0, latencies: lat },
        );
        let mut all = Samples::default();
        all.record(Duration::from_millis(3));
        let report = LoadReport {
            tasks: vec!["rte_s".into()],
            wall_s: 0.5,
            requests: 10,
            errors: 0,
            per_task,
            all,
        };
        let cfg = LoadgenConfig { addr: "x".into(), ..Default::default() };
        let j = report.to_json(&cfg);
        // must re-parse as valid JSON with the pinned schema fields
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.at("bench").as_str(), Some("serve"));
        assert_eq!(back.at("schema_version").as_usize(), Some(1));
        assert_eq!(back.at("totals").at("requests").as_usize(), Some(10));
        assert!(back.at("totals").at("throughput_rps").as_f64().unwrap() > 0.0);
        let lt = back.at("per_task").at("rte_s").at("latency_ms");
        for key in ["mean", "p50", "p95", "p99", "max"] {
            assert!(lt.at(key).as_f64().is_some(), "missing {key}");
        }
    }

    #[test]
    fn empty_latency_emits_zeros_not_nan() {
        let j = latency_json(&Samples::default());
        let s = j.to_string();
        assert!(!s.contains("NaN"), "{s}");
        assert_eq!(j.at("p99").as_f64(), Some(0.0));
    }

    #[test]
    fn run_requires_a_stop_condition() {
        let cfg = LoadgenConfig {
            addr: "127.0.0.1:1".into(),
            requests: 0,
            duration: None,
            ..Default::default()
        };
        assert!(run(&cfg).is_err());
    }
}
