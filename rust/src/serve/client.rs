//! Blocking Rust client for the gateway protocol — one keep-alive
//! connection per client, suitable for one thread of a load generator or
//! a remote trainer pushing banks via hot registration.

use std::io::BufReader;
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

use super::http;
use super::protocol::{
    Health, PredictRequest, PredictResponse, RegisterRequest, RegisterResponse,
    TaskEntry, TrainJobRequest, TrainJobStatus,
};
use crate::util::json::Json;

/// A blocking HTTP client pinned to one gateway address.
pub struct Client {
    addr: String,
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    /// Connect to `addr` (`host:port`).
    pub fn connect(addr: &str) -> Result<Client> {
        let stream = TcpStream::connect(addr)
            .with_context(|| format!("connecting to gateway at {addr}"))?;
        let _ = stream.set_nodelay(true);
        let reader = BufReader::new(stream.try_clone().context("clone stream")?);
        Ok(Client { addr: addr.to_string(), reader, writer: stream })
    }

    /// The gateway address this client talks to.
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Drop the current connection and dial again (after an io error).
    pub fn reconnect(&mut self) -> Result<()> {
        let fresh = Client::connect(&self.addr)?;
        *self = fresh;
        Ok(())
    }

    /// One request/response exchange; returns (status, parsed JSON body).
    pub fn roundtrip(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&Json>,
    ) -> Result<(u16, Json)> {
        let bytes = body.map(|j| j.to_string().into_bytes());
        http::write_request(&mut self.writer, method, path, bytes.as_deref())
            .context("writing request")?;
        let resp = http::read_client_response(&mut self.reader)?;
        let text =
            String::from_utf8(resp.body).context("response body not utf-8")?;
        let j = if text.trim().is_empty() {
            Json::Null
        } else {
            Json::parse(&text).map_err(|e| anyhow::anyhow!("bad response json: {e}"))?
        };
        Ok((resp.status, j))
    }

    fn expect_ok(&mut self, method: &str, path: &str, body: Option<&Json>) -> Result<Json> {
        let (status, j) = self.roundtrip(method, path, body)?;
        if status != 200 {
            let msg = j
                .get("error")
                .and_then(Json::as_str)
                .unwrap_or("(no error message)");
            bail!("{method} {path}: HTTP {status}: {msg}");
        }
        Ok(j)
    }

    /// `GET /health`.
    pub fn health(&mut self) -> Result<Health> {
        let j = self.expect_ok("GET", "/health", None)?;
        Health::from_json(&j)
    }

    /// `GET /tasks`.
    pub fn tasks(&mut self) -> Result<Vec<TaskEntry>> {
        let j = self.expect_ok("GET", "/tasks", None)?;
        j.at("tasks")
            .as_arr()
            .context("tasks must be an array")?
            .iter()
            .map(TaskEntry::from_json)
            .collect()
    }

    /// `GET /metrics` (raw JSON — shape documented in `serve::gateway`).
    pub fn metrics(&mut self) -> Result<Json> {
        self.expect_ok("GET", "/metrics", None)
    }

    /// `GET /metrics?format=prometheus` — the text exposition body.
    pub fn metrics_prometheus(&mut self) -> Result<String> {
        http::write_request(&mut self.writer, "GET", "/metrics?format=prometheus", None)
            .context("writing request")?;
        let resp = http::read_client_response(&mut self.reader)?;
        if resp.status != 200 {
            bail!("GET /metrics?format=prometheus: HTTP {}", resp.status);
        }
        String::from_utf8(resp.body).context("exposition body not utf-8")
    }

    /// `GET /trace` — recent request/cold-load/train-job spans.
    pub fn trace(&mut self) -> Result<Json> {
        self.expect_ok("GET", "/trace", None)
    }

    /// `POST /predict` with an arbitrary request.
    pub fn predict(&mut self, req: &PredictRequest) -> Result<PredictResponse> {
        let j = self.expect_ok("POST", "/predict", Some(&req.to_json()))?;
        PredictResponse::from_json(&j)
    }

    /// Predict on a single sentence.
    pub fn predict_text(&mut self, task: &str, text: &str) -> Result<PredictResponse> {
        self.predict(&PredictRequest::text(task, text))
    }

    /// Predict on a sentence pair.
    pub fn predict_pair(
        &mut self,
        task: &str,
        a: &str,
        b: &str,
    ) -> Result<PredictResponse> {
        self.predict(&PredictRequest::pair(task, a, b))
    }

    /// Predict on pre-tokenized input (`POST /predict_ids`).
    pub fn predict_ids(&mut self, task: &str, tokens: &[i32]) -> Result<PredictResponse> {
        let req = PredictRequest::ids(task, tokens.to_vec());
        let j = self.expect_ok("POST", "/predict_ids", Some(&req.to_json()))?;
        PredictResponse::from_json(&j)
    }

    /// Hot-register a trained bank (`POST /tasks`).
    pub fn register_task(&mut self, req: &RegisterRequest) -> Result<RegisterResponse> {
        let j = self.expect_ok("POST", "/tasks", Some(&req.to_json()))?;
        RegisterResponse::from_json(&j)
    }

    /// Start a background training job (`POST /train`); the returned
    /// status carries the assigned `job_id`.
    pub fn submit_train(&mut self, req: &TrainJobRequest) -> Result<TrainJobStatus> {
        let j = self.expect_ok("POST", "/train", Some(&req.to_json()))?;
        TrainJobStatus::from_json(&j)
    }

    /// One job's live status (`GET /train/<id>`).
    pub fn train_status(&mut self, id: u64) -> Result<TrainJobStatus> {
        let j = self.expect_ok("GET", &format!("/train/{id}"), None)?;
        TrainJobStatus::from_json(&j)
    }

    /// Every training job the gateway knows about (`GET /train`).
    pub fn train_jobs(&mut self) -> Result<Vec<TrainJobStatus>> {
        let j = self.expect_ok("GET", "/train", None)?;
        j.at("jobs")
            .as_arr()
            .context("jobs must be an array")?
            .iter()
            .map(TrainJobStatus::from_json)
            .collect()
    }
}
