//! Table 1 (GLUE) and Table 2 (17 additional tasks) regenerators.

use anyhow::Result;

use super::{trained_params_of_exe, Ctx};
use crate::coordinator::memory::{self, Method};
use crate::data::tasks::{extra_suite, glue_suite, Labels};
use crate::eval::evaluate;
use crate::report::{fmt_score, write_table, Table};
use crate::util::stats;

/// Table 1 — GLUE: full fine-tuning vs adapters (size swept per task) vs
/// adapters at a fixed size. Columns: per-task metric, the "total params"
/// multiple and "trained params/task" percentage.
pub fn table1(ctx: &Ctx) -> Result<()> {
    let dims = ctx.rt.manifest.dims.clone();
    let suite = glue_suite();
    let seeds: Vec<u64> = if ctx.quick { vec![0] } else { vec![0, 1, 2, 3, 4] };
    // per-task best adapter size, as in the paper ({8,64,256} there);
    // fixed-size column uses m=16 (the analogue of the paper's 64)
    let avail = ctx.available_sizes("cls");
    let swept_sizes: Vec<usize> = if ctx.quick {
        [4usize, 16].iter().map(|m| ctx.pick_size("cls", *m)).collect()
    } else {
        avail.iter().copied().filter(|m| [4usize, 16, 64].contains(m)).collect()
    };
    let fixed_size = ctx.pick_size("cls", 16);
    let full_k = dims.n_layers;

    let mut rows_ft = Vec::new();
    let mut rows_ad_swept = Vec::new();
    let mut rows_ad_fixed = Vec::new();
    let mut names = Vec::new();
    let mut swept_param_pcts = Vec::new();

    for spec in &suite {
        let data = ctx.gen(spec);
        let kind = spec.kind.artifact_kind();
        let epochs = ctx.epochs_for(&data);
        println!("[table1] {} ({} train)", spec.name, data.train.n);

        // full fine-tuning
        let ft = ctx.train_best(
            &data,
            &[(format!("{kind}_train_topk_k{full_k}"), ctx.ft_lr())],
            epochs,
            &seeds,
        )?;
        // adapters, size swept on validation (sizes resolved per artifact
        // family — reg/span ship different size sets than cls)
        let mut kind_sizes: Vec<usize> =
            swept_sizes.iter().map(|m| ctx.pick_size(kind, *m)).collect();
        kind_sizes.dedup();
        let cands: Vec<(String, f64)> = kind_sizes
            .iter()
            .map(|m| (format!("{kind}_train_adapter_m{m}"), ctx.adapter_lr()))
            .collect();
        let ad = ctx.train_best(&data, &cands, epochs, &seeds)?;
        // adapters, fixed size
        let kind_fixed = ctx.pick_size(kind, fixed_size);
        let ad_fixed = ctx.train_best(
            &data,
            &[(format!("{kind}_train_adapter_m{kind_fixed}"), ctx.adapter_lr())],
            epochs,
            &seeds,
        )?;

        swept_param_pcts.push(
            100.0 * trained_params_of_exe(&ctx.rt, &ad.exe) as f64
                / memory::base_params(&dims) as f64,
        );
        names.push(spec.name.clone());
        rows_ft.push(ft.test);
        rows_ad_swept.push(ad.test);
        rows_ad_fixed.push(ad_fixed.test);

        // MNLI-mm extra split, evaluated with the trained mnli model
        if !data.extra_eval.is_empty() {
            let (mm_name, mm_split) = &data.extra_eval[0];
            let n_classes = ctx.n_classes(spec);
            let mm_ft = evaluate(&ctx.rt, &ft.model, &ctx.base, mm_split,
                                 n_classes, spec.metric)?;
            let mm_ad = evaluate(&ctx.rt, &ad.model, &ctx.base, mm_split,
                                 n_classes, spec.metric)?;
            let mm_fixed = evaluate(&ctx.rt, &ad_fixed.model, &ctx.base, mm_split,
                                    n_classes, spec.metric)?;
            names.push(mm_name.clone());
            rows_ft.push(mm_ft);
            rows_ad_swept.push(mm_ad);
            rows_ad_fixed.push(mm_fixed);
            swept_param_pcts.push(*swept_param_pcts.last().unwrap());
        }
    }

    let n_tasks = names.len();
    let mut headers: Vec<&str> =
        vec!["method", "total params ×", "trained/task %"];
    let name_strs: Vec<String> = names.clone();
    headers.extend(name_strs.iter().map(|s| s.as_str()));
    headers.push("avg");
    let mut t = Table::new(
        "Table 1 — GLUE stand-in: test scores (paper: FT 80.4 vs adapters 80.0 \
         at 3.6% trained params)",
        &headers,
    );
    let avg = |xs: &[f64]| stats::mean(xs);
    let mk_row = |label: &str, total: f64, pct: f64, scores: &[f64]| {
        let mut row = vec![
            label.to_string(),
            format!("{total:.2}"),
            format!("{pct:.2}"),
        ];
        row.extend(scores.iter().map(|s| fmt_score(*s)));
        row.push(fmt_score(avg(scores)));
        row
    };
    t.row(mk_row(
        "full fine-tune",
        n_tasks as f64,
        100.0,
        &rows_ft,
    ));
    let swept_pct = stats::mean(&swept_param_pcts);
    let ad_total = 1.0
        + n_tasks as f64 * swept_pct / 100.0;
    t.row(mk_row("adapters (swept)", ad_total, swept_pct, &rows_ad_swept));
    let fixed_pct = memory::trained_percent(&dims, Method::Adapter { m: fixed_size });
    t.row(mk_row(
        &format!("adapters ({fixed_size})"),
        1.0 + n_tasks as f64 * fixed_pct / 100.0,
        fixed_pct,
        &rows_ad_fixed,
    ));
    write_table("table1", &t)?;
    println!(
        "paper shape check: |FT avg - adapters avg| = {:.2} points (paper: 0.4)",
        100.0 * (avg(&rows_ft) - avg(&rows_ad_swept)).abs()
    );
    Ok(())
}

/// Table 2 — the 17 additional tasks: no-BERT baseline vs fine-tune vs
/// variable fine-tune (top-k swept) vs adapters (size swept); mean ± sem
/// over seeds in full mode.
pub fn table2(ctx: &Ctx) -> Result<()> {
    let dims = ctx.rt.manifest.dims.clone();
    let suite = extra_suite();
    let seeds: Vec<u64> = if ctx.quick { vec![0] } else { vec![0, 1, 2] };
    let avail = ctx.available_sizes("cls");
    let adapter_sizes: Vec<usize> = if ctx.quick {
        [4usize, 16].iter().map(|m| ctx.pick_size("cls", *m)).collect()
    } else {
        avail.clone()
    };
    let all_ks = ctx.available_ks("cls");
    let var_ks: Vec<usize> = if ctx.quick {
        let lo = all_ks[all_ks.len() / 3];
        let hi = *all_ks.last().unwrap();
        vec![lo, hi]
    } else {
        all_ks.clone()
    };
    let full_k = dims.n_layers;
    let budget = if ctx.quick { 12 } else { 40 };

    let mut t = Table::new(
        "Table 2 — additional tasks (paper avg: baseline 72.7 / FT 73.7 / \
         var-FT 74.0 / adapters 73.3)",
        &["task", "no-BERT baseline", "fine-tune", "variable FT", "adapters"],
    );
    let mut cols: [Vec<f64>; 4] = [vec![], vec![], vec![], vec![]];
    let mut var_ft_layers = Vec::new();
    let mut adapter_pcts = Vec::new();

    for spec in &suite {
        let data = ctx.gen(spec);
        let epochs = ctx.epochs_for(&data);
        let n_classes = ctx.n_classes(spec);
        println!("[table2] {} ({} train, {} classes)", spec.name, data.train.n,
                 n_classes);

        let bl = crate::baseline::run_baseline(&ctx.rt, &ctx.base, &data, budget,
                                               n_classes)?;
        let ft = ctx.train_best(
            &data,
            &[(format!("cls_train_topk_k{full_k}"), ctx.ft_lr())],
            epochs,
            &seeds,
        )?;
        let var_cands: Vec<(String, f64)> = var_ks
            .iter()
            .map(|k| (format!("cls_train_topk_k{k}"), ctx.ft_lr()))
            .collect();
        let var = ctx.train_best(&data, &var_cands, epochs, &seeds)?;
        let ad_cands: Vec<(String, f64)> = adapter_sizes
            .iter()
            .map(|m| (format!("cls_train_adapter_m{m}"), ctx.adapter_lr()))
            .collect();
        let ad = ctx.train_best(&data, &ad_cands, epochs, &seeds)?;

        var_ft_layers.push(
            ctx.rt.manifest.exe(&var.exe)?.k.unwrap_or(full_k) as f64,
        );
        adapter_pcts.push(
            100.0 * trained_params_of_exe(&ctx.rt, &ad.exe) as f64
                / memory::base_params(&dims) as f64,
        );
        for (c, v) in cols.iter_mut().zip([bl.test_acc, ft.test, var.test, ad.test]) {
            c.push(v);
        }
        t.row(vec![
            spec.name.clone(),
            fmt_score(bl.test_acc),
            fmt_score(ft.test),
            fmt_score(var.test),
            fmt_score(ad.test),
        ]);
    }

    t.row(vec![
        "Average".into(),
        fmt_score(stats::mean(&cols[0])),
        fmt_score(stats::mean(&cols[1])),
        fmt_score(stats::mean(&cols[2])),
        fmt_score(stats::mean(&cols[3])),
    ]);
    let n = suite.len() as f64;
    let mean_var_frac = stats::mean(&var_ft_layers) / full_k as f64;
    let ad_pct = stats::mean(&adapter_pcts);
    t.row(vec![
        "Total params ×".into(),
        "-".into(),
        format!("{n:.0}"),
        format!("{:.1}", 1.0 + n * mean_var_frac),
        format!("{:.2}", 1.0 + n * ad_pct / 100.0),
    ]);
    t.row(vec![
        "Trained params/task %".into(),
        "-".into(),
        "100".into(),
        format!("{:.1}", 100.0 * mean_var_frac),
        format!("{ad_pct:.2}"),
    ]);
    write_table("table2", &t)?;
    Ok(())
}

/// Majority-class floors per task (used by the Fig. 6 narrative and the
/// extensibility example).
pub fn majority_floor(data_labels: &Labels) -> f64 {
    match data_labels {
        Labels::Class(l) => stats::majority_fraction(l),
        _ => f64::NAN,
    }
}

/// Audit: closed-form parameter accounting vs real manifest signatures.
pub fn audit_params(ctx: &Ctx) -> Result<()> {
    let rows = memory::audit_against_manifest(&ctx.rt.manifest);
    let mut t = Table::new(
        "Parameter accounting audit (formula vs manifest)",
        &["executable", "formula", "manifest", "match"],
    );
    let mut all_ok = true;
    for (name, formula, actual) in rows {
        let ok = formula == actual;
        all_ok &= ok;
        t.row(vec![
            name,
            formula.to_string(),
            actual.to_string(),
            if ok { "✓".into() } else { "✗".into() },
        ]);
    }
    t.print();
    anyhow::ensure!(all_ok, "parameter accounting mismatch");
    // paper's headline ratios at this scale
    let dims = &ctx.rt.manifest.dims;
    for m in [1usize, 4, 16, 64] {
        println!(
            "adapters m={m:3}: {:.2}% trained/task, {:.2}x total for 9 tasks",
            memory::trained_percent(dims, Method::Adapter { m }),
            memory::total_params_ratio(dims, Method::Adapter { m }, 9),
        );
    }
    println!(
        "fine-tuning    : 100% trained/task, {:.1}x total for 9 tasks",
        memory::total_params_ratio(dims, Method::FullFineTune, 9)
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn majority_floor_of_class_labels() {
        assert_eq!(majority_floor(&Labels::Class(vec![0, 0, 1])), 2.0 / 3.0);
        assert!(majority_floor(&Labels::Score(vec![0.0])).is_nan());
    }
}
