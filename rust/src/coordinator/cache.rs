//! Byte-budget paged cache with single-flight loading.
//!
//! The serving-cost story (PAPER.md §1, "A Comprehensive Analysis of
//! Adapter Efficiency" in PAPERS.md) only holds if resident memory is
//! bounded: ~3MB/task banks are hub economics precisely because not all
//! of them sit in RAM at once. This module is the mechanism: a cache of
//! built banks with
//!
//! * an optional **byte budget** — inserting past it evicts the
//!   least-recently-used entries until the new entry fits. A single
//!   entry larger than the whole budget is still admitted (the task must
//!   stay servable); it is evicted as soon as anything else arrives;
//! * **single-flight loads** — concurrent [`PagedCache::get_or_load`]
//!   calls for one cold key run the loader exactly once; the others
//!   block on a gate and re-check. A *failed* load releases the gate
//!   without poisoning the key, so a waiter retries the load itself —
//!   that is what makes "retry after the fault heals" work;
//! * **atomic snapshots** — residency, byte totals and the
//!   hit/miss/eviction/load-error counters live under one lock, so a
//!   [`PagedCache::snapshot`] is a single consistent view (the
//!   `/metrics` fix in PR 6 depends on this).
//!
//! The cache stores values by clone (use `Arc<…>` values); eviction only
//! drops the cache's reference, so in-flight batches holding their own
//! `Arc` pin the actual bytes until they finish.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;

use crate::check::order;
use crate::check::sync::{Arc, Condvar, Mutex};
use crate::util::timer::Samples;

/// Cold-load latency keeps a bounded reservoir (slot replacement like the
/// coordinator's request-latency buffer).
const COLD_LOAD_SAMPLE_CAP: usize = 4_096;

struct Slot<V> {
    value: V,
    bytes: u64,
    last_used: u64,
}

struct Inner<V> {
    map: BTreeMap<String, Slot<V>>,
    bytes: u64,
    tick: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    load_errors: u64,
}

/// One-shot gate: waiters block until the loader opens it.
struct Gate {
    done: Mutex<bool>,
    cv: Condvar,
}

impl Gate {
    fn new() -> Gate {
        Gate { done: Mutex::new(false), cv: Condvar::new() }
    }

    fn wait(&self) {
        let mut done = self.done.lock().unwrap();
        while !*done {
            done = self.cv.wait(done).unwrap();
        }
    }

    fn open(&self) {
        *self.done.lock().unwrap() = true;
        self.cv.notify_all();
    }
}

/// A consistent point-in-time view of the cache (one lock acquisition).
#[derive(Debug, Clone)]
pub struct CacheSnapshot {
    pub resident: usize,
    pub resident_bytes: u64,
    pub budget_bytes: Option<u64>,
    pub resident_tasks: Vec<String>,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub load_errors: u64,
    /// Completed cold loads (miss that produced a resident entry).
    pub cold_loads: u64,
    pub cold_load_p50_ms: f64,
    pub cold_load_p95_ms: f64,
}

impl CacheSnapshot {
    /// Fraction of lookups answered from residency; 0 when idle.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// LRU cache keyed by task name with a byte budget and single-flight
/// cold loads. Values are cloned out (use `Arc`).
pub struct PagedCache<V: Clone> {
    budget: Option<u64>,
    inner: Mutex<Inner<V>>,
    loading: Mutex<BTreeMap<String, Arc<Gate>>>,
    cold_loads: Mutex<Samples>,
}

impl<V: Clone> PagedCache<V> {
    /// `budget` is the resident-byte ceiling; `None` means unbounded
    /// (the pre-PR-6 "always resident" behaviour).
    pub fn new(budget: Option<u64>) -> PagedCache<V> {
        PagedCache {
            budget,
            inner: Mutex::new(Inner {
                map: BTreeMap::new(),
                bytes: 0,
                tick: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
                load_errors: 0,
            }),
            loading: Mutex::new(BTreeMap::new()),
            cold_loads: Mutex::new(Samples::default()),
        }
    }

    pub fn budget(&self) -> Option<u64> {
        self.budget
    }

    /// Resident value for `key`, loading it on a miss. The loader returns
    /// the value plus its byte size for budget accounting. Exactly one
    /// concurrent caller runs the loader per cold key; a failed load is
    /// returned to its caller (and counted) while waiters retry.
    pub fn get_or_load(
        &self,
        key: &str,
        load: impl Fn() -> Result<(V, u64)>,
    ) -> Result<V> {
        loop {
            if let Some(v) = self.touch(key) {
                return Ok(v);
            }
            // miss: join an in-flight load or become the loader
            let gate = {
                let _ord = order::Held::enter(order::CACHE_LOADING);
                let mut loading = self.loading.lock().unwrap();
                match loading.get(key) {
                    Some(g) => Some(g.clone()),
                    None => {
                        loading.insert(key.to_string(), Arc::new(Gate::new()));
                        None
                    }
                }
            };
            if let Some(gate) = gate {
                gate.wait();
                continue; // re-check: hit on success, retry load on failure
            }
            {
                let _ord = order::Held::enter(order::BANK_CACHE);
                self.inner.lock().unwrap().misses += 1;
            }
            let t0 = Instant::now();
            let outcome = load();
            let result = match outcome {
                Ok((value, bytes)) => {
                    self.insert(key, value.clone(), bytes);
                    let dur = t0.elapsed();
                    // lock order matches snapshot(): inner is released
                    // before the reservoir lock is taken
                    let miss_no = {
                        let _ord = order::Held::enter(order::BANK_CACHE);
                        self.inner.lock().unwrap().misses as usize
                    };
                    let _ord = order::Held::enter(order::CACHE_SAMPLES);
                    let mut s = self.cold_loads.lock().unwrap();
                    if s.durs.len() >= COLD_LOAD_SAMPLE_CAP {
                        s.durs[miss_no % COLD_LOAD_SAMPLE_CAP] = dur;
                    } else {
                        s.record(dur);
                    }
                    Ok(value)
                }
                Err(e) => {
                    let _ord = order::Held::enter(order::BANK_CACHE);
                    self.inner.lock().unwrap().load_errors += 1;
                    Err(e)
                }
            };
            let gate = {
                let _ord = order::Held::enter(order::CACHE_LOADING);
                self.loading.lock().unwrap().remove(key)
            };
            if let Some(gate) = gate {
                gate.open();
            }
            return result;
        }
    }

    /// Hit path: clone the value and refresh recency.
    fn touch(&self, key: &str) -> Option<V> {
        let _ord = order::Held::enter(order::BANK_CACHE);
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        match inner.map.get_mut(key) {
            Some(slot) => {
                slot.last_used = tick;
                let v = slot.value.clone();
                inner.hits += 1;
                Some(v)
            }
            None => None,
        }
    }

    /// Install (or replace) an entry, evicting least-recently-used
    /// entries until the budget holds again. The entry just inserted is
    /// never evicted to make room for itself — a bank larger than the
    /// whole budget still serves, alone.
    pub fn insert(&self, key: &str, value: V, bytes: u64) {
        let _ord = order::Held::enter(order::BANK_CACHE);
        let mut inner = self.inner.lock().unwrap();
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(old) = inner.map.remove(key) {
            inner.bytes -= old.bytes;
        }
        inner.bytes += bytes;
        inner.map.insert(key.to_string(), Slot { value, bytes, last_used: tick });
        if let Some(budget) = self.budget {
            while inner.bytes > budget && inner.map.len() > 1 {
                let victim = inner
                    .map
                    .iter()
                    .filter(|(k, _)| k.as_str() != key)
                    .min_by_key(|(_, s)| s.last_used)
                    .map(|(k, _)| k.clone());
                let Some(victim) = victim else { break };
                let Some(slot) = inner.map.remove(&victim) else { break };
                inner.bytes -= slot.bytes;
                inner.evictions += 1;
                crate::log_debug!(
                    "cache",
                    "evicted {victim} ({} bytes) for {key}; resident_bytes={}",
                    slot.bytes,
                    inner.bytes
                );
            }
        }
    }

    /// Residency probe — does **not** refresh recency.
    pub fn contains(&self, key: &str) -> bool {
        let _ord = order::Held::enter(order::BANK_CACHE);
        self.inner.lock().unwrap().map.contains_key(key)
    }

    /// Drop an entry (no eviction counter — this is an explicit removal).
    pub fn remove(&self, key: &str) {
        let _ord = order::Held::enter(order::BANK_CACHE);
        let mut inner = self.inner.lock().unwrap();
        if let Some(slot) = inner.map.remove(key) {
            inner.bytes -= slot.bytes;
        }
    }

    pub fn resident_bytes(&self) -> u64 {
        let _ord = order::Held::enter(order::BANK_CACHE);
        self.inner.lock().unwrap().bytes
    }

    pub fn snapshot(&self) -> CacheSnapshot {
        // fixed order: inner before the cold-load reservoir; no caller
        // holds either across this call
        let _ord_inner = order::Held::enter(order::BANK_CACHE);
        let inner = self.inner.lock().unwrap();
        let _ord_samples = order::Held::enter(order::CACHE_SAMPLES);
        let samples = self.cold_loads.lock().unwrap();
        // percentile of an empty set is NaN, which util::json cannot
        // render — report 0 until the first cold load
        let (p50, p95) = if samples.is_empty() {
            (0.0, 0.0)
        } else {
            (samples.pctl_s(50.0) * 1e3, samples.pctl_s(95.0) * 1e3)
        };
        CacheSnapshot {
            resident: inner.map.len(),
            resident_bytes: inner.bytes,
            budget_bytes: self.budget,
            resident_tasks: inner.map.keys().cloned().collect(),
            hits: inner.hits,
            misses: inner.misses,
            evictions: inner.evictions,
            load_errors: inner.load_errors,
            cold_loads: inner.misses - inner.load_errors,
            cold_load_p50_ms: p50,
            cold_load_p95_ms: p95,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn lru_eviction_respects_budget_and_recency() {
        let c: PagedCache<u32> = PagedCache::new(Some(30));
        c.insert("a", 1, 10);
        c.insert("b", 2, 10);
        c.insert("c", 3, 10);
        assert_eq!(c.resident_bytes(), 30);
        // touch `a` so `b` is the LRU victim
        c.get_or_load("a", || unreachable!()).unwrap();
        c.insert("d", 4, 10);
        assert!(c.contains("a") && c.contains("c") && c.contains("d"));
        assert!(!c.contains("b"));
        let snap = c.snapshot();
        assert_eq!(snap.evictions, 1);
        assert_eq!(snap.resident_bytes, 30);
    }

    #[test]
    fn oversized_entry_is_admitted_alone() {
        let c: PagedCache<u32> = PagedCache::new(Some(10));
        c.insert("big", 1, 100);
        assert!(c.contains("big"), "oversized bank must still serve");
        assert_eq!(c.get_or_load("big", || unreachable!()).unwrap(), 1);
        // anything else displaces it
        c.insert("small", 2, 5);
        assert!(!c.contains("big"));
        assert!(c.contains("small"));
        assert_eq!(c.resident_bytes(), 5);
    }

    #[test]
    fn failed_load_is_retried_by_next_caller() {
        let c: PagedCache<u32> = PagedCache::new(Some(100));
        let err = c.get_or_load("k", || anyhow::bail!("injected"));
        assert!(err.is_err());
        assert_eq!(c.snapshot().load_errors, 1);
        // the key is not poisoned: a later call loads fine
        assert_eq!(c.get_or_load("k", || Ok((7, 10))).unwrap(), 7);
        assert!(c.contains("k"));
    }

    #[test]
    fn single_flight_runs_loader_once() {
        let c: Arc<PagedCache<u32>> = Arc::new(PagedCache::new(Some(1000)));
        let loads = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..8 {
                let c = &c;
                let loads = &loads;
                scope.spawn(move || {
                    let v = c
                        .get_or_load("cold", || {
                            loads.fetch_add(1, Ordering::SeqCst);
                            std::thread::sleep(
                                std::time::Duration::from_millis(50),
                            );
                            Ok((42, 10))
                        })
                        .unwrap();
                    assert_eq!(v, 42);
                });
            }
        });
        assert_eq!(loads.load(Ordering::SeqCst), 1, "loader ran more than once");
        let snap = c.snapshot();
        assert_eq!(snap.misses, 1);
        assert_eq!(snap.hits, 7);
    }
}
