//! The "no BERT baseline" (Table 2, first column).
//!
//! The paper runs a week of Neural AutoML over feed-forward/conv networks
//! stacked on frozen or fine-tuned pre-trained text embeddings. The
//! reproduction keeps the *role* at CPU scale: mean-pooled token
//! embeddings (from the pre-trained MiniBERT, extracted once through the
//! `embed_fwd` artifact) feed a pure-Rust MLP trained with Adam and a
//! budgeted random/grid search over topology + hyper-parameters. The
//! search explores dozens of models per task instead of 10k — same
//! selection rule (best validation accuracy), same freeze-vs-finetune
//! embedding choice (here: embeddings are always frozen features; the
//! MLP owns all trained capacity).

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::data::tasks::{Labels, Split, TaskData};
use crate::model::params::NamedTensors;
use crate::runtime::{Bank, Runtime};
use crate::util::rng::Rng;
use crate::util::stats;


// ---------------------------------------------------------------------------
// feature extraction (embed_fwd artifact; python never runs here)
// ---------------------------------------------------------------------------

/// Mean-pooled embedding features for every row of a split. [n, d]
pub fn embed_features(
    rt: &Arc<Runtime>,
    base: &NamedTensors,
    split: &Split,
) -> Result<Vec<Vec<f32>>> {
    let exe = rt.load("embed_fwd")?;
    let b = exe.spec.batch;
    let d = rt.manifest.dims.d;
    let tok_embed = base.get("tok_embed").context("base missing tok_embed")?;
    let emb_bank: Bank = vec![tok_embed.clone()];
    let mut feats = Vec::with_capacity(split.n);
    for batch in crate::data::batcher::eval_batches(split, b) {
        let (tok, _seg, mask) = batch.to_fwd_banks();
        let out = exe.run(&[&emb_bank, &tok, &mask])?;
        let pooled = &out[0][0];
        for row in 0..batch.real_rows {
            feats.push(pooled.as_f32()[row * d..(row + 1) * d].to_vec());
        }
    }
    Ok(feats)
}

// ---------------------------------------------------------------------------
// a small dense MLP with manual backprop (no autograd available in rust)
// ---------------------------------------------------------------------------

/// Topology + hyper-parameters of one candidate (the search space axes
/// mirror the paper's appendix Table 5 at MLP scale).
#[derive(Debug, Clone)]
pub struct Candidate {
    pub hidden: Vec<usize>,
    pub lr: f64,
    pub epochs: usize,
    pub l2: f64,
    pub seed: u64,
}

pub struct Mlp {
    sizes: Vec<usize>, // [in, h1, ..., out]
    w: Vec<Vec<f32>>,  // per layer, row-major [in × out]
    b: Vec<Vec<f32>>,
    // Adam state
    mw: Vec<Vec<f32>>,
    vw: Vec<Vec<f32>>,
    mb: Vec<Vec<f32>>,
    vb: Vec<Vec<f32>>,
    t: usize,
}

impl Mlp {
    pub fn new(sizes: &[usize], rng: &mut Rng) -> Mlp {
        let mut w = Vec::new();
        let mut b = Vec::new();
        for win in sizes.windows(2) {
            let (n_in, n_out) = (win[0], win[1]);
            let scale = (2.0 / n_in as f64).sqrt();
            w.push((0..n_in * n_out).map(|_| (rng.gauss() * scale) as f32).collect());
            b.push(vec![0.0; n_out]);
        }
        let zeros = |v: &Vec<Vec<f32>>| v.iter().map(|x| vec![0.0; x.len()]).collect();
        Mlp {
            sizes: sizes.to_vec(),
            mw: zeros(&w),
            vw: zeros(&w),
            mb: zeros(&b),
            vb: zeros(&b),
            w,
            b,
            t: 0,
        }
    }

    /// Forward pass; returns activations per layer (input included).
    fn forward(&self, x: &[f32]) -> Vec<Vec<f32>> {
        let mut acts = vec![x.to_vec()];
        for (li, (w, b)) in self.w.iter().zip(&self.b).enumerate() {
            let n_in = self.sizes[li];
            let n_out = self.sizes[li + 1];
            let a = acts.last().unwrap();
            let mut z = b.clone();
            for i in 0..n_in {
                let ai = a[i];
                if ai != 0.0 {
                    let row = &w[i * n_out..(i + 1) * n_out];
                    for (zj, wj) in z.iter_mut().zip(row) {
                        *zj += ai * wj;
                    }
                }
            }
            if li + 1 < self.w.len() {
                for zj in &mut z {
                    *zj = zj.max(0.0); // ReLU
                }
            }
            acts.push(z);
        }
        acts
    }

    pub fn logits(&self, x: &[f32]) -> Vec<f32> {
        self.forward(x).pop().unwrap()
    }

    /// One Adam step on a minibatch; returns mean CE loss.
    pub fn train_batch(
        &mut self,
        xs: &[&[f32]],
        ys: &[usize],
        lr: f64,
        l2: f64,
    ) -> f64 {
        let layers = self.w.len();
        let mut gw: Vec<Vec<f32>> = self.w.iter().map(|x| vec![0.0; x.len()]).collect();
        let mut gb: Vec<Vec<f32>> = self.b.iter().map(|x| vec![0.0; x.len()]).collect();
        let mut loss = 0.0f64;
        for (x, &y) in xs.iter().zip(ys) {
            let acts = self.forward(x);
            let out = acts.last().unwrap();
            // softmax CE grad
            let max = out.iter().cloned().fold(f32::MIN, f32::max);
            let exps: Vec<f32> = out.iter().map(|v| (v - max).exp()).collect();
            let sum: f32 = exps.iter().sum();
            let probs: Vec<f32> = exps.iter().map(|e| e / sum).collect();
            loss -= (probs[y].max(1e-12)).ln() as f64;
            let mut delta: Vec<f32> = probs;
            delta[y] -= 1.0;
            // backprop
            for li in (0..layers).rev() {
                let n_in = self.sizes[li];
                let n_out = self.sizes[li + 1];
                let a_in = &acts[li];
                for i in 0..n_in {
                    let ai = a_in[i];
                    if ai != 0.0 {
                        let grow = &mut gw[li][i * n_out..(i + 1) * n_out];
                        for (g, d) in grow.iter_mut().zip(&delta) {
                            *g += ai * d;
                        }
                    }
                }
                for (g, d) in gb[li].iter_mut().zip(&delta) {
                    *g += d;
                }
                if li > 0 {
                    let w = &self.w[li];
                    let mut next = vec![0.0f32; n_in];
                    for i in 0..n_in {
                        let row = &w[i * n_out..(i + 1) * n_out];
                        let mut acc = 0.0;
                        for (wj, d) in row.iter().zip(&delta) {
                            acc += wj * d;
                        }
                        // ReLU grad
                        next[i] = if acts[li][i] > 0.0 { acc } else { 0.0 };
                    }
                    delta = next;
                }
            }
        }
        let n = xs.len() as f32;
        self.t += 1;
        let (b1, b2, eps) = (0.9f64, 0.999f64, 1e-8f64);
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        for li in 0..layers {
            for (i, g) in gw[li].iter().enumerate() {
                let g = (*g / n) as f64 + l2 * self.w[li][i] as f64;
                let m = &mut self.mw[li][i];
                *m = (b1 * *m as f64 + (1.0 - b1) * g) as f32;
                let v = &mut self.vw[li][i];
                *v = (b2 * *v as f64 + (1.0 - b2) * g * g) as f32;
                self.w[li][i] -=
                    (lr * (self.mw[li][i] as f64 / bc1)
                        / ((self.vw[li][i] as f64 / bc2).sqrt() + eps)) as f32;
            }
            for (i, g) in gb[li].iter().enumerate() {
                let g = (*g / n) as f64;
                let m = &mut self.mb[li][i];
                *m = (b1 * *m as f64 + (1.0 - b1) * g) as f32;
                let v = &mut self.vb[li][i];
                *v = (b2 * *v as f64 + (1.0 - b2) * g * g) as f32;
                self.b[li][i] -=
                    (lr * (self.mb[li][i] as f64 / bc1)
                        / ((self.vb[li][i] as f64 / bc2).sqrt() + eps)) as f32;
            }
        }
        loss / xs.len() as f64
    }
}

// ---------------------------------------------------------------------------
// budgeted search (the AutoML stand-in)
// ---------------------------------------------------------------------------

#[derive(Debug)]
pub struct BaselineOutcome {
    pub best: Candidate,
    pub val_acc: f64,
    pub test_acc: f64,
    pub explored: usize,
}

/// Default search space (appendix Table 5 at MLP scale).
pub fn search_space(budget: usize, seed: u64) -> Vec<Candidate> {
    let hiddens: &[&[usize]] = &[&[], &[64], &[128], &[256], &[128, 64], &[256, 128]];
    let lrs = [3e-4, 1e-3, 3e-3, 1e-2];
    let l2s = [0.0, 1e-4, 1e-3];
    let mut rng = Rng::new(seed ^ 0xBA5E);
    let mut all: Vec<Candidate> = Vec::new();
    for h in hiddens {
        for &lr in &lrs {
            for &l2 in &l2s {
                all.push(Candidate {
                    hidden: h.to_vec(),
                    lr,
                    epochs: 30,
                    l2,
                    seed: rng.next_u64(),
                });
            }
        }
    }
    rng.shuffle(&mut all);
    all.truncate(budget);
    all
}

fn class_labels(labels: &Labels) -> Result<&[usize]> {
    match labels {
        Labels::Class(l) => Ok(l),
        _ => anyhow::bail!("baseline supports classification tasks only"),
    }
}

fn train_eval_candidate(
    cand: &Candidate,
    train_x: &[Vec<f32>],
    train_y: &[usize],
    val_x: &[Vec<f32>],
    val_y: &[usize],
    n_classes: usize,
) -> (Mlp, f64) {
    let d = train_x[0].len();
    let mut sizes = vec![d];
    sizes.extend(&cand.hidden);
    sizes.push(n_classes);
    let mut rng = Rng::new(cand.seed);
    let mut mlp = Mlp::new(&sizes, &mut rng);
    let batch = 32.min(train_x.len());
    let mut order: Vec<usize> = (0..train_x.len()).collect();
    for _ in 0..cand.epochs {
        rng.shuffle(&mut order);
        for chunk in order.chunks(batch) {
            let xs: Vec<&[f32]> = chunk.iter().map(|&i| train_x[i].as_slice()).collect();
            let ys: Vec<usize> = chunk.iter().map(|&i| train_y[i]).collect();
            mlp.train_batch(&xs, &ys, cand.lr, cand.l2);
        }
    }
    let preds: Vec<usize> = val_x.iter().map(|x| argmax(&mlp.logits(x))).collect();
    let acc = stats::accuracy(&preds, val_y);
    (mlp, acc)
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap()
        .0
}

/// Run the budgeted search for one classification task.
pub fn run_baseline(
    rt: &Arc<Runtime>,
    base: &NamedTensors,
    task: &TaskData,
    budget: usize,
    n_classes: usize,
) -> Result<BaselineOutcome> {
    let train_x = embed_features(rt, base, &task.train)?;
    let val_x = embed_features(rt, base, &task.val)?;
    let test_x = embed_features(rt, base, &task.test)?;
    let train_y = class_labels(&task.train.labels)?;
    let val_y = class_labels(&task.val.labels)?;
    let test_y = class_labels(&task.test.labels)?;

    let mut best: Option<(Candidate, Mlp, f64)> = None;
    let cands = search_space(budget, task.spec.seed);
    let explored = cands.len();
    for cand in cands {
        let (mlp, acc) =
            train_eval_candidate(&cand, &train_x, train_y, &val_x, val_y, n_classes);
        if best.as_ref().map(|(_, _, b)| acc > *b).unwrap_or(true) {
            best = Some((cand, mlp, acc));
        }
    }
    let (best_cand, mlp, val_acc) = best.context("empty search budget")?;
    let preds: Vec<usize> = test_x.iter().map(|x| argmax(&mlp.logits(x))).collect();
    let test_acc = stats::accuracy(&preds, test_y);
    Ok(BaselineOutcome { best: best_cand, val_acc, test_acc, explored })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mlp_learns_xor() {
        let xs: Vec<Vec<f32>> = vec![
            vec![0.0, 0.0],
            vec![0.0, 1.0],
            vec![1.0, 0.0],
            vec![1.0, 1.0],
        ];
        let ys = [0usize, 1, 1, 0];
        let mut rng = Rng::new(3);
        let mut mlp = Mlp::new(&[2, 16, 2], &mut rng);
        for _ in 0..800 {
            let refs: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
            mlp.train_batch(&refs, &ys, 1e-2, 0.0);
        }
        let preds: Vec<usize> = xs.iter().map(|x| argmax(&mlp.logits(x))).collect();
        assert_eq!(preds, ys.to_vec());
    }

    #[test]
    fn mlp_loss_decreases() {
        let mut rng = Rng::new(4);
        let xs: Vec<Vec<f32>> =
            (0..64).map(|_| (0..8).map(|_| rng.f32()).collect()).collect();
        let ys: Vec<usize> = xs.iter().map(|x| (x[0] > 0.5) as usize).collect();
        let mut mlp = Mlp::new(&[8, 16, 2], &mut rng);
        let refs: Vec<&[f32]> = xs.iter().map(|x| x.as_slice()).collect();
        let first = mlp.train_batch(&refs, &ys, 1e-2, 0.0);
        let mut last = first;
        for _ in 0..100 {
            last = mlp.train_batch(&refs, &ys, 1e-2, 0.0);
        }
        assert!(last < first * 0.5, "{first} -> {last}");
    }

    #[test]
    fn linear_model_when_no_hidden() {
        let mut rng = Rng::new(5);
        let mlp = Mlp::new(&[4, 3], &mut rng);
        assert_eq!(mlp.w.len(), 1);
        assert_eq!(mlp.logits(&[0.0; 4]).len(), 3);
    }

    #[test]
    fn search_space_is_budgeted_and_deterministic() {
        let a = search_space(10, 1);
        let b = search_space(10, 1);
        assert_eq!(a.len(), 10);
        assert_eq!(
            a.iter().map(|c| (c.hidden.clone(), c.lr.to_bits())).collect::<Vec<_>>(),
            b.iter().map(|c| (c.hidden.clone(), c.lr.to_bits())).collect::<Vec<_>>()
        );
    }
}
