//! Typed execution facade: manifest signature validation, the compile
//! cache, and group packing/unpacking — independent of which backend runs
//! the math.
//!
//! [`Runtime`] owns a [`Backend`] (PJRT or native, see
//! [`super::backend::BackendKind`]) plus a compile cache: preparing the
//! larger train-step graphs is expensive on the PJRT path, so every caller
//! shares one [`Executable`] per name. [`Executable::run`]/[`run_refs`]
//! take *banks* — slices of tensors in manifest group order — validate them
//! against the signature, execute, and split the result tuple back into
//! output groups. Long-lived banks (the frozen base, a task's adapters)
//! can be moved into backend storage **once** as a [`DeviceBank`] and
//! reused across steps/batches; only per-step data (batches, scalars,
//! updated trained params) is re-supplied per call.
//!
//! Backend selection: [`Runtime::open`] resolves
//! [`BackendKind::from_env`] (`ADAPTERBERT_BACKEND`, or the CLI's
//! `--backend` flag which sets it); [`Runtime::open_with`] takes the kind
//! explicitly. `Auto` tries PJRT and falls back to the native kernels, so
//! everything — training, evaluation, the serving loop — runs on machines
//! with no PJRT plugin installed. When the manifest itself is missing and
//! the preset is a built-in, it is synthesized in-process
//! ([`super::synth`]), removing the artifacts dependency entirely.
//!
//! [`run_refs`]: Executable::run_refs
//! [`Backend`]: super::backend::Backend

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::backend::{ArgTensor, Backend, BackendExec, BackendKind, BankStorage};
use super::manifest::{ExeSpec, LeafSpec, Manifest};
use super::native::NativeBackend;
use super::pjrt::PjrtBackend;
use super::synth;
use crate::util::tensor::{DType, Tensor};

pub use super::backend::Bank;

/// A bank resident in backend storage, uploaded once and reused.
pub struct DeviceBank {
    storage: Box<dyn BankStorage>,
}

impl DeviceBank {
    /// Number of tensors in the bank.
    pub fn len(&self) -> usize {
        self.storage.shapes().len()
    }

    /// True when the bank holds no tensors.
    pub fn is_empty(&self) -> bool {
        self.storage.shapes().is_empty()
    }
}

/// Input argument: host tensors (supplied per call) or a resident bank.
pub enum BankRef<'a> {
    /// Host-side bank, validated and uploaded on every call.
    Host(&'a Bank),
    /// Backend-resident bank uploaded earlier via [`Runtime::upload_bank`].
    Device(&'a DeviceBank),
}

/// The execution runtime for one preset's artifacts.
pub struct Runtime {
    backend: Box<dyn Backend>,
    /// Signature contract with the compiler (loaded or synthesized).
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    /// cumulative time spent preparing executables (perf accounting)
    compile_seconds: Mutex<f64>,
}

impl Runtime {
    /// Open the artifacts directory for `preset` under `root`, selecting
    /// the backend from `ADAPTERBERT_BACKEND` (default: `auto`).
    pub fn open(root: &Path, preset: &str) -> Result<Runtime> {
        Self::open_with(root, preset, BackendKind::from_env()?)
    }

    /// Open with an explicit backend choice.
    ///
    /// * `Pjrt` requires both the plugin and on-disk artifacts.
    /// * `Native` and `Auto` fall back to a synthesized manifest when
    ///   `manifest.json` is absent and `preset` is a built-in.
    pub fn open_with(root: &Path, preset: &str, kind: BackendKind) -> Result<Runtime> {
        let dir = root.join(preset);
        // synthesize only when the manifest is genuinely absent — a present
        // but unparseable manifest.json is corruption the user must see,
        // not something to silently paper over with a built-in preset
        let on_disk = dir.join("manifest.json").exists();
        let (manifest, synthesized) = if on_disk {
            (Manifest::load(&dir)?, false)
        } else {
            match synth::builtin_manifest(preset, &dir) {
                Some(m) if kind != BackendKind::Pjrt => (m, true),
                _ => (Manifest::load(&dir)?, false), // reports the missing file
            }
        };
        let backend: Box<dyn Backend> = match kind {
            BackendKind::Pjrt => Box::new(PjrtBackend::new()?),
            BackendKind::Native => Box::new(NativeBackend::new(&manifest)),
            // a synthesized manifest has no HLO files on disk, so even a
            // working PJRT plugin could not compile anything — go native
            BackendKind::Auto if synthesized => Box::new(NativeBackend::new(&manifest)),
            BackendKind::Auto => match PjrtBackend::new() {
                Ok(b) => Box::new(b),
                Err(_) => Box::new(NativeBackend::new(&manifest)),
            },
        };
        Ok(Runtime {
            backend,
            manifest,
            cache: Mutex::new(HashMap::new()),
            compile_seconds: Mutex::new(0.0),
        })
    }

    /// Which backend this runtime resolved to ("pjrt" or "native").
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Get (preparing on first use) the named executable.
    pub fn load(self: &Arc<Self>, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.exe(name)?.clone();
        let t0 = Instant::now();
        let inner = self.backend.compile(&self.manifest, &spec)?;
        *self.compile_seconds.lock().unwrap() += t0.elapsed().as_secs_f64();
        let exe = Arc::new(Executable { inner, spec });
        // two threads may have compiled concurrently; everyone returns the
        // cached winner so the one-shared-executable invariant holds
        Ok(self
            .cache
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| exe)
            .clone())
    }

    /// Pre-compile several executables (startup warm-up).
    pub fn preload(self: &Arc<Self>, names: &[&str]) -> Result<()> {
        for n in names {
            self.load(n)?;
        }
        Ok(())
    }

    /// Cumulative executable-preparation time in seconds.
    pub fn compile_seconds(&self) -> f64 {
        *self.compile_seconds.lock().unwrap()
    }

    /// Number of executables currently cached.
    pub fn cached_executables(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Move a whole bank into backend storage for reuse across executions.
    pub fn upload_bank(&self, bank: &Bank) -> Result<DeviceBank> {
        Ok(DeviceBank { storage: self.backend.upload_bank(bank)? })
    }

    /// The backend's fused multi-task engine, when it has one (native
    /// only — PJRT callers keep the per-task path).
    pub fn fused(&self) -> Option<&dyn super::fused::FusedBackend> {
        self.backend.fused()
    }
}

/// A prepared executable bound to its manifest signature.
pub struct Executable {
    inner: Box<dyn BackendExec>,
    /// The manifest signature this executable was prepared from.
    pub spec: ExeSpec,
}

impl Executable {
    /// Execute with all-host input banks in manifest group order.
    pub fn run(&self, banks: &[&Bank]) -> Result<Vec<Bank>> {
        let refs: Vec<BankRef> = banks.iter().map(|b| BankRef::Host(b)).collect();
        self.run_refs(&refs)
    }

    /// Execute with a mix of host banks and resident device banks.
    ///
    /// Returns one bank per *output group* (top-level tuple element), so a
    /// train step's `(trained, opt_m, opt_v, loss, metric)` comes back as
    /// five banks.
    pub fn run_refs(&self, banks: &[BankRef]) -> Result<Vec<Bank>> {
        let groups = self.spec.input_groups();
        if banks.len() != groups.len() {
            bail!(
                "{}: expected {} input banks ({:?}), got {}",
                self.spec.name,
                groups.len(),
                groups,
                banks.len()
            );
        }
        let mut flat: Vec<ArgTensor> = Vec::with_capacity(self.spec.inputs.len());
        let mut idx = 0usize;
        for (bank, group) in banks.iter().zip(&groups) {
            match bank {
                BankRef::Host(b) => {
                    for t in b.iter() {
                        self.leaf(idx, group, &t.shape, t.dtype())?;
                        flat.push(ArgTensor::Host(t));
                        idx += 1;
                    }
                }
                BankRef::Device(d) => {
                    for (pos, (shape, dt)) in d.storage.shapes().iter().enumerate() {
                        self.leaf(idx, group, shape, *dt)?;
                        flat.push(ArgTensor::Stored {
                            bank: d.storage.as_ref(),
                            index: pos,
                        });
                        idx += 1;
                    }
                }
            }
            if idx < self.spec.inputs.len() && self.spec.inputs[idx].group == *group {
                bail!(
                    "{}: bank for group {group:?} is missing tensors (next: {})",
                    self.spec.name,
                    self.spec.inputs[idx].name
                );
            }
        }
        if idx != self.spec.inputs.len() {
            bail!("{}: packed {idx}/{} inputs", self.spec.name, self.spec.inputs.len());
        }
        let outs = self.inner.execute(&self.spec, &flat)?;
        self.split_outputs(outs)
    }

    fn leaf(
        &self,
        idx: usize,
        group: &str,
        shape: &[usize],
        dtype: DType,
    ) -> Result<&LeafSpec> {
        let leaf = self.spec.inputs.get(idx).with_context(|| {
            format!("{}: bank for group {group:?} has too many tensors", self.spec.name)
        })?;
        if leaf.group != group {
            bail!(
                "{}: bank for group {group:?} has too many tensors (at {})",
                self.spec.name,
                leaf.name
            );
        }
        if shape != leaf.shape.as_slice() || dtype != leaf.dtype {
            bail!(
                "{}: input {} ({}) expects {:?} {}, got {:?} {}",
                self.spec.name,
                idx,
                leaf.name,
                leaf.shape,
                leaf.dtype.name(),
                shape,
                dtype.name()
            );
        }
        Ok(leaf)
    }

    fn split_outputs(&self, parts: Vec<Tensor>) -> Result<Vec<Bank>> {
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: backend returned {} leaves, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        let mut out: Vec<Bank> = Vec::new();
        let mut current_group: Option<&str> = None;
        for (t, leaf) in parts.into_iter().zip(&self.spec.outputs) {
            if t.shape != leaf.shape {
                bail!(
                    "{}: output {} shape {:?} != manifest {:?}",
                    self.spec.name,
                    leaf.name,
                    t.shape,
                    leaf.shape
                );
            }
            if current_group != Some(leaf.group.as_str()) {
                out.push(Vec::new());
                current_group = Some(leaf.group.as_str());
            }
            out.last_mut().unwrap().push(t);
        }
        Ok(out)
    }
}
