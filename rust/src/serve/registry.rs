//! Hot registration: `POST /tasks` → store append → live bank swap, and
//! the wire→job resolution for `POST /train`.
//!
//! This operationalizes the store's append-only guarantee end to end: a
//! new task (or a new version of an existing one) becomes servable over
//! the network **without restarting or pausing other tasks**. The order
//! of operations matters:
//!
//! 1. decode + **prepare** — the bank is validated against the manifest
//!    and merged with the frozen base entirely off to the side. A
//!    malformed payload fails here and nothing has changed;
//! 2. **store append** — the immutable version record (disk write when
//!    the store is disk-backed);
//! 3. **install** — one map insert under a short write lock makes the
//!    banks visible to executors. In-flight batches for other tasks hold
//!    their own `Arc`s and never block on, or observe, the swap.
//!
//! [`install_trained`] is that sequence under the server's
//! [`registration lock`](crate::coordinator::Server::registration_lock),
//! shared by both producers — the wire path (`POST /tasks`, a remote
//! trainer pushing a finished bank) and the in-process training service
//! (a background job completing) — so store version order always matches
//! executor-side install order no matter who finishes first.

use anyhow::{bail, Context, Result};

use super::protocol::{RegisterRequest, RegisterResponse, TrainJobRequest};
use crate::coordinator::server::Server;
use crate::data::tasks::{self, Metric, TaskKind, TaskSpec};
use crate::check::order;
use crate::eval::TaskModel;
use crate::runtime::Manifest;
use crate::store::{AdapterStore, BankMeta};
use crate::train::{JobSpec, TrainConfig};

/// Prepare → store append → install, under the server's registration
/// lock. The single entry point for making a trained bank servable; a
/// bank that fails validation leaves both the store and the server
/// untouched.
pub fn install_trained(
    store: &AdapterStore,
    server: &Server,
    task: &str,
    n_classes: usize,
    val_score: f64,
    model: &TaskModel,
) -> Result<BankMeta> {
    let _ord = order::Held::enter(order::REGISTRATION);
    let _serial = server.registration_lock();
    // validate + build first: a bad bank must not leave a store version
    // behind that can never serve
    let prepared = server
        .prepare_task(n_classes, model)
        .with_context(|| format!("bank for task {task:?} is not servable"))?;
    let meta = store
        .register_with_classes(task, model, n_classes, val_score)
        .with_context(|| format!("storing bank for task {task:?}"))?;
    server.install_task(task, prepared);
    Ok(meta)
}

/// Handle one wire-format registration against a live server.
pub fn register_from_wire(
    store: &AdapterStore,
    server: &Server,
    req: &RegisterRequest,
) -> Result<RegisterResponse> {
    let model = req
        .to_model()
        .with_context(|| format!("decoding bank for task {:?}", req.task))?;
    let meta =
        install_trained(store, server, &req.task, req.n_classes, req.val_score, &model)?;
    Ok(RegisterResponse::from_meta(&meta))
}

/// Resolve a `POST /train` request into a runnable [`JobSpec`].
///
/// A `task` naming one of the built-in suites (`tasks::find_spec`) trains
/// that suite task — size/difficulty overrides apply, class structure is
/// the suite's. Any other name defines a **custom** synthetic
/// classification task from the request's `n_classes`/`pair`/`purity`/
/// `noise`/`data_seed` knobs (defaults in [`TrainJobRequest`]). Training
/// hyper-parameters (`method`, `m`, `lr`, `epochs`, `seed`) use the same
/// method grammar as the CLI's `train` subcommand; note the *serving*
/// defaults differ from the offline CLI's (`m` defaults to 8 here, like
/// `serve`'s tenant training, vs the CLI `train` default of 16) — pass
/// `m` explicitly when an online job must reproduce an offline run. The
/// chosen train executable is validated against the manifest here so an
/// impossible job is a `400`, not a failure discovered after queueing.
pub fn job_spec_from_wire(req: &TrainJobRequest, manifest: &Manifest) -> Result<JobSpec> {
    let mut spec = match tasks::find_spec(&req.task) {
        Some(s) => {
            if req.n_classes.is_some() || req.pair.is_some() {
                bail!(
                    "task {:?} is a built-in suite task; its class structure \
                     is fixed (omit n_classes/pair, or pick a new task name)",
                    req.task
                );
            }
            s
        }
        None => {
            let n_classes = req.n_classes.unwrap_or(2);
            TaskSpec {
                name: req.task.clone(),
                kind: TaskKind::Cls {
                    n_classes,
                    pair: req.pair.unwrap_or(false),
                },
                metric: Metric::Accuracy,
                n_train: 240,
                n_val: 64,
                n_test: 64,
                purity: 0.8,
                noise: 0.0,
                // derived from the name so two different custom tasks get
                // different data by default
                seed: fnv1a(&req.task),
            }
        }
    };
    if let Some(n) = req.n_train {
        spec.n_train = n;
    }
    if let Some(n) = req.n_val {
        spec.n_val = n;
        spec.n_test = n;
    }
    if let Some(p) = req.purity {
        if !(0.0..=1.0).contains(&p) {
            bail!("purity {p} outside [0, 1]");
        }
        spec.purity = p;
    }
    if let Some(z) = req.noise {
        if !(0.0..=1.0).contains(&z) {
            bail!("noise {z} outside [0, 1]");
        }
        spec.noise = z;
    }
    if let Some(s) = req.data_seed {
        spec.seed = s;
    }
    if let TaskKind::Cls { n_classes, .. } = &spec.kind {
        let max = manifest.dims.max_classes;
        if !(2..=max).contains(n_classes) {
            bail!("n_classes {n_classes} outside the servable range [2, {max}]");
        }
    }

    let kind = spec.kind.artifact_kind();
    let method = req.method.as_deref().unwrap_or("adapter");
    let exe = match method {
        "adapter" => format!("{kind}_train_adapter_m{}", req.m.unwrap_or(8)),
        "lnonly" => format!("{kind}_train_lnonly"),
        "finetune" => format!("{kind}_train_topk_k{}", manifest.dims.n_layers),
        m if m.starts_with("topk:") => {
            let k: usize = m[5..]
                .parse()
                .with_context(|| format!("bad top-k depth in method {m:?}"))?;
            format!("{kind}_train_topk_k{k}")
        }
        other => bail!("unknown method {other:?} (adapter|lnonly|topk:K|finetune)"),
    };
    manifest
        .exe(&exe)
        .with_context(|| format!("method {method:?} resolves to no executable"))?;
    let default_lr = if method == "adapter" { 1e-3 } else { 1e-4 };
    let train = TrainConfig::new(
        &exe,
        req.lr.unwrap_or(default_lr),
        req.epochs.unwrap_or(6),
        req.seed.unwrap_or(0),
    );
    if train.epochs == 0 {
        bail!("epochs must be at least 1");
    }
    Ok(JobSpec { task: spec, train })
}

/// FNV-1a over the task name — a stable default data seed for custom
/// tasks.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::synth;
    use std::path::Path;

    fn manifest() -> Manifest {
        synth::builtin_manifest("test", Path::new("artifacts/test")).unwrap()
    }

    #[test]
    fn custom_task_resolves_with_defaults() {
        let m = manifest();
        let req = TrainJobRequest::new("fresh_task");
        let job = job_spec_from_wire(&req, &m).unwrap();
        assert_eq!(job.task.name, "fresh_task");
        assert_eq!(job.task.kind, TaskKind::Cls { n_classes: 2, pair: false });
        assert_eq!(job.train.exe, "cls_train_adapter_m8");
        assert_eq!(job.train.lr, 1e-3);
        assert_eq!(job.train.epochs, 6);
        // name-derived data seed is stable
        let again = job_spec_from_wire(&req, &m).unwrap();
        assert_eq!(job.task.seed, again.task.seed);
        let other = job_spec_from_wire(&TrainJobRequest::new("other_task"), &m).unwrap();
        assert_ne!(job.task.seed, other.task.seed);
    }

    #[test]
    fn suite_task_keeps_its_structure() {
        let m = manifest();
        let mut req = TrainJobRequest::new("rte_s");
        req.n_train = Some(120);
        let job = job_spec_from_wire(&req, &m).unwrap();
        assert_eq!(job.task.kind, TaskKind::Cls { n_classes: 2, pair: true });
        assert_eq!(job.task.n_train, 120);
        // overriding a suite task's class structure is refused
        req.n_classes = Some(5);
        assert!(job_spec_from_wire(&req, &m).is_err());
    }

    #[test]
    fn bad_requests_are_rejected_up_front() {
        let m = manifest();
        // adapter size the preset doesn't ship
        let mut req = TrainJobRequest::new("x");
        req.m = Some(999);
        assert!(job_spec_from_wire(&req, &m).is_err());
        // unknown method
        let mut req = TrainJobRequest::new("x");
        req.method = Some("magic".into());
        assert!(job_spec_from_wire(&req, &m).is_err());
        // class count beyond the padded head
        let mut req = TrainJobRequest::new("x");
        req.n_classes = Some(10_000);
        assert!(job_spec_from_wire(&req, &m).is_err());
        // zero epochs
        let mut req = TrainJobRequest::new("x");
        req.epochs = Some(0);
        assert!(job_spec_from_wire(&req, &m).is_err());
        // out-of-range difficulty knobs
        let mut req = TrainJobRequest::new("x");
        req.purity = Some(1.5);
        assert!(job_spec_from_wire(&req, &m).is_err());
    }

    #[test]
    fn method_strings_resolve_like_the_cli() {
        let m = manifest();
        let mut req = TrainJobRequest::new("x");
        req.method = Some("lnonly".into());
        assert_eq!(
            job_spec_from_wire(&req, &m).unwrap().train.exe,
            "cls_train_lnonly"
        );
        req.method = Some("topk:1".into());
        let job = job_spec_from_wire(&req, &m).unwrap();
        assert_eq!(job.train.exe, "cls_train_topk_k1");
        assert_eq!(job.train.lr, 1e-4, "non-adapter default lr");
    }
}
