//! `bench cluster`: scaling + failover for the router tier →
//! `BENCH_cluster.json`.
//!
//! Everything runs in-process on ephemeral ports: one shared runtime
//! and one shared in-memory `AdapterStore` back N independent
//! `Gateway` replicas (each with its own coordinator and adapter
//! cache) behind one `cluster::Router`. Two phases:
//!
//! * **scaling** — the identical closed-loop predict load is driven at
//!   the router over 1 replica, then over N; the report records
//!   throughput and p50/p95 per replica count plus the aggregate
//!   speedup (CI pins a floor on it). Tasks shard across replicas via
//!   the hash ring, so N coordinators batch independently;
//! * **failover** — with N replicas under continuous traffic, the
//!   replica owning the first task is shut down mid-run. Per-request
//!   outcomes are timestamped; convergence is the time from the kill to
//!   the *last* failed request (the router needs `fail_after` bad
//!   signals to eject the corpse; until then some requests eat the
//!   drain/refused window), and the post-convergence tail must be
//!   error-free — that quiet tail is what CI asserts, together with
//!   convergence finishing well inside the observation window.
//!
//! The report is schema-pinned (v1) like the other bench documents.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use super::loadgen::{self, LoadgenConfig};
use crate::cluster::{HashRing, HealthPolicy, Router, RouterConfig, DEFAULT_VNODES};
use crate::coordinator::{FlushPolicy, Server, ServerConfig};
use crate::data::grammar::World;
use crate::data::tasks::{self, Metric, TaskKind, TaskSpec};
use crate::model::params::NamedTensors;
use crate::runtime::Runtime;
use crate::serve::{Client, ClientConfig, Gateway, GatewayConfig};
use crate::store::AdapterStore;
use crate::train::{self, PretrainConfig, TrainConfig};
use crate::util::json::Json;

/// Harness knobs.
#[derive(Debug, Clone)]
pub struct ClusterBenchConfig {
    pub preset: String,
    /// Replica count for the scaled phase (the baseline is always 1).
    pub replicas: usize,
    /// Tenant tasks trained into the shared store (≥ replicas keeps
    /// every replica owning at least one shard in expectation).
    pub tenants: usize,
    /// Predict requests per scaling phase.
    pub requests: u64,
    /// Closed-loop client threads.
    pub concurrency: usize,
    /// Adapter size for the tenants.
    pub m: usize,
    /// MLM pre-training steps when no cached base exists.
    pub pretrain_steps: usize,
    /// Failover phase: traffic before the kill…
    pub failover_warmup: Duration,
    /// …and observation window after it.
    pub failover_window: Duration,
}

impl Default for ClusterBenchConfig {
    fn default() -> Self {
        ClusterBenchConfig {
            preset: "test".to_string(),
            replicas: 2,
            tenants: 4,
            requests: 240,
            concurrency: 4,
            m: 8,
            pretrain_steps: 120,
            failover_warmup: Duration::from_millis(1500),
            failover_window: Duration::from_secs(6),
        }
    }
}

/// One scaling row: the same load at a given replica count.
#[derive(Debug, Clone)]
pub struct ScalingRow {
    pub replicas: usize,
    pub requests: u64,
    pub errors: u64,
    pub throughput_rps: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
}

impl ScalingRow {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("replicas", Json::num(self.replicas as f64)),
            ("requests", Json::num(self.requests as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("throughput_rps", Json::num(self.throughput_rps)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p95_ms", Json::num(self.p95_ms)),
        ])
    }
}

/// The kill-one-mid-traffic phase.
#[derive(Debug, Clone)]
pub struct FailoverReport {
    /// Address of the replica that was shut down.
    pub killed: String,
    /// Requests/errors over the whole phase (warmup + window).
    pub requests: u64,
    pub errors: u64,
    /// Kill → last failed request. 0 when no request ever failed.
    pub convergence_ms: f64,
    pub errors_during_convergence: u64,
    /// The tail after convergence: must be busy and error-free.
    pub post_requests: u64,
    pub post_errors: u64,
    /// Router-side transition counters over the phase.
    pub ejections: u64,
    pub reroutes: u64,
}

impl FailoverReport {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("killed", Json::str(&self.killed)),
            ("requests", Json::num(self.requests as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("convergence_ms", Json::num(self.convergence_ms)),
            (
                "errors_during_convergence",
                Json::num(self.errors_during_convergence as f64),
            ),
            ("post_requests", Json::num(self.post_requests as f64)),
            ("post_errors", Json::num(self.post_errors as f64)),
            ("ejections", Json::num(self.ejections as f64)),
            ("reroutes", Json::num(self.reroutes as f64)),
        ])
    }
}

/// The whole run.
#[derive(Debug)]
pub struct ClusterReport {
    pub scaling: Vec<ScalingRow>,
    /// Last row's throughput over the first row's.
    pub speedup: f64,
    pub failover: FailoverReport,
}

impl ClusterReport {
    /// The `BENCH_cluster.json` document (schema v1).
    pub fn to_json(&self, cfg: &ClusterBenchConfig) -> Json {
        Json::obj(vec![
            ("bench", Json::str("cluster")),
            ("schema_version", Json::num(1.0)),
            (
                "config",
                Json::obj(vec![
                    ("preset", Json::str(&cfg.preset)),
                    ("replicas", Json::num(cfg.replicas as f64)),
                    ("tenants", Json::num(cfg.tenants as f64)),
                    ("requests", Json::num(cfg.requests as f64)),
                    ("concurrency", Json::num(cfg.concurrency as f64)),
                    ("m", Json::num(cfg.m as f64)),
                    (
                        "failover_window_s",
                        Json::num(cfg.failover_window.as_secs_f64()),
                    ),
                ]),
            ),
            ("scaling", Json::arr(self.scaling.iter().map(ScalingRow::to_json))),
            ("speedup", Json::num(self.speedup)),
            ("failover", self.failover.to_json()),
        ])
    }
}

/// Shared fixture: runtime, base, tenants in one in-memory store.
struct Fixture {
    rt: Arc<Runtime>,
    base: NamedTensors,
    store: Arc<AdapterStore>,
    tenants: Vec<String>,
    classes: BTreeMap<String, usize>,
}

fn tenant_spec(name: &str, seed: u64) -> TaskSpec {
    TaskSpec {
        name: name.to_string(),
        kind: TaskKind::Cls { n_classes: 2, pair: false },
        metric: Metric::Accuracy,
        n_train: 240,
        n_val: 48,
        n_test: 48,
        purity: 0.85,
        noise: 0.0,
        seed,
    }
}

fn setup(cfg: &ClusterBenchConfig) -> Result<Fixture> {
    let rt = Arc::new(Runtime::open(Path::new("artifacts"), &cfg.preset)?);
    let world = World::new(rt.manifest.dims.vocab, 0);
    let base = train::load_or_pretrain(
        &rt,
        &world,
        &PretrainConfig { steps: cfg.pretrain_steps, ..Default::default() },
        Path::new(&format!("runs/base_{}.bank", cfg.preset)),
    )?;
    let store = Arc::new(AdapterStore::in_memory());
    let exe = format!("cls_train_adapter_m{}", cfg.m);
    let mut tenants = Vec::new();
    let mut classes = BTreeMap::new();
    for k in 0..cfg.tenants.max(1) {
        let name = format!("shard{k:02}");
        let data =
            tasks::generate(&world, &tenant_spec(&name, 300 + k as u64), rt.manifest.dims.seq);
        let res = train::train_task(&rt, &TrainConfig::new(&exe, 1e-3, 3, 0), &data, &base)?;
        store.register_with_classes(&name, &res.model, 2, res.val_score)?;
        classes.insert(name.clone(), 2usize);
        tenants.push(name.clone());
        println!("  tenant {name}: val {:.3}", res.val_score);
    }
    Ok(Fixture { rt, base, store, tenants, classes })
}

/// One gateway replica over the shared store, on an ephemeral port.
fn start_replica(fx: &Fixture) -> Result<Gateway> {
    let server = Server::start(
        fx.rt.clone(),
        &fx.store,
        &fx.base,
        &fx.classes,
        ServerConfig {
            flush: FlushPolicy {
                max_batch: fx.rt.manifest.batch,
                max_delay: Duration::from_millis(2),
            },
            executors: 2,
            ..Default::default()
        },
    )?;
    Gateway::start(
        fx.rt.clone(),
        fx.store.clone(),
        server,
        GatewayConfig { addr: "127.0.0.1:0".to_string(), ..Default::default() },
    )
}

/// Bench-speed health policy: eject a corpse within a few hundred ms so
/// the failover window stays short.
fn router_config() -> RouterConfig {
    RouterConfig {
        health: HealthPolicy {
            interval: Duration::from_millis(100),
            timeout: Duration::from_millis(500),
            fail_after: 2,
            pass_after: 2,
        },
        upstream: ClientConfig {
            connect_timeout: Duration::from_millis(500),
            read_timeout: Some(Duration::from_secs(30)),
            retries: 0,
            backoff: Duration::from_millis(10),
            deadline: None,
        },
        ..Default::default()
    }
}

/// Poll the router's `/health` until `healthy` reaches `want`.
fn wait_healthy(addr: &str, want: usize, timeout: Duration) -> Result<()> {
    let deadline = Instant::now() + timeout;
    loop {
        if let Ok(mut c) = Client::connect(addr) {
            if let Ok((status, j)) = c.roundtrip("GET", "/health", None) {
                if status == 200
                    && j.get("healthy").and_then(Json::as_usize) == Some(want)
                {
                    return Ok(());
                }
            }
        }
        if Instant::now() > deadline {
            bail!("router at {addr} never reported {want} healthy replica(s)");
        }
        std::thread::sleep(Duration::from_millis(50));
    }
}

/// One scaling measurement: n replicas behind a fresh router.
fn scaling_phase(fx: &Fixture, cfg: &ClusterBenchConfig, n: usize) -> Result<ScalingRow> {
    let gateways: Vec<Gateway> =
        (0..n).map(|_| start_replica(fx)).collect::<Result<_>>()?;
    let addrs: Vec<String> = gateways.iter().map(|g| g.local_addr().to_string()).collect();
    let router = Router::start(addrs, router_config())?;
    let addr = router.local_addr().to_string();
    wait_healthy(&addr, n, Duration::from_secs(10))?;

    let report = loadgen::run(&LoadgenConfig {
        addr,
        tasks: fx.tenants.clone(),
        concurrency: cfg.concurrency,
        requests: cfg.requests,
        seed: 40 + n as u64,
        ..Default::default()
    })?;
    router.shutdown();
    for g in gateways {
        g.shutdown()?;
    }
    Ok(ScalingRow {
        replicas: n,
        requests: report.requests,
        errors: report.errors,
        throughput_rps: report.throughput_rps(),
        p50_ms: if report.all.is_empty() { 0.0 } else { report.all.pctl_s(50.0) * 1e3 },
        p95_ms: if report.all.is_empty() { 0.0 } else { report.all.pctl_s(95.0) * 1e3 },
    })
}

/// Kill the replica owning the first tenant mid-traffic and watch the
/// router converge.
fn failover_phase(fx: &Fixture, cfg: &ClusterBenchConfig) -> Result<FailoverReport> {
    let n = cfg.replicas.max(2);
    let mut gateways: Vec<Gateway> =
        (0..n).map(|_| start_replica(fx)).collect::<Result<_>>()?;
    let addrs: Vec<String> = gateways.iter().map(|g| g.local_addr().to_string()).collect();
    let router = Router::start(addrs.clone(), router_config())?;
    let raddr = router.local_addr().to_string();
    wait_healthy(&raddr, n, Duration::from_secs(10))?;

    // kill the replica that actually owns traffic for the first tenant,
    // so the phase provably exercises re-routing
    let ring = HashRing::new(&addrs, DEFAULT_VNODES);
    let victim = ring.route(&fx.tenants[0]).expect("non-empty ring");
    let killed = addrs[victim].clone();

    let stop = AtomicBool::new(false);
    let t0 = Instant::now();
    let mut kill_at_s = 0.0f64;
    // (seconds since t0, ok) per request, across all workers
    let mut events: Vec<(f64, bool)> = Vec::new();
    let mut worker_err: Option<anyhow::Error> = None;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for w in 0..cfg.concurrency.max(2) {
            let (stop, raddr, tenants) = (&stop, &raddr, &fx.tenants);
            handles.push(scope.spawn(move || {
                let mut out: Vec<(f64, bool)> = Vec::new();
                let Ok(mut client) = Client::connect(raddr) else { return out };
                let mut i = w;
                while !stop.load(Ordering::Relaxed) {
                    let task = &tenants[i % tenants.len()];
                    i += 1;
                    let at = t0.elapsed().as_secs_f64();
                    match client.predict_text(task, "moresa zu kari letu") {
                        Ok(_) => out.push((at, true)),
                        Err(_) => {
                            out.push((at, false));
                            // the router connection itself should stay
                            // up; redial defensively anyway
                            let _ = client.reconnect();
                        }
                    }
                }
                out
            }));
        }

        std::thread::sleep(cfg.failover_warmup);
        kill_at_s = t0.elapsed().as_secs_f64();
        let dead = gateways.swap_remove(victim);
        if let Err(e) = dead.shutdown() {
            worker_err = Some(e.context("shutting down the victim replica"));
        }
        std::thread::sleep(cfg.failover_window);
        stop.store(true, Ordering::Relaxed);
        for h in handles {
            if let Ok(v) = h.join() {
                events.extend(v);
            }
        }
    });
    if let Some(e) = worker_err {
        return Err(e);
    }
    let rrep = router.shutdown();
    for g in gateways {
        g.shutdown()?;
    }

    let requests = events.len() as u64;
    let errors = events.iter().filter(|(_, ok)| !ok).count() as u64;
    // convergence: the last error after the kill bounds the re-route
    // window; everything after it is the quiet tail CI asserts on
    let mut last_err = kill_at_s;
    for &(at, ok) in &events {
        if !ok && at >= kill_at_s && at > last_err {
            last_err = at;
        }
    }
    let errors_during_convergence = events
        .iter()
        .filter(|&&(at, ok)| !ok && at >= kill_at_s)
        .count() as u64;
    let post_requests =
        events.iter().filter(|&&(at, _)| at > last_err).count() as u64;
    let post_errors = events
        .iter()
        .filter(|&&(at, ok)| !ok && at > last_err)
        .count() as u64;
    ensure!(
        post_requests > 0,
        "no traffic after convergence — widen failover_window (converged {:.0}ms \
         into a {:.0}ms window)",
        (last_err - kill_at_s) * 1e3,
        cfg.failover_window.as_secs_f64() * 1e3
    );
    Ok(FailoverReport {
        killed,
        requests,
        errors,
        convergence_ms: (last_err - kill_at_s) * 1e3,
        errors_during_convergence,
        post_requests,
        post_errors,
        ejections: rrep.ejections,
        reroutes: rrep.reroutes,
    })
}

/// Run both phases.
pub fn run(cfg: &ClusterBenchConfig) -> Result<ClusterReport> {
    ensure!(cfg.replicas >= 1, "need at least one replica");
    let fx = setup(cfg).context("cluster bench fixture")?;

    let mut scaling = Vec::new();
    let mut counts = vec![1usize];
    if cfg.replicas > 1 {
        counts.push(cfg.replicas);
    }
    for n in counts {
        println!("  scaling: {} replica(s), {} requests …", n, cfg.requests);
        let row = scaling_phase(&fx, cfg, n)?;
        println!(
            "    {:.1} rps, p50 {:.2} ms, p95 {:.2} ms, {} errors",
            row.throughput_rps, row.p50_ms, row.p95_ms, row.errors
        );
        scaling.push(row);
    }
    let speedup = match (scaling.first(), scaling.last()) {
        (Some(a), Some(b)) if a.throughput_rps > 0.0 => {
            b.throughput_rps / a.throughput_rps
        }
        _ => 0.0,
    };

    println!("  failover: kill owner of {:?} mid-traffic …", fx.tenants[0]);
    let failover = failover_phase(&fx, cfg)?;
    println!(
        "    converged in {:.0} ms ({} errors during, {} requests / {} errors after)",
        failover.convergence_ms,
        failover.errors_during_convergence,
        failover.post_requests,
        failover.post_errors
    );

    Ok(ClusterReport { scaling, speedup, failover })
}

/// Atomically persist the report (same contract as the other benches).
pub fn write_report(path: &Path, report: &Json) -> Result<()> {
    loadgen::write_report(path, report)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Pins the BENCH_cluster.json v1 schema CI validates against.
    #[test]
    fn report_json_schema() {
        let report = ClusterReport {
            scaling: vec![
                ScalingRow {
                    replicas: 1,
                    requests: 240,
                    errors: 0,
                    throughput_rps: 100.0,
                    p50_ms: 8.0,
                    p95_ms: 14.0,
                },
                ScalingRow {
                    replicas: 2,
                    requests: 240,
                    errors: 0,
                    throughput_rps: 185.0,
                    p50_ms: 7.0,
                    p95_ms: 13.0,
                },
            ],
            speedup: 1.85,
            failover: FailoverReport {
                killed: "127.0.0.1:7701".into(),
                requests: 900,
                errors: 3,
                convergence_ms: 240.0,
                errors_during_convergence: 3,
                post_requests: 600,
                post_errors: 0,
                ejections: 1,
                reroutes: 5,
            },
        };
        let cfg = ClusterBenchConfig::default();
        let back = Json::parse(&report.to_json(&cfg).to_string()).unwrap();
        assert_eq!(back.at("bench").as_str(), Some("cluster"));
        assert_eq!(back.at("schema_version").as_usize(), Some(1));
        assert_eq!(back.at("config").at("replicas").as_usize(), Some(2));
        let rows = back.at("scaling").as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        for (row, want) in rows.iter().zip([1usize, 2]) {
            assert_eq!(row.at("replicas").as_usize(), Some(want));
            assert!(row.at("throughput_rps").as_f64().unwrap() > 0.0);
            assert!(row.at("p95_ms").as_f64().unwrap() > 0.0);
            assert_eq!(row.at("errors").as_usize(), Some(0));
        }
        assert!(back.at("speedup").as_f64().unwrap() > 1.7);
        let f = back.at("failover");
        assert_eq!(f.at("killed").as_str(), Some("127.0.0.1:7701"));
        assert_eq!(f.at("post_errors").as_usize(), Some(0));
        assert!(f.at("post_requests").as_usize().unwrap() > 0);
        assert!(f.at("convergence_ms").as_f64().is_some());
        assert_eq!(f.at("ejections").as_usize(), Some(1));
    }
}
