//! Closed-loop load generator for the serving gateway.
//!
//! Drives `serve::Gateway` over real sockets: N worker threads, each with
//! its own keep-alive connection, issue predict-by-text requests against
//! a configurable task mix until a request budget or deadline runs out
//! (closed loop: a worker sends its next request only after the previous
//! response lands, so concurrency == open requests). The report — total
//! and per-task throughput, latency quantiles, the batch-size histogram
//! observed in responses and the server-side occupancy over the run
//! window — serializes to `BENCH_serve.json` (schema v2), the serving
//! entry in the repo's perf trajectory.
//!
//! The **many-tasks/low-rate preset** (`task_count` + `rate`) recreates
//! the paper's serving regime — 26 tasks, modest traffic each — where
//! per-task batching collapses to 1–2-row batches and the fused engine's
//! cross-task batches win; the recorded `mean_occupancy` is the
//! comparison the CI smoke job pins.
//!
//! The **cache-pressure preset** (`zipf`) skews the task pick
//! Zipf(s)-style instead of round-robin: a few hot tasks dominate while
//! the long tail arrives cold — the access pattern a byte-budget paged
//! bank cache (`serve --adapter-cache-mb`) is built for. During the run
//! a sampler thread polls `GET /metrics` and tracks the peak
//! `resident_bytes`, and the report windows the cache counters
//! (hits/misses/evictions/cold loads) over exactly this run; it all
//! serializes to `BENCH_cache.json` (schema v1, [`LoadReport::to_cache_json`]),
//! which the CI cache-pressure job validates (hit rate, budget ceiling,
//! zero errors).

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::serve::{CacheMetrics, Client};
use crate::tokenizer::Tokenizer;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::timer::Samples;

/// What to fire at the gateway.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    /// Gateway address (`host:port`).
    pub addr: String,
    /// Task mix, cycled round-robin; empty = every task the gateway lists.
    pub tasks: Vec<String>,
    /// Many-tasks preset: use the first N discovered tasks (errors if the
    /// gateway serves fewer). Ignored when `tasks` is non-empty.
    pub task_count: Option<usize>,
    /// Closed-loop worker threads (= open requests at any moment).
    pub concurrency: usize,
    /// Total request budget (0 = unlimited, stop on `duration`).
    pub requests: u64,
    /// Optional wall-clock cap.
    pub duration: Option<Duration>,
    /// Low-rate preset: pace the closed loop to ≈ this many req/s total
    /// (request `i` is not issued before `t0 + i/rate`). `None` = as
    /// fast as responses come back.
    pub rate: Option<f64>,
    /// Cache-pressure preset: pick tasks Zipf(s)-distributed (rank 0 =
    /// first task = hottest) instead of round-robin, so a byte-budget
    /// bank cache sees hot residents plus a cold long tail. `None` =
    /// round-robin.
    pub zipf: Option<f64>,
    /// Words of random text per request.
    pub words_per_request: usize,
    /// RNG seed for the request text.
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: String::new(),
            tasks: Vec::new(),
            task_count: None,
            concurrency: 4,
            requests: 200,
            duration: None,
            rate: None,
            zipf: None,
            words_per_request: 12,
            seed: 7,
        }
    }
}

/// Per-task slice of the report.
#[derive(Debug, Default, Clone)]
pub struct TaskLoad {
    pub requests: u64,
    pub errors: u64,
    pub latencies: Samples,
    /// `batch_size → count` as observed in responses (how many real rows
    /// rode in the batch that served each request).
    pub batch_sizes: BTreeMap<usize, u64>,
}

/// Server-side counters over the run window, from `GET /metrics` deltas
/// (absent when the gateway predates them or metrics were unreachable).
#[derive(Debug, Clone)]
pub struct ServerWindow {
    /// `per_task` | `fused`.
    pub exec_mode: String,
    /// Batches executed during the run.
    pub batches: f64,
    /// Of those, batches through the fused engine.
    pub fused_batches: f64,
    /// Sum of per-batch occupancy during the run.
    pub occupancy_sum: f64,
}

impl ServerWindow {
    /// Mean batch occupancy over the run window, in `[0, 1]`.
    pub fn mean_occupancy(&self) -> f64 {
        if self.batches <= 0.0 {
            0.0
        } else {
            self.occupancy_sum / self.batches
        }
    }
}

/// Paged-bank-cache state over the run window, from the `cache` section
/// of `GET /metrics`: counters are before/after deltas, residency is the
/// final state plus the peak seen by the in-run sampler thread. Absent
/// when the gateway predates the cache section.
#[derive(Debug, Clone)]
pub struct CacheWindow {
    /// Byte budget; `None` = unbounded cache.
    pub budget_bytes: Option<u64>,
    /// Tasks in the coordinator directory at the end of the run.
    pub registered: u64,
    /// Banks resident at the end of the run.
    pub resident: u64,
    pub resident_bytes: u64,
    /// Peak `resident_bytes` observed (sampler polls + final state) —
    /// the number the CI job checks against the budget.
    pub max_resident_bytes: u64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub cold_loads: u64,
    pub load_errors: u64,
    /// Server-lifetime cold-load p95 (the reservoir isn't windowed).
    pub cold_load_p95_ms: f64,
}

impl CacheWindow {
    /// Fraction of lookups over the window served without a cold load.
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// The whole run.
#[derive(Debug)]
pub struct LoadReport {
    /// Resolved task mix (after discovery).
    pub tasks: Vec<String>,
    pub wall_s: f64,
    pub requests: u64,
    pub errors: u64,
    pub per_task: BTreeMap<String, TaskLoad>,
    /// All successful request latencies.
    pub all: Samples,
    /// Aggregate `batch_size → count` across tasks.
    pub batch_size_hist: BTreeMap<usize, u64>,
    /// Server-side occupancy/mode over the run window.
    pub server: Option<ServerWindow>,
    /// Paged-bank-cache window (gateways with the `cache` metrics section).
    pub cache: Option<CacheWindow>,
}

impl LoadReport {
    pub fn throughput_rps(&self) -> f64 {
        if self.wall_s <= 0.0 {
            0.0
        } else {
            self.requests as f64 / self.wall_s
        }
    }

    /// The `BENCH_serve.json` document, schema v2 (see `write_report`).
    /// v2 adds `config.rate_rps`, `totals.batch_size_hist` and the
    /// `server` section (exec mode + occupancy over the run window).
    pub fn to_json(&self, cfg: &LoadgenConfig) -> Json {
        let per_task = Json::Obj(
            self.per_task
                .iter()
                .map(|(task, t)| {
                    (
                        task.clone(),
                        Json::obj(vec![
                            ("requests", Json::num(t.requests as f64)),
                            ("errors", Json::num(t.errors as f64)),
                            ("latency_ms", latency_json(&t.latencies)),
                        ]),
                    )
                })
                .collect(),
        );
        let server = match &self.server {
            Some(w) => Json::obj(vec![
                ("exec_mode", Json::str(&w.exec_mode)),
                ("batches", Json::num(w.batches)),
                ("fused_batches", Json::num(w.fused_batches)),
                ("mean_occupancy", Json::num(w.mean_occupancy())),
            ]),
            None => Json::Null,
        };
        Json::obj(vec![
            ("bench", Json::str("serve")),
            ("schema_version", Json::num(2.0)),
            (
                "config",
                Json::obj(vec![
                    ("concurrency", Json::num(cfg.concurrency as f64)),
                    ("requests", Json::num(cfg.requests as f64)),
                    (
                        "duration_s",
                        cfg.duration
                            .map(|d| Json::num(d.as_secs_f64()))
                            .unwrap_or(Json::Null),
                    ),
                    (
                        "rate_rps",
                        cfg.rate.map(Json::num).unwrap_or(Json::Null),
                    ),
                    ("words_per_request", Json::num(cfg.words_per_request as f64)),
                    (
                        "tasks",
                        Json::arr(self.tasks.iter().map(|t| Json::str(t))),
                    ),
                ]),
            ),
            (
                "totals",
                Json::obj(vec![
                    ("requests", Json::num(self.requests as f64)),
                    ("errors", Json::num(self.errors as f64)),
                    ("wall_s", Json::num(self.wall_s)),
                    ("throughput_rps", Json::num(self.throughput_rps())),
                    ("latency_ms", latency_json(&self.all)),
                    (
                        "batch_size_hist",
                        Json::Obj(
                            self.batch_size_hist
                                .iter()
                                .map(|(size, count)| {
                                    (size.to_string(), Json::num(*count as f64))
                                })
                                .collect(),
                        ),
                    ),
                ]),
            ),
            ("server", server),
            ("per_task", per_task),
        ])
    }

    /// The `BENCH_cache.json` document, schema v1: the cache-pressure
    /// run's totals plus the windowed cache counters and the peak
    /// residency the CI job pins against the byte budget. `cache` is
    /// `null` when the gateway exposed no cache section.
    pub fn to_cache_json(&self, cfg: &LoadgenConfig) -> Json {
        let cache = match &self.cache {
            Some(c) => Json::obj(vec![
                (
                    "budget_bytes",
                    c.budget_bytes.map(|b| Json::num(b as f64)).unwrap_or(Json::Null),
                ),
                ("registered", Json::num(c.registered as f64)),
                ("resident", Json::num(c.resident as f64)),
                ("resident_bytes", Json::num(c.resident_bytes as f64)),
                ("max_resident_bytes", Json::num(c.max_resident_bytes as f64)),
                ("hits", Json::num(c.hits as f64)),
                ("misses", Json::num(c.misses as f64)),
                ("hit_rate", Json::num(c.hit_rate())),
                ("evictions", Json::num(c.evictions as f64)),
                ("cold_loads", Json::num(c.cold_loads as f64)),
                ("load_errors", Json::num(c.load_errors as f64)),
                ("cold_load_p95_ms", Json::num(c.cold_load_p95_ms)),
            ]),
            None => Json::Null,
        };
        Json::obj(vec![
            ("bench", Json::str("cache")),
            ("schema_version", Json::num(1.0)),
            (
                "config",
                Json::obj(vec![
                    ("concurrency", Json::num(cfg.concurrency as f64)),
                    ("requests", Json::num(cfg.requests as f64)),
                    ("zipf", cfg.zipf.map(Json::num).unwrap_or(Json::Null)),
                    ("task_count", Json::num(self.tasks.len() as f64)),
                    ("words_per_request", Json::num(cfg.words_per_request as f64)),
                ]),
            ),
            (
                "totals",
                Json::obj(vec![
                    ("requests", Json::num(self.requests as f64)),
                    ("errors", Json::num(self.errors as f64)),
                    ("wall_s", Json::num(self.wall_s)),
                    ("throughput_rps", Json::num(self.throughput_rps())),
                    ("latency_ms", latency_json(&self.all)),
                ]),
            ),
            ("cache", cache),
        ])
    }
}

/// `{mean, p50, p95, p99, max}` in milliseconds (zeros when empty — JSON
/// has no NaN). Shared with the train-and-serve harness.
pub(crate) fn latency_json(s: &Samples) -> Json {
    let (mean, p50, p95, p99, max) = if s.is_empty() {
        (0.0, 0.0, 0.0, 0.0, 0.0)
    } else {
        (
            s.mean_s() * 1e3,
            s.pctl_s(50.0) * 1e3,
            s.pctl_s(95.0) * 1e3,
            s.pctl_s(99.0) * 1e3,
            s.pctl_s(100.0) * 1e3,
        )
    };
    Json::obj(vec![
        ("mean", Json::num(mean)),
        ("p50", Json::num(p50)),
        ("p95", Json::num(p95)),
        ("p99", Json::num(p99)),
        ("max", Json::num(max)),
    ])
}

/// Parse the `cache` section of a `GET /metrics` document (`None` when
/// missing — gateway predates the paged cache).
fn cache_counters(metrics: &Json) -> Option<CacheMetrics> {
    CacheMetrics::from_json(metrics.get("cache")?).ok()
}

/// Parse the server-side counters this harness windows over from a
/// `GET /metrics` document (`None` when the fields are missing).
fn server_counters(metrics: &Json) -> Option<(String, f64, f64, f64)> {
    let coord = metrics.get("coordinator")?;
    Some((
        metrics
            .get("exec_mode")
            .and_then(Json::as_str)
            .unwrap_or("per_task")
            .to_string(),
        coord.get("batches").and_then(Json::as_f64)?,
        coord.get("fused_batches").and_then(Json::as_f64).unwrap_or(0.0),
        coord.get("occupancy_sum").and_then(Json::as_f64)?,
    ))
}

/// Run the closed loop and aggregate.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadReport> {
    if cfg.requests == 0 && cfg.duration.is_none() {
        bail!("loadgen needs a request budget or a duration");
    }
    let mut probe = Client::connect(&cfg.addr)?;
    let health = probe.health().context("gateway health check")?;
    let tasks: Vec<String> = if cfg.tasks.is_empty() {
        let discovered: Vec<String> = probe
            .tasks()
            .context("task discovery")?
            .into_iter()
            .map(|t| t.task)
            .collect();
        match cfg.task_count {
            Some(n) => {
                if discovered.len() < n {
                    bail!(
                        "many-tasks preset wants {n} tasks but the gateway \
                         serves only {} ({discovered:?})",
                        discovered.len()
                    );
                }
                discovered.into_iter().take(n).collect()
            }
            None => discovered,
        }
    } else {
        cfg.tasks.clone()
    };
    if tasks.is_empty() {
        bail!("gateway serves no tasks and none were given");
    }
    // snapshot the server counters so the report windows occupancy (and
    // cache hits/misses/evictions) over exactly this run, not the
    // gateway's whole lifetime
    let before_doc = probe.metrics().ok();
    let before = before_doc.as_ref().and_then(server_counters);
    let cache_before = before_doc.as_ref().and_then(cache_counters);
    // close the discovery connection before the closed loop starts, so
    // the gateway's worker rotation only carries live load connections
    drop(probe);
    let tok = Tokenizer::new(health.vocab);
    let word_ids = health.vocab.saturating_sub(4).max(1);

    let issued = AtomicU64::new(0);
    let deadline = cfg.duration.map(|d| Instant::now() + d);
    // in-run residency sampler: the budget invariant is about *peak*
    // memory, which before/after snapshots can't see
    let stop_sampler = AtomicBool::new(false);
    let max_resident = AtomicU64::new(0);
    let t0 = Instant::now();
    let mut worker_stats: Vec<Result<BTreeMap<String, TaskLoad>>> = Vec::new();
    std::thread::scope(|scope| {
        let sampler = cache_before.is_some().then(|| {
            let (stop, peak, addr) = (&stop_sampler, &max_resident, &cfg.addr);
            scope.spawn(move || {
                let Ok(mut c) = Client::connect(addr) else { return };
                while !stop.load(Ordering::Relaxed) {
                    match c.metrics() {
                        Ok(m) => {
                            if let Some(cm) = cache_counters(&m) {
                                peak.fetch_max(cm.resident_bytes, Ordering::Relaxed);
                            }
                        }
                        Err(_) => {
                            let _ = c.reconnect();
                        }
                    }
                    std::thread::sleep(Duration::from_millis(100));
                }
            })
        });
        let mut handles = Vec::new();
        for w in 0..cfg.concurrency.max(1) {
            let tasks = &tasks;
            let tok = &tok;
            let issued = &issued;
            handles.push(scope.spawn(move || {
                worker_loop(cfg, w as u64, tasks, tok, word_ids, issued, deadline, t0)
            }));
        }
        for h in handles {
            worker_stats.push(match h.join() {
                Ok(r) => r,
                Err(_) => Err(anyhow::anyhow!("loadgen worker panicked")),
            });
        }
        stop_sampler.store(true, Ordering::Relaxed);
        if let Some(s) = sampler {
            let _ = s.join();
        }
    });

    let wall_s = t0.elapsed().as_secs_f64();
    let after_doc = Client::connect(&cfg.addr)
        .ok()
        .and_then(|mut c| c.metrics().ok());
    let server = match (before, after_doc.as_ref().and_then(server_counters)) {
        (Some((mode, b0, f0, o0)), Some((_, b1, f1, o1))) => Some(ServerWindow {
            exec_mode: mode,
            batches: (b1 - b0).max(0.0),
            fused_batches: (f1 - f0).max(0.0),
            occupancy_sum: (o1 - o0).max(0.0),
        }),
        _ => None,
    };
    let cache = match (cache_before, after_doc.as_ref().and_then(cache_counters)) {
        (Some(b), Some(a)) => Some(CacheWindow {
            budget_bytes: a.budget_bytes,
            registered: a.registered as u64,
            resident: a.resident as u64,
            resident_bytes: a.resident_bytes,
            max_resident_bytes: max_resident
                .load(Ordering::Relaxed)
                .max(a.resident_bytes),
            hits: a.hits.saturating_sub(b.hits),
            misses: a.misses.saturating_sub(b.misses),
            evictions: a.evictions.saturating_sub(b.evictions),
            cold_loads: a.cold_loads.saturating_sub(b.cold_loads),
            load_errors: a.load_errors.saturating_sub(b.load_errors),
            cold_load_p95_ms: a.cold_load_p95_ms,
        }),
        _ => None,
    };
    let mut per_task: BTreeMap<String, TaskLoad> = BTreeMap::new();
    for stats in worker_stats {
        for (task, t) in stats? {
            let agg = per_task.entry(task).or_default();
            agg.requests += t.requests;
            agg.errors += t.errors;
            agg.latencies.durs.extend(t.latencies.durs);
            for (size, count) in t.batch_sizes {
                *agg.batch_sizes.entry(size).or_insert(0) += count;
            }
        }
    }
    let mut all = Samples::default();
    let mut requests = 0;
    let mut errors = 0;
    let mut batch_size_hist: BTreeMap<usize, u64> = BTreeMap::new();
    for t in per_task.values() {
        requests += t.requests;
        errors += t.errors;
        all.durs.extend(t.latencies.durs.iter().copied());
        for (size, count) in &t.batch_sizes {
            *batch_size_hist.entry(*size).or_insert(0) += count;
        }
    }
    Ok(LoadReport {
        tasks,
        wall_s,
        requests,
        errors,
        per_task,
        all,
        batch_size_hist,
        server,
        cache,
    })
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    cfg: &LoadgenConfig,
    worker: u64,
    tasks: &[String],
    tok: &Tokenizer,
    word_ids: usize,
    issued: &AtomicU64,
    deadline: Option<Instant>,
    t0: Instant,
) -> Result<BTreeMap<String, TaskLoad>> {
    let mut client = Client::connect(&cfg.addr)?;
    let mut rng = Rng::new(cfg.seed ^ (worker.wrapping_mul(0x9E37_79B9)));
    let mut stats: BTreeMap<String, TaskLoad> = BTreeMap::new();
    let mut consecutive_errors = 0usize;
    loop {
        let i = issued.fetch_add(1, Ordering::Relaxed);
        if cfg.requests > 0 && i >= cfg.requests {
            break;
        }
        if let Some(d) = deadline {
            if Instant::now() >= d {
                break;
            }
        }
        // low-rate pacing: request i is not issued before t0 + i/rate
        if let Some(rate) = cfg.rate {
            let slot = t0 + Duration::from_secs_f64(i as f64 / rate);
            let now = Instant::now();
            if slot > now {
                std::thread::sleep(slot - now);
            }
        }
        // cache-pressure preset: Zipf-skewed pick (rank 0 hottest);
        // default is round-robin
        let task = match cfg.zipf {
            Some(s) => &tasks[rng.zipf(tasks.len(), s)],
            None => &tasks[(i as usize) % tasks.len()],
        };
        let words: Vec<&str> = (0..cfg.words_per_request.max(1))
            .map(|_| tok.word(4 + rng.below(word_ids) as i32))
            .collect();
        let text = words.join(" ");
        let t_req = Instant::now();
        let entry = stats.entry(task.clone()).or_default();
        match client.predict_text(task, &text) {
            Ok(resp) => {
                entry.requests += 1;
                entry.latencies.record(t_req.elapsed());
                *entry.batch_sizes.entry(resp.batch_size).or_insert(0) += 1;
                consecutive_errors = 0;
            }
            Err(e) => {
                entry.errors += 1;
                consecutive_errors += 1;
                if consecutive_errors > 50 {
                    return Err(e).context("worker giving up after 50 straight errors");
                }
                // connection may be poisoned (timeout mid-response); redial
                let _ = client.reconnect();
            }
        }
    }
    Ok(stats)
}

/// Atomically (write + rename) persist the report document.
pub fn write_report(path: &Path, report: &Json) -> Result<()> {
    let tmp = path.with_extension("json.tmp");
    std::fs::write(&tmp, format!("{report}\n"))
        .with_context(|| format!("writing {tmp:?}"))?;
    std::fs::rename(&tmp, path).with_context(|| format!("renaming into {path:?}"))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_schema() {
        let mut per_task = BTreeMap::new();
        let mut lat = Samples::default();
        lat.record(Duration::from_millis(3));
        let mut batch_sizes = BTreeMap::new();
        batch_sizes.insert(3usize, 10u64);
        per_task.insert(
            "rte_s".to_string(),
            TaskLoad { requests: 10, errors: 0, latencies: lat, batch_sizes },
        );
        let mut all = Samples::default();
        all.record(Duration::from_millis(3));
        let mut hist = BTreeMap::new();
        hist.insert(3usize, 10u64);
        let report = LoadReport {
            tasks: vec!["rte_s".into()],
            wall_s: 0.5,
            requests: 10,
            errors: 0,
            per_task,
            all,
            batch_size_hist: hist,
            server: Some(ServerWindow {
                exec_mode: "fused".into(),
                batches: 4.0,
                fused_batches: 4.0,
                occupancy_sum: 3.0,
            }),
            cache: None,
        };
        let cfg = LoadgenConfig {
            addr: "x".into(),
            rate: Some(50.0),
            ..Default::default()
        };
        let j = report.to_json(&cfg);
        // must re-parse as valid JSON with the pinned schema fields
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(back.at("bench").as_str(), Some("serve"));
        assert_eq!(back.at("schema_version").as_usize(), Some(2));
        assert_eq!(back.at("config").at("rate_rps").as_f64(), Some(50.0));
        assert_eq!(back.at("totals").at("requests").as_usize(), Some(10));
        assert!(back.at("totals").at("throughput_rps").as_f64().unwrap() > 0.0);
        assert_eq!(
            back.at("totals").at("batch_size_hist").at("3").as_usize(),
            Some(10)
        );
        assert_eq!(back.at("server").at("exec_mode").as_str(), Some("fused"));
        assert_eq!(back.at("server").at("mean_occupancy").as_f64(), Some(0.75));
        assert_eq!(back.at("server").at("fused_batches").as_usize(), Some(4));
        let lt = back.at("per_task").at("rte_s").at("latency_ms");
        for key in ["mean", "p50", "p95", "p99", "max"] {
            assert!(lt.at(key).as_f64().is_some(), "missing {key}");
        }
    }

    #[test]
    fn report_without_server_window_emits_null() {
        let report = LoadReport {
            tasks: vec![],
            wall_s: 0.0,
            requests: 0,
            errors: 0,
            per_task: BTreeMap::new(),
            all: Samples::default(),
            batch_size_hist: BTreeMap::new(),
            server: None,
            cache: None,
        };
        let cfg = LoadgenConfig { addr: "x".into(), ..Default::default() };
        let back = Json::parse(&report.to_json(&cfg).to_string()).unwrap();
        assert_eq!(back.at("server"), &Json::Null);
        assert_eq!(back.at("config").at("rate_rps"), &Json::Null);
        // the cache document degrades the same way
        let back = Json::parse(&report.to_cache_json(&cfg).to_string()).unwrap();
        assert_eq!(back.at("cache"), &Json::Null);
        assert_eq!(back.at("config").at("zipf"), &Json::Null);
    }

    #[test]
    fn cache_report_json_schema() {
        let mut all = Samples::default();
        all.record(Duration::from_millis(2));
        let report = LoadReport {
            tasks: (0..64).map(|i| format!("syn_{i:03}")).collect(),
            wall_s: 1.0,
            requests: 400,
            errors: 0,
            per_task: BTreeMap::new(),
            all,
            batch_size_hist: BTreeMap::new(),
            server: None,
            cache: Some(CacheWindow {
                budget_bytes: Some(1 << 20),
                registered: 64,
                resident: 8,
                resident_bytes: 900_000,
                max_resident_bytes: 1_000_000,
                hits: 300,
                misses: 100,
                evictions: 92,
                cold_loads: 100,
                load_errors: 0,
                cold_load_p95_ms: 7.5,
            }),
        };
        let cfg = LoadgenConfig {
            addr: "x".into(),
            requests: 400,
            zipf: Some(1.2),
            ..Default::default()
        };
        let j = report.to_cache_json(&cfg);
        let back = Json::parse(&j.to_string()).unwrap();
        // pinned schema: the CI cache-pressure job reads these fields
        assert_eq!(back.at("bench").as_str(), Some("cache"));
        assert_eq!(back.at("schema_version").as_usize(), Some(1));
        assert_eq!(back.at("config").at("zipf").as_f64(), Some(1.2));
        assert_eq!(back.at("config").at("task_count").as_usize(), Some(64));
        assert_eq!(back.at("totals").at("requests").as_usize(), Some(400));
        assert_eq!(back.at("totals").at("errors").as_usize(), Some(0));
        let c = back.at("cache");
        assert_eq!(c.at("budget_bytes").as_usize(), Some(1 << 20));
        assert_eq!(c.at("max_resident_bytes").as_usize(), Some(1_000_000));
        assert_eq!(c.at("registered").as_usize(), Some(64));
        assert_eq!(c.at("resident").as_usize(), Some(8));
        assert_eq!(c.at("evictions").as_usize(), Some(92));
        assert_eq!(c.at("hit_rate").as_f64(), Some(0.75));
        assert!(c.at("cold_load_p95_ms").as_f64().is_some());
        // unbounded cache → budget_bytes null
        let mut unbounded = report;
        if let Some(cw) = unbounded.cache.as_mut() {
            cw.budget_bytes = None;
        }
        let back =
            Json::parse(&unbounded.to_cache_json(&cfg).to_string()).unwrap();
        assert_eq!(back.at("cache").at("budget_bytes"), &Json::Null);
    }

    #[test]
    fn server_counters_parses_metrics_document() {
        let j = Json::parse(
            r#"{"exec_mode":"fused",
                "coordinator":{"batches":7,"fused_batches":5,
                               "occupancy_sum":4.5,"requests":30}}"#,
        )
        .unwrap();
        let (mode, b, f, o) = server_counters(&j).unwrap();
        assert_eq!(mode, "fused");
        assert_eq!(b, 7.0);
        assert_eq!(f, 5.0);
        assert_eq!(o, 4.5);
        // missing occupancy_sum (older gateway) → None
        let j = Json::parse(r#"{"coordinator":{"batches":7}}"#).unwrap();
        assert!(server_counters(&j).is_none());
    }

    #[test]
    fn empty_latency_emits_zeros_not_nan() {
        let j = latency_json(&Samples::default());
        let s = j.to_string();
        assert!(!s.contains("NaN"), "{s}");
        assert_eq!(j.at("p99").as_f64(), Some(0.0));
    }

    #[test]
    fn run_requires_a_stop_condition() {
        let cfg = LoadgenConfig {
            addr: "127.0.0.1:1".into(),
            requests: 0,
            duration: None,
            ..Default::default()
        };
        assert!(run(&cfg).is_err());
    }
}
