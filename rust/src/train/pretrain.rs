//! MLM pre-training: produces the repo's "pre-trained BERT" (ARCHITECTURE.md).
//!
//! Drives the `pretrain_step` artifact over the synthetic topic corpus and
//! checkpoints the resulting base parameters; every downstream experiment
//! loads that checkpoint as its frozen base. The loss curve is returned so
//! the end-to-end example can log it (and EXPERIMENTS.md records it).

use std::path::Path;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::data::grammar::{CorpusSampler, World};
use crate::model::init;
use crate::model::params::NamedTensors;
use crate::runtime::{Bank, Runtime};
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;

#[derive(Debug, Clone)]
pub struct PretrainConfig {
    pub steps: usize,
    pub lr: f64,
    pub warmup_frac: f64,
    pub seed: u64,
    /// log every n steps (0 = silent)
    pub log_every: usize,
}

impl Default for PretrainConfig {
    fn default() -> Self {
        PretrainConfig { steps: 600, lr: 1e-3, warmup_frac: 0.1, seed: 0, log_every: 50 }
    }
}

#[derive(Debug)]
pub struct PretrainResult {
    pub base: NamedTensors,
    /// (step, loss) samples
    pub loss_curve: Vec<(usize, f64)>,
    pub final_loss: f64,
    pub initial_loss: f64,
}

/// Run MLM pre-training from random init.
pub fn pretrain(
    rt: &Arc<Runtime>,
    world: &World,
    cfg: &PretrainConfig,
) -> Result<PretrainResult> {
    let exe = rt.load("pretrain_step")?;
    let spec = exe.spec.clone();
    let dims = rt.manifest.dims.clone();
    let batch = spec.batch;

    let base_named = init::init_group(&spec, "base", cfg.seed, 1e-2)?;
    let mut base: Bank = base_named.to_bank(&spec, "base")?;
    let zeros = |b: &Bank| -> Bank {
        b.iter().map(|t| Tensor::zeros(&t.shape, t.dtype())).collect()
    };
    let mut opt_m = zeros(&base);
    let mut opt_v = zeros(&base);

    let sampler = CorpusSampler::new(world.clone());
    let mut rng = Rng::new(cfg.seed ^ 0xC0DE);
    let mut curve = Vec::new();
    let mut initial_loss = f64::NAN;
    let mut final_loss = f64::NAN;

    for step in 0..cfg.steps {
        // assemble a batch of MLM examples
        let p = dims.mlm_positions;
        let mut tokens = Vec::with_capacity(batch * dims.seq);
        let mut positions = Vec::with_capacity(batch * p);
        let mut targets = Vec::with_capacity(batch * p);
        let mut weights = Vec::with_capacity(batch * p);
        for _ in 0..batch {
            let (t, pos, tgt, w) = sampler.mlm_example(&mut rng, dims.seq, p);
            tokens.extend(t);
            positions.extend(pos);
            targets.extend(tgt);
            weights.extend(w);
        }
        let lr = super::r#loop::lr_at(step, cfg.steps, cfg.lr, cfg.warmup_frac);
        let banks: Vec<Bank> = vec![
            vec![Tensor::scalar_i32(step as i32 + 1)],
            vec![Tensor::i32(vec![batch, dims.seq], tokens)],
            vec![Tensor::i32(vec![batch, dims.seq], vec![0; batch * dims.seq])],
            vec![Tensor::full_f32(&[batch, dims.seq], 1.0)],
            vec![Tensor::i32(vec![batch, p], positions)],
            vec![Tensor::i32(vec![batch, p], targets)],
            vec![Tensor::f32(vec![batch, p], weights)],
            vec![Tensor::scalar_f32(lr as f32)],
        ];
        let all: Vec<&Bank> = std::iter::once(&base)
            .chain([&opt_m, &opt_v])
            .chain(banks.iter())
            .collect();
        let mut out = exe.run(&all).context("pretrain step")?;
        let loss = out.pop().unwrap()[0].scalar_value_f32() as f64;
        opt_v = out.pop().unwrap();
        opt_m = out.pop().unwrap();
        base = out.pop().unwrap();
        if step == 0 {
            initial_loss = loss;
        }
        final_loss = loss;
        if cfg.log_every > 0 && (step % cfg.log_every == 0 || step + 1 == cfg.steps) {
            crate::log_info!(
                "pretrain",
                "step {step:5}  lr {lr:.2e}  mlm loss {loss:.4}"
            );
            curve.push((step, loss));
        } else if step % 10 == 0 {
            curve.push((step, loss));
        }
    }

    Ok(PretrainResult {
        base: NamedTensors::from_bank(&spec, "base", &base)?,
        loss_curve: curve,
        final_loss,
        initial_loss,
    })
}

/// Checkpoint helpers: the shared base lives beside the run artifacts.
/// Writes go through a temp file + rename so concurrent readers (parallel
/// test binaries sharing one checkpoint) never observe a partial file.
pub fn save_base(base: &NamedTensors, path: &Path) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let tmp = path.with_extension(format!("tmp{}", std::process::id()));
    std::fs::write(&tmp, base.to_bytes()).with_context(|| format!("writing {tmp:?}"))?;
    std::fs::rename(&tmp, path).with_context(|| format!("renaming into {path:?}"))
}

pub fn load_base(path: &Path) -> Result<NamedTensors> {
    let buf =
        std::fs::read(path).with_context(|| format!("reading base ckpt {path:?}"))?;
    NamedTensors::from_bytes(&buf)
}

/// Load the checkpoint at `path`, or pre-train + save it if absent.
pub fn load_or_pretrain(
    rt: &Arc<Runtime>,
    world: &World,
    cfg: &PretrainConfig,
    path: &Path,
) -> Result<NamedTensors> {
    if path.exists() {
        let base = load_base(path)?;
        crate::log_info!(
            "pretrain",
            "loaded pre-trained base from {path:?} ({} tensors)",
            base.len()
        );
        return Ok(base);
    }
    crate::log_info!("pretrain", "pre-training base ({} steps)…", cfg.steps);
    let res = pretrain(rt, world, cfg)?;
    crate::log_info!(
        "pretrain",
        "pre-training done: mlm loss {:.3} → {:.3}",
        res.initial_loss,
        res.final_loss
    );
    save_base(&res.base, path)?;
    Ok(res.base)
}
