//! Hand-written CPU kernels for the native backend.
//!
//! These mirror `python/compile/kernels/ref.py` — the repo's correctness
//! ground truth — including the tanh-form GELU, `-1e9` masking (not
//! `-inf`), and the `eps` placement in LayerNorm. Each differentiable op
//! comes with its hand-derived backward pass; the whole set was validated
//! against `jax.grad` of the reference model to machine precision before
//! being transcribed here (see `graph.rs` module docs).
//!
//! Everything is plain `f32` on row-major slices, single-threaded and
//! allocation-simple: at reproduction scale (d ≤ 64) the matmuls
//! autovectorize well and determinism matters more than peak FLOPs —
//! `train_task` must be bitwise reproducible per seed.

/// `sqrt(2/π)` for the tanh-form GELU.
pub const SQRT_2_OVER_PI: f32 = 0.797_884_6;
/// Additive mask value for padded keys/classes (matches the jnp reference).
pub const NEG: f32 = -1e9;

/// `out[n,m] = a[n,k] @ b[k,m]`.
pub fn matmul(a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), k * m);
    let mut out = vec![0.0f32; n * m];
    for i in 0..n {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * m..(i + 1) * m];
        for (kk, &av) in arow.iter().enumerate() {
            let brow = &b[kk * m..(kk + 1) * m];
            for j in 0..m {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// `out[k,m] = a[n,k]ᵀ @ b[n,m]` (gradient of weights: `xᵀ·dy`).
pub fn matmul_tn(a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), n * m);
    let mut out = vec![0.0f32; k * m];
    for i in 0..n {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * m..(i + 1) * m];
        for (kk, &av) in arow.iter().enumerate() {
            let orow = &mut out[kk * m..(kk + 1) * m];
            for j in 0..m {
                orow[j] += av * brow[j];
            }
        }
    }
    out
}

/// `out[n,m] = a[n,k] @ b[m,k]ᵀ` (gradient of inputs: `dy·Wᵀ`).
pub fn matmul_nt(a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), m * k);
    let mut out = vec![0.0f32; n * m];
    for i in 0..n {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * m..(i + 1) * m];
        for (j, ov) in orow.iter_mut().enumerate() {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += arow[kk] * brow[kk];
            }
            *ov = acc;
        }
    }
    out
}

/// `x[n,m] += bias[m]` broadcast over rows.
pub fn add_bias(x: &mut [f32], bias: &[f32]) {
    let m = bias.len();
    for row in x.chunks_exact_mut(m) {
        for (v, b) in row.iter_mut().zip(bias) {
            *v += b;
        }
    }
}

/// `x @ w + b` for `x[n,k]`, `w[k,m]`, `b[m]`.
pub fn linear(x: &[f32], w: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
    let mut out = matmul(x, w, n, k, m);
    add_bias(&mut out, b);
    out
}

/// Column sums of `x[n,m]` (bias gradients).
pub fn col_sums(x: &[f32], m: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m];
    for row in x.chunks_exact(m) {
        for (o, v) in out.iter_mut().zip(row) {
            *o += v;
        }
    }
    out
}

/// Element-wise `a += b`.
pub fn add_assign(a: &mut [f32], b: &[f32]) {
    debug_assert_eq!(a.len(), b.len());
    for (x, y) in a.iter_mut().zip(b) {
        *x += y;
    }
}

/// tanh-approximation GELU (the BERT variant; matches `ref.gelu`).
pub fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + 0.044715 * x * x * x)).tanh())
}

/// `d gelu(x) / dx`.
pub fn gelu_grad(x: f32) -> f32 {
    let u = SQRT_2_OVER_PI * (x + 0.044715 * x * x * x);
    let t = u.tanh();
    0.5 * (1.0 + t)
        + 0.5 * x * (1.0 - t * t) * SQRT_2_OVER_PI * (1.0 + 3.0 * 0.044715 * x * x)
}

/// Element-wise GELU over a slice.
pub fn gelu_vec(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| gelu(v)).collect()
}

/// Saved activations of one LayerNorm application (enough for backward).
pub struct LnTape {
    /// Normalized input `(x - μ)·rstd`, row-major.
    pub xhat: Vec<f32>,
    /// Per-row `1/√(σ² + eps)`.
    pub rstd: Vec<f32>,
}

/// Row-wise LayerNorm over the last dim: `y = x̂·γ + β` (matches
/// `ref.layernorm_ref`).
pub fn ln_fwd(x: &[f32], gamma: &[f32], beta: &[f32], d: usize, eps: f32) -> (Vec<f32>, LnTape) {
    let rows = x.len() / d;
    let mut y = vec![0.0f32; x.len()];
    let mut xhat = vec![0.0f32; x.len()];
    let mut rstd = vec![0.0f32; rows];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mu = xr.iter().sum::<f32>() / d as f32;
        let var = xr.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let rs = 1.0 / (var + eps).sqrt();
        rstd[r] = rs;
        for j in 0..d {
            let xh = (xr[j] - mu) * rs;
            xhat[r * d + j] = xh;
            y[r * d + j] = xh * gamma[j] + beta[j];
        }
    }
    (y, LnTape { xhat, rstd })
}

/// LayerNorm backward: returns `dx` and accumulates `dγ`/`dβ`.
pub fn ln_bwd(
    dy: &[f32],
    tape: &LnTape,
    gamma: &[f32],
    d: usize,
    dgamma: &mut [f32],
    dbeta: &mut [f32],
) -> Vec<f32> {
    let rows = dy.len() / d;
    let mut dx = vec![0.0f32; dy.len()];
    for r in 0..rows {
        let dyr = &dy[r * d..(r + 1) * d];
        let xhr = &tape.xhat[r * d..(r + 1) * d];
        let rs = tape.rstd[r];
        let mut m1 = 0.0f32; // mean of dŷ = dy·γ
        let mut m2 = 0.0f32; // mean of dŷ·x̂
        for j in 0..d {
            let dxh = dyr[j] * gamma[j];
            m1 += dxh;
            m2 += dxh * xhr[j];
            dgamma[j] += dyr[j] * xhr[j];
            dbeta[j] += dyr[j];
        }
        m1 /= d as f32;
        m2 /= d as f32;
        for j in 0..d {
            let dxh = dyr[j] * gamma[j];
            dx[r * d + j] = rs * (dxh - m1 - xhr[j] * m2);
        }
    }
    dx
}

/// Multi-head scaled-dot-product attention forward over already-projected
/// `q`/`k`/`v` (each `[b*s, d]` with heads packed along `d`): returns
/// `(probs [b, h, s, s], ctx [b*s, d])`. Shared by the per-task encoder
/// and the fused multi-task path, so both run bit-identical float ops.
#[allow(clippy::too_many_arguments)]
pub fn attention_fwd(
    q: &[f32],
    kt: &[f32],
    v: &[f32],
    mask: &[f32],
    b: usize,
    s: usize,
    d: usize,
    h: usize,
    dh: usize,
) -> (Vec<f32>, Vec<f32>) {
    let alpha = 1.0 / (dh as f32).sqrt();
    let mut probs = vec![0.0f32; b * h * s * s];
    let mut ctx = vec![0.0f32; b * s * d];
    for bi in 0..b {
        for hi in 0..h {
            let pbase = (bi * h + hi) * s * s;
            for si in 0..s {
                let qrow = &q[(bi * s + si) * d + hi * dh..][..dh];
                let prow = &mut probs[pbase + si * s..][..s];
                for (ti, pv) in prow.iter_mut().enumerate() {
                    *pv = if mask[bi * s + ti] > 0.0 {
                        let krow = &kt[(bi * s + ti) * d + hi * dh..][..dh];
                        let mut acc = 0.0f32;
                        for j in 0..dh {
                            acc += qrow[j] * krow[j];
                        }
                        alpha * acc
                    } else {
                        NEG
                    };
                }
            }
            softmax_rows(&mut probs[pbase..pbase + s * s], s);
            for si in 0..s {
                let prow = &probs[pbase + si * s..][..s];
                for ti in 0..s {
                    let pv = prow[ti];
                    if pv != 0.0 {
                        let vrow = &v[(bi * s + ti) * d + hi * dh..][..dh];
                        let crow = &mut ctx[(bi * s + si) * d + hi * dh..][..dh];
                        for j in 0..dh {
                            crow[j] += pv * vrow[j];
                        }
                    }
                }
            }
        }
    }
    (probs, ctx)
}

/// Forward-only attention: same math as [`attention_fwd`] (row-for-row
/// identical ops) but without materializing the `[b, h, s, s]` probs
/// tensor — only one `[s]` scratch row is live at a time. This is the
/// serving hot path (no backward tape needed); `attention_fwd` remains
/// for the training path, which tapes probs.
#[allow(clippy::too_many_arguments)]
pub fn attention_ctx(
    q: &[f32],
    kt: &[f32],
    v: &[f32],
    mask: &[f32],
    b: usize,
    s: usize,
    d: usize,
    h: usize,
    dh: usize,
) -> Vec<f32> {
    let alpha = 1.0 / (dh as f32).sqrt();
    let mut ctx = vec![0.0f32; b * s * d];
    let mut row = vec![0.0f32; s];
    for bi in 0..b {
        for hi in 0..h {
            for si in 0..s {
                let qrow = &q[(bi * s + si) * d + hi * dh..][..dh];
                for (ti, pv) in row.iter_mut().enumerate() {
                    *pv = if mask[bi * s + ti] > 0.0 {
                        let krow = &kt[(bi * s + ti) * d + hi * dh..][..dh];
                        let mut acc = 0.0f32;
                        for j in 0..dh {
                            acc += qrow[j] * krow[j];
                        }
                        alpha * acc
                    } else {
                        NEG
                    };
                }
                softmax_rows(&mut row, s);
                for ti in 0..s {
                    let pv = row[ti];
                    if pv != 0.0 {
                        let vrow = &v[(bi * s + ti) * d + hi * dh..][..dh];
                        let crow = &mut ctx[(bi * s + si) * d + hi * dh..][..dh];
                        for j in 0..dh {
                            crow[j] += pv * vrow[j];
                        }
                    }
                }
            }
        }
    }
    ctx
}

/// LayerNorm forward without a tape (serving path — no backward needed).
/// Same math as [`ln_fwd`].
pub fn ln_apply(x: &[f32], gamma: &[f32], beta: &[f32], d: usize, eps: f32) -> Vec<f32> {
    let rows = x.len() / d;
    let mut y = vec![0.0f32; x.len()];
    for r in 0..rows {
        let xr = &x[r * d..(r + 1) * d];
        let mu = xr.iter().sum::<f32>() / d as f32;
        let var = xr.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
        let rs = 1.0 / (var + eps).sqrt();
        for j in 0..d {
            y[r * d + j] = (xr[j] - mu) * rs * gamma[j] + beta[j];
        }
    }
    y
}

/// Segmented LayerNorm: `x[rows, d]` is split into contiguous row
/// segments, each normalized with its **own** `γ`/`β` — the per-task LN
/// gather of the fused multi-task path. `segs` entries are
/// `(row_count, gamma, beta)`; row counts must sum to `rows`.
pub fn segment_ln(
    x: &[f32],
    d: usize,
    eps: f32,
    segs: &[(usize, &[f32], &[f32])],
) -> Vec<f32> {
    let mut y = Vec::with_capacity(x.len());
    let mut row0 = 0usize;
    for &(rows, gamma, beta) in segs {
        let xs = &x[row0 * d..(row0 + rows) * d];
        y.extend(ln_apply(xs, gamma, beta, d, eps));
        row0 += rows;
    }
    debug_assert_eq!(row0 * d, x.len());
    y
}

/// In-place numerically stable softmax over each row of `x[rows, cols]`.
pub fn softmax_rows(x: &mut [f32], cols: usize) {
    for row in x.chunks_exact_mut(cols) {
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        for v in row.iter_mut() {
            *v /= sum;
        }
    }
}

/// `log(Σ exp(row))` of one row, numerically stable.
pub fn log_sum_exp(row: &[f32]) -> f32 {
    let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    max + row.iter().map(|v| (v - max).exp()).sum::<f32>().ln()
}

/// Index of the first maximum (ties break low, like `jnp.argmax`).
pub fn argmax(row: &[f32]) -> usize {
    let mut best = 0usize;
    for (i, &v) in row.iter().enumerate() {
        if v > row[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: f32, b: f32, tol: f32) {
        assert!((a - b).abs() <= tol, "{a} vs {b}");
    }

    #[test]
    fn matmul_identity_and_transposes() {
        // a = [[1,2],[3,4]], b = I
        let a = vec![1.0, 2.0, 3.0, 4.0];
        let eye = vec![1.0, 0.0, 0.0, 1.0];
        assert_eq!(matmul(&a, &eye, 2, 2, 2), a);
        // aᵀ·I = aᵀ
        assert_eq!(matmul_tn(&a, &eye, 2, 2, 2), vec![1.0, 3.0, 2.0, 4.0]);
        // a·Iᵀ = a
        assert_eq!(matmul_nt(&a, &eye, 2, 2, 2), a);
        // rectangular sanity: [1,3]x[3,1]
        let r = matmul(&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0], 1, 3, 1);
        assert_eq!(r, vec![32.0]);
    }

    #[test]
    fn gelu_reference_values() {
        assert_eq!(gelu(0.0), 0.0);
        assert_close(gelu(1.0), 0.8412, 1e-3);
        assert_close(gelu(-1.0), -0.1588, 1e-3);
        // gelu is odd about a shift: gelu(x) - x·1 ≈ gelu(-x) for large |x|
        assert_close(gelu(6.0), 6.0, 1e-4);
    }

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for &x in &[-2.0f32, -0.5, 0.0, 0.3, 1.7] {
            let h = 1e-3;
            let fd = (gelu(x + h) - gelu(x - h)) / (2.0 * h);
            assert_close(gelu_grad(x), fd, 1e-3);
        }
    }

    #[test]
    fn layernorm_normalizes_and_applies_affine() {
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let g = vec![1.0, 1.0, 1.0, 1.0];
        let b = vec![0.5, 0.5, 0.5, 0.5];
        let (y, tape) = ln_fwd(&x, &g, &b, 4, 1e-6);
        let mean: f32 = y.iter().sum::<f32>() / 4.0;
        assert_close(mean, 0.5, 1e-5);
        // x̂ has unit variance
        let var: f32 = tape.xhat.iter().map(|v| v * v).sum::<f32>() / 4.0;
        assert_close(var, 1.0, 1e-4);
    }

    #[test]
    fn layernorm_backward_matches_finite_difference() {
        let d = 5;
        let x: Vec<f32> = (0..2 * d).map(|i| (i as f32 * 0.7).sin()).collect();
        let g: Vec<f32> = (0..d).map(|i| 1.0 + 0.1 * i as f32).collect();
        let b: Vec<f32> = (0..d).map(|i| 0.05 * i as f32).collect();
        // scalar objective: sum of squares of the LN output
        let f = |x: &[f32]| {
            let (y, _) = ln_fwd(x, &g, &b, d, 1e-6);
            y.iter().map(|v| v * v).sum::<f32>()
        };
        let (y, tape) = ln_fwd(&x, &g, &b, d, 1e-6);
        let dy: Vec<f32> = y.iter().map(|v| 2.0 * v).collect();
        let mut dg = vec![0.0f32; d];
        let mut db = vec![0.0f32; d];
        let dx = ln_bwd(&dy, &tape, &g, d, &mut dg, &mut db);
        for i in 0..x.len() {
            let mut xp = x.clone();
            let mut xm = x.clone();
            let h = 1e-2;
            xp[i] += h;
            xm[i] -= h;
            let fd = (f(&xp) - f(&xm)) / (2.0 * h);
            assert_close(dx[i], fd, 2e-2);
        }
    }

    #[test]
    fn ln_apply_matches_ln_fwd() {
        let d = 4;
        let x: Vec<f32> = (0..3 * d).map(|i| (i as f32 * 0.37).cos()).collect();
        let g: Vec<f32> = (0..d).map(|i| 1.0 + 0.2 * i as f32).collect();
        let b: Vec<f32> = (0..d).map(|i| -0.1 * i as f32).collect();
        let (want, _) = ln_fwd(&x, &g, &b, d, 1e-6);
        assert_eq!(ln_apply(&x, &g, &b, d, 1e-6), want);
    }

    #[test]
    fn segment_ln_gathers_per_segment_params() {
        let d = 2;
        let x = vec![1.0, 3.0, 2.0, 6.0, -1.0, 1.0];
        let g1 = [1.0, 1.0];
        let b1 = [0.0, 0.0];
        let g2 = [2.0, 2.0];
        let b2 = [5.0, 5.0];
        // first 2 rows with (g1,b1), last row with (g2,b2)
        let y = segment_ln(&x, d, 1e-6, &[(2, &g1, &b1), (1, &g2, &b2)]);
        let y1 = ln_apply(&x[..4], &g1, &b1, d, 1e-6);
        let y2 = ln_apply(&x[4..], &g2, &b2, d, 1e-6);
        assert_eq!(&y[..4], &y1[..]);
        assert_eq!(&y[4..], &y2[..]);
    }

    #[test]
    fn attention_ctx_matches_attention_fwd() {
        let (b, s, d, h, dh) = (2usize, 4usize, 4usize, 2usize, 2usize);
        let mk = |seed: f32| -> Vec<f32> {
            (0..b * s * d).map(|i| ((i as f32 + seed) * 0.37).sin()).collect()
        };
        let (q, k, v) = (mk(1.0), mk(2.0), mk(3.0));
        let mask = vec![1.0, 1.0, 0.0, 1.0, 1.0, 0.0, 1.0, 1.0];
        let (_, ctx_taped) = attention_fwd(&q, &k, &v, &mask, b, s, d, h, dh);
        let ctx = attention_ctx(&q, &k, &v, &mask, b, s, d, h, dh);
        assert_eq!(ctx, ctx_taped, "serving attention must match the taped path");
    }

    #[test]
    fn attention_fwd_uniform_probs_average_values() {
        // q = 0 -> uniform attention over unmasked keys -> ctx = mean(v)
        let (b, s, d, h, dh) = (1usize, 3usize, 2usize, 1usize, 2usize);
        let q = vec![0.0; b * s * d];
        let k = vec![0.1, 0.2, 0.3, 0.4, 0.5, 0.6];
        let v = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mask = vec![1.0, 1.0, 1.0];
        let (probs, ctx) = attention_fwd(&q, &k, &v, &mask, b, s, d, h, dh);
        for &p in &probs {
            assert!((p - 1.0 / 3.0).abs() < 1e-6, "{p}");
        }
        for si in 0..s {
            assert!((ctx[si * d] - 3.0).abs() < 1e-5);
            assert!((ctx[si * d + 1] - 4.0).abs() < 1e-5);
        }
        // masked key gets exactly zero probability
        let mask = vec![1.0, 0.0, 1.0];
        let (probs, _) = attention_fwd(&q, &k, &v, &mask, b, s, d, h, dh);
        assert_eq!(probs[1], 0.0);
    }

    #[test]
    fn softmax_rows_sum_to_one_and_argmax_breaks_ties_low() {
        let mut x = vec![1.0, 2.0, 3.0, -1.0, NEG, 0.0];
        softmax_rows(&mut x, 3);
        assert_close(x[0..3].iter().sum::<f32>(), 1.0, 1e-6);
        assert_close(x[3..6].iter().sum::<f32>(), 1.0, 1e-6);
        assert_eq!(x[4], 0.0); // masked key underflows to exactly zero
        assert_eq!(argmax(&[1.0, 3.0, 3.0, 2.0]), 1);
    }
}
