//! Serving loop: multi-task inference over the shared frozen base.
//!
//! Thread topology (std threads + mpsc; tokio is unavailable offline):
//!
//! ```text
//!   clients ── sync_channel (bounded = backpressure) ──► router thread
//!      ▲                                                   │ flush jobs
//!      │            per-request reply channels             ▼
//!      └───────────────◄──────────────── executor pool (N threads)
//! ```
//!
//! The router owns the per-task queues and flush policy; executors pick up
//! flushed batches, swap in the task's cached parameter banks (base merge
//! + adapters done **once per task version**, not per batch) and run the
//! `*_fwd_*` executable. This is the adapter economics in action: one
//! resident base, per-batch task switch = feeding different small input
//! literals, no model reload.
//!
//! The bank cache is a **paged** [`PagedCache`]: banks are resident only
//! while hot, bounded by an optional byte budget
//! ([`ServerConfig::cache_budget`]), and a cold task's banks are fetched
//! back from the durable store on first request — a *fallible* seam
//! ([`crate::store::BankSource`]), since the fetch re-reads and re-decodes
//! the bank from disk. Eviction drops only the cache's `Arc`: in-flight
//! batches (and fused segments — see `runtime::fused`) hold their own
//! reference, so a forward pass can never race an eviction. The task
//! **directory** (name → kind/classes/fusability) is separate from the
//! cache and always complete, so routing and 404 checks never trigger a
//! load. Tasks can still be **hot-installed** while traffic flows:
//! [`Server::prepare_task`] builds and validates the fwd banks off to the
//! side (no lock held), [`Server::install_task`] makes them visible —
//! counting against the budget, evicting colder banks if needed. This is
//! the executor-side half of the store's append-only guarantee: adding
//! task N+1 touches no bytes serving tasks 1…N. [`Server::drain`] starts a
//! graceful shutdown: new submits are refused, queued work is flushed and
//! answered, then [`Server::shutdown`] joins every thread.
//!
//! **Execution modes** ([`ExecMode`]): `PerTask` batches per task as
//! above. `Fused` replaces the router with the cross-task planner
//! (`fuse::plan`) and executes mixed batches through the backend's fused
//! engine — one shared-trunk forward, per-segment LN/adapter/head gather
//! (`runtime::fused`), no padding to the artifact batch shape. Tasks
//! whose trunk cannot be shared (`topk`) and backends without a fused
//! engine (PJRT) transparently keep the per-task path; requesting
//! `Fused` on such a backend warns and falls back. Hot registration
//! builds the new task's gatherable bank in [`Server::prepare_task`], so
//! it becomes fusable the instant it installs — fused traffic for other
//! tasks never pauses.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::cache::{CacheSnapshot, PagedCache};
use super::router::{FlushPolicy, Router};
use crate::eval::{fused_bank, fwd_param_banks, TaskModel};
use crate::fuse::plan::{FusePlanner, FusedFlush, PlanSegment};
use crate::model::params::NamedTensors;
use crate::obs::prof;
use crate::obs::trace::{Stage, TraceHandle};
use crate::runtime::fused::{FusedBackend, FusedSegment, RowOutput};
use crate::runtime::{Bank, FusedTaskBank, Runtime};
use crate::serve::deadline::Deadline;
use crate::store::{AdapterStore, BankSource};
use crate::util::tensor::Tensor;
use crate::util::timer::Samples;

/// One inference request (already tokenized; see `tokenizer` for text).
pub struct Request {
    /// Which registered task should serve this request.
    pub task: String,
    /// Token ids, padded to the model's sequence length.
    pub tokens: Vec<i32>,
    /// Segment ids (sentence-pair encoding).
    pub segments: Vec<i32>,
    /// 1.0 for real tokens, 0.0 for padding.
    pub attn_mask: Vec<f32>,
    /// Where the [`Response`] is delivered.
    pub reply: mpsc::Sender<Response>,
    /// Submission time (latency accounting).
    pub submitted: Instant,
    /// Remaining-budget deadline propagated from the caller. Expired
    /// rows are purged from the batch queues, dropped pre-execution,
    /// and their replies suppressed — the engine never spends a trunk
    /// forward on a request whose caller already gave up. `None` keeps
    /// the pre-deadline behavior.
    pub deadline: Option<Deadline>,
    /// Tracing handle: the router stamps the queue→flush boundary and
    /// the executor the plan/execute boundaries on it. The no-op handle
    /// ([`TraceHandle::none`]) costs one null check per mark.
    pub trace: TraceHandle,
}

/// What a task's head produced for one request — one variant per artifact
/// kind (`cls` / `reg` / `span`), so the server can serve all three.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Prediction {
    /// argmax class (classification heads)
    Class(usize),
    /// scalar score (regression heads, e.g. the STS-B stand-in)
    Score(f32),
    /// (start, end) token positions (span heads, e.g. the SQuAD stand-in)
    Span(usize, usize),
}

impl Prediction {
    /// The artifact kind that produces this payload.
    pub fn kind(&self) -> &'static str {
        match self {
            Prediction::Class(_) => "cls",
            Prediction::Score(_) => "reg",
            Prediction::Span(..) => "span",
        }
    }

    /// The class index, for classification predictions.
    pub fn class(&self) -> Option<usize> {
        match self {
            Prediction::Class(c) => Some(*c),
            _ => None,
        }
    }

    /// The scalar score, for regression predictions.
    pub fn score(&self) -> Option<f32> {
        match self {
            Prediction::Score(s) => Some(*s),
            _ => None,
        }
    }

    /// The (start, end) positions, for span predictions.
    pub fn span(&self) -> Option<(usize, usize)> {
        match self {
            Prediction::Span(s, e) => Some((*s, *e)),
            _ => None,
        }
    }
}

/// The server's answer to one [`Request`].
#[derive(Debug, Clone)]
pub struct Response {
    /// The task that served the request.
    pub task: String,
    /// The head's output (class / score / span, by task kind).
    pub prediction: Prediction,
    /// Submit→reply wall time.
    pub latency: Duration,
    /// Real rows in the batch this request rode in.
    pub batch_size: usize,
}

/// How flushed work is mapped onto forward passes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExecMode {
    /// One task per batch; the batch runs the task's `*_fwd_*` executable
    /// padded to the artifact batch shape.
    PerTask,
    /// Mixed batches: rows from many tasks share one trunk forward with
    /// per-segment parameter gather (native backend only; falls back to
    /// [`ExecMode::PerTask`] with a warning elsewhere).
    Fused,
}

impl ExecMode {
    /// Wire/metrics name (`per_task` | `fused`).
    pub fn name(&self) -> &'static str {
        match self {
            ExecMode::PerTask => "per_task",
            ExecMode::Fused => "fused",
        }
    }
}

/// Serving-loop knobs: batching policy, executor pool size, queue bound.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// When the router flushes a task's queue into a batch.
    pub flush: FlushPolicy,
    /// Worker threads executing flushed batches.
    pub executors: usize,
    /// bounded client→router channel (backpressure)
    pub queue_capacity: usize,
    /// Per-task or fused cross-task execution.
    pub mode: ExecMode,
    /// Resident-bank byte budget (`serve --adapter-cache-mb`). `None`
    /// keeps every bank resident forever (the pre-paging behaviour, with
    /// eager startup builds); `Some(b)` starts lazy — banks load on first
    /// request and evict LRU-first back to store-only residency.
    pub cache_budget: Option<u64>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            flush: FlushPolicy::default(),
            executors: 2,
            queue_capacity: 1024,
            mode: ExecMode::PerTask,
            cache_budget: None,
        }
    }
}

/// Latency samples kept in memory at most — beyond this the recorder
/// switches to slot replacement, so a long-running server (the gateway
/// runs indefinitely) holds O(1) memory instead of one `Duration` per
/// request ever served.
pub const LATENCY_SAMPLE_CAP: usize = 65_536;

/// Aggregated serving metrics, returned by [`Server::shutdown`].
#[derive(Debug, Default, Clone)]
pub struct ServerMetrics {
    /// Per-request submit→reply latencies. Exact below
    /// [`LATENCY_SAMPLE_CAP`] samples; a sliding replacement set after
    /// that (quantiles stay representative, memory stays bounded).
    pub latencies: Samples,
    /// Number of executed batches.
    pub batches: usize,
    /// Batches that ran through the fused multi-task engine.
    pub fused_batches: usize,
    /// Number of completed requests.
    pub requests: u64,
    /// Sum over batches of `real rows / batch capacity` (capacity is the
    /// artifact batch shape on the per-task path, the flush policy's
    /// `max_batch` on the fused path — what the hardware actually ran).
    pub occupancy_sum: f64,
    /// Rows purged from the batch queues with their deadline already
    /// expired (they never rode a batch).
    pub expired_queue: u64,
    /// Rows dropped between flush and execution with their deadline
    /// expired (they rode a flush but never a trunk forward).
    pub expired_exec: u64,
    /// Rows that finished executing after their deadline: the reply is
    /// suppressed (the caller has already been answered 504), counted
    /// here so `requests` always equals delivered + late.
    pub late_replies: u64,
}

impl ServerMetrics {
    /// Mean batch occupancy in `[0, 1]` (0 when nothing ran).
    pub fn mean_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.occupancy_sum / self.batches as f64
        }
    }
}

/// Atomic all-in-one metrics view — see [`Server::metrics_snapshot`].
#[derive(Debug, Clone)]
pub struct ServerSnapshot {
    /// Request/batch counters and latency samples.
    pub server: ServerMetrics,
    /// Bank-cache residency and hit/miss/eviction counters.
    pub cache: CacheSnapshot,
    /// Registered (directory) task count; `>= cache.resident`.
    pub registered: usize,
}

struct TaskBanks {
    fwd_name: String,
    /// artifact kind (cls | reg | span) — decides output decoding
    kind: String,
    n_classes: usize,
    /// parameter banks (base, adapters?, head, gates?) ready to execute
    params: Vec<Bank>,
    /// gatherable bank for the fused engine; `None` for task-specific
    /// trunks (topk), which keep the per-task path even in fused mode
    fused: Option<Arc<FusedTaskBank>>,
}

/// Directory entry: what routing needs to know about a *registered* task
/// without loading its banks. Unlike cache residency, the directory is
/// always complete — an evicted task still lists, still routes, still
/// answers [`Server::task_info`]; only its parameters moved back to
/// store-only residency.
#[derive(Debug, Clone)]
struct TaskDir {
    kind: String,
    n_classes: usize,
    /// `adapter`/`lnonly` variants share the trunk; `topk` does not.
    fusable: bool,
}

/// The executor-side fetch seam: directory + paged bank cache over the
/// durable store. Executors resolve banks through [`BankProvider::resolve`]
/// — a hit clones the resident `Arc`, a miss streams the bank back from
/// the store (single-flight per task) and rebuilds the serving banks.
struct BankProvider {
    rt: Arc<Runtime>,
    base: Arc<NamedTensors>,
    source: Arc<dyn BankSource>,
    cache: PagedCache<Arc<TaskBanks>>,
    directory: RwLock<BTreeMap<String, TaskDir>>,
    build_fused: bool,
}

impl BankProvider {
    /// Resident banks for `task`, cold-loading from the store on a miss.
    /// Fails when the task is unknown to the store (e.g. hot-installed
    /// without a durable write, then evicted) or the store read fails.
    fn resolve(&self, task: &str) -> Result<Arc<TaskBanks>> {
        self.cache.get_or_load(task, || {
            let (meta, model) =
                self.source.fetch_latest(task)?.with_context(|| {
                    format!(
                        "task {task:?} has no bank in the durable store \
                         (an evicted task can only reload from the store)"
                    )
                })?;
            let n_classes = self
                .directory
                .read()
                .unwrap()
                .get(task)
                .map(|d| d.n_classes)
                .unwrap_or(2);
            let banks = build_task_banks(
                &self.rt,
                &self.base,
                n_classes,
                &model,
                self.build_fused,
            )
            .with_context(|| {
                format!("rebuilding banks for task {task:?} v{}", meta.version)
            })?;
            let bytes = banks_bytes(&banks);
            Ok((banks, bytes))
        })
    }

    /// Routing probe from the directory — never loads banks. Unknown
    /// tasks default to fusable; the executor reports them.
    fn fusable(&self, task: &str) -> bool {
        self.directory
            .read()
            .unwrap()
            .get(task)
            .map(|d| d.fusable)
            .unwrap_or(true)
    }
}

/// Resident footprint of built serving banks: parameter bank tensors
/// (4 bytes/element) plus the gatherable fused bank, if built.
fn banks_bytes(tb: &TaskBanks) -> u64 {
    let mut bytes: u64 = 0;
    for bank in &tb.params {
        for t in bank {
            bytes += t.len() as u64 * 4;
        }
    }
    if let Some(f) = &tb.fused {
        bytes += f.byte_len();
    }
    bytes
}

/// A task's serving banks, built and validated by [`Server::prepare_task`]
/// and not yet visible to executors. Installing is a cache insert — the
/// expensive work (base merge, executable warm-up) already happened here.
pub struct PreparedTask {
    banks: Arc<TaskBanks>,
    bytes: u64,
    dir: TaskDir,
}

/// Mode-selected batcher driven by the router thread: the classic
/// per-task router, or the cross-task planner. Either way the executors
/// receive [`FusedFlush`]es (per-task batches are single-segment).
///
/// In fused mode, tasks **without** a fused bank (topk trunks) are routed
/// to a side per-task router instead of the planner: mixing them into
/// cross-task batches would split their rows into 1–2-row padded per-task
/// forwards, which is strictly worse than letting them batch among
/// themselves under the normal flush policy. Fusability is looked up per
/// push against the task **directory** (not cache residency — an evicted
/// task must still route correctly), so a hot-registered task lands on
/// the right side immediately.
enum Batcher {
    PerTask(Router<Request>),
    Fused {
        planner: FusePlanner<Request>,
        side: Router<Request>,
        provider: Arc<BankProvider>,
    },
}

impl Batcher {
    fn push(&mut self, task: &str, req: Request, now: Instant) -> Option<FusedFlush<Request>> {
        match self {
            Batcher::PerTask(r) => r.push(task, req, now).map(FusedFlush::from_single),
            Batcher::Fused { planner, side, provider } => {
                // unknown tasks go to the planner; the executor reports them
                if provider.fusable(task) {
                    planner.push(task, req, now)
                } else {
                    side.push(task, req, now).map(FusedFlush::from_single)
                }
            }
        }
    }

    fn poll(&mut self, now: Instant) -> Vec<FusedFlush<Request>> {
        match self {
            Batcher::PerTask(r) => {
                r.poll(now).into_iter().map(FusedFlush::from_single).collect()
            }
            Batcher::Fused { planner, side, .. } => {
                let mut out = planner.poll(now);
                out.extend(side.poll(now).into_iter().map(FusedFlush::from_single));
                out
            }
        }
    }

    fn drain(&mut self, now: Instant) -> Vec<FusedFlush<Request>> {
        match self {
            Batcher::PerTask(r) => {
                r.drain(now).into_iter().map(FusedFlush::from_single).collect()
            }
            Batcher::Fused { planner, side, .. } => {
                let mut out = planner.drain(now);
                out.extend(side.drain(now).into_iter().map(FusedFlush::from_single));
                out
            }
        }
    }

    fn next_deadline(&self, now: Instant) -> Option<Duration> {
        match self {
            Batcher::PerTask(r) => r.next_deadline(now),
            Batcher::Fused { planner, side, .. } => {
                match (planner.next_deadline(now), side.next_deadline(now)) {
                    (Some(a), Some(b)) => Some(a.min(b)),
                    (a, None) => a,
                    (None, b) => b,
                }
            }
        }
    }

    /// Arrival time of the oldest queued row across every queue — its
    /// age is the sojourn signal the gateway's brownout watches.
    fn oldest_arrival(&self) -> Option<Instant> {
        match self {
            Batcher::PerTask(r) => r.oldest_arrival(),
            Batcher::Fused { planner, side, .. } => {
                match (planner.oldest_arrival(), side.oldest_arrival()) {
                    (Some(a), Some(b)) => Some(a.min(b)),
                    (a, None) => a,
                    (None, b) => b,
                }
            }
        }
    }

    /// Drop queued rows whose deadline already expired, before they ride
    /// a batch. The returned rows are simply dropped by the caller —
    /// their reply senders close, and the gateway has already answered
    /// 504 (its reply wait is clamped to the same deadline).
    fn purge_expired(&mut self) -> usize {
        let pred =
            |r: &Request| r.deadline.map(|d| d.expired()).unwrap_or(false);
        match self {
            Batcher::PerTask(r) => r.purge_expired(pred).len(),
            Batcher::Fused { planner, side, .. } => {
                planner.purge_expired(pred).len() + side.purge_expired(pred).len()
            }
        }
    }
}

/// A running server; drop-safe shutdown via `shutdown()`.
pub struct Server {
    tx: mpsc::SyncSender<Request>,
    stop: Arc<AtomicBool>,
    draining: Arc<AtomicBool>,
    router_handle: Option<std::thread::JoinHandle<()>>,
    executor_handles: Vec<std::thread::JoinHandle<()>>,
    provider: Arc<BankProvider>,
    mode: ExecMode,
    /// Serializes registration flows (store append + install) across
    /// producers — see [`Server::registration_lock`].
    reg_serial: Mutex<()>,
    /// Live metrics (also returned, aggregated, from [`Server::shutdown`]).
    pub metrics: Arc<Mutex<ServerMetrics>>,
    /// Requests rejected by backpressure (`submit` on a full queue).
    pub rejected: Arc<AtomicU64>,
    /// Age of the oldest queued row in microseconds (0 when the queues
    /// are empty), refreshed every router-loop iteration. This is the
    /// sojourn signal the gateway's CoDel-style brownout watches.
    queue_wait_us: Arc<AtomicU64>,
}

impl Server {
    /// Start serving every task currently registered in `store`.
    pub fn start(
        rt: Arc<Runtime>,
        store: &Arc<AdapterStore>,
        base: &NamedTensors,
        task_classes: &BTreeMap<String, usize>,
        cfg: ServerConfig,
    ) -> Result<Server> {
        let source: Arc<dyn BankSource> = store.clone();
        Server::start_with_source(rt, source, base, task_classes, cfg)
    }

    /// [`Server::start`] over any [`BankSource`] — the seam the
    /// fault-injection tests use to wrap the store with injected read
    /// failures without touching production code.
    pub fn start_with_source(
        rt: Arc<Runtime>,
        source: Arc<dyn BankSource>,
        base: &NamedTensors,
        task_classes: &BTreeMap<String, usize>,
        cfg: ServerConfig,
    ) -> Result<Server> {
        // fused mode needs a fused engine; PJRT keeps the per-task path
        let mode = match cfg.mode {
            ExecMode::Fused if rt.fused().is_none() => {
                crate::log_warn!(
                    "coordinator",
                    "backend={} has no fused engine; falling back to per-task batching",
                    rt.backend_name()
                );
                ExecMode::PerTask
            }
            m => m,
        };
        let base = Arc::new(base.clone());
        let provider = Arc::new(BankProvider {
            rt: rt.clone(),
            base,
            source,
            cache: PagedCache::new(cfg.cache_budget),
            directory: RwLock::new(BTreeMap::new()),
            build_fused: mode == ExecMode::Fused,
        });
        // The directory covers every store task up front (routing and 404
        // checks never load banks). Bank residency depends on the budget:
        // unbounded keeps the old behaviour — build everything eagerly,
        // so startup still validates every bank; a budget starts lazy and
        // banks page in on first request.
        for task in provider.source.task_names() {
            let Some(meta) = provider.source.latest_meta(&task) else {
                continue;
            };
            // caller-provided class counts win; otherwise trust the
            // store's persisted metadata (failover replicas have no
            // out-of-band class map for tasks registered elsewhere)
            let n_classes = task_classes.get(&task).copied().unwrap_or(meta.n_classes);
            provider.directory.write().unwrap().insert(
                task.clone(),
                TaskDir {
                    kind: meta.kind.clone(),
                    n_classes,
                    fusable: variant_is_fusable(&meta.variant),
                },
            );
            if cfg.cache_budget.is_none() {
                provider
                    .resolve(&task)
                    .with_context(|| format!("loading banks for task {task:?}"))?;
            }
        }

        let (tx, rx) = mpsc::sync_channel::<Request>(cfg.queue_capacity);
        let (batch_tx, batch_rx) = mpsc::channel::<FusedFlush<Request>>();
        let batch_rx = Arc::new(Mutex::new(batch_rx));
        let stop = Arc::new(AtomicBool::new(false));
        let metrics = Arc::new(Mutex::new(ServerMetrics::default()));
        let rejected = Arc::new(AtomicU64::new(0));

        // router thread
        let stop_r = stop.clone();
        let flush = cfg.flush;
        let provider_r = provider.clone();
        let metrics_r = metrics.clone();
        let queue_wait_us = Arc::new(AtomicU64::new(0));
        let queue_wait_r = queue_wait_us.clone();
        let router_handle = std::thread::Builder::new()
            .name("ab-router".into())
            .spawn(move || {
                let mut batcher = match mode {
                    ExecMode::PerTask => Batcher::PerTask(Router::new(flush)),
                    ExecMode::Fused => Batcher::Fused {
                        planner: FusePlanner::new(flush),
                        side: Router::new(flush),
                        provider: provider_r,
                    },
                };
                loop {
                    let now = Instant::now();
                    let timeout = batcher
                        .next_deadline(now)
                        .unwrap_or(Duration::from_millis(2))
                        .max(Duration::from_micros(100));
                    match rx.recv_timeout(timeout) {
                        Ok(req) => {
                            let task = req.task.clone();
                            if let Some(b) = batcher.push(&task, req, Instant::now()) {
                                send_flushed(&batch_tx, b);
                            }
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => {}
                        Err(mpsc::RecvTimeoutError::Disconnected) => break,
                    }
                    // shed dead rows before they ride a batch (their
                    // callers were answered 504 when the budget ran out)
                    let purged = batcher.purge_expired();
                    if purged > 0 {
                        metrics_r.lock().unwrap().expired_queue += purged as u64;
                    }
                    for b in batcher.poll(Instant::now()) {
                        send_flushed(&batch_tx, b);
                    }
                    let now = Instant::now();
                    let wait_us = batcher
                        .oldest_arrival()
                        .map(|a| now.saturating_duration_since(a).as_micros() as u64)
                        .unwrap_or(0);
                    queue_wait_r.store(wait_us, Ordering::Relaxed);
                    if stop_r.load(Ordering::Relaxed) {
                        break;
                    }
                }
                queue_wait_r.store(0, Ordering::Relaxed);
                for b in batcher.drain(Instant::now()) {
                    send_flushed(&batch_tx, b);
                }
                // dropping batch_tx stops the executors
            })?;

        // executor pool
        let capacity = cfg.flush.max_batch;
        let mut executor_handles = Vec::new();
        for i in 0..cfg.executors.max(1) {
            let provider = provider.clone();
            let batch_rx = batch_rx.clone();
            let metrics = metrics.clone();
            let handle = std::thread::Builder::new()
                .name(format!("ab-exec-{i}"))
                .spawn(move || loop {
                    let flush = {
                        let rx = batch_rx.lock().unwrap();
                        rx.recv()
                    };
                    let Ok(flush) = flush else { return };
                    let fused = mode == ExecMode::Fused;
                    if let Err(e) =
                        run_flush(&provider, capacity, fused, flush, &metrics)
                    {
                        crate::log_error!("coordinator", "executor error err={e:#}");
                    }
                })?;
            executor_handles.push(handle);
        }

        Ok(Server {
            tx,
            stop,
            draining: Arc::new(AtomicBool::new(false)),
            router_handle: Some(router_handle),
            executor_handles,
            provider,
            mode,
            reg_serial: Mutex::new(()),
            metrics,
            rejected,
            queue_wait_us,
        })
    }

    /// Age of the oldest row queued in the batcher right now — the
    /// sojourn signal behind adaptive shedding. Zero when idle.
    pub fn queue_wait(&self) -> Duration {
        Duration::from_micros(self.queue_wait_us.load(Ordering::Relaxed))
    }

    /// Take the registration serialization lock. Every producer that
    /// appends to a store **and** installs into this server (the
    /// gateway's `POST /tasks`, a completing training job) must hold this
    /// across both operations so store version order matches executor-side
    /// install order — otherwise two producers finishing the same task
    /// concurrently could leave the server serving version N while the
    /// store's latest is N+1.
    pub fn registration_lock(&self) -> std::sync::MutexGuard<'_, ()> {
        self.reg_serial.lock().unwrap()
    }

    /// The execution mode this server resolved to (fused requests fall
    /// back to per-task when the backend has no fused engine).
    pub fn mode(&self) -> ExecMode {
        self.mode
    }

    /// Build and validate serving banks for a task **without** installing
    /// them: the base merge runs, the bank shapes are checked against the
    /// manifest, and the fwd executable is warmed in the compile cache.
    /// No lock is held, so traffic is unaffected. Errors here leave the
    /// server exactly as it was.
    pub fn prepare_task(&self, n_classes: usize, model: &TaskModel) -> Result<PreparedTask> {
        let banks = build_task_banks(
            &self.provider.rt,
            &self.provider.base,
            n_classes,
            model,
            self.mode == ExecMode::Fused,
        )?;
        let bytes = banks_bytes(&banks);
        Ok(PreparedTask {
            banks,
            bytes,
            dir: TaskDir {
                kind: model.kind.clone(),
                n_classes,
                fusable: variant_is_fusable(&model.variant),
            },
        })
    }

    /// Make a prepared task visible to the executors: the directory entry
    /// is inserted (or replaced) and the banks go into the paged cache,
    /// **counting against the byte budget** — hot-installing a finished
    /// training job can evict colder banks. Batches already in flight
    /// keep the bank `Arc` they resolved — no request is ever served from
    /// a half-swapped state.
    pub fn install_task(&self, task: &str, prepared: PreparedTask) {
        {
            let _ord = crate::check::order::Held::enter(crate::check::order::DIRECTORY);
            self.provider
                .directory
                .write()
                .unwrap()
                .insert(task.to_string(), prepared.dir);
        }
        self.provider.cache.insert(task, prepared.banks, prepared.bytes);
    }

    /// Prepare + install in one call (the store write, if any, is the
    /// caller's job — see `serve::registry` for the networked path).
    pub fn register_live(&self, task: &str, n_classes: usize, model: &TaskModel) -> Result<()> {
        let prepared = self.prepare_task(n_classes, model)?;
        self.install_task(task, prepared);
        Ok(())
    }

    /// Names of the registered tasks, sorted. Registration — a directory
    /// entry — outlives residency: an evicted task still lists here.
    pub fn tasks(&self) -> Vec<String> {
        self.provider.directory.read().unwrap().keys().cloned().collect()
    }

    /// (artifact kind, n_classes) for a registered task — directory only,
    /// never loads banks.
    pub fn task_info(&self, task: &str) -> Option<(String, usize)> {
        self.provider
            .directory
            .read()
            .unwrap()
            .get(task)
            .map(|d| (d.kind.clone(), d.n_classes))
    }

    /// Admit a task this server has never seen **from the durable store**:
    /// on a directory miss, look the task up in the bank source and, if it
    /// exists there, insert a directory entry from its stored metadata
    /// (kind, class count, variant). Returns `Ok(true)` when the task is
    /// routable afterwards (already known, or admitted now), `Ok(false)`
    /// when the store has never heard of it either.
    ///
    /// This is the cluster failover path: when a replica dies and the ring
    /// reassigns its shard, the new owner may receive traffic for tasks
    /// that were hot-registered through the *old* owner. The shared store
    /// is the source of truth — admission here puts the task in the
    /// directory so the normal cold-load seam ([`Server::prefetch`])
    /// pages its banks in.
    pub fn admit_from_store(&self, task: &str) -> Result<bool> {
        if self.provider.directory.read().unwrap().contains_key(task) {
            return Ok(true);
        }
        let Some(meta) = self.provider.source.latest_meta(task) else {
            return Ok(false);
        };
        self.provider.directory.write().unwrap().insert(
            task.to_string(),
            TaskDir {
                kind: meta.kind.clone(),
                n_classes: meta.n_classes,
                fusable: variant_is_fusable(&meta.variant),
            },
        );
        Ok(true)
    }

    /// Is the task's bank resident right now? (Does not refresh recency.)
    pub fn is_resident(&self, task: &str) -> bool {
        self.provider.cache.contains(task)
    }

    /// Load a registered task's banks into residency (no-op on a hit).
    /// This is the gateway's pre-admission warm-up: cold-load failures
    /// surface here as descriptive errors instead of dropped batches.
    pub fn prefetch(&self, task: &str) -> Result<()> {
        if self.provider.directory.read().unwrap().get(task).is_none() {
            bail!("unknown task {task:?}");
        }
        self.provider.resolve(task).map(|_| ())
    }

    /// Point-in-time cache view (residency, byte totals, counters) from a
    /// single lock acquisition.
    pub fn cache_stats(&self) -> CacheSnapshot {
        self.provider.cache.snapshot()
    }

    /// One consistent metrics view: request counters, cache residency and
    /// the registered-task count, sampled in a fixed lock order with the
    /// request counters held across the cache snapshot — `/metrics`
    /// assembled from this can never pair a mid-registration cache state
    /// with counters from a different moment.
    pub fn metrics_snapshot(&self) -> ServerSnapshot {
        let m = self.metrics.lock().unwrap();
        let cache = self.provider.cache.snapshot();
        let registered = self.provider.directory.read().unwrap().len();
        ServerSnapshot { server: m.clone(), cache, registered }
    }

    /// Stop admitting new requests; queued and in-flight work still
    /// completes and is answered. Part of graceful shutdown — call this
    /// first, then [`Server::shutdown`] once callers have stopped.
    pub fn drain(&self) {
        self.draining.store(true, Ordering::SeqCst);
    }

    /// True once [`Server::drain`] has been called.
    pub fn is_draining(&self) -> bool {
        self.draining.load(Ordering::SeqCst)
    }

    /// Submit a request; `Err` when the bounded queue is full
    /// (backpressure) or the server is draining.
    pub fn submit(&self, req: Request) -> Result<(), Request> {
        if self.is_draining() {
            return Err(req);
        }
        match self.tx.try_send(req) {
            Ok(()) => Ok(()),
            Err(mpsc::TrySendError::Full(r)) => {
                self.rejected.fetch_add(1, Ordering::Relaxed);
                Err(r)
            }
            Err(mpsc::TrySendError::Disconnected(r)) => Err(r),
        }
    }

    /// Blocking submit (client-side throttle).
    pub fn submit_blocking(&self, req: Request) -> Result<()> {
        if self.is_draining() {
            bail!("server draining");
        }
        self.tx.send(req).map_err(|_| anyhow::anyhow!("server stopped"))
    }

    /// Stop accepting work, drain the queues, join every thread and
    /// return the aggregated metrics. Every request accepted before the
    /// drain began is still answered.
    pub fn shutdown(mut self) -> ServerMetrics {
        self.drain();
        self.stop.store(true, Ordering::Relaxed);
        drop(self.tx);
        if let Some(h) = self.router_handle.take() {
            let _ = h.join();
        }
        for h in self.executor_handles.drain(..) {
            let _ = h.join();
        }
        let m = self.metrics.lock().unwrap();
        m.clone()
    }
}

/// Resolve a task's fwd banks (base merge + adapters + head + gates) and
/// warm the executable in the compile cache before traffic arrives. The
/// bank is validated against the manifest first, so a malformed
/// registration fails here with a descriptive error instead of inside
/// `execute`. With `build_fused` (a fused-mode server), fusable variants
/// (adapter/lnonly) also get their gatherable fused bank built, making
/// the task fusable the moment it installs; per-task/PJRT servers skip
/// that work and memory entirely.
fn build_task_banks(
    rt: &Arc<Runtime>,
    base: &NamedTensors,
    n_classes: usize,
    model: &TaskModel,
    build_fused: bool,
) -> Result<Arc<TaskBanks>> {
    model.validate_against(&rt.manifest, n_classes)?;
    let fwd_name = model.fwd_name();
    let params = fwd_param_banks(rt, model, base, None)?;
    rt.load(&fwd_name)?;
    let fused = match model.variant.as_str() {
        "adapter" | "lnonly" if build_fused => {
            Some(Arc::new(fused_bank(rt, model, base, n_classes)?))
        }
        _ => None,
    };
    Ok(Arc::new(TaskBanks {
        fwd_name,
        kind: model.kind.clone(),
        n_classes,
        params,
        fused,
    }))
}

/// Bounded-memory latency recording: exact below [`LATENCY_SAMPLE_CAP`]
/// samples, then pseudo-random slot replacement (Fibonacci hashing of the
/// request counter) so old samples age out of the quantiles.
fn record_latency(m: &mut ServerMetrics, latency: Duration) {
    if m.latencies.durs.len() < LATENCY_SAMPLE_CAP {
        m.latencies.record(latency);
    } else {
        let slot = (m.requests as usize).wrapping_mul(2654435761) % LATENCY_SAMPLE_CAP;
        m.latencies.durs[slot] = latency;
    }
}

/// `adapter`/`lnonly` banks share the trunk; `topk` rewrites trunk layers
/// per task and keeps the per-task path even in fused mode.
fn variant_is_fusable(variant: &str) -> bool {
    matches!(variant, "adapter" | "lnonly")
}

/// Hand a planned batch to the executor channel, stamping every item's
/// queue→flush trace boundary on the way out of the router.
fn send_flushed(tx: &mpsc::Sender<FusedFlush<Request>>, b: FusedFlush<Request>) {
    for item in &b.items {
        item.trace.mark(Stage::Flushed);
    }
    let _ = tx.send(b);
}

/// Execute one flush: fusable segments share a single trunk forward;
/// everything else (topk trunks, or per-task mode) runs the classic
/// per-task executable per segment. Bank resolution goes through the
/// paged cache — a cold task's bank streams back from the store here,
/// single-flight. The resolved `Arc<TaskBanks>` **pins** the banks for
/// the whole flush: an eviction in between only drops the cache's
/// reference. Segments whose banks cannot be resolved (unknown task,
/// store read failure) are dropped (their reply channels close → the
/// gateway answers 5xx) without taking the rest of the batch down.
fn run_flush(
    provider: &Arc<BankProvider>,
    capacity: usize,
    use_fused: bool,
    flush: FusedFlush<Request>,
    metrics: &Arc<Mutex<ServerMetrics>>,
) -> Result<()> {
    let rt = &provider.rt;
    let FusedFlush { segments, mut items, .. } = flush;
    // split the row vector back into per-segment request vectors
    let mut per_seg: Vec<(PlanSegment, Vec<Request>)> = Vec::with_capacity(segments.len());
    for seg in segments.into_iter().rev() {
        let reqs = items.split_off(seg.start);
        per_seg.push((seg, reqs));
    }
    per_seg.reverse();

    let engine = if use_fused { rt.fused() } else { None };
    let mut fused_groups: Vec<(Arc<TaskBanks>, Vec<Request>)> = Vec::new();
    let mut first_err: Option<anyhow::Error> = None;
    for (seg, reqs) in per_seg {
        // last line of deadline defense: a row whose budget expired
        // between flush and pickup is dropped *before* bank resolution,
        // so a dead request can neither ride a trunk forward nor force
        // a cold load
        let reqs: Vec<Request> = {
            let (dead, live): (Vec<Request>, Vec<Request>) = reqs
                .into_iter()
                .partition(|r| r.deadline.map(|d| d.expired()).unwrap_or(false));
            if !dead.is_empty() {
                metrics.lock().unwrap().expired_exec += dead.len() as u64;
            }
            live
        };
        if reqs.is_empty() {
            continue;
        }
        let tb = match provider.resolve(&seg.task) {
            Ok(tb) => tb,
            Err(e) => {
                let n = reqs.len();
                first_err.get_or_insert_with(|| {
                    e.context(format!(
                        "no banks for task {:?} ({n} rows dropped)",
                        seg.task
                    ))
                });
                continue;
            }
        };
        if engine.is_some() && tb.fused.is_some() {
            fused_groups.push((tb, reqs));
        } else if let Err(e) = run_per_task(rt, &tb, reqs, metrics) {
            first_err.get_or_insert(e);
        }
    }
    // groups are only collected when an engine is present (see the
    // `engine.is_some()` guard above), so a None here is unreachable and
    // the groups would simply be skipped
    if let (false, Some(engine)) = (fused_groups.is_empty(), engine) {
        if let Err(e) = run_fused_groups(
            rt,
            engine,
            &provider.base,
            capacity,
            fused_groups,
            metrics,
        ) {
            first_err.get_or_insert(e);
        }
    }
    match first_err {
        None => Ok(()),
        Some(e) => Err(e),
    }
}

/// Classic path: one task, its `*_fwd_*` executable, rows padded to the
/// artifact batch shape.
fn run_per_task(
    rt: &Arc<Runtime>,
    tb: &Arc<TaskBanks>,
    items: Vec<Request>,
    metrics: &Arc<Mutex<ServerMetrics>>,
) -> Result<()> {
    prof::start_batch();
    for req in &items {
        req.trace.mark(Stage::ExecStart);
    }
    let exe = rt.load(&tb.fwd_name)?;
    let b = exe.spec.batch;
    let seq = rt.manifest.dims.seq;
    let n = items.len();
    // assemble padded token banks
    let mut tokens = Vec::with_capacity(b * seq);
    let mut segments = Vec::with_capacity(b * seq);
    let mut attn = Vec::with_capacity(b * seq);
    for req in &items {
        tokens.extend_from_slice(&req.tokens);
        segments.extend_from_slice(&req.segments);
        attn.extend_from_slice(&req.attn_mask);
    }
    for _ in n..b {
        tokens.extend(std::iter::repeat(0).take(seq));
        segments.extend(std::iter::repeat(0).take(seq));
        let mut m = vec![0.0f32; seq];
        m[0] = 1.0;
        attn.extend(m);
    }
    let tok_bank = vec![Tensor::i32(vec![b, seq], tokens)];
    let seg_bank = vec![Tensor::i32(vec![b, seq], segments)];
    let mask_bank = vec![Tensor::f32(vec![b, seq], attn)];
    let mut all: Vec<&Bank> = tb.params.iter().collect();
    all.push(&tok_bank);
    all.push(&seg_bank);
    all.push(&mask_bank);
    let out = exe.run(&all)?;
    // decode per-row predictions by head kind
    let preds: Vec<Prediction> = match tb.kind.as_str() {
        "cls" => {
            let logits = &out[0][0]; // [B, max_classes]
            let c = logits.shape[1];
            (0..n)
                .map(|row| {
                    let r = &logits.as_f32()[row * c..(row + 1) * c];
                    Prediction::Class(argmax(&r[..tb.n_classes]))
                })
                .collect()
        }
        "reg" => {
            let scores = out[0][0].as_f32(); // [B]
            (0..n).map(|row| Prediction::Score(scores[row])).collect()
        }
        "span" => {
            let start = &out[0][0]; // [B, S]
            let end = &out[1][0];
            let s = start.shape[1];
            (0..n)
                .map(|row| {
                    let rs = &start.as_f32()[row * s..(row + 1) * s];
                    let re = &end.as_f32()[row * s..(row + 1) * s];
                    Prediction::Span(argmax(rs), argmax(re))
                })
                .collect()
        }
        other => bail!("unservable artifact kind {other:?}"),
    };
    let stage_table = prof::take_batch();
    let now = Instant::now();
    let mut m = metrics.lock().unwrap();
    m.batches += 1;
    m.occupancy_sum += n as f64 / b as f64;
    for (req, pred) in items.into_iter().zip(preds) {
        let latency = now.duration_since(req.submitted);
        record_latency(&mut m, latency);
        m.requests += 1;
        req.trace.set_batch_rows(n);
        req.trace.add_meta_all(&stage_table);
        req.trace.mark(Stage::Replied);
        // the budget ran out mid-forward: the caller was already
        // answered 504, so suppress (and count) the late reply
        if req.deadline.map(|d| d.expired()).unwrap_or(false) {
            m.late_replies += 1;
            continue;
        }
        let _ = req.reply.send(Response {
            task: req.task,
            prediction: pred,
            latency,
            batch_size: n,
        });
    }
    Ok(())
}

/// Fused path: one shared-trunk forward over every fusable segment of the
/// flush — no padding, per-segment parameter gather (`runtime::fused`).
fn run_fused_groups(
    rt: &Arc<Runtime>,
    engine: &dyn FusedBackend,
    base: &Arc<NamedTensors>,
    capacity: usize,
    groups: Vec<(Arc<TaskBanks>, Vec<Request>)>,
    metrics: &Arc<Mutex<ServerMetrics>>,
) -> Result<()> {
    let seq = rt.manifest.dims.seq;
    prof::start_batch();
    for (_, reqs) in &groups {
        for req in reqs {
            req.trace.mark(Stage::ExecStart);
        }
    }
    let rows: usize = groups.iter().map(|(_, r)| r.len()).sum();
    let mut tokens = Vec::with_capacity(rows * seq);
    let mut type_ids = Vec::with_capacity(rows * seq);
    let mut attn = Vec::with_capacity(rows * seq);
    let mut segs: Vec<FusedSegment> = Vec::with_capacity(groups.len());
    for (tb, reqs) in &groups {
        let bank = tb.fused.clone().context("fusable group lost its bank")?;
        segs.push(FusedSegment { bank, len: reqs.len() });
        for req in reqs {
            tokens.extend_from_slice(&req.tokens);
            type_ids.extend_from_slice(&req.segments);
            attn.extend_from_slice(&req.attn_mask);
        }
    }
    let outs = engine.fused_forward(&base.map, &segs, &tokens, &type_ids, &attn)?;
    anyhow::ensure!(
        outs.len() == rows,
        "fused forward returned {} rows for a {rows}-row batch",
        outs.len()
    );
    let stage_table = prof::take_batch();
    let now = Instant::now();
    let mut m = metrics.lock().unwrap();
    m.batches += 1;
    m.fused_batches += 1;
    m.occupancy_sum += rows as f64 / capacity.max(1) as f64;
    let mut it = outs.into_iter();
    for (tb, reqs) in groups {
        for req in reqs {
            // row count was ensured against `rows` right after the
            // forward, so exhaustion here cannot happen
            let Some(row) = it.next() else {
                anyhow::bail!("fused forward produced fewer rows than requests");
            };
            let pred = match row {
                RowOutput::Class(logits) => {
                    let n = tb.n_classes.min(logits.len()).max(1);
                    Prediction::Class(argmax(&logits[..n]))
                }
                RowOutput::Score(s) => Prediction::Score(s),
                RowOutput::Span(start, end) => {
                    Prediction::Span(argmax(&start), argmax(&end))
                }
            };
            let latency = now.duration_since(req.submitted);
            record_latency(&mut m, latency);
            m.requests += 1;
            req.trace.set_batch_rows(rows);
            req.trace.add_meta_all(&stage_table);
            req.trace.mark(Stage::Replied);
            // see `run_per_task`: a reply past its deadline is
            // suppressed, never delivered
            if req.deadline.map(|d| d.expired()).unwrap_or(false) {
                m.late_replies += 1;
                continue;
            }
            let _ = req.reply.send(Response {
                task: req.task,
                prediction: pred,
                latency,
                batch_size: rows,
            });
        }
    }
    Ok(())
}

fn argmax(xs: &[f32]) -> usize {
    // manual scan: total order without NaN-comparison panics (a NaN
    // logit loses every `>` test and can never become the winner)
    let mut best = 0;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in xs.iter().enumerate() {
        if v > best_v {
            best = i;
            best_v = v;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prediction_accessors_match_kind() {
        let c = Prediction::Class(3);
        assert_eq!(c.kind(), "cls");
        assert_eq!(c.class(), Some(3));
        assert_eq!(c.score(), None);
        assert_eq!(c.span(), None);
        let r = Prediction::Score(0.25);
        assert_eq!(r.kind(), "reg");
        assert_eq!(r.score(), Some(0.25));
        let s = Prediction::Span(2, 5);
        assert_eq!(s.kind(), "span");
        assert_eq!(s.span(), Some((2, 5)));
        assert_eq!(s.class(), None);
    }
}
