"""L2: MiniBERT — a from-scratch BERT-style encoder with Houlsby adapters.

This is the paper's Figure 2 exactly, at reproduction scale:

  * every Transformer layer has two sub-layers (multi-head attention and
    FFN), each followed by a projection back to ``d``;
  * a bottleneck adapter is inserted *after each projection, before the
    residual add*, and its output feeds the sub-layer LayerNorm;
  * during adapter tuning only the adapters, the LayerNorm parameters and
    the task head are trained — the frozen base is shared across tasks.

The module is pure-functional over parameter pytrees so the whole training
step (forward, backward, Adam update) lowers to a single HLO executable
(see :mod:`compile.aot`). Parameter *values* are runtime inputs: one
artifact serves every task, seed and checkpoint.

Trained-parameter partitions (what differs between artifacts):
  * ``adapter`` — adapters + all LayerNorm params + head   (the paper's method)
  * ``topk_K``  — head + the top K layers (K = n_layers also unlocks
                  embeddings → full fine-tuning)            (baseline)
  * ``lnonly``  — LayerNorm params + head                   (Fig. 4 baseline)

Inference graphs route the hot spots through the Pallas kernels
(:mod:`compile.kernels`); training graphs use the fused adapter kernel via
its custom VJP and the jnp references elsewhere so XLA autodiff applies.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from .kernels import adapter as adapter_k
from .kernels import attention as attention_k
from .kernels import layernorm as layernorm_k
from .kernels import ref

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Static architecture hyper-parameters (baked into each artifact)."""

    vocab: int = 512
    d: int = 64
    n_layers: int = 6
    n_heads: int = 4
    ffn: int = 256
    seq: int = 32
    max_classes: int = 20
    type_vocab: int = 2
    mlm_positions: int = 5
    adapter_size: int = 16  # m; 0 = no adapters in the graph
    ln_eps: float = 1e-6

    @property
    def head_dim(self) -> int:
        assert self.d % self.n_heads == 0
        return self.d // self.n_heads


PRESETS: Dict[str, ModelConfig] = {
    # the reproduction's "pre-trained BERT" stand-in (see DESIGN.md §2)
    "default": ModelConfig(),
    # tiny preset for fast CI artifacts
    "test": ModelConfig(
        vocab=256, d=32, n_layers=2, n_heads=2, ffn=64, seq=16,
        max_classes=6, mlm_positions=4, adapter_size=8,
    ),
}


# ---------------------------------------------------------------------------
# initialization (used to shape example args + python tests; Rust re-implements
# the task-side initializers so it can sweep the init scale — Fig. 6 right)
# ---------------------------------------------------------------------------


def init_base_params(cfg: ModelConfig, key: jax.Array) -> Params:
    """Initialize the *base* (pre-trainable, later frozen) parameters."""

    def dense(key, n_in, n_out):
        return jax.random.truncated_normal(
            key, -2.0, 2.0, (n_in, n_out), jnp.float32
        ) * 0.02

    keys = iter(jax.random.split(key, 6 + 10 * cfg.n_layers))
    p: Params = {
        "tok_embed": dense(next(keys), cfg.vocab, cfg.d),
        "pos_embed": dense(next(keys), cfg.seq, cfg.d),
        "type_embed": dense(next(keys), cfg.type_vocab, cfg.d),
        "embed_ln_g": jnp.ones((cfg.d,), jnp.float32),
        "embed_ln_b": jnp.zeros((cfg.d,), jnp.float32),
        "mlm_bias": jnp.zeros((cfg.vocab,), jnp.float32),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        layer = {
            "wq": dense(next(keys), cfg.d, cfg.d),
            "bq": jnp.zeros((cfg.d,), jnp.float32),
            "wk": dense(next(keys), cfg.d, cfg.d),
            "bk": jnp.zeros((cfg.d,), jnp.float32),
            "wv": dense(next(keys), cfg.d, cfg.d),
            "bv": jnp.zeros((cfg.d,), jnp.float32),
            "wo": dense(next(keys), cfg.d, cfg.d),
            "bo": jnp.zeros((cfg.d,), jnp.float32),
            "w1": dense(next(keys), cfg.d, cfg.ffn),
            "b1": jnp.zeros((cfg.ffn,), jnp.float32),
            "w2": dense(next(keys), cfg.ffn, cfg.d),
            "b2": jnp.zeros((cfg.d,), jnp.float32),
            "ln1_g": jnp.ones((cfg.d,), jnp.float32),
            "ln1_b": jnp.zeros((cfg.d,), jnp.float32),
            "ln2_g": jnp.ones((cfg.d,), jnp.float32),
            "ln2_b": jnp.zeros((cfg.d,), jnp.float32),
        }
        p["layers"].append(layer)
    return p


def init_adapter_params(cfg: ModelConfig, key: jax.Array, std: float = 1e-2) -> Params:
    """Near-identity adapter bank (paper §2: trunc-normal, σ=1e-2)."""
    m = cfg.adapter_size
    keys = iter(jax.random.split(key, 4 * cfg.n_layers))

    def tn(key, shape):
        return jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std

    bank: List[Params] = []
    for _ in range(cfg.n_layers):
        bank.append({
            "attn": {
                "w_down": tn(next(keys), (cfg.d, m)),
                "b_down": jnp.zeros((m,), jnp.float32),
                "w_up": tn(next(keys), (m, cfg.d)),
                "b_up": jnp.zeros((cfg.d,), jnp.float32),
            },
            "ffn": {
                "w_down": tn(next(keys), (cfg.d, m)),
                "b_down": jnp.zeros((m,), jnp.float32),
                "w_up": tn(next(keys), (m, cfg.d)),
                "b_up": jnp.zeros((cfg.d,), jnp.float32),
            },
        })
    return {"layers": bank}


def init_head_params(cfg: ModelConfig, key: jax.Array, kind: str) -> Params:
    """Task head. kind ∈ {cls, reg, span}."""
    n_out = {"cls": cfg.max_classes, "reg": 1, "span": 2}[kind]
    w = jax.random.truncated_normal(
        key, -2.0, 2.0, (cfg.d, n_out), jnp.float32
    ) * 0.02
    return {"w": w, "b": jnp.zeros((n_out,), jnp.float32)}


# ---------------------------------------------------------------------------
# encoder
# ---------------------------------------------------------------------------


def _multi_head_attention(cfg, layer, x, attn_mask, use_pallas):
    """x: [B,S,d], attn_mask: [B,S] → [B,S,d] (pre-adapter, post-projection)."""
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.head_dim

    def split(t):  # [B,S,d] -> [B*h, S, dh]
        return t.reshape(b, s, h, dh).transpose(0, 2, 1, 3).reshape(b * h, s, dh)

    q = split(x @ layer["wq"] + layer["bq"])
    k = split(x @ layer["wk"] + layer["bk"])
    v = split(x @ layer["wv"] + layer["bv"])
    mask = jnp.repeat(attn_mask, h, axis=0)  # [B*h, S]
    if use_pallas:
        ctx = attention_k.attention_pallas(q, k, v, mask)
    else:
        ctx = jax.vmap(ref.attention_ref)(q, k, v, mask)
    ctx = ctx.reshape(b, h, s, dh).transpose(0, 2, 1, 3).reshape(b, s, d)
    return ctx @ layer["wo"] + layer["bo"]


def _apply_adapter(cfg, ad, x, gate):
    """Adapter delta gated by the Fig. 6 ablation mask (gate ∈ {0,1}).

    Always the fused Pallas kernel: its custom VJP (a second Pallas kernel)
    makes it differentiable, so training and inference share the hot path.
    """
    y = adapter_k.adapter_nd(x, ad["w_down"], ad["b_down"], ad["w_up"], ad["b_up"])
    return x + gate * (y - x)


def _layernorm(cfg, x, g, b, use_pallas):
    if use_pallas:
        return layernorm_k.layernorm_nd(x, g, b)
    return ref.layernorm_ref(x, g, b, cfg.ln_eps)


def encode(
    cfg: ModelConfig,
    base: Params,
    tokens: jnp.ndarray,       # i32 [B,S]
    segments: jnp.ndarray,     # i32 [B,S]
    attn_mask: jnp.ndarray,    # f32 [B,S]
    adapters: Optional[Params] = None,
    adapter_gates: Optional[jnp.ndarray] = None,  # f32 [L,2]
    inference_kernels: bool = False,
) -> jnp.ndarray:
    """Run the encoder; returns final hidden states [B,S,d].

    ``adapters=None`` builds the plain (fine-tuning) graph — no adapter ops
    at all. ``inference_kernels=True`` routes LayerNorm/attention through the
    Pallas kernels (fwd-only graphs); training graphs keep the jnp reference
    there so XLA autodiff applies. The adapter itself is *always* the fused
    Pallas kernel (differentiable via its custom VJP). ``adapter_gates`` multiplies each adapter's delta (1 = active,
    0 = exact identity) and is a *runtime input* so the Fig. 6 span-ablation
    re-evaluates trained banks without retraining or re-lowering.
    """
    x = (
        base["tok_embed"][tokens]
        + base["pos_embed"][None, : tokens.shape[1]]
        + base["type_embed"][segments]
    )
    x = _layernorm(cfg, x, base["embed_ln_g"], base["embed_ln_b"], inference_kernels)
    if adapter_gates is None:
        adapter_gates = jnp.ones((cfg.n_layers, 2), jnp.float32)
    for li, layer in enumerate(base["layers"]):
        # --- attention sub-layer ---
        sub = _multi_head_attention(cfg, layer, x, attn_mask, inference_kernels)
        if adapters is not None:
            sub = _apply_adapter(
                cfg, adapters["layers"][li]["attn"], sub, adapter_gates[li, 0]
            )
        x = _layernorm(cfg, x + sub, layer["ln1_g"], layer["ln1_b"], inference_kernels)
        # --- FFN sub-layer ---
        sub = ref.gelu(x @ layer["w1"] + layer["b1"]) @ layer["w2"] + layer["b2"]
        if adapters is not None:
            sub = _apply_adapter(
                cfg, adapters["layers"][li]["ffn"], sub, adapter_gates[li, 1]
            )
        x = _layernorm(cfg, x + sub, layer["ln2_g"], layer["ln2_b"], inference_kernels)
    return x


# ---------------------------------------------------------------------------
# heads and losses
# ---------------------------------------------------------------------------


def cls_logits(cfg, head, hidden):
    """Classification from the [CLS] (position-0) embedding. → [B,C]."""
    return hidden[:, 0, :] @ head["w"] + head["b"]


def reg_prediction(cfg, head, hidden):
    """Scalar regression from [CLS]. → [B]."""
    return (hidden[:, 0, :] @ head["w"] + head["b"])[:, 0]


def span_logits(cfg, head, hidden, attn_mask):
    """Start/end position logits. → ([B,S], [B,S]) masked to valid tokens."""
    both = hidden @ head["w"] + head["b"]  # [B,S,2]
    neg = jnp.asarray(-1e9, both.dtype)
    valid = attn_mask > 0
    start = jnp.where(valid, both[..., 0], neg)
    end = jnp.where(valid, both[..., 1], neg)
    return start, end


def cls_loss(cfg, logits, labels, class_valid):
    return ref.softmax_xent_ref(logits, labels, class_valid)


def cls_accuracy(cfg, logits, labels, class_valid):
    neg = jnp.asarray(-1e9, logits.dtype)
    masked = jnp.where(class_valid[None, :] > 0, logits, neg)
    return jnp.mean((jnp.argmax(masked, axis=-1) == labels).astype(jnp.float32))


def reg_loss(cfg, preds, targets):
    return jnp.mean((preds - targets) ** 2)


def span_loss(cfg, start_logits, end_logits, spans):
    """spans: i32 [B,2] (start,end). Mean CE over both boundaries."""
    ls = jax.nn.log_softmax(start_logits, axis=-1)
    le = jax.nn.log_softmax(end_logits, axis=-1)
    nll_s = -jnp.take_along_axis(ls, spans[:, 0:1], axis=-1)[:, 0]
    nll_e = -jnp.take_along_axis(le, spans[:, 1:2], axis=-1)[:, 0]
    return jnp.mean(0.5 * (nll_s + nll_e))


def mlm_loss(cfg, base, hidden, positions, targets, weights):
    """Masked-LM loss at ``positions`` (tied output embedding + bias)."""
    gathered = jnp.take_along_axis(
        hidden, positions[:, :, None].astype(jnp.int32), axis=1
    )  # [B,P,d]
    logits = gathered @ base["tok_embed"].T + base["mlm_bias"]  # [B,P,V]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[:, :, None], axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(weights), 1.0)
    return jnp.sum(nll * weights) / denom


# ---------------------------------------------------------------------------
# trained-parameter partitions
# ---------------------------------------------------------------------------


def split_base_for_topk(cfg: ModelConfig, base: Params, k: int) -> Tuple[Params, Params]:
    """Partition the base for top-k fine-tuning.

    Returns (trained_subtree, frozen_subtree); ``merge_topk`` re-joins.
    k = n_layers also unlocks the embedding tables (≡ full fine-tuning).
    """
    assert 1 <= k <= cfg.n_layers
    lo = cfg.n_layers - k
    trained: Params = {"layers": base["layers"][lo:]}
    frozen: Params = {"layers": base["layers"][:lo]}
    emb_keys = [
        "tok_embed", "pos_embed", "type_embed",
        "embed_ln_g", "embed_ln_b", "mlm_bias",
    ]
    for key in emb_keys:
        (trained if k == cfg.n_layers else frozen)[key] = base[key]
    return trained, frozen


def merge_topk(cfg: ModelConfig, trained: Params, frozen: Params) -> Params:
    base = {}
    for src in (trained, frozen):
        for key, val in src.items():
            if key != "layers":
                base[key] = val
    base["layers"] = list(frozen["layers"]) + list(trained["layers"])
    return base


def split_base_for_ln(cfg: ModelConfig, base: Params) -> Tuple[Params, Params]:
    """Partition for LayerNorm-only tuning (Fig. 4 green baseline)."""
    ln_keys = {"ln1_g", "ln1_b", "ln2_g", "ln2_b"}
    trained: Params = {
        "embed_ln_g": base["embed_ln_g"],
        "embed_ln_b": base["embed_ln_b"],
        "layers": [{k: l[k] for k in sorted(ln_keys)} for l in base["layers"]],
    }
    frozen: Params = {
        k: v for k, v in base.items()
        if k not in ("embed_ln_g", "embed_ln_b", "layers")
    }
    frozen["layers"] = [
        {k: v for k, v in l.items() if k not in ln_keys} for l in base["layers"]
    ]
    return trained, frozen


def merge_ln(cfg: ModelConfig, trained: Params, frozen: Params) -> Params:
    base = dict(frozen)
    base["embed_ln_g"] = trained["embed_ln_g"]
    base["embed_ln_b"] = trained["embed_ln_b"]
    base["layers"] = [
        {**fl, **tl} for fl, tl in zip(frozen["layers"], trained["layers"])
    ]
    return base


def split_base_for_adapter(cfg: ModelConfig, base: Params) -> Tuple[Params, Params]:
    """Adapter tuning trains the LayerNorms too (paper §2.1)."""
    return split_base_for_ln(cfg, base)


merge_adapter_base = merge_ln


# ---------------------------------------------------------------------------
# Adam (inside the graph; lr is a runtime input, schedule lives in Rust)
# ---------------------------------------------------------------------------


ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8


def adam_init(trained: Params) -> Tuple[Params, Params]:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, trained)
    return zeros, jax.tree_util.tree_map(jnp.zeros_like, trained)


def adam_update(trained, grads, m, v, step, lr):
    """One Adam step. ``step`` is the 1-based i32 step for bias correction."""
    t = step.astype(jnp.float32)
    bc1 = 1.0 - ADAM_B1 ** t
    bc2 = 1.0 - ADAM_B2 ** t
    m = jax.tree_util.tree_map(lambda mm, g: ADAM_B1 * mm + (1 - ADAM_B1) * g, m, grads)
    v = jax.tree_util.tree_map(lambda vv, g: ADAM_B2 * vv + (1 - ADAM_B2) * g * g, v, grads)
    new = jax.tree_util.tree_map(
        lambda p, mm, vv: p - lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + ADAM_EPS),
        trained, m, v,
    )
    return new, m, v
