"""AOT pipeline invariants: manifest ↔ lowered HLO consistency.

The Rust runtime trusts the manifest blindly (positional packing), so these
tests are the contract check: the recorded leaf order, shapes and dtypes
must match both the example pytrees and the HLO entry computation.
"""

import json
import os

import jax
import jax.numpy as jnp
import jax.tree_util as tu
import pytest

from compile import aot
from compile import model as M

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "test")

pytestmark = pytest.mark.skipif(
    not os.path.exists(os.path.join(ART, "manifest.json")),
    reason="test-preset artifacts not built (run `make artifacts-test`)",
)


def manifest():
    with open(os.path.join(ART, "manifest.json")) as f:
        return json.load(f)


def test_manifest_covers_registry():
    man = manifest()
    names = {e["name"] for e in man["executables"]}
    expected = {a.name for a in aot.build_registry("test")}
    assert names == expected


def test_manifest_files_exist_and_parse_as_hlo():
    man = manifest()
    for e in man["executables"]:
        path = os.path.join(ART, e["file"])
        assert os.path.exists(path), e["file"]
        head = open(path).read(200)
        assert "HloModule" in head, f"{e['file']} is not HLO text"


def test_manifest_input_order_matches_flattened_args():
    """Leaf order in the manifest == jax flattening order of example args."""
    man = {e["name"]: e for e in manifest()["executables"]}
    for art in aot.build_registry("test"):
        entry = man[art.name]
        flat = tu.tree_flatten(art.args)[0]
        assert len(flat) == len(entry["inputs"]), art.name
        for leaf, rec in zip(flat, entry["inputs"]):
            assert list(leaf.shape) == rec["shape"], (art.name, rec["name"])
            want_dt = {"float32": "f32", "int32": "i32"}[str(leaf.dtype)]
            assert want_dt == rec["dtype"], (art.name, rec["name"])


def test_manifest_output_order_matches_eval_shape():
    man = {e["name"]: e for e in manifest()["executables"]}
    for art in aot.build_registry("test"):
        out = jax.eval_shape(art.fn, *art.args)
        flat = tu.tree_flatten(out)[0]
        entry = man[art.name]
        assert len(flat) == len(entry["outputs"]), art.name
        for leaf, rec in zip(flat, entry["outputs"]):
            assert list(leaf.shape) == rec["shape"], (art.name, rec["name"])


def test_hlo_entry_parameter_count_matches_manifest():
    """The HLO ENTRY computation must take exactly the manifest's inputs."""
    man = manifest()
    for e in man["executables"]:
        text = open(os.path.join(ART, e["file"])).read()
        # ENTRY is the last computation; its body lists one
        # `%Arg_k = ... parameter(k)` instruction per input.
        body = text[text.index("\nENTRY "):]
        n_params = sum(
            1 for l in body.splitlines() if " parameter(" in l
        )
        assert n_params == len(e["inputs"]), e["name"]


def test_groups_partition_inputs():
    """Every input belongs to exactly one group; group order is contiguous."""
    for e in manifest()["executables"]:
        seen = []
        for rec in e["inputs"]:
            if not seen or seen[-1] != rec["group"]:
                seen.append(rec["group"])
        assert len(seen) == len(set(seen)), f"{e['name']}: interleaved groups"


def test_adam_constants_recorded():
    man = manifest()
    assert man["adam"] == {"b1": M.ADAM_B1, "b2": M.ADAM_B2, "eps": M.ADAM_EPS}


def test_config_roundtrip():
    man = manifest()
    cfg = M.PRESETS["test"]
    for k, v in man["config"].items():
        assert getattr(cfg, k) == v
