//! Training drivers: the per-step numeric work is AOT-compiled; Rust owns
//! schedules, selection and orchestration.
//!
//! `loop` — single-run training with best-on-validation selection;
//! `pretrain` — MLM pre-training of the shared base;
//! `sweep` — hyper-parameter grids with fan-out over worker threads.

pub mod r#loop;
pub mod pretrain;
pub mod sweep;

pub use r#loop::{lr_at, train_task, TrainConfig, TrainResult};
pub use pretrain::{load_or_pretrain, pretrain, PretrainConfig};
pub use sweep::{run_sweep, SweepGrid, SweepOutcome};
