//! Tokenizer: text ↔ token ids for the serving path.
//!
//! The synthetic world is defined over token ids; to exercise a realistic
//! request path (clients send *text*), every word id gets a deterministic
//! pronounceable surface form ("zu", "kari", "moresa", …) built from CV
//! syllables. The vocabulary is a bijection, so round-trips are exact —
//! which the tests pin, and which makes the serving demo's inputs/outputs
//! human-readable.

use std::collections::HashMap;

use crate::data::grammar::{CLS, MASK, PAD, SEP, WORD0};

const CONSONANTS: &[&str] = &[
    "b", "d", "f", "g", "h", "j", "k", "l", "m", "n", "p", "r", "s", "t", "v", "z",
];
const VOWELS: &[&str] = &["a", "e", "i", "o", "u"];

/// Deterministic surface form for a word id (id ≥ WORD0).
fn surface(word_index: usize) -> String {
    // base-80 positional code over CV syllables, at least two syllables so
    // words look like words and never collide with specials
    let mut n = word_index;
    let mut syllables = Vec::new();
    loop {
        let c = CONSONANTS[n % CONSONANTS.len()];
        let v = VOWELS[(n / CONSONANTS.len()) % VOWELS.len()];
        syllables.push(format!("{c}{v}"));
        n /= CONSONANTS.len() * VOWELS.len();
        if n == 0 {
            break;
        }
        n -= 1; // bijective numeration: no leading-zero ambiguity
    }
    syllables.reverse();
    syllables.concat()
}

#[derive(Debug, Clone)]
pub struct Tokenizer {
    pub vocab: usize,
    id_to_word: Vec<String>,
    word_to_id: HashMap<String, i32>,
}

impl Tokenizer {
    pub fn new(vocab: usize) -> Tokenizer {
        let mut id_to_word = vec![String::new(); vocab];
        id_to_word[PAD as usize] = "[PAD]".into();
        id_to_word[CLS as usize] = "[CLS]".into();
        id_to_word[SEP as usize] = "[SEP]".into();
        id_to_word[MASK as usize] = "[MASK]".into();
        for id in WORD0..vocab {
            id_to_word[id] = surface(id - WORD0);
        }
        let word_to_id = id_to_word
            .iter()
            .enumerate()
            .map(|(i, w)| (w.clone(), i as i32))
            .collect();
        Tokenizer { vocab, id_to_word, word_to_id }
    }

    /// Encode whitespace-separated text; unknown words map to `[MASK]`
    /// (the closest analogue of BERT's [UNK] in our 4-special layout).
    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.split_whitespace()
            .map(|w| *self.word_to_id.get(w).unwrap_or(&MASK))
            .collect()
    }

    /// Encode into the classifier wire format `[CLS] text…` padded to `seq`.
    pub fn encode_for_cls(&self, text: &str, seq: usize) -> (Vec<i32>, Vec<f32>) {
        let mut ids = vec![CLS];
        ids.extend(self.encode(text).into_iter().take(seq - 1));
        let mut mask = vec![1.0; ids.len()];
        while ids.len() < seq {
            ids.push(PAD);
            mask.push(0.0);
        }
        (ids, mask)
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        ids.iter()
            .filter(|&&id| id != PAD)
            .map(|&id| self.id_to_word[id as usize].as_str())
            .collect::<Vec<_>>()
            .join(" ")
    }

    pub fn word(&self, id: i32) -> &str {
        &self.id_to_word[id as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn surfaces_are_unique() {
        let t = Tokenizer::new(1024);
        let mut seen = std::collections::HashSet::new();
        for w in &t.id_to_word {
            assert!(seen.insert(w.clone()), "duplicate surface {w}");
        }
    }

    #[test]
    fn roundtrip_exact() {
        let t = Tokenizer::new(512);
        let ids: Vec<i32> = vec![5, 100, 511, 42, 4];
        let text = t.decode(&ids);
        assert_eq!(t.encode(&text), ids);
    }

    #[test]
    fn encode_for_cls_pads_and_masks() {
        let t = Tokenizer::new(256);
        let text = format!("{} {}", t.word(10), t.word(20));
        let (ids, mask) = t.encode_for_cls(&text, 8);
        assert_eq!(ids.len(), 8);
        assert_eq!(ids[0], CLS);
        assert_eq!(&ids[1..3], &[10, 20]);
        assert_eq!(ids[3..], [PAD; 5]);
        assert_eq!(&mask[0..3], &[1.0, 1.0, 1.0]);
        assert!(mask[3..].iter().all(|&m| m == 0.0));
    }

    #[test]
    fn unknown_words_become_mask() {
        let t = Tokenizer::new(256);
        assert_eq!(t.encode("xyzzyplugh"), vec![MASK]);
    }

    #[test]
    fn truncates_to_seq() {
        let t = Tokenizer::new(256);
        let long = (0..50).map(|_| t.word(9).to_string()).collect::<Vec<_>>().join(" ");
        let (ids, _) = t.encode_for_cls(&long, 16);
        assert_eq!(ids.len(), 16);
    }
}
