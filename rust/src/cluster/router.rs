//! The routing front-end: consistent-hash placement + health-checked
//! forwarding over the existing HTTP/1.1 wire protocol.
//!
//! A [`Router`] owns no model runtime at all — it is a thin tier in
//! front of N gateway replicas that all share one `AdapterStore`. Task
//! routes (`POST /predict`, `/predict_ids`, `/tasks`, `/train`) extract
//! the `task` field from the request body, hash it onto the
//! [`HashRing`](super::ring::HashRing), and forward the request bytes
//! verbatim to the first *alive* replica on the key's preference list,
//! propagating the inbound `X-Request-Id` so the replica's `Request`
//! span and the router's `Forward` span correlate in the trace ring.
//!
//! Failover is the composition of three independent pieces:
//! * the ring's preference order says *where* a dead owner's shard
//!   spills (its clockwise successor — no other key moves);
//! * the [`ClusterView`](super::health::ClusterView) says *when*
//!   (`fail_after` bad signals eject; forward errors count, so crashes
//!   are detected at traffic speed);
//! * the shared store says *how* the new owner serves: hot-registered
//!   banks were appended to the store once, so the successor admits the
//!   task from store metadata and cold-loads its banks through the
//!   normal `BankSource` seam. No replica-to-replica state transfer.
//!
//! Fan-in routes: `GET /tasks` and `GET /train` union the replicas'
//! answers; `GET /health` reflects one healthy replica's identity
//! document annotated with per-replica liveness; `GET /metrics` is the
//! router's own counters (JSON or Prometheus `adapterbert_router_*`).

use std::collections::BTreeMap;
use std::net::SocketAddr;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{ensure, Context, Result};

use super::health::{ClusterView, HealthMonitor, HealthPolicy};
use super::ring::{HashRing, DEFAULT_VNODES};
use crate::obs::prom::Prom;
use crate::obs::trace::{self, SpanKind, Stage};
use crate::serve::http::{
    ClientResponse, Handler, HttpConfig, HttpRequest, HttpResponse, HttpServer,
};
use crate::serve::{Client, ClientConfig, Deadline, LatencyHist, DEADLINE_HEADER};
use crate::util::json::Json;

use super::breaker::{Breaker, BreakerPolicy};

/// Router knobs.
#[derive(Debug, Clone)]
pub struct RouterConfig {
    /// Bind address (`host:port`, port 0 = ephemeral).
    pub addr: String,
    pub http: HttpConfig,
    /// Virtual nodes per replica on the hash ring.
    pub vnodes: usize,
    pub health: HealthPolicy,
    /// Dial/read behavior for upstream forwards.
    pub upstream: ClientConfig,
    /// Idle keep-alive connections retained per replica.
    pub pool_per_replica: usize,
    /// Per-replica circuit breaker: consecutive forward failures open
    /// the circuit so later requests fast-fail to the ring successor
    /// instead of eating the upstream read timeout each.
    pub breaker: BreakerPolicy,
    /// Record `Forward` spans in the global trace ring.
    pub trace: bool,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            addr: "127.0.0.1:0".to_string(),
            http: HttpConfig::default(),
            vnodes: DEFAULT_VNODES,
            health: HealthPolicy::default(),
            upstream: ClientConfig {
                connect_timeout: std::time::Duration::from_secs(2),
                read_timeout: Some(std::time::Duration::from_secs(30)),
                // the preference walk is the retry mechanism; per-dial
                // retries would just slow ejection down
                retries: 0,
                backoff: std::time::Duration::from_millis(10),
                // forwards carry the *inbound* request's budget, re-minted
                // per forward — a per-connection deadline would be wrong
                deadline: None,
            },
            pool_per_replica: 8,
            breaker: BreakerPolicy::default(),
            trace: false,
        }
    }
}

/// Router-tier counters (the replicas keep their own).
struct RouterStats {
    /// Successful forwards, per replica.
    forwards: Vec<AtomicU64>,
    /// Forward attempts that died on the wire (feeds passive ejection).
    forward_errors: AtomicU64,
    /// Requests that landed on a non-primary replica (failover working).
    reroutes: AtomicU64,
    /// Requests refused because no replica was alive.
    no_replica: AtomicU64,
    /// Task routes with no parsable `task` field (400s).
    bad_requests: AtomicU64,
    /// Requests refused (504) because their budget expired at this tier.
    deadline_rejected: AtomicU64,
    /// Wall time of successful forwards, upstream-inclusive.
    latency: Mutex<LatencyHist>,
}

/// Shared handler state behind the router's HTTP server.
pub struct RouterState {
    ring: HashRing,
    view: Arc<ClusterView>,
    pools: Vec<Mutex<Vec<Client>>>,
    breaker: Breaker,
    cfg: RouterConfig,
    stats: RouterStats,
}

impl Handler for RouterState {
    fn handle(&self, req: &HttpRequest) -> HttpResponse {
        // honor the inbound id, mint otherwise — and pass it upstream on
        // every forward, so one rid names the request across both tiers
        let rid = match req.header("x-request-id") {
            Some(v) if !v.trim().is_empty() => v.trim().to_string(),
            _ => trace::global().gen_rid(),
        };
        let (path, query) = match req.path.split_once('?') {
            Some((p, q)) => (p, Some(q)),
            None => (req.path.as_str(), None),
        };
        let resp = match (req.method.as_str(), path) {
            ("GET", "/health") => self.health(&rid),
            ("GET", "/tasks") => self.fan_in(&rid, "/tasks", "tasks", "task"),
            ("GET", "/train") => self.fan_in(&rid, "/train", "jobs", "job_id"),
            ("GET", "/metrics") => {
                let prom = query
                    .map(|q| q.split('&').any(|kv| kv == "format=prometheus"))
                    .unwrap_or(false);
                if prom {
                    self.metrics_prometheus()
                } else {
                    self.metrics()
                }
            }
            ("GET", "/trace") => self.trace_spans(),
            ("GET", p) if p.starts_with("/train/") => self.train_status(p, &rid),
            ("POST", "/predict" | "/predict_ids" | "/tasks" | "/train") => {
                self.forward_by_task(req, path, &rid)
            }
            ("GET" | "POST", _) => HttpResponse::error(404, "no such route"),
            _ => HttpResponse::error(405, "method not allowed"),
        };
        resp.with_header("x-request-id", &rid)
    }
}

impl RouterState {
    /// A task route: hash the body's `task` onto the ring, forward to
    /// the first alive replica in preference order, walking onward when
    /// a forward dies on the wire. The replica's status and body pass
    /// through untouched — the router adds no opinion of its own to a
    /// 4xx/5xx the owner chose to send.
    fn forward_by_task(&self, req: &HttpRequest, path: &str, rid: &str) -> HttpResponse {
        let task = req
            .json_body()
            .ok()
            .and_then(|j| j.get("task").and_then(Json::as_str).map(str::to_string));
        let Some(task) = task else {
            self.stats.bad_requests.fetch_add(1, Ordering::Relaxed);
            return HttpResponse::error(
                400,
                "body must be a JSON object with a \"task\" field",
            );
        };
        // re-anchor the inbound budget to this tier's clock; the walk
        // below spends it, and each forward re-mints what is left
        let deadline = req.header(DEADLINE_HEADER).and_then(Deadline::from_header);
        let mut attempted = 0usize;
        for i in self.ring.preference(&task) {
            if !self.view.is_alive(i) {
                continue;
            }
            if let Some(d) = &deadline {
                if d.expired() {
                    self.stats.deadline_rejected.fetch_add(1, Ordering::Relaxed);
                    return HttpResponse::error(
                        504,
                        &format!("deadline exceeded for task {task:?} at router"),
                    );
                }
            }
            // open circuit: fast-fail to the successor inside the
            // caller's budget instead of a wire timeout
            if !self.breaker.allow(i) {
                continue;
            }
            if attempted > 0 {
                self.stats.reroutes.fetch_add(1, Ordering::Relaxed);
            }
            attempted += 1;
            let fwd = self.forward(
                i,
                &req.method,
                path,
                Some(&req.body),
                &task,
                rid,
                deadline.as_ref(),
            );
            match fwd {
                Ok(resp) => return passthrough(resp),
                Err(e) => {
                    crate::log_warn!(
                        "cluster",
                        "forward failed rid={rid} task={task} replica={} err={e:#}",
                        self.ring.node(i)
                    );
                }
            }
        }
        if attempted == 0 {
            self.stats.no_replica.fetch_add(1, Ordering::Relaxed);
            HttpResponse::error(503, &format!("no healthy replica for task {task:?}"))
                .with_header("retry-after", "1")
        } else {
            HttpResponse::error(
                502,
                &format!("all {attempted} live replica(s) failed for task {task:?}"),
            )
        }
    }

    /// One upstream hop, wrapped in a `Forward` span sharing the rid
    /// with the replica-side `Request` span. The outcome feeds both the
    /// health view and the circuit breaker as passive signals.
    #[allow(clippy::too_many_arguments)]
    fn forward(
        &self,
        i: usize,
        method: &str,
        path: &str,
        body: Option<&[u8]>,
        task: &str,
        rid: &str,
        deadline: Option<&Deadline>,
    ) -> Result<ClientResponse> {
        let recorder = trace::global();
        let span = recorder.begin(SpanKind::Forward, rid);
        span.set_task(task);
        let t0 = Instant::now();
        let result = self.roundtrip_pooled_deadline(i, method, path, body, rid, deadline);
        match &result {
            Ok(resp) => {
                span.set_status(resp.status);
                self.stats.forwards[i].fetch_add(1, Ordering::Relaxed);
                self.stats.latency.lock().unwrap().record(t0.elapsed());
                self.breaker.record_success(i);
            }
            Err(_) => {
                span.set_status(502);
                self.stats.forward_errors.fetch_add(1, Ordering::Relaxed);
                // a wire death is a liveness signal, not just a lost
                // request — crashes eject at traffic speed
                self.view.record_fail(i);
                self.breaker.record_failure(i);
            }
        }
        span.mark(Stage::Responded);
        recorder.record(&span);
        result
    }

    fn roundtrip_pooled(
        &self,
        i: usize,
        method: &str,
        path: &str,
        body: Option<&[u8]>,
        rid: &str,
    ) -> Result<ClientResponse> {
        self.roundtrip_pooled_deadline(i, method, path, body, rid, None)
    }

    /// Checkout-or-dial a connection to replica `i`, round-trip the raw
    /// bytes with the rid (and the re-minted remaining budget, when the
    /// request carries one) attached, return the connection to the pool
    /// on success. A stale keep-alive (replica restarted, idle timeout)
    /// gets one fresh dial before the attempt counts as failed. With a
    /// deadline, the socket read wait defaults to the remaining budget
    /// rather than the full configured upstream read timeout.
    fn roundtrip_pooled_deadline(
        &self,
        i: usize,
        method: &str,
        path: &str,
        body: Option<&[u8]>,
        rid: &str,
        deadline: Option<&Deadline>,
    ) -> Result<ClientResponse> {
        let pooled = self.pools[i].lock().unwrap().pop();
        let mut client = match pooled {
            Some(c) => c,
            None => Client::connect_with(self.ring.node(i), self.cfg.upstream.clone())?,
        };
        client.clamp_read_to(deadline)?;
        let budget = deadline.map(|d| d.header_value());
        let mut extra: Vec<(&str, &str)> = vec![("x-request-id", rid)];
        if let Some(v) = budget.as_deref() {
            extra.push((DEADLINE_HEADER, v));
        }
        let resp = match client.roundtrip_raw(method, path, body, &extra) {
            Ok(r) => r,
            Err(_) => {
                client.reconnect()?;
                client.clamp_read_to(deadline)?;
                client.roundtrip_raw(method, path, body, &extra)?
            }
        };
        let mut pool = self.pools[i].lock().unwrap();
        if pool.len() < self.cfg.pool_per_replica {
            pool.push(client);
        }
        Ok(resp)
    }

    /// `GET /health`: one healthy replica's identity document (clients
    /// bootstrap tokenizers from `vocab`/`seq`, so those fields must
    /// survive the extra tier) annotated with the router's per-replica
    /// liveness. 503 when the whole fleet is dark.
    fn health(&self, rid: &str) -> HttpResponse {
        let mask = self.view.alive_mask();
        let mut base: Option<Json> = None;
        for (i, alive) in mask.iter().enumerate() {
            if !alive {
                continue;
            }
            if let Ok(resp) = self.roundtrip_pooled(i, "GET", "/health", None, rid) {
                if resp.status == 200 {
                    if let Some(j) = parse_body(&resp.body) {
                        base = Some(j);
                        break;
                    }
                }
            }
        }
        match base {
            Some(Json::Obj(mut doc)) => {
                doc.insert("role".to_string(), Json::str("router"));
                doc.insert("replicas".to_string(), self.replica_json(&mask));
                doc.insert(
                    "healthy".to_string(),
                    Json::num(mask.iter().filter(|a| **a).count() as f64),
                );
                HttpResponse::json(200, &Json::Obj(doc))
            }
            _ => HttpResponse::error(503, "no healthy replicas"),
        }
    }

    fn replica_json(&self, mask: &[bool]) -> Json {
        Json::arr(self.view.nodes().iter().enumerate().map(|(i, addr)| {
            Json::obj(vec![
                ("addr", Json::str(addr)),
                ("alive", Json::Bool(mask[i])),
                (
                    "forwards",
                    Json::num(self.stats.forwards[i].load(Ordering::Relaxed) as f64),
                ),
            ])
        }))
    }

    /// `GET /tasks` / `GET /train`: ask every live replica, union the
    /// named array, dedup by `key` (first answer wins — entries for the
    /// same task are equal anyway, since all replicas serve one store).
    fn fan_in(&self, rid: &str, path: &str, array: &str, key: &str) -> HttpResponse {
        let mut merged: BTreeMap<String, Json> = BTreeMap::new();
        let mut reached = false;
        for (i, alive) in self.view.alive_mask().iter().enumerate() {
            if !alive {
                continue;
            }
            let Ok(resp) = self.roundtrip_pooled(i, "GET", path, None, rid) else {
                continue;
            };
            if resp.status != 200 {
                continue;
            }
            let Some(j) = parse_body(&resp.body) else { continue };
            reached = true;
            if let Some(arr) = j.get(array).and_then(Json::as_arr) {
                for entry in arr {
                    let id = match entry.get(key) {
                        Some(Json::Str(s)) => s.clone(),
                        Some(Json::Num(n)) => format!("{n}"),
                        _ => continue,
                    };
                    merged.entry(id).or_insert_with(|| entry.clone());
                }
            }
        }
        if !reached {
            return HttpResponse::error(503, "no healthy replicas");
        }
        HttpResponse::json(
            200,
            &Json::obj(vec![(
                array,
                Json::arr(merged.into_values().collect::<Vec<_>>()),
            )]),
        )
    }

    /// `GET /train/<id>`: job ids are replica-local, so ask each live
    /// replica in turn and pass through the first non-404 answer.
    fn train_status(&self, path: &str, rid: &str) -> HttpResponse {
        let mut reached = false;
        for (i, alive) in self.view.alive_mask().iter().enumerate() {
            if !alive {
                continue;
            }
            let Ok(resp) = self.roundtrip_pooled(i, "GET", path, None, rid) else {
                continue;
            };
            reached = true;
            if resp.status != 404 {
                return passthrough(resp);
            }
        }
        if reached {
            HttpResponse::error(404, "no replica knows this job")
        } else {
            HttpResponse::error(503, "no healthy replicas")
        }
    }

    /// `GET /metrics`: the router tier's own counters.
    fn metrics(&self) -> HttpResponse {
        let mask = self.view.alive_mask();
        let s = &self.stats;
        let total: u64 = s.forwards.iter().map(|f| f.load(Ordering::Relaxed)).sum();
        let j = Json::obj(vec![
            ("role", Json::str("router")),
            ("replicas", self.replica_json(&mask)),
            (
                "healthy",
                Json::num(mask.iter().filter(|a| **a).count() as f64),
            ),
            ("forwards", Json::num(total as f64)),
            (
                "forward_errors",
                Json::num(s.forward_errors.load(Ordering::Relaxed) as f64),
            ),
            ("reroutes", Json::num(s.reroutes.load(Ordering::Relaxed) as f64)),
            (
                "no_replica",
                Json::num(s.no_replica.load(Ordering::Relaxed) as f64),
            ),
            (
                "bad_requests",
                Json::num(s.bad_requests.load(Ordering::Relaxed) as f64),
            ),
            (
                "deadline_rejected",
                Json::num(s.deadline_rejected.load(Ordering::Relaxed) as f64),
            ),
            (
                "breaker_fast_fails",
                Json::num(self.breaker.fast_fails() as f64),
            ),
            ("breaker_trips", Json::num(self.breaker.trips() as f64)),
            (
                "ejections",
                Json::num(self.view.ejections.load(Ordering::Relaxed) as f64),
            ),
            (
                "readmissions",
                Json::num(self.view.readmissions.load(Ordering::Relaxed) as f64),
            ),
            ("forward_latency", s.latency.lock().unwrap().to_json()),
        ]);
        HttpResponse::json(200, &j)
    }

    /// `GET /metrics?format=prometheus`: the same counters as text
    /// exposition, in the `adapterbert_router_*` namespace so a scrape
    /// config can keep router and replica series apart.
    fn metrics_prometheus(&self) -> HttpResponse {
        let mut p = Prom::new();
        let s = &self.stats;
        let mask = self.view.alive_mask();
        for (i, addr) in self.view.nodes().iter().enumerate() {
            p.counter(
                "adapterbert_router_forwards_total",
                "Successful upstream forwards.",
                &[("replica", addr)],
                s.forwards[i].load(Ordering::Relaxed) as f64,
            );
            p.gauge(
                "adapterbert_router_replica_alive",
                "1 when the replica is routable, 0 when ejected.",
                &[("replica", addr)],
                if mask[i] { 1.0 } else { 0.0 },
            );
            p.gauge(
                "adapterbert_router_breaker_open",
                "1 while the replica's circuit breaker is rejecting forwards.",
                &[("replica", addr)],
                if self.breaker.is_open(i) { 1.0 } else { 0.0 },
            );
        }
        p.counter(
            "adapterbert_router_deadline_rejected_total",
            "Requests shed 504 with their budget already expired at the router.",
            &[],
            s.deadline_rejected.load(Ordering::Relaxed) as f64,
        );
        p.counter(
            "adapterbert_router_breaker_fast_fails_total",
            "Forwards skipped because a replica's circuit was open.",
            &[],
            self.breaker.fast_fails() as f64,
        );
        p.counter(
            "adapterbert_router_breaker_trips_total",
            "Circuit transitions into the open state.",
            &[],
            self.breaker.trips() as f64,
        );
        p.counter(
            "adapterbert_router_forward_errors_total",
            "Forward attempts that died on the wire.",
            &[],
            s.forward_errors.load(Ordering::Relaxed) as f64,
        );
        p.counter(
            "adapterbert_router_reroutes_total",
            "Requests served by a non-primary replica.",
            &[],
            s.reroutes.load(Ordering::Relaxed) as f64,
        );
        p.counter(
            "adapterbert_router_no_replica_total",
            "Requests refused with no replica alive.",
            &[],
            s.no_replica.load(Ordering::Relaxed) as f64,
        );
        p.counter(
            "adapterbert_router_ejections_total",
            "Healthy→ejected transitions.",
            &[],
            self.view.ejections.load(Ordering::Relaxed) as f64,
        );
        p.counter(
            "adapterbert_router_readmissions_total",
            "Ejected→healthy transitions.",
            &[],
            self.view.readmissions.load(Ordering::Relaxed) as f64,
        );
        {
            let hist = s.latency.lock().unwrap();
            p.histogram(
                "adapterbert_router_forward_duration_seconds",
                "Wall time of successful forwards, upstream-inclusive.",
                &[],
                &hist.cumulative(),
                hist.sum_s(),
                hist.count(),
            );
        }
        HttpResponse::text(200, "text/plain; version=0.0.4", p.finish())
    }

    /// `GET /trace`: the global ring — on a router process that is
    /// `Forward` spans, one per upstream hop.
    fn trace_spans(&self) -> HttpResponse {
        let rec = trace::global();
        let spans: Vec<Json> = rec.snapshot().iter().map(|s| s.to_json()).collect();
        HttpResponse::json(
            200,
            &Json::obj(vec![
                ("enabled", Json::Bool(rec.enabled())),
                ("capacity", Json::num(rec.capacity() as f64)),
                ("recorded", Json::num(rec.recorded() as f64)),
                ("spans", Json::arr(spans)),
            ]),
        )
    }
}

/// Re-emit an upstream response downstream byte-exact (status + body;
/// the rid header is re-attached by `handle`).
fn passthrough(resp: ClientResponse) -> HttpResponse {
    let mut out = HttpResponse { status: resp.status, headers: Vec::new(), body: Vec::new() };
    if let Some(ct) = resp.header("content-type") {
        out.headers.push(("content-type".to_string(), ct.to_string()));
    }
    out.body = resp.body;
    out
}

fn parse_body(body: &[u8]) -> Option<Json> {
    Json::parse(std::str::from_utf8(body).ok()?).ok()
}

/// What a router did over its lifetime, returned by [`Router::shutdown`].
#[derive(Debug, Clone)]
pub struct RouterReport {
    pub forwards: u64,
    pub forward_errors: u64,
    pub reroutes: u64,
    pub no_replica: u64,
    pub deadline_rejected: u64,
    pub breaker_fast_fails: u64,
    pub breaker_trips: u64,
    pub ejections: u64,
    pub readmissions: u64,
}

/// The running tier: HTTP front-end + health monitor over a fixed
/// replica set.
pub struct Router {
    state: Arc<RouterState>,
    http: HttpServer,
    monitor: Option<HealthMonitor>,
}

impl Router {
    pub fn start(replicas: Vec<String>, cfg: RouterConfig) -> Result<Router> {
        ensure!(!replicas.is_empty(), "router needs at least one replica address");
        if cfg.trace {
            trace::global().set_enabled(true);
        }
        let ring = HashRing::new(&replicas, cfg.vnodes);
        let view = Arc::new(ClusterView::new(replicas.clone(), &cfg.health));
        let state = Arc::new(RouterState {
            ring,
            view: view.clone(),
            pools: replicas.iter().map(|_| Mutex::new(Vec::new())).collect(),
            breaker: Breaker::new(replicas.len(), cfg.breaker.clone()),
            stats: RouterStats {
                forwards: replicas.iter().map(|_| AtomicU64::new(0)).collect(),
                forward_errors: AtomicU64::new(0),
                reroutes: AtomicU64::new(0),
                no_replica: AtomicU64::new(0),
                bad_requests: AtomicU64::new(0),
                deadline_rejected: AtomicU64::new(0),
                latency: Mutex::new(LatencyHist::default()),
            },
            cfg: cfg.clone(),
        });
        let monitor = HealthMonitor::start(view, cfg.health.clone())?;
        let http = HttpServer::start(&cfg.addr, cfg.http.clone(), state.clone())
            .context("starting router http server")?;
        Ok(Router { state, http, monitor: Some(monitor) })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.http.local_addr()
    }

    /// Replicas currently routable (probe-side view).
    pub fn healthy_replicas(&self) -> usize {
        self.state.view.healthy_count()
    }

    /// The owning replica's address for a task, liveness-blind — what
    /// the ring says, not what failover is currently doing.
    pub fn owner_of(&self, task: &str) -> Option<&str> {
        self.state.ring.route(task).map(|i| self.state.ring.node(i))
    }

    pub fn shutdown(mut self) -> RouterReport {
        if let Some(m) = self.monitor.take() {
            m.stop();
        }
        self.http.stop();
        let s = &self.state.stats;
        RouterReport {
            forwards: s.forwards.iter().map(|f| f.load(Ordering::Relaxed)).sum(),
            forward_errors: s.forward_errors.load(Ordering::Relaxed),
            reroutes: s.reroutes.load(Ordering::Relaxed),
            no_replica: s.no_replica.load(Ordering::Relaxed),
            deadline_rejected: s.deadline_rejected.load(Ordering::Relaxed),
            breaker_fast_fails: self.state.breaker.fast_fails(),
            breaker_trips: self.state.breaker.trips(),
            ejections: self.state.view.ejections.load(Ordering::Relaxed),
            readmissions: self.state.view.readmissions.load(Ordering::Relaxed),
        }
    }
}
