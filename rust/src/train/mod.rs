//! Training drivers: the per-step numeric work is AOT-compiled; Rust owns
//! schedules, selection and orchestration.
//!
//! `loop` — single-run training, as a one-shot call (`train_task`) or the
//! resumable [`TrainState`] state machine;
//! `checkpoint` — the durable snapshot format `TrainState` persists;
//! `service` — background training jobs on a bounded pool, with
//! checkpoint/resume and live hot-install on completion;
//! `pretrain` — MLM pre-training of the shared base;
//! `sweep` — hyper-parameter grids with fan-out over worker threads.

pub mod checkpoint;
pub mod r#loop;
pub mod pretrain;
pub mod service;
pub mod sweep;

pub use checkpoint::TrainCheckpoint;
pub use r#loop::{lr_at, train_task, TrainConfig, TrainResult, TrainState};
pub use pretrain::{load_or_pretrain, pretrain, PretrainConfig};
pub use service::{
    InstallFn, JobRecord, JobSpec, JobState, ServiceConfig, TrainService,
};
pub use sweep::{run_sweep, SweepGrid, SweepOutcome};
