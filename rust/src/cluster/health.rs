//! Replica liveness: probe loop, ejection/readmission state machine.
//!
//! Each replica is `Healthy` or `Ejected`, with hysteresis on both
//! edges: `fail_after` consecutive bad signals eject it (the ring
//! spills its shard to the successor), `pass_after` consecutive good
//! probes readmit it (the shard snaps back — the store has everything
//! it needs to cold-load any bank it missed). A *signal* is either an
//! active probe (`GET /health` must return 200 **and** be ready:
//! status `ok`, not draining, store reachable) or a passive one — a
//! forward that dies on the wire counts as a failed probe, so a crash
//! is detected at traffic speed, not probe-interval speed.

use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::check::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use crate::check::sync::Arc;

use crate::serve::{Client, ClientConfig};

/// Probe cadence and hysteresis thresholds.
#[derive(Debug, Clone)]
pub struct HealthPolicy {
    /// Time between probe rounds (every replica is probed each round).
    pub interval: Duration,
    /// Connect + read budget for one probe.
    pub timeout: Duration,
    /// Consecutive bad signals before ejection.
    pub fail_after: u32,
    /// Consecutive good probes before readmission.
    pub pass_after: u32,
}

impl Default for HealthPolicy {
    fn default() -> Self {
        HealthPolicy {
            interval: Duration::from_millis(500),
            timeout: Duration::from_millis(1000),
            fail_after: 2,
            pass_after: 2,
        }
    }
}

/// Shared liveness state: read on every routed request, written by the
/// monitor thread and by forward-error reports. Lock-free — a request
/// never waits on the prober.
pub struct ClusterView {
    nodes: Vec<String>,
    alive: Vec<AtomicBool>,
    consec_fail: Vec<AtomicU32>,
    consec_pass: Vec<AtomicU32>,
    pub ejections: AtomicU64,
    pub readmissions: AtomicU64,
    fail_after: u32,
    pass_after: u32,
}

impl ClusterView {
    pub fn new(nodes: Vec<String>, policy: &HealthPolicy) -> ClusterView {
        let n = nodes.len();
        ClusterView {
            nodes,
            // optimistic start: replicas are routable until proven dead,
            // so a router can come up before its replicas finish booting
            alive: (0..n).map(|_| AtomicBool::new(true)).collect(),
            consec_fail: (0..n).map(|_| AtomicU32::new(0)).collect(),
            consec_pass: (0..n).map(|_| AtomicU32::new(0)).collect(),
            ejections: AtomicU64::new(0),
            readmissions: AtomicU64::new(0),
            fail_after: policy.fail_after.max(1),
            pass_after: policy.pass_after.max(1),
        }
    }

    pub fn nodes(&self) -> &[String] {
        &self.nodes
    }

    pub fn is_alive(&self, i: usize) -> bool {
        // Acquire: pairs with the AcqRel transition swaps so a router
        // that observes a flip also observes the streak resets and
        // transition counts that preceded it
        self.alive[i].load(Ordering::Acquire)
    }

    pub fn alive_mask(&self) -> Vec<bool> {
        // Acquire: see is_alive
        self.alive.iter().map(|a| a.load(Ordering::Acquire)).collect()
    }

    pub fn healthy_count(&self) -> usize {
        self.alive
            .iter()
            // Acquire: see is_alive
            .filter(|a| a.load(Ordering::Acquire))
            .count()
    }

    /// A good probe: reset the fail streak; if ejected, advance toward
    /// readmission.
    pub fn record_pass(&self, i: usize) {
        // relaxed: streak counters are only read back by this same
        // signal path (monitor thread + forward-error reporters); the
        // alive flip below is the publication point
        self.consec_fail[i].store(0, Ordering::Relaxed);
        if self.alive[i].load(Ordering::Acquire) {
            return;
        }
        // relaxed: see above — streak bookkeeping, not publication
        let passes = self.consec_pass[i].fetch_add(1, Ordering::Relaxed) + 1;
        if passes >= self.pass_after {
            // relaxed: reset before the AcqRel swap publishes it
            self.consec_pass[i].store(0, Ordering::Relaxed);
            // AcqRel: the transition point — Release publishes the streak
            // resets above to Acquire readers of `alive`, and the swap's
            // old value makes each flip count exactly once under racing
            // reporters
            if !self.alive[i].swap(true, Ordering::AcqRel) {
                // relaxed: monotonic metrics counter
                self.readmissions.fetch_add(1, Ordering::Relaxed);
                crate::log_info!(
                    "cluster",
                    "readmitting replica {} after {} passing probe(s)",
                    self.nodes[i],
                    passes
                );
            }
        }
    }

    /// A bad signal (failed probe, not-ready health, or forward error):
    /// reset the pass streak; if healthy, advance toward ejection.
    pub fn record_fail(&self, i: usize) {
        // relaxed: streak bookkeeping, see record_pass
        self.consec_pass[i].store(0, Ordering::Relaxed);
        if !self.alive[i].load(Ordering::Acquire) {
            return;
        }
        // relaxed: streak bookkeeping, see record_pass
        let fails = self.consec_fail[i].fetch_add(1, Ordering::Relaxed) + 1;
        if fails >= self.fail_after {
            // relaxed: reset before the AcqRel swap publishes it
            self.consec_fail[i].store(0, Ordering::Relaxed);
            // AcqRel: transition point, counted once; see record_pass
            if self.alive[i].swap(false, Ordering::AcqRel) {
                // relaxed: monotonic metrics counter
                self.ejections.fetch_add(1, Ordering::Relaxed);
                crate::log_info!(
                    "cluster",
                    "ejecting replica {} after {} bad signal(s)",
                    self.nodes[i],
                    fails
                );
            }
        }
    }
}

/// One probe: fresh connection (a pooled one could be wedged — that is
/// exactly what we're checking for), short timeouts, no retries. Ready
/// means the replica can actually take failover traffic, not merely
/// that its socket answers.
fn probe(addr: &str, policy: &HealthPolicy) -> bool {
    let cfg = ClientConfig {
        connect_timeout: policy.timeout,
        read_timeout: Some(policy.timeout),
        retries: 0,
        backoff: Duration::from_millis(1),
        // probes are their own timeout regime; no deadline header
        deadline: None,
    };
    match Client::connect_with(addr, cfg) {
        Ok(mut c) => match c.health() {
            Ok(h) => h.ready(),
            Err(_) => false,
        },
        Err(_) => false,
    }
}

/// The probe loop, on its own thread for the router's lifetime.
pub struct HealthMonitor {
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl HealthMonitor {
    pub fn start(view: Arc<ClusterView>, policy: HealthPolicy) -> Result<HealthMonitor> {
        let stop = Arc::new(AtomicBool::new(false));
        let stop_t = stop.clone();
        let handle = std::thread::Builder::new()
            .name("cluster-health".to_string())
            .spawn(move || {
                // relaxed: stop flag carries no data; the join in stop()
                // is the synchronization point
                while !stop_t.load(Ordering::Relaxed) {
                    for i in 0..view.nodes().len() {
                        // relaxed: see loop condition
                        if stop_t.load(Ordering::Relaxed) {
                            return;
                        }
                        if probe(&view.nodes()[i], &policy) {
                            view.record_pass(i);
                        } else {
                            view.record_fail(i);
                        }
                    }
                    // sleep in short slices so stop() doesn't wait out a
                    // long interval
                    let t0 = Instant::now();
                    // relaxed: see loop condition
                    while t0.elapsed() < policy.interval
                        && !stop_t.load(Ordering::Relaxed)
                    {
                        std::thread::sleep(Duration::from_millis(20).min(policy.interval));
                    }
                }
            })
            .context("spawning cluster health monitor")?;
        Ok(HealthMonitor { stop, handle: Some(handle) })
    }

    pub fn stop(mut self) {
        // relaxed: flag only; the join below synchronizes
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view(n: usize, fail_after: u32, pass_after: u32) -> ClusterView {
        let nodes = (0..n).map(|i| format!("n{i}")).collect();
        ClusterView::new(
            nodes,
            &HealthPolicy { fail_after, pass_after, ..Default::default() },
        )
    }

    #[test]
    fn ejection_needs_consecutive_failures() {
        let v = view(2, 3, 2);
        v.record_fail(0);
        v.record_fail(0);
        assert!(v.is_alive(0), "two of three failures is not enough");
        v.record_pass(0); // streak broken
        v.record_fail(0);
        v.record_fail(0);
        assert!(v.is_alive(0));
        v.record_fail(0);
        assert!(!v.is_alive(0), "third consecutive failure ejects");
        assert_eq!(v.ejections.load(Ordering::Relaxed), 1);
        assert!(v.is_alive(1), "other replica untouched");
        assert_eq!(v.healthy_count(), 1);
    }

    #[test]
    fn readmission_needs_consecutive_passes() {
        let v = view(1, 1, 2);
        v.record_fail(0);
        assert!(!v.is_alive(0));
        v.record_pass(0);
        assert!(!v.is_alive(0), "one pass is not enough");
        v.record_fail(0); // breaks the pass streak, already ejected
        v.record_pass(0);
        v.record_pass(0);
        assert!(v.is_alive(0), "two consecutive passes readmit");
        assert_eq!(v.readmissions.load(Ordering::Relaxed), 1);
        // a stable replica doesn't re-count readmissions
        v.record_pass(0);
        assert_eq!(v.readmissions.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn flapping_never_double_counts_transitions() {
        let v = view(1, 1, 1);
        for _ in 0..5 {
            v.record_fail(0);
            v.record_pass(0);
        }
        assert_eq!(v.ejections.load(Ordering::Relaxed), 5);
        assert_eq!(v.readmissions.load(Ordering::Relaxed), 5);
        assert!(v.is_alive(0));
    }
}
