//! adapterbert: reproduction of "Parameter-Efficient Transfer Learning for
//! NLP" (Houlsby et al., ICML 2019) as a three-layer Rust + JAX + Pallas
//! system. See ARCHITECTURE.md for the layer/backend architecture and
//! README.md for the quickstart and paper mapping.
//!
//! Layer map:
//!   * `runtime`   — pluggable execution backends (PJRT for the AOT
//!     HLO-text artifacts, pure-Rust native kernels) behind one facade
//!   * `model`     — parameter banks, partitions, initializers
//!   * `data`      — synthetic corpus + task suites (paper's 26 datasets)
//!   * `tokenizer` — text ↔ ids for the serving path
//!   * `train`     — training loops and hyper-parameter sweeps (paper §3.1)
//!   * `coordinator` — the cloud-service layer: task stream, router,
//!     batcher, server (paper §1's motivating setting)
//!   * `fuse`      — the fused multi-task batch engine's policy layer:
//!     cross-task flush planning for one-shared-trunk mixed batches
//!   * `serve`     — the networked gateway over the coordinator: HTTP
//!     front end, wire protocol, hot task registration, blocking client
//!   * `cluster`   — sharded multi-replica serving: consistent-hash
//!     router tier with health-checked failover over N gateways
//!   * `store`     — versioned adapter banks + checkpoints
//!   * `baseline`  — the no-BERT baseline searcher (Table 2, col. 1)
//!   * `eval`      — task metrics and GLUE-style aggregation
//!   * `report`    — table/figure emitters (stdout + CSV)
//!   * `obs`       — observability: leveled structured logging, request
//!     tracing (ring-buffer spans, Chrome trace export), Prometheus
//!     metric exposition, feature-gated kernel profiling
//!   * `util`      — dependency-free substrates (json/rng/stats/tensor)

pub mod baseline;
pub mod bench;
pub mod check;
pub mod cluster;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod fuse;
pub mod model;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod store;
pub mod tokenizer;
pub mod train;
pub mod util;
