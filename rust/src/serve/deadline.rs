//! Request deadline budgets, propagated hop-to-hop as remaining time.
//!
//! A deadline crosses process boundaries as the `X-Deadline-Ms` header
//! carrying the *remaining* budget in milliseconds — never an absolute
//! timestamp, so no cross-host clock agreement is needed. Each tier
//! parses the header into an [`Deadline`] anchored to its own clock,
//! spends local time (queueing, forwarding), and re-mints the header
//! with whatever budget is left when it forwards downstream. A request
//! whose budget hits zero is shed with `504 deadline exceeded` wherever
//! it is first noticed: at gateway admission, at batcher flush time, or
//! pre-execution in the worker — the engine never spends a trunk
//! forward on a request whose caller already gave up.

use std::time::{Duration, Instant};

/// Wire header carrying the remaining budget in integer milliseconds.
/// (Lower-case: our HTTP layer normalises header names on read.)
pub const DEADLINE_HEADER: &str = "x-deadline-ms";

/// A request deadline: an expiry instant on the local clock.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Deadline {
    expires: Instant,
}

impl Deadline {
    /// A deadline `budget` from now.
    pub fn after(budget: Duration) -> Deadline {
        Deadline { expires: Instant::now() + budget }
    }

    /// A deadline `ms` milliseconds from now.
    pub fn after_ms(ms: u64) -> Deadline {
        Deadline::after(Duration::from_millis(ms))
    }

    /// Parse an `X-Deadline-Ms` header value (remaining milliseconds)
    /// into a local deadline. Malformed values are ignored — a request
    /// with a garbage budget is treated as having no deadline rather
    /// than shed, so a buggy client degrades to pre-deadline behavior.
    pub fn from_header(value: &str) -> Option<Deadline> {
        value.trim().parse::<u64>().ok().map(Deadline::after_ms)
    }

    /// Remaining budget (zero once expired; never negative).
    pub fn remaining(&self) -> Duration {
        self.expires.saturating_duration_since(Instant::now())
    }

    /// Remaining budget in whole milliseconds.
    pub fn remaining_ms(&self) -> u64 {
        self.remaining().as_millis() as u64
    }

    /// True once the budget is exhausted.
    pub fn expired(&self) -> bool {
        self.remaining() == Duration::ZERO
    }

    /// Header value re-minting the *current* remaining budget for the
    /// next hop (floor of remaining ms — rounding down means budgets
    /// only shrink across hops, never grow).
    pub fn header_value(&self) -> String {
        self.remaining_ms().to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_budget_is_not_expired() {
        let d = Deadline::after_ms(10_000);
        assert!(!d.expired());
        let ms = d.remaining_ms();
        assert!(ms > 9_000 && ms <= 10_000, "remaining {ms}ms");
    }

    #[test]
    fn zero_budget_is_immediately_expired() {
        let d = Deadline::after_ms(0);
        assert!(d.expired());
        assert_eq!(d.remaining(), Duration::ZERO);
        assert_eq!(d.remaining_ms(), 0);
    }

    #[test]
    fn header_roundtrip_shrinks_monotonically() {
        let d = Deadline::after_ms(5_000);
        let v = d.header_value();
        let d2 = Deadline::from_header(&v).expect("numeric header parses");
        // the re-anchored deadline can only be tighter than the original
        assert!(d2.remaining_ms() <= d.remaining_ms() + 1);
        assert!(d2.remaining_ms() > 4_000);
    }

    #[test]
    fn malformed_header_is_ignored() {
        assert!(Deadline::from_header("").is_none());
        assert!(Deadline::from_header("abc").is_none());
        assert!(Deadline::from_header("-5").is_none());
        assert!(Deadline::from_header("1.5").is_none());
        assert!(Deadline::from_header(" 250 ").is_some());
    }

    #[test]
    fn expired_deadline_reports_zero_budget() {
        let d = Deadline { expires: Instant::now() - Duration::from_millis(50) };
        assert!(d.expired());
        assert_eq!(d.header_value(), "0");
    }
}
