//! Full lifecycle on one machine: pre-train the base from scratch with the
//! MLM objective (loss curve logged), then compare the paper's three
//! tuning strategies on one task — full fine-tuning, adapters, and
//! LayerNorm-only — reporting score vs trained-parameter count.
//!
//! This is the "train the system end-to-end and log the loss curve"
//! driver recorded in EXPERIMENTS.md.
//!
//! Run: `cargo run --release --example pretrain_and_adapt [--steps 600]`

use std::path::Path;
use std::sync::Arc;

use adapterbert::data::grammar::World;
use adapterbert::data::tasks::{self, TaskKind};
use adapterbert::eval::evaluate;
use adapterbert::runtime::Runtime;
use adapterbert::train::{self, PretrainConfig, TrainConfig};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args
        .iter()
        .position(|a| a == "--steps")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(600);

    let rt = Arc::new(Runtime::open(Path::new("artifacts"), "default")?);
    let dims = rt.manifest.dims.clone();
    let world = World::new(dims.vocab, 0);

    // --- phase 1: MLM pre-training from random init -----------------------
    println!("=== phase 1: MLM pre-training ({steps} steps) ===");
    let res = train::pretrain(
        &rt,
        &world,
        &PretrainConfig { steps, log_every: 50, ..Default::default() },
    )?;
    println!(
        "loss curve: {} samples, {:.3} → {:.3}",
        res.loss_curve.len(),
        res.initial_loss,
        res.final_loss
    );
    assert!(
        res.final_loss < res.initial_loss - 0.3,
        "pre-training must reduce MLM loss"
    );
    let base = res.base;

    // --- phase 2: three tuning strategies on one task ---------------------
    let spec = tasks::find_spec("qnli_s").unwrap();
    let data = tasks::generate(&world, &spec, dims.seq);
    let n_classes = match spec.kind {
        TaskKind::Cls { n_classes, .. } => n_classes,
        _ => unreachable!(),
    };
    println!("\n=== phase 2: tuning strategies on {} ===", spec.name);
    let full_k = dims.n_layers;
    let strategies = [
        ("full fine-tune", format!("cls_train_topk_k{full_k}"), 1e-4),
        ("adapters m=16", "cls_train_adapter_m16".to_string(), 1e-3),
        ("adapters m=4", "cls_train_adapter_m4".to_string(), 1e-3),
        ("layernorm only", "cls_train_lnonly".to_string(), 1e-3),
    ];
    let mut rows = Vec::new();
    for (label, exe, lr) in &strategies {
        let cfg = TrainConfig::new(exe, *lr, 6, 0);
        let out = train::train_task(&rt, &cfg, &data, &base)?;
        let test =
            evaluate(&rt, &out.model, &base, &data.test, n_classes, spec.metric)?;
        let params = out.model.trained_param_count_no_head();
        println!(
            "{label:16} test {test:.3}  trained params {params:7} \
             ({:.2}% of base)",
            100.0 * params as f64 / rt.manifest.base_param_count() as f64
        );
        rows.push((label.to_string(), test, params));
    }

    // paper-shape assertions: adapters ≈ FT at a fraction of the params;
    // LN-only trails both
    let ft = rows[0].1;
    let ad = rows[1].1;
    let ln = rows[3].1;
    println!(
        "\nshape check: FT {ft:.3} vs adapters {ad:.3} (Δ {:.3}); LN-only {ln:.3}",
        ft - ad
    );
    assert!(rows[1].2 < rows[0].2 / 10, "adapters must train ≪ FT params");
    assert!(
        ad > ln,
        "adapters should beat LayerNorm-only (paper Fig. 4)"
    );
    Ok(())
}
