//! Consistent-hash ring with virtual nodes.
//!
//! The router's placement function: task name → owning replica. Each
//! replica contributes `vnodes` points to a 64-bit hash circle (FNV-1a
//! over `"{addr}#{v}"`), and a key routes to the node owning the first
//! point at or clockwise-after the key's own hash. Virtual nodes keep
//! per-replica load within a small factor of uniform; consistent
//! hashing keeps churn minimal — adding or removing one of N replicas
//! remaps only ~1/N of the keyspace, so a membership change doesn't
//! stampede every replica's adapter cache at once.
//!
//! The ring is immutable after construction: membership is fixed at
//! router start, and *liveness* is layered on top by walking the
//! [`preference`](HashRing::preference) list (distinct owners in
//! successor order) and skipping ejected replicas. That way a failed
//! replica's shard spills to its ring successor — the same node that
//! would own those keys if the replica were removed outright — and
//! routing snaps back with zero churn when it is readmitted.

/// Virtual nodes per replica when the caller doesn't say.
pub const DEFAULT_VNODES: usize = 64;

/// FNV-1a with a splitmix64 avalanche finalizer. Plain FNV-1a leaves
/// the high bits poorly mixed for short, near-identical strings — and
/// vnode keys (`"10.0.0.2:7700#17"`) are exactly that shape, skewing
/// per-replica load far past 2× uniform. The finalizer restores the
/// balance guarantee; placement is still a pure function of the string,
/// so it is identical across router restarts.
pub fn hash_key(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h ^= h >> 30;
    h = h.wrapping_mul(0xbf58476d1ce4e5b9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94d049bb133111eb);
    h ^= h >> 31;
    h
}

/// The ring: `points` is sorted by position; each point names the index
/// of its owner in `nodes`.
#[derive(Debug, Clone)]
pub struct HashRing {
    nodes: Vec<String>,
    points: Vec<(u64, usize)>,
}

impl HashRing {
    pub fn new(nodes: &[String], vnodes: usize) -> HashRing {
        let vnodes = vnodes.max(1);
        let mut points = Vec::with_capacity(nodes.len() * vnodes);
        for (i, node) in nodes.iter().enumerate() {
            for v in 0..vnodes {
                points.push((hash_key(&format!("{node}#{v}")), i));
            }
        }
        points.sort_unstable();
        HashRing { nodes: nodes.to_vec(), points }
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn node(&self, i: usize) -> &str {
        &self.nodes[i]
    }

    pub fn nodes(&self) -> &[String] {
        &self.nodes
    }

    /// The owning node index for `key` (`None` on an empty ring).
    pub fn route(&self, key: &str) -> Option<usize> {
        self.preference_iter(key).next()
    }

    /// Every node exactly once, in clockwise-successor order from the
    /// key's position: `[owner, first failover target, second, …]`. The
    /// router forwards to the first *alive* entry, so a dead owner's
    /// keys land on the node that would inherit them if the owner were
    /// removed from the ring — no other key moves.
    pub fn preference(&self, key: &str) -> Vec<usize> {
        self.preference_iter(key).collect()
    }

    fn preference_iter(&self, key: &str) -> impl Iterator<Item = usize> + '_ {
        let start = if self.points.is_empty() {
            0
        } else {
            let h = hash_key(key);
            self.points.partition_point(|&(p, _)| p < h) % self.points.len()
        };
        let mut seen = vec![false; self.nodes.len()];
        let n = self.points.len();
        (0..n).filter_map(move |k| {
            let (_, i) = self.points[(start + k) % n];
            if seen[i] {
                None
            } else {
                seen[i] = true;
                Some(i)
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn addrs(n: usize) -> Vec<String> {
        (0..n).map(|i| format!("127.0.0.1:{}", 7700 + i)).collect()
    }

    #[test]
    fn routing_is_deterministic_and_total() {
        let ring = HashRing::new(&addrs(3), DEFAULT_VNODES);
        for k in 0..200 {
            let key = format!("task_{k}");
            let a = ring.route(&key).unwrap();
            let b = ring.route(&key).unwrap();
            assert_eq!(a, b, "{key}");
            assert!(a < 3);
        }
    }

    #[test]
    fn preference_lists_every_node_once_starting_with_owner() {
        let ring = HashRing::new(&addrs(4), DEFAULT_VNODES);
        for k in 0..50 {
            let key = format!("task_{k}");
            let pref = ring.preference(&key);
            assert_eq!(pref.len(), 4, "{key}");
            let mut sorted = pref.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, vec![0, 1, 2, 3], "{key}: {pref:?}");
            assert_eq!(pref[0], ring.route(&key).unwrap(), "{key}");
        }
    }

    #[test]
    fn empty_ring_routes_nowhere() {
        let ring = HashRing::new(&[], DEFAULT_VNODES);
        assert!(ring.is_empty());
        assert_eq!(ring.route("anything"), None);
        assert!(ring.preference("anything").is_empty());
    }

    #[test]
    fn single_node_owns_everything() {
        let ring = HashRing::new(&addrs(1), DEFAULT_VNODES);
        for k in 0..20 {
            assert_eq!(ring.route(&format!("t{k}")), Some(0));
        }
    }
}
