//! Leveled structured logger: `key=value` lines on stderr.
//!
//! One process-wide level, read lazily from `ADAPTERBERT_LOG`
//! (`error|warn|info|debug`). When the variable is unset the default is
//! [`Level::Error`], which keeps `cargo test` output clean; CLI entry
//! points call [`init_cli`] to raise the unset-default to [`Level::Warn`]
//! so operators still see warnings without any configuration.
//!
//! Use through the crate-root macros, which skip formatting entirely when
//! the level is disabled (one relaxed atomic load on the fast path):
//!
//! ```
//! adapterbert::log_warn!("store", "task={} quarantined path={:?}", "rte_s", "b.bin");
//! ```
//!
//! Line format (stderr):
//!
//! ```text
//! ts=1754650000.123 level=warn target=store task=rte_s quarantined path="b.bin"
//! ```
//!
//! The message body is free-form but by convention `key=value` pairs;
//! request-scoped lines include `rid=<request id>` (see `obs::trace`).

use std::fmt;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

/// Log severity, most severe first. Ordering is by verbosity: a level is
/// emitted when `level <= max_level()`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 1,
    Warn = 2,
    Info = 3,
    Debug = 4,
}

impl Level {
    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }

    /// Parse a level name (case-insensitive). `off`/`none` map to a level
    /// below `error` by returning `None` — callers treat that as "leave
    /// the default".
    pub fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }
}

/// 0 = uninitialized (first `enabled()` call reads the env).
static LEVEL: AtomicU8 = AtomicU8::new(0);

fn init(default: Level) -> u8 {
    let l = std::env::var("ADAPTERBERT_LOG")
        .ok()
        .and_then(|s| Level::parse(&s))
        .unwrap_or(default) as u8;
    // Racing initializers agree on the env value; only the default can
    // differ, and `init_cli` runs before any worker threads exist.
    LEVEL.store(l, Ordering::Relaxed);
    l
}

/// Initialize for a CLI run: `ADAPTERBERT_LOG` wins if set, otherwise
/// default to `warn` (library default is `error`). Call once from `main`.
pub fn init_cli() {
    init(Level::Warn);
}

/// Override the level programmatically (tests, `bench profile`).
pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

/// The current maximum emitted level.
pub fn max_level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => match init(Level::Error) {
            2 => Level::Warn,
            3 => Level::Info,
            4 => Level::Debug,
            _ => Level::Error,
        },
        2 => Level::Warn,
        3 => Level::Info,
        4 => Level::Debug,
        _ => Level::Error,
    }
}

/// Would a record at `l` be emitted? One relaxed load after first use.
#[inline]
pub fn enabled(l: Level) -> bool {
    let cur = LEVEL.load(Ordering::Relaxed);
    let cur = if cur == 0 { init(Level::Error) } else { cur };
    (l as u8) <= cur
}

/// Emit one line. Callers go through the macros, which pre-check
/// [`enabled`] so arguments are never formatted for disabled levels.
pub fn write(l: Level, target: &str, args: fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let ts = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    eprintln!(
        "ts={}.{:03} level={} target={} {}",
        ts.as_secs(),
        ts.subsec_millis(),
        l.as_str(),
        target,
        args
    );
}

/// `log_error!(target, fmt, args…)` — always-on operational errors.
#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Error) {
            $crate::obs::log::write(
                $crate::obs::log::Level::Error, $target, core::format_args!($($arg)*));
        }
    };
}

/// `log_warn!(target, fmt, args…)` — recoverable anomalies (quarantined
/// banks, backend fallbacks, slow requests).
#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Warn) {
            $crate::obs::log::write(
                $crate::obs::log::Level::Warn, $target, core::format_args!($($arg)*));
        }
    };
}

/// `log_info!(target, fmt, args…)` — lifecycle events (job started,
/// task installed, server draining).
#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Info) {
            $crate::obs::log::write(
                $crate::obs::log::Level::Info, $target, core::format_args!($($arg)*));
        }
    };
}

/// `log_debug!(target, fmt, args…)` — per-request / per-eviction detail.
#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::Debug) {
            $crate::obs::log::write(
                $crate::obs::log::Level::Debug, $target, core::format_args!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_and_order() {
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("debug"), Some(Level::Debug));
        assert_eq!(Level::parse("verbose"), None);
        assert!(Level::Error < Level::Debug);
    }

    #[test]
    fn set_level_gates() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Error);
        assert!(!enabled(Level::Warn));
    }
}
