//! Tracing-overhead harness → `BENCH_trace.json`.
//!
//! The claim under test: the trace recorder is cheap enough to leave on
//! in production. The harness stands up a complete gateway (two
//! pre-trained tenants), then drives the identical closed-loop predict
//! load in alternating rounds — tracing **off**, tracing **on** — and
//! compares the best (min) p95 of each mode; alternation plus min-of-N
//! keeps scheduler noise from masquerading as tracing overhead. After
//! the timed rounds it pulls `GET /trace` over the same socket and
//! checks the exported spans themselves: what fraction carry a complete
//! admission→queue→plan→execute→respond chain, what fraction's stage
//! durations sum to the end-to-end latency within 10%, and the
//! per-stage p50/p95 breakdown. The report is schema-pinned (v1); CI's
//! tracing smoke job validates it and gates on chain completeness and
//! recorded overhead.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{ensure, Context, Result};

use super::loadgen::{self, LoadgenConfig, LoadReport};
use crate::coordinator::{FlushPolicy, Server, ServerConfig};
use crate::data::grammar::World;
use crate::data::tasks::{self, Metric, TaskKind, TaskSpec};
use crate::obs::trace;
use crate::serve::{Client, Gateway, GatewayConfig};
use crate::store::AdapterStore;
use crate::train::{self, PretrainConfig, TrainConfig};
use crate::util::json::Json;

/// Harness knobs.
#[derive(Debug, Clone)]
pub struct ProfileConfig {
    pub preset: String,
    /// Predict requests per round (each mode runs `rounds` of these).
    pub requests: u64,
    /// Closed-loop client threads.
    pub concurrency: usize,
    /// Alternating off/on round pairs; per-mode p50/p95 are min-of-rounds.
    pub rounds: usize,
    /// Adapter size for the tenants.
    pub m: usize,
    /// MLM pre-training steps when no cached base exists.
    pub pretrain_steps: usize,
}

impl Default for ProfileConfig {
    fn default() -> Self {
        ProfileConfig {
            preset: "test".to_string(),
            requests: 200,
            concurrency: 2,
            rounds: 3,
            m: 8,
            pretrain_steps: 120,
        }
    }
}

/// One mode's serving numbers across its rounds.
#[derive(Debug, Clone)]
pub struct ModeStats {
    /// Total requests across the mode's rounds.
    pub requests: u64,
    pub errors: u64,
    /// Best (min) per-round percentile — the mode's noise floor.
    pub p50_ms: f64,
    pub p95_ms: f64,
}

impl ModeStats {
    fn from_rounds(rounds: &[LoadReport]) -> ModeStats {
        let min_pctl = |p: f64| {
            rounds
                .iter()
                .map(|r| r.all.pctl_s(p) * 1e3)
                .fold(f64::INFINITY, f64::min)
        };
        ModeStats {
            requests: rounds.iter().map(|r| r.requests).sum(),
            errors: rounds.iter().map(|r| r.errors).sum(),
            p50_ms: min_pctl(50.0),
            p95_ms: min_pctl(95.0),
        }
    }

    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("requests", Json::num(self.requests as f64)),
            ("errors", Json::num(self.errors as f64)),
            ("p50_ms", Json::num(self.p50_ms)),
            ("p95_ms", Json::num(self.p95_ms)),
        ])
    }
}

/// Chain-quality and stage-latency numbers from the exported spans.
#[derive(Debug, Clone)]
pub struct SpanAnalysis {
    /// Request-kind, status-200 spans the analysis covers.
    pub sampled: usize,
    /// Fraction with all six boundaries stamped, in order.
    pub complete_chain_frac: f64,
    /// Fraction whose stage durations sum to within 10% of `total_us`.
    pub stage_sum_within_10pct_frac: f64,
    /// Stage → (p50_ms, p95_ms, count), in lifecycle order.
    pub stages: Vec<(String, f64, f64, usize)>,
}

/// Percentile of an unsorted sample set (nearest-rank).
fn pctl(xs: &mut [f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (xs.len() - 1) as f64).round() as usize;
    xs[rank.min(xs.len() - 1)]
}

/// Analyze the `spans` array of a `GET /trace` body. Pure, so the span
/// acceptance predicates are unit-testable without a gateway.
pub fn analyze_spans(spans: &[Json]) -> SpanAnalysis {
    let mut sampled = 0usize;
    let mut complete = 0usize;
    let mut sum_ok = 0usize;
    let mut per_stage: BTreeMap<&str, Vec<f64>> = BTreeMap::new();
    for sp in spans {
        if sp.at("kind").as_str() != Some("request")
            || sp.at("status").as_usize() != Some(200)
        {
            continue;
        }
        sampled += 1;
        let is_complete = sp.at("complete").as_f64() == Some(1.0);
        if is_complete {
            complete += 1;
        }
        let total = sp.at("total_us").as_f64().unwrap_or(0.0);
        if let Some(stages) = sp.at("stages_us").as_obj() {
            let sum: f64 = stages.values().filter_map(Json::as_f64).sum();
            if is_complete && total > 0.0 && (sum - total).abs() <= 0.1 * total {
                sum_ok += 1;
            }
            for name in trace::STAGES {
                if let Some(us) = stages.get(name).and_then(|j| j.as_f64()) {
                    per_stage.entry(name).or_default().push(us / 1e3);
                }
            }
        }
    }
    let frac = |n: usize| if sampled == 0 { 0.0 } else { n as f64 / sampled as f64 };
    let stages = trace::STAGES
        .iter()
        .map(|&name| {
            let mut xs = per_stage.remove(name).unwrap_or_default();
            let (p50, p95) = (pctl(&mut xs, 50.0), pctl(&mut xs, 95.0));
            (name.to_string(), p50, p95, xs.len())
        })
        .collect();
    SpanAnalysis {
        sampled,
        complete_chain_frac: frac(complete),
        stage_sum_within_10pct_frac: frac(sum_ok),
        stages,
    }
}

/// The whole run: per-mode latencies plus the span-quality analysis.
#[derive(Debug)]
pub struct ProfileReport {
    pub baseline: ModeStats,
    pub tracing: ModeStats,
    pub analysis: SpanAnalysis,
}

impl ProfileReport {
    /// Tracing-on p95 over tracing-off p95, as a percentage delta.
    pub fn overhead_p95_pct(&self) -> f64 {
        if self.baseline.p95_ms <= 0.0 {
            return 0.0;
        }
        (self.tracing.p95_ms - self.baseline.p95_ms) / self.baseline.p95_ms * 100.0
    }

    /// The `BENCH_trace.json` document (schema v1).
    pub fn to_json(&self, cfg: &ProfileConfig) -> Json {
        let stages = Json::obj(
            self.analysis
                .stages
                .iter()
                .map(|(name, p50, p95, count)| {
                    (
                        name.as_str(),
                        Json::obj(vec![
                            ("p50_ms", Json::num(*p50)),
                            ("p95_ms", Json::num(*p95)),
                            ("count", Json::num(*count as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        Json::obj(vec![
            ("bench", Json::str("trace")),
            ("schema_version", Json::num(1.0)),
            (
                "config",
                Json::obj(vec![
                    ("preset", Json::str(&cfg.preset)),
                    ("requests", Json::num(cfg.requests as f64)),
                    ("concurrency", Json::num(cfg.concurrency as f64)),
                    ("rounds", Json::num(cfg.rounds as f64)),
                ]),
            ),
            ("baseline", self.baseline.to_json()),
            ("tracing", self.tracing.to_json()),
            ("overhead_p95_pct", Json::num(self.overhead_p95_pct())),
            ("spans_sampled", Json::num(self.analysis.sampled as f64)),
            (
                "complete_chain_frac",
                Json::num(self.analysis.complete_chain_frac),
            ),
            (
                "stage_sum_within_10pct_frac",
                Json::num(self.analysis.stage_sum_within_10pct_frac),
            ),
            ("stages", stages),
        ])
    }
}

fn tenant_spec(name: &str, seed: u64) -> TaskSpec {
    TaskSpec {
        name: name.to_string(),
        kind: TaskKind::Cls { n_classes: 2, pair: false },
        metric: Metric::Accuracy,
        n_train: 240,
        n_val: 48,
        n_test: 48,
        purity: 0.85,
        noise: 0.0,
        seed,
    }
}

/// Stand up the gateway and run the alternating off/on rounds.
pub fn run(cfg: &ProfileConfig) -> Result<ProfileReport> {
    let rt = Arc::new(crate::runtime::Runtime::open(
        Path::new("artifacts"),
        &cfg.preset,
    )?);
    let world = World::new(rt.manifest.dims.vocab, 0);
    let base = train::load_or_pretrain(
        &rt,
        &world,
        &PretrainConfig { steps: cfg.pretrain_steps, ..Default::default() },
        Path::new(&format!("runs/base_{}.bank", cfg.preset)),
    )?;

    let store = Arc::new(AdapterStore::in_memory());
    let mut classes = BTreeMap::new();
    let exe = format!("cls_train_adapter_m{}", cfg.m);
    for (name, seed) in [("pra", 21u64), ("prb", 22u64)] {
        let data = tasks::generate(&world, &tenant_spec(name, seed), rt.manifest.dims.seq);
        let res = train::train_task(
            &rt,
            &TrainConfig::new(&exe, 1e-3, 3, 0),
            &data,
            &base,
        )?;
        store.register(name, &res.model, res.val_score)?;
        classes.insert(name.to_string(), 2usize);
        println!("  tenant {name}: val {:.3}", res.val_score);
    }

    let server = Arc::new(Server::start(
        rt.clone(),
        &store,
        &base,
        &classes,
        ServerConfig {
            flush: FlushPolicy {
                max_batch: rt.manifest.batch,
                max_delay: Duration::from_millis(2),
            },
            executors: 2,
            ..Default::default()
        },
    )?);
    let gw = Gateway::start_with_trainer(
        rt,
        store,
        server,
        None,
        GatewayConfig { addr: "127.0.0.1:0".to_string(), ..Default::default() },
    )?;
    let addr = gw.local_addr().to_string();

    let recorder = trace::global();
    recorder.set_enabled(false);
    recorder.clear();

    let load_cfg = |seed: u64| LoadgenConfig {
        addr: addr.clone(),
        tasks: vec!["pra".into(), "prb".into()],
        concurrency: cfg.concurrency,
        requests: cfg.requests,
        seed,
        ..Default::default()
    };

    // untimed warmup: first-connection and cold-path costs stay out of
    // both modes' numbers
    let warm = loadgen::run(&LoadgenConfig { requests: 40, ..load_cfg(3) })?;
    ensure!(warm.errors == 0, "{} warmup request(s) failed", warm.errors);

    let mut off_rounds = Vec::new();
    let mut on_rounds = Vec::new();
    for round in 0..cfg.rounds.max(1) {
        recorder.set_enabled(false);
        println!("  round {round}: tracing off, {} requests …", cfg.requests);
        let off = loadgen::run(&load_cfg(10 + round as u64))?;
        ensure!(off.errors == 0, "{} tracing-off request(s) failed", off.errors);
        off_rounds.push(off);

        recorder.set_enabled(true);
        println!("  round {round}: tracing on,  {} requests …", cfg.requests);
        let on = loadgen::run(&load_cfg(50 + round as u64))?;
        ensure!(on.errors == 0, "{} tracing-on request(s) failed", on.errors);
        on_rounds.push(on);
    }

    // the span chains, exported over the same socket the load used
    let mut client = Client::connect(&addr)?;
    let trace_body = client.trace().context("GET /trace")?;
    ensure!(
        trace_body.at("enabled").as_bool() == Some(true),
        "recorder reports disabled after tracing-on rounds"
    );
    let spans = trace_body
        .at("spans")
        .as_arr()
        .context("trace body has no spans array")?;
    let analysis = analyze_spans(spans);
    ensure!(analysis.sampled > 0, "tracing-on rounds left no spans in the ring");
    drop(client);
    gw.shutdown()?;

    Ok(ProfileReport {
        baseline: ModeStats::from_rounds(&off_rounds),
        tracing: ModeStats::from_rounds(&on_rounds),
        analysis,
    })
}

/// Atomically persist the report (same contract as the other benches).
pub fn write_report(path: &Path, report: &Json) -> Result<()> {
    loadgen::write_report(path, report)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(status: f64, stages: Vec<(&str, f64)>, total: f64, complete: f64) -> Json {
        Json::obj(vec![
            ("kind", Json::str("request")),
            ("rid", Json::str("req-t")),
            ("task", Json::str("pra")),
            ("status", Json::num(status)),
            ("total_us", Json::num(total)),
            ("complete", Json::num(complete)),
            ("stages_us", Json::obj(stages.into_iter().map(|(k, v)| (k, Json::num(v))).collect())),
        ])
    }

    #[test]
    fn analysis_counts_chains_and_stage_sums() {
        let good = span(
            200.0,
            vec![
                ("admission", 100.0),
                ("queue", 200.0),
                ("plan", 50.0),
                ("execute", 600.0),
                ("respond", 50.0),
            ],
            1000.0,
            1.0,
        );
        // sums to half the reported total → outside the 10% band
        let torn = span(200.0, vec![("admission", 500.0)], 1000.0, 0.0);
        let err = span(404.0, vec![], 10.0, 0.0);
        let a = analyze_spans(&[good, torn, err]);
        assert_eq!(a.sampled, 2); // the 404 is excluded
        assert!((a.complete_chain_frac - 0.5).abs() < 1e-9);
        assert!((a.stage_sum_within_10pct_frac - 0.5).abs() < 1e-9);
        let exec = a.stages.iter().find(|(n, ..)| n == "execute").unwrap();
        assert!((exec.1 - 0.6).abs() < 1e-9); // µs → ms
    }

    /// Pins the BENCH_trace.json v1 schema CI validates against.
    #[test]
    fn report_json_schema() {
        let mk = |p95: f64| ModeStats {
            requests: 600,
            errors: 0,
            p50_ms: p95 / 2.0,
            p95_ms: p95,
        };
        let report = ProfileReport {
            baseline: mk(10.0),
            tracing: mk(10.3),
            analysis: SpanAnalysis {
                sampled: 600,
                complete_chain_frac: 1.0,
                stage_sum_within_10pct_frac: 1.0,
                stages: trace::STAGES
                    .iter()
                    .map(|s| (s.to_string(), 1.0, 2.0, 600))
                    .collect(),
            },
        };
        let cfg = ProfileConfig::default();
        let back = Json::parse(&report.to_json(&cfg).to_string()).unwrap();
        assert_eq!(back.at("bench").as_str(), Some("trace"));
        assert_eq!(back.at("schema_version").as_usize(), Some(1));
        assert_eq!(back.at("config").at("rounds").as_usize(), Some(3));
        for mode in ["baseline", "tracing"] {
            let m = back.at(mode);
            assert_eq!(m.at("requests").as_usize(), Some(600), "{mode}");
            assert_eq!(m.at("errors").as_usize(), Some(0), "{mode}");
            assert!(m.at("p50_ms").as_f64().unwrap() > 0.0, "{mode}");
            assert!(m.at("p95_ms").as_f64().unwrap() > 0.0, "{mode}");
        }
        let overhead = back.at("overhead_p95_pct").as_f64().unwrap();
        assert!((overhead - 3.0).abs() < 1e-9, "{overhead}");
        assert_eq!(back.at("complete_chain_frac").as_f64(), Some(1.0));
        assert_eq!(back.at("stage_sum_within_10pct_frac").as_f64(), Some(1.0));
        assert_eq!(back.at("spans_sampled").as_usize(), Some(600));
        for name in trace::STAGES {
            let st = back.at("stages").at(name);
            assert!(st.at("p50_ms").as_f64().is_some(), "{name}");
            assert!(st.at("p95_ms").as_f64().is_some(), "{name}");
            assert_eq!(st.at("count").as_usize(), Some(600), "{name}");
        }
    }

    #[test]
    fn pctl_nearest_rank() {
        let mut xs = vec![5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(pctl(&mut xs, 50.0), 3.0);
        assert_eq!(pctl(&mut xs, 95.0), 5.0);
        assert_eq!(pctl(&mut [], 50.0), 0.0);
    }
}
