//! Task suites: the synthetic stand-ins for GLUE, the 17 additional
//! classification datasets, and SQuAD (DESIGN.md §2).
//!
//! Every task is a labeled function of the *same* latent-topic world the
//! MiniBERT was pre-trained on, so transfer works for the same reason it
//! does in the paper. The suites mirror the papers' experimental design:
//! size spread (hundreds to thousands of examples), class counts 2–20,
//! single-sentence and sentence-pair tasks, one regression task scored
//! with Spearman, one task scored with Matthews (CoLA's metric), two with
//! F1, and a span-extraction task scored with EM/F1.

use crate::data::grammar::{World, CLS, PAD, SEP, WORD0};
use crate::util::rng::Rng;

/// How a task is scored (Table 1's per-column metrics).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    Accuracy,
    F1,
    Matthews,
    Spearman,
    SpanF1,
}

impl Metric {
    pub fn name(self) -> &'static str {
        match self {
            Metric::Accuracy => "accuracy",
            Metric::F1 => "f1",
            Metric::Matthews => "matthews",
            Metric::Spearman => "spearman",
            Metric::SpanF1 => "span_f1",
        }
    }

    /// Inverse of [`Metric::name`] (job descriptors round-trip through
    /// JSON).
    pub fn from_name(s: &str) -> Option<Metric> {
        Some(match s {
            "accuracy" => Metric::Accuracy,
            "f1" => Metric::F1,
            "matthews" => Metric::Matthews,
            "spearman" => Metric::Spearman,
            "span_f1" => Metric::SpanF1,
            _ => return None,
        })
    }
}

/// Task family — decides head/artifact kind.
#[derive(Debug, Clone, PartialEq)]
pub enum TaskKind {
    /// classification; `pair` tasks encode two segments
    Cls { n_classes: usize, pair: bool },
    /// scalar regression on a sentence pair (STS-B stand-in)
    Reg,
    /// extractive span selection (SQuAD stand-in)
    Span,
}

impl TaskKind {
    pub fn artifact_kind(&self) -> &'static str {
        match self {
            TaskKind::Cls { .. } => "cls",
            TaskKind::Reg => "reg",
            TaskKind::Span => "span",
        }
    }
}

#[derive(Debug, Clone)]
pub struct TaskSpec {
    pub name: String,
    pub kind: TaskKind,
    pub metric: Metric,
    pub n_train: usize,
    pub n_val: usize,
    pub n_test: usize,
    /// word-from-topic probability during generation (difficulty knob)
    pub purity: f64,
    /// label-noise rate (creates headroom below 100%)
    pub noise: f64,
    /// task-level seed (combined with the run seed)
    pub seed: u64,
}

/// Labels for one split.
#[derive(Debug, Clone, PartialEq)]
pub enum Labels {
    Class(Vec<usize>),
    Score(Vec<f32>),
    Span(Vec<(usize, usize)>),
}

impl Labels {
    pub fn len(&self) -> usize {
        match self {
            Labels::Class(v) => v.len(),
            Labels::Score(v) => v.len(),
            Labels::Span(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// One split: `n` examples of fixed length `seq` (row-major).
#[derive(Debug, Clone)]
pub struct Split {
    pub n: usize,
    pub seq: usize,
    pub tokens: Vec<i32>,
    pub segments: Vec<i32>,
    pub attn_mask: Vec<f32>,
    pub labels: Labels,
}

impl Split {
    pub fn row_tokens(&self, i: usize) -> &[i32] {
        &self.tokens[i * self.seq..(i + 1) * self.seq]
    }
}

#[derive(Debug, Clone)]
pub struct TaskData {
    pub spec: TaskSpec,
    pub train: Split,
    pub val: Split,
    pub test: Split,
    /// extra evaluation splits (e.g. MNLI-mm), name → split
    pub extra_eval: Vec<(String, Split)>,
}

// ---------------------------------------------------------------------------
// generation
// ---------------------------------------------------------------------------

/// Per-class topic signatures: each class boosts 2 distinct topics.
fn class_topics(rng: &mut Rng, n_topics: usize, n_classes: usize) -> Vec<Vec<usize>> {
    let mut out = Vec::with_capacity(n_classes);
    for _ in 0..n_classes {
        let a = rng.below(n_topics);
        let mut b = rng.below(n_topics);
        while b == a {
            b = rng.below(n_topics);
        }
        out.push(vec![a, b]);
    }
    out
}

fn mixture_for(topics: &[usize], n_topics: usize, rng: &mut Rng) -> Vec<f64> {
    let mut w = vec![0.05; n_topics]; // small leak to every topic
    for &t in topics {
        w[t] += 1.0 + rng.f64();
    }
    w
}

struct RowSink<'a> {
    split: &'a mut Split,
}

impl<'a> RowSink<'a> {
    fn push_row(&mut self, tokens: Vec<i32>, segments: Vec<i32>) {
        let seq = self.split.seq;
        assert_eq!(tokens.len(), seq);
        assert_eq!(segments.len(), seq);
        for (t, s) in tokens.iter().zip(&segments) {
            self.split.tokens.push(*t);
            self.split.segments.push(*s);
            self.split.attn_mask.push(if *t == PAD { 0.0 } else { 1.0 });
        }
        self.split.n += 1;
    }
}

fn empty_split(seq: usize, labels: Labels) -> Split {
    Split { n: 0, seq, tokens: vec![], segments: vec![], attn_mask: vec![], labels }
}

/// Assemble `[CLS] s1 (SEP s2 SEP)` padded to `seq`.
fn assemble(seq: usize, s1: &[i32], s2: Option<&[i32]>) -> (Vec<i32>, Vec<i32>) {
    let mut tokens = Vec::with_capacity(seq);
    let mut segments = Vec::with_capacity(seq);
    tokens.push(CLS);
    segments.push(0);
    for &w in s1 {
        tokens.push(w);
        segments.push(0);
    }
    if let Some(s2) = s2 {
        tokens.push(SEP);
        segments.push(0);
        for &w in s2 {
            tokens.push(w);
            segments.push(1);
        }
        tokens.push(SEP);
        segments.push(1);
    }
    assert!(tokens.len() <= seq, "assembled {} > seq {seq}", tokens.len());
    while tokens.len() < seq {
        tokens.push(PAD);
        segments.push(0);
    }
    (tokens, segments)
}

/// Generate one classification split.
#[allow(clippy::too_many_arguments)]
fn gen_cls_split(
    world: &World,
    rng: &mut Rng,
    seq: usize,
    n: usize,
    n_classes: usize,
    pair: bool,
    class_sig: &[Vec<usize>],
    purity: f64,
    noise: f64,
) -> Split {
    let mut split = empty_split(seq, Labels::Class(Vec::with_capacity(n)));
    let mut labels = Vec::with_capacity(n);
    for _ in 0..n {
        let class = rng.below(n_classes);
        let (tokens, segments) = if pair {
            gen_pair_example(world, rng, seq, n_classes, class, class_sig, purity)
        } else {
            let len = seq - 1 - rng.below(seq / 4);
            let weights = mixture_for(&class_sig[class], world.n_topics, rng);
            let s = world.sentence(rng, &weights, len, purity);
            assemble(seq, &s, None)
        };
        let mut sink = RowSink { split: &mut split };
        sink.push_row(tokens, segments);
        let observed = if rng.f64() < noise { rng.below(n_classes) } else { class };
        labels.push(observed);
    }
    split.labels = Labels::Class(labels);
    split
}

/// Sentence-pair semantics:
///   2-class: 1 = same mixture ("paraphrase"), 0 = different;
///   3-class: 0 = same ("entail"), 1 = one shared topic ("neutral"),
///            2 = disjoint ("contradict");
///   ≥4-class: class = relation pattern index over shared-topic counts.
fn gen_pair_example(
    world: &World,
    rng: &mut Rng,
    seq: usize,
    n_classes: usize,
    class: usize,
    class_sig: &[Vec<usize>],
    purity: f64,
) -> (Vec<i32>, Vec<i32>) {
    let budget = (seq - 3) / 2;
    let len1 = budget - rng.below(budget / 3);
    let len2 = budget - rng.below(budget / 3);
    let t1 = class_sig[class % class_sig.len()].clone();
    let w1 = mixture_for(&t1, world.n_topics, rng);
    let s1 = world.sentence(rng, &w1, len1, purity);
    let overlap = match n_classes {
        2 => {
            if class == 1 {
                2
            } else {
                0
            }
        }
        _ => 2usize.saturating_sub(class.min(2)), // 0->2 shared, 1->1, 2+->0
    };
    let mut t2: Vec<usize> = t1.iter().copied().take(overlap).collect();
    while t2.len() < 2 {
        let c = rng.below(world.n_topics);
        if !t1.contains(&c) && !t2.contains(&c) {
            t2.push(c);
        }
    }
    let w2 = mixture_for(&t2, world.n_topics, rng);
    let s2 = world.sentence(rng, &w2, len2, purity);
    assemble(seq, &s1, Some(&s2))
}

/// Regression split: target = 5 × cosine(topic hist s1, topic hist s2),
/// computed from the *generated tokens*, so it is exactly learnable.
fn gen_reg_split(world: &World, rng: &mut Rng, seq: usize, n: usize, purity: f64)
                 -> Split {
    let mut split = empty_split(seq, Labels::Score(vec![]));
    let mut scores = Vec::with_capacity(n);
    for _ in 0..n {
        let budget = (seq - 3) / 2;
        let k1 = 1 + rng.below(2);
        let w1 = world.random_mixture(rng, k1);
        let s1 = world.sentence(rng, &w1, budget, purity);
        // half the time reuse (a noisy copy of) the same mixture
        let w2 = if rng.f64() < 0.5 {
            let mut w = w1.clone();
            if rng.f64() < 0.5 {
                let t = rng.below(world.n_topics);
                w[t] += 0.7;
            }
            w
        } else {
            let k2 = 1 + rng.below(2);
            world.random_mixture(rng, k2)
        };
        let s2 = world.sentence(rng, &w2, budget, purity);
        let target =
            5.0 * World::topic_cosine(&world.topic_histogram(&s1),
                                      &world.topic_histogram(&s2)) as f32;
        let (tokens, segments) = assemble(seq, &s1, Some(&s2));
        let mut sink = RowSink { split: &mut split };
        sink.push_row(tokens, segments);
        scores.push(target);
    }
    split.labels = Labels::Score(scores);
    split
}

/// Span split: `[CLS] q q q [SEP] context [SEP]`. The three question words
/// come from one topic; the context embeds exactly one contiguous run of
/// 2–4 words from that topic in a background stream; the label is the run.
fn gen_span_split(world: &World, rng: &mut Rng, seq: usize, n: usize, _purity: f64)
                  -> Split {
    let mut split = empty_split(seq, Labels::Span(vec![]));
    let mut spans = Vec::with_capacity(n);
    for _ in 0..n {
        let topic = rng.below(world.n_topics);
        let tw = &world.topic_words[topic];
        let q: Vec<i32> = (0..3).map(|_| tw[rng.below(tw.len())] as i32).collect();
        let ctx_len = seq - 6; // CLS + 3q + 2 SEP
        // background context that avoids the query topic
        let mut ctx = Vec::with_capacity(ctx_len);
        while ctx.len() < ctx_len {
            let w = (WORD0 + rng.zipf(world.vocab - WORD0, 1.1)) as i32;
            if world.word_topic[w as usize] != Some(topic) {
                ctx.push(w);
            }
        }
        let run = 2 + rng.below(3);
        let start_in_ctx = rng.below(ctx_len - run);
        for j in 0..run {
            ctx[start_in_ctx + j] = tw[rng.below(tw.len())] as i32;
        }
        // assemble manually (question is segment 0, context segment 1)
        let mut tokens = vec![CLS];
        let mut segments = vec![0];
        tokens.extend(&q);
        segments.extend([0, 0, 0]);
        tokens.push(SEP);
        segments.push(0);
        let ctx_offset = tokens.len();
        tokens.extend(&ctx);
        segments.extend(std::iter::repeat(1).take(ctx.len()));
        tokens.push(SEP);
        segments.push(1);
        assert_eq!(tokens.len(), seq);
        let mut sink = RowSink { split: &mut split };
        sink.push_row(tokens, segments);
        spans.push((ctx_offset + start_in_ctx, ctx_offset + start_in_ctx + run - 1));
    }
    split.labels = Labels::Span(spans);
    split
}

/// Generate all splits of a task deterministically from `(world, spec)`.
pub fn generate(world: &World, spec: &TaskSpec, seq: usize) -> TaskData {
    let mut rng = Rng::new(world.seed ^ spec.seed.wrapping_mul(0x9E3779B97F4A7C15));
    let gen_split = |rng: &mut Rng, n: usize, purity: f64| -> Split {
        match &spec.kind {
            TaskKind::Cls { n_classes, pair } => {
                // class signatures must be shared across splits: derive from
                // a fixed fork of the task rng
                let mut sig_rng = Rng::new(world.seed ^ spec.seed ^ 0xC1A55);
                let sig = class_topics(&mut sig_rng, world.n_topics, *n_classes);
                gen_cls_split(world, rng, seq, n, *n_classes, *pair, &sig, purity,
                              spec.noise)
            }
            TaskKind::Reg => gen_reg_split(world, rng, seq, n, purity),
            TaskKind::Span => gen_span_split(world, rng, seq, n, purity),
        }
    };
    let train = gen_split(&mut rng, spec.n_train, spec.purity);
    let val = gen_split(&mut rng, spec.n_val, spec.purity);
    let test = gen_split(&mut rng, spec.n_test, spec.purity);
    let mut extra_eval = Vec::new();
    if spec.name.starts_with("mnli") {
        // MNLI-mm: same labeling function, mismatched "domain" (purity shift)
        let mm = gen_split(&mut rng, spec.n_val, (spec.purity - 0.12).max(0.25));
        extra_eval.push(("mnli_s_mm".to_string(), mm));
    }
    TaskData { spec: spec.clone(), train, val, test, extra_eval }
}

// ---------------------------------------------------------------------------
// suites
// ---------------------------------------------------------------------------

fn cls(name: &str, n_classes: usize, pair: bool, metric: Metric, n_train: usize,
       purity: f64, noise: f64, seed: u64) -> TaskSpec {
    let n_eval = (n_train / 6).clamp(96, 512);
    TaskSpec {
        name: name.to_string(),
        kind: TaskKind::Cls { n_classes, pair },
        metric,
        n_train,
        n_val: n_eval,
        n_test: n_eval,
        purity,
        noise,
        seed,
    }
}

/// The GLUE stand-in (Table 1; WNLI omitted as in the paper, MNLI-mm is an
/// extra eval split of `mnli_s`).
pub fn glue_suite() -> Vec<TaskSpec> {
    vec![
        cls("cola_s", 2, false, Metric::Matthews, 860, 0.34, 0.12, 101),
        cls("sst_s", 2, false, Metric::Accuracy, 3200, 0.50, 0.06, 102),
        cls("mrpc_s", 2, true, Metric::F1, 400, 0.48, 0.08, 103),
        TaskSpec {
            name: "stsb_s".into(),
            kind: TaskKind::Reg,
            metric: Metric::Spearman,
            n_train: 600,
            n_val: 192,
            n_test: 192,
            purity: 0.5,
            noise: 0.0,
            seed: 104,
        },
        cls("qqp_s", 2, true, Metric::F1, 3600, 0.50, 0.08, 105),
        cls("mnli_s", 3, true, Metric::Accuracy, 3900, 0.50, 0.05, 106),
        cls("qnli_s", 2, true, Metric::Accuracy, 1000, 0.44, 0.07, 107),
        cls("rte_s", 2, true, Metric::Accuracy, 250, 0.40, 0.10, 108),
    ]
}

/// The 17 additional classification tasks (Table 2). Sizes are the paper's
/// appendix Table 3 scaled by 1/8 (cap 3000, floor 120); class counts are
/// the paper's, capped at `max_classes` = 20 (customer-complaint's 157
/// classes exceed the padded head; DESIGN.md §2).
pub fn extra_suite() -> Vec<TaskSpec> {
    let raw: &[(&str, usize, usize)] = &[
        // (name, paper train size, classes)
        ("news20_s", 15076, 20),
        ("cf_airline_s", 11712, 3),
        ("cf_corporate_s", 2494, 4),
        ("cf_disasters_s", 8688, 2),
        ("cf_econ_news_s", 6392, 2),
        ("cf_emotion_s", 32000, 13),
        ("cf_warming_s", 3380, 2),
        ("cf_pol_audience_s", 4000, 2),
        ("cf_pol_bias_s", 4000, 2),
        ("cf_pol_message_s", 4000, 9),
        ("cf_prim_emotions_s", 2019, 18),
        ("cf_prog_opinion_s", 927, 3),
        ("cf_prog_stance_s", 927, 4),
        ("cf_us_econ_s", 3961, 2),
        ("complaints_s", 146667, 20),
        ("news_agg_s", 338349, 4),
        ("sms_spam_s", 4459, 2),
    ];
    raw.iter()
        .enumerate()
        .map(|(i, &(name, n, c))| {
            let n_train = (n / 8).clamp(120, 3000);
            // deterministic per-task difficulty spread
            let mut r = Rng::new(0xD1FF ^ i as u64);
            let purity = 0.32 + 0.26 * r.f64();
            let noise = 0.03 + 0.12 * r.f64();
            let metric = Metric::Accuracy;
            cls(name, c.min(20), false, metric, n_train, purity, noise,
                200 + i as u64)
        })
        .collect()
}

/// SQuAD stand-in (Fig. 5).
pub fn span_task() -> TaskSpec {
    TaskSpec {
        name: "squad_s".into(),
        kind: TaskKind::Span,
        metric: Metric::SpanF1,
        n_train: 2400,
        n_val: 384,
        n_test: 384,
        purity: 0.9,
        noise: 0.0,
        seed: 300,
    }
}

pub fn find_spec(name: &str) -> Option<TaskSpec> {
    glue_suite()
        .into_iter()
        .chain(extra_suite())
        .chain(std::iter::once(span_task()))
        .find(|s| s.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn world() -> World {
        World::new(256, 11)
    }

    #[test]
    fn generation_is_deterministic() {
        let w = world();
        let spec = cls("t", 3, false, Metric::Accuracy, 50, 0.5, 0.05, 1);
        let a = generate(&w, &spec, 16);
        let b = generate(&w, &spec, 16);
        assert_eq!(a.train.tokens, b.train.tokens);
        assert_eq!(a.train.labels, b.train.labels);
        assert_eq!(a.val.tokens, b.val.tokens);
    }

    #[test]
    fn splits_have_requested_sizes_and_shapes() {
        let w = world();
        let spec = cls("t", 4, true, Metric::Accuracy, 40, 0.5, 0.05, 2);
        let d = generate(&w, &spec, 32);
        assert_eq!(d.train.n, 40);
        assert_eq!(d.val.n, spec.n_val);
        assert_eq!(d.train.tokens.len(), 40 * 32);
        assert_eq!(d.train.attn_mask.len(), 40 * 32);
        if let Labels::Class(l) = &d.train.labels {
            assert!(l.iter().all(|&c| c < 4));
        } else {
            panic!("wrong label type")
        }
    }

    #[test]
    fn cls_rows_start_with_cls_token() {
        let w = world();
        let spec = cls("t", 2, false, Metric::Accuracy, 10, 0.5, 0.0, 3);
        let d = generate(&w, &spec, 16);
        for i in 0..d.train.n {
            assert_eq!(d.train.row_tokens(i)[0], CLS);
        }
    }

    #[test]
    fn pair_rows_use_both_segments() {
        let w = world();
        let spec = cls("t", 3, true, Metric::Accuracy, 10, 0.5, 0.0, 4);
        let d = generate(&w, &spec, 32);
        let segs = &d.train.segments[0..32];
        assert!(segs.contains(&0) && segs.contains(&1));
    }

    #[test]
    fn labels_are_learnable_from_topics() {
        // a topic-histogram nearest-centroid classifier must beat chance
        // comfortably — otherwise no tuning method could learn the task
        let w = world();
        let spec = cls("t", 3, false, Metric::Accuracy, 300, 0.5, 0.05, 5);
        let d = generate(&w, &spec, 32);
        let (train_l, val_l) = match (&d.train.labels, &d.val.labels) {
            (Labels::Class(a), Labels::Class(b)) => (a.clone(), b.clone()),
            _ => panic!(),
        };
        let mut centroids = vec![vec![0.0; w.n_topics]; 3];
        let mut counts = [0usize; 3];
        for i in 0..d.train.n {
            let h = w.topic_histogram(d.train.row_tokens(i));
            for (c, x) in centroids[train_l[i]].iter_mut().zip(&h) {
                *c += x;
            }
            counts[train_l[i]] += 1;
        }
        for (c, n) in centroids.iter_mut().zip(counts) {
            for x in c.iter_mut() {
                *x /= n.max(1) as f64;
            }
        }
        let mut hits = 0;
        for i in 0..d.val.n {
            let h = w.topic_histogram(d.val.row_tokens(i));
            let pred = (0..3)
                .max_by(|&a, &b| {
                    World::topic_cosine(&centroids[a], &h)
                        .partial_cmp(&World::topic_cosine(&centroids[b], &h))
                        .unwrap()
                })
                .unwrap();
            if pred == val_l[i] {
                hits += 1;
            }
        }
        let acc = hits as f64 / d.val.n as f64;
        assert!(acc > 0.6, "nearest-centroid acc {acc} — task not learnable");
    }

    #[test]
    fn reg_targets_in_range_and_varied() {
        let w = world();
        let spec = TaskSpec {
            name: "r".into(),
            kind: TaskKind::Reg,
            metric: Metric::Spearman,
            n_train: 100,
            n_val: 50,
            n_test: 50,
            purity: 0.5,
            noise: 0.0,
            seed: 6,
        };
        let d = generate(&w, &spec, 32);
        if let Labels::Score(s) = &d.train.labels {
            assert!(s.iter().all(|&x| (0.0..=5.0 + 1e-5).contains(&x)));
            let spread = s.iter().cloned().fold(f32::MIN, f32::max)
                - s.iter().cloned().fold(f32::MAX, f32::min);
            assert!(spread > 1.0, "targets too flat: spread {spread}");
        } else {
            panic!()
        }
    }

    #[test]
    fn span_labels_point_at_topic_runs() {
        let w = world();
        let spec = span_task();
        let mut spec = spec;
        spec.n_train = 30;
        spec.n_val = 10;
        spec.n_test = 10;
        let d = generate(&w, &spec, 64);
        if let Labels::Span(spans) = &d.train.labels {
            for (i, &(s, e)) in spans.iter().enumerate() {
                assert!(s <= e && e < 64);
                let row = d.train.row_tokens(i);
                // the labeled span's words share the question's topic
                let q_topic = w.word_topic[row[1] as usize].unwrap();
                for &tok in &row[s..=e] {
                    assert_eq!(w.word_topic[tok as usize], Some(q_topic));
                }
            }
        } else {
            panic!()
        }
    }

    #[test]
    fn mnli_gets_mm_split() {
        let w = world();
        let spec = glue_suite().into_iter().find(|s| s.name == "mnli_s").unwrap();
        let mut small = spec.clone();
        small.n_train = 30;
        small.n_val = 20;
        small.n_test = 20;
        let d = generate(&w, &small, 32);
        assert_eq!(d.extra_eval.len(), 1);
        assert_eq!(d.extra_eval[0].0, "mnli_s_mm");
        assert_eq!(d.extra_eval[0].1.n, 20);
    }

    #[test]
    fn suites_have_paper_counts() {
        assert_eq!(glue_suite().len(), 8); // 9 GLUE tasks with MNLI-m/mm shared
        assert_eq!(extra_suite().len(), 17);
        let names: std::collections::HashSet<_> =
            glue_suite().iter().map(|s| s.name.clone()).collect();
        assert_eq!(names.len(), 8);
    }

    #[test]
    fn find_spec_resolves_names() {
        assert!(find_spec("cola_s").is_some());
        assert!(find_spec("squad_s").is_some());
        assert!(find_spec("nope").is_none());
    }
}
