//! Prometheus text-exposition rendering (`GET /metrics?format=prometheus`).
//!
//! A tiny writer for the [text format]: `# HELP` / `# TYPE` headers,
//! label escaping, histogram `_bucket`/`_sum`/`_count` triads with a
//! `+Inf` bucket. The gateway renders from the same atomic metrics
//! snapshot the JSON endpoint uses, so the two formats never disagree.
//!
//! [text format]: https://prometheus.io/docs/instrumenting/exposition_formats/

/// Accumulates one exposition document.
#[derive(Default)]
pub struct Prom {
    out: String,
    typed: std::collections::BTreeSet<String>,
}

fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn fmt_value(v: f64) -> String {
    if v == f64::INFINITY {
        "+Inf".to_string()
    } else if v == v.trunc() && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

fn fmt_labels(labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return String::new();
    }
    let inner: Vec<String> =
        labels.iter().map(|(k, v)| format!("{k}=\"{}\"", escape_label(v))).collect();
    format!("{{{}}}", inner.join(","))
}

impl Prom {
    pub fn new() -> Prom {
        Prom::default()
    }

    fn header(&mut self, name: &str, kind: &str, help: &str) {
        if self.typed.insert(name.to_string()) {
            self.out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
        }
    }

    fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) {
        self.out.push_str(&format!("{name}{} {}\n", fmt_labels(labels), fmt_value(value)));
    }

    /// A monotonically increasing counter.
    pub fn counter(&mut self, name: &str, help: &str, labels: &[(&str, &str)], value: f64) {
        self.header(name, "counter", help);
        self.sample(name, labels, value);
    }

    /// A point-in-time gauge.
    pub fn gauge(&mut self, name: &str, help: &str, labels: &[(&str, &str)], value: f64) {
        self.header(name, "gauge", help);
        self.sample(name, labels, value);
    }

    /// A histogram from *cumulative* `(upper_bound_seconds, count)`
    /// buckets. Appends the implicit `+Inf` bucket (= `count`), `_sum`,
    /// and `_count` series.
    pub fn histogram(
        &mut self,
        name: &str,
        help: &str,
        labels: &[(&str, &str)],
        cumulative: &[(f64, u64)],
        sum: f64,
        count: u64,
    ) {
        self.header(name, "histogram", help);
        let bucket = format!("{name}_bucket");
        for &(le, c) in cumulative {
            let mut ls: Vec<(&str, &str)> = labels.to_vec();
            let le_s = fmt_value(le);
            ls.push(("le", &le_s));
            self.sample(&bucket, &ls, c as f64);
        }
        let mut ls: Vec<(&str, &str)> = labels.to_vec();
        ls.push(("le", "+Inf"));
        self.sample(&bucket, &ls, count as f64);
        self.sample(&format!("{name}_sum"), labels, sum);
        self.sample(&format!("{name}_count"), labels, count as f64);
    }

    /// The finished document (`text/plain; version=0.0.4` body).
    pub fn finish(self) -> String {
        self.out
    }
}

/// Minimal line-format check used by tests and CI: every non-comment,
/// non-blank line must be `name{labels} value` with a parseable value
/// and balanced braces. Returns the first offending line on failure.
pub fn check_exposition(body: &str) -> Result<(), String> {
    for line in body.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (series, value) = match line.rsplit_once(' ') {
            Some(p) => p,
            None => return Err(format!("no value separator: {line:?}")),
        };
        let name_part = match series.split_once('{') {
            Some((n, rest)) => {
                if !rest.ends_with('}') {
                    return Err(format!("unbalanced labels: {line:?}"));
                }
                n
            }
            None => series,
        };
        if name_part.is_empty()
            || !name_part
                .chars()
                .all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
            || name_part.chars().next().unwrap().is_ascii_digit()
        {
            return Err(format!("bad metric name: {line:?}"));
        }
        if value != "+Inf" && value != "-Inf" && value != "NaN" && value.parse::<f64>().is_err() {
            return Err(format!("bad value: {line:?}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_and_passes_line_check() {
        let mut p = Prom::new();
        p.counter("adapterbert_served_total", "Requests answered 200.", &[], 42.0);
        p.gauge(
            "adapterbert_cache_resident_bytes",
            "Bytes resident.",
            &[("pool", "adapters")],
            1.5e6,
        );
        p.histogram(
            "adapterbert_request_seconds",
            "End-to-end latency.",
            &[("task", "rte\"s")],
            &[(0.001, 3), (0.01, 7)],
            0.05,
            9,
        );
        let body = p.finish();
        check_exposition(&body).unwrap();
        assert!(body.contains("# TYPE adapterbert_request_seconds histogram"));
        assert!(body.contains("le=\"+Inf\"} 9"));
        assert!(body.contains("adapterbert_request_seconds_sum{task=\"rte\\\"s\"} 0.05"));
    }

    #[test]
    fn line_check_rejects_garbage() {
        assert!(check_exposition("not a metric line at all\n").is_err());
        assert!(check_exposition("9bad_name 1\n").is_err());
        assert!(check_exposition("name{unbalanced 1\n").is_err());
        assert!(check_exposition("ok_name 1\n").is_ok());
    }
}
