//! Fused multi-task engine integration tests (test preset, native
//! backend) — the acceptance path for `ExecMode::Fused`:
//!
//! * **per-row parity**: a mixed batch (cls + lnonly + reg + span
//!   segments) through `FusedBackend::fused_forward` produces raw head
//!   outputs within 1e-5 of the per-task `*_fwd_*` executables, row by
//!   row, regardless of segment order;
//! * **throughput**: on the many-tasks/low-rate shape (one row per task)
//!   the fused engine serves the same rows ≥2× faster than the per-task
//!   path, which pads every row to the artifact batch;
//! * **occupancy**: driving the same wave trace through a fused
//!   `coordinator::Server` yields strictly higher mean batch occupancy
//!   than per-task mode, with correct predictions and genuinely mixed
//!   batches;
//! * **hot registration**: a task registered while fused traffic flows
//!   becomes gatherable immediately, without pausing other tasks;
//! * **validation**: malformed banks fail `prepare_task` with
//!   descriptive errors naming the offending leaf/size — at registration
//!   time, not inside `execute`.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{mpsc, Arc, OnceLock};
use std::time::{Duration, Instant};

use adapterbert::coordinator::server::Request;
use adapterbert::coordinator::{
    ExecMode, FlushPolicy, Server, ServerConfig, ServerMetrics,
};
use adapterbert::data::grammar::World;
use adapterbert::data::tasks::{self, TaskData, TaskKind, TaskSpec};
use adapterbert::eval::{
    fused_bank, fwd_param_banks, predict_split, Predictions, TaskModel,
};
use adapterbert::model::params::NamedTensors;
use adapterbert::obs::trace::TraceHandle;
use adapterbert::runtime::{
    Bank, Executable, FusedSegment, FusedTaskBank, RowOutput, Runtime,
};
use adapterbert::store::AdapterStore;
use adapterbert::train::{self, PretrainConfig, TrainConfig};
use adapterbert::util::tensor::Tensor;

fn runtime() -> Arc<Runtime> {
    Arc::new(
        Runtime::open(
            Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts")),
            "test",
        )
        .expect("open test preset (built-in presets synthesize their manifest)"),
    )
}

fn world(rt: &Runtime) -> World {
    World::new(rt.manifest.dims.vocab, 0)
}

fn pretrained_base(rt: &Arc<Runtime>) -> NamedTensors {
    static BASE: OnceLock<NamedTensors> = OnceLock::new();
    BASE.get_or_init(|| {
        train::load_or_pretrain(
            rt,
            &world(rt),
            &PretrainConfig { steps: 3000, log_every: 0, ..Default::default() },
            Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/runs/base_test.bank")),
        )
        .unwrap()
    })
    .clone()
}

fn cls_spec(name: &str, seed: u64) -> TaskSpec {
    TaskSpec {
        name: name.to_string(),
        kind: TaskKind::Cls { n_classes: 2, pair: false },
        metric: tasks::Metric::Accuracy,
        n_train: 240,
        n_val: 48,
        n_test: 48,
        purity: 0.85,
        noise: 0.0,
        seed,
    }
}

fn train_cls(
    rt: &Arc<Runtime>,
    base: &NamedTensors,
    name: &str,
    seed: u64,
    exe: &str,
) -> (TaskModel, TaskData, f64) {
    let spec = cls_spec(name, seed);
    let data = tasks::generate(&world(rt), &spec, rt.manifest.dims.seq);
    let cfg = TrainConfig::new(exe, 1e-3, 4, 0);
    let res = train::train_task(rt, &cfg, &data, base).unwrap();
    (res.model, data, res.val_score)
}

fn class_preds(
    rt: &Arc<Runtime>,
    model: &TaskModel,
    base: &NamedTensors,
    split: &tasks::Split,
) -> Vec<usize> {
    match predict_split(rt, model, base, split, 2, None).unwrap() {
        Predictions::Class(v) => v,
        other => panic!("expected class predictions, got {other:?}"),
    }
}

/// `(tokens, type_ids, attn_mask)` for one split row, server-style.
type RowIn = (Vec<i32>, Vec<i32>, Vec<f32>);

fn row_from_split(split: &tasks::Split, row: usize, seq: usize) -> RowIn {
    let tokens = split.row_tokens(row).to_vec();
    let mask: Vec<f32> = tokens
        .iter()
        .map(|&t| if t == 0 { 0.0 } else { 1.0 })
        .collect();
    (tokens, vec![0; seq], mask)
}

/// The per-task reference path, exactly as the server executes it: the
/// task's `*_fwd_*` executable with rows padded to the artifact batch.
struct RefExec {
    exe: Arc<Executable>,
    params: Vec<Bank>,
    kind: String,
}

fn build_ref(rt: &Arc<Runtime>, model: &TaskModel, base: &NamedTensors) -> RefExec {
    RefExec {
        exe: rt.load(&model.fwd_name()).unwrap(),
        params: fwd_param_banks(rt, model, base, None).unwrap(),
        kind: model.kind.clone(),
    }
}

fn run_ref(rt: &Arc<Runtime>, r: &RefExec, rows: &[RowIn]) -> Vec<RowOutput> {
    let b = r.exe.spec.batch;
    let seq = rt.manifest.dims.seq;
    assert!(rows.len() <= b, "reference path is single-batch");
    let n = rows.len();
    let mut tokens = Vec::with_capacity(b * seq);
    let mut type_ids = Vec::with_capacity(b * seq);
    let mut mask = Vec::with_capacity(b * seq);
    for (t, s, m) in rows {
        tokens.extend_from_slice(t);
        type_ids.extend_from_slice(s);
        mask.extend_from_slice(m);
    }
    for _ in n..b {
        tokens.extend(std::iter::repeat(0).take(seq));
        type_ids.extend(std::iter::repeat(0).take(seq));
        let mut mrow = vec![0.0f32; seq];
        mrow[0] = 1.0;
        mask.extend(mrow);
    }
    let tok_bank = vec![Tensor::i32(vec![b, seq], tokens)];
    let seg_bank = vec![Tensor::i32(vec![b, seq], type_ids)];
    let mask_bank = vec![Tensor::f32(vec![b, seq], mask)];
    let mut all: Vec<&Bank> = r.params.iter().collect();
    all.push(&tok_bank);
    all.push(&seg_bank);
    all.push(&mask_bank);
    let out = r.exe.run(&all).unwrap();
    match r.kind.as_str() {
        "cls" => {
            let logits = &out[0][0];
            let c = logits.shape[1];
            (0..n)
                .map(|row| {
                    RowOutput::Class(logits.as_f32()[row * c..(row + 1) * c].to_vec())
                })
                .collect()
        }
        "reg" => {
            let scores = out[0][0].as_f32();
            (0..n).map(|row| RowOutput::Score(scores[row])).collect()
        }
        "span" => {
            let start = &out[0][0];
            let end = &out[1][0];
            let s = start.shape[1];
            (0..n)
                .map(|row| {
                    RowOutput::Span(
                        start.as_f32()[row * s..(row + 1) * s].to_vec(),
                        end.as_f32()[row * s..(row + 1) * s].to_vec(),
                    )
                })
                .collect()
        }
        other => panic!("unexpected kind {other}"),
    }
}

fn assert_close(a: &[f32], b: &[f32], tol: f32, ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!((x - y).abs() <= tol, "{ctx}[{i}]: fused {x} vs per-task {y}");
    }
}

fn assert_rows_close(got: &RowOutput, want: &RowOutput, tol: f32, ctx: &str) {
    match (got, want) {
        (RowOutput::Class(a), RowOutput::Class(b)) => assert_close(a, b, tol, ctx),
        (RowOutput::Score(a), RowOutput::Score(b)) => {
            assert!((a - b).abs() <= tol, "{ctx}: fused {a} vs per-task {b}");
        }
        (RowOutput::Span(a1, a2), RowOutput::Span(b1, b2)) => {
            assert_close(a1, b1, tol, &format!("{ctx}.start"));
            assert_close(a2, b2, tol, &format!("{ctx}.end"));
        }
        other => panic!("{ctx}: head kind mismatch {other:?}"),
    }
}

/// Four small adapter-tuned classification tenants, trained once and
/// shared by the throughput/occupancy and hot-registration tests.
struct Fixture {
    models: Vec<(String, TaskModel, TaskData, f64)>,
}

fn fixture() -> &'static Fixture {
    static F: OnceLock<Fixture> = OnceLock::new();
    F.get_or_init(|| {
        let rt = runtime();
        let base = pretrained_base(&rt);
        let models = ["fta", "ftb", "ftc", "ftd"]
            .iter()
            .enumerate()
            .map(|(i, name)| {
                let (m, d, v) =
                    train_cls(&rt, &base, name, 61 + i as u64, "cls_train_adapter_m4");
                (name.to_string(), m, d, v)
            })
            .collect();
        Fixture { models }
    })
}

/// Headline parity: one mixed batch across all three head kinds and both
/// fusable variants matches the per-task executables to ≤ 1e-5 per row,
/// in either segment order.
#[test]
fn fused_forward_matches_per_task_per_row() {
    let rt = runtime();
    let base = pretrained_base(&rt);
    let seq = rt.manifest.dims.seq;

    let (cls_model, cls_data, _) =
        train_cls(&rt, &base, "fpa", 51, "cls_train_adapter_m4");
    let (ln_model, ln_data, _) = train_cls(&rt, &base, "fpl", 52, "cls_train_lnonly");
    let reg_spec = TaskSpec {
        name: "fpr".to_string(),
        kind: TaskKind::Reg,
        metric: tasks::Metric::Spearman,
        n_train: 160,
        n_val: 32,
        n_test: 32,
        purity: 0.5,
        noise: 0.0,
        seed: 53,
    };
    let span_spec = TaskSpec {
        name: "fps".to_string(),
        kind: TaskKind::Span,
        metric: tasks::Metric::SpanF1,
        n_train: 160,
        n_val: 32,
        n_test: 32,
        purity: 0.9,
        noise: 0.0,
        seed: 54,
    };
    let reg_data = tasks::generate(&world(&rt), &reg_spec, seq);
    let span_data = tasks::generate(&world(&rt), &span_spec, seq);
    let reg_model = train::train_task(
        &rt,
        &TrainConfig::new("reg_train_adapter_m8", 1e-3, 2, 0),
        &reg_data,
        &base,
    )
    .unwrap()
    .model;
    let span_model = train::train_task(
        &rt,
        &TrainConfig::new("span_train_adapter_m8", 1e-3, 2, 0),
        &span_data,
        &base,
    )
    .unwrap()
    .model;

    // (model, n_classes, rows) per segment — mixed sizes on purpose
    let groups: Vec<(&TaskModel, usize, Vec<RowIn>)> = vec![
        (
            &cls_model,
            2,
            (0..3).map(|r| row_from_split(&cls_data.test, r, seq)).collect(),
        ),
        (
            &ln_model,
            2,
            (0..2).map(|r| row_from_split(&ln_data.test, r, seq)).collect(),
        ),
        (
            &reg_model,
            0,
            (0..2).map(|r| row_from_split(&reg_data.test, r, seq)).collect(),
        ),
        (
            &span_model,
            0,
            (0..1).map(|r| row_from_split(&span_data.test, r, seq)).collect(),
        ),
    ];

    let engine = rt.fused().expect("native backend exposes the fused engine");
    let mut orders: Vec<Vec<usize>> = vec![(0..groups.len()).collect()];
    orders.push((0..groups.len()).rev().collect());
    for order in orders {
        let mut segments: Vec<FusedSegment> = Vec::new();
        let mut tokens = Vec::new();
        let mut type_ids = Vec::new();
        let mut mask = Vec::new();
        for &gi in &order {
            let (model, n_classes, rows) = &groups[gi];
            let bank = Arc::new(fused_bank(&rt, model, &base, *n_classes).unwrap());
            segments.push(FusedSegment { bank, len: rows.len() });
            for (t, s, m) in rows {
                tokens.extend_from_slice(t);
                type_ids.extend_from_slice(s);
                mask.extend_from_slice(m);
            }
        }
        let fused_out = engine
            .fused_forward(&base.map, &segments, &tokens, &type_ids, &mask)
            .unwrap();
        assert_eq!(fused_out.len(), 8);
        let mut idx = 0usize;
        for &gi in &order {
            let (model, _, rows) = &groups[gi];
            let reference = build_ref(&rt, model, &base);
            let want = run_ref(&rt, &reference, rows);
            for (ri, w) in want.iter().enumerate() {
                let ctx = format!("order {order:?} group {gi} row {ri}");
                assert_rows_close(&fused_out[idx], w, 1e-5, &ctx);
                idx += 1;
            }
        }
    }
}

/// Engine throughput on the many-tasks/low-rate shape: four tasks, one
/// row each. Per-task execution pads each row to the artifact batch (8
/// row-slots per real row); the fused forward runs exactly 4 rows.
/// Acceptance floor is 2×; the expected ratio is ~8×.
#[test]
fn fused_engine_at_least_2x_on_low_rate_shape() {
    let rt = runtime();
    let base = pretrained_base(&rt);
    let seq = rt.manifest.dims.seq;
    let fix = fixture();

    let engine = rt.fused().unwrap();
    let refs: Vec<RefExec> =
        fix.models.iter().map(|(_, m, _, _)| build_ref(&rt, m, &base)).collect();
    let rows: Vec<RowIn> = fix
        .models
        .iter()
        .map(|(_, _, d, _)| row_from_split(&d.test, 0, seq))
        .collect();
    let banks: Vec<Arc<FusedTaskBank>> = fix
        .models
        .iter()
        .map(|(_, m, _, _)| Arc::new(fused_bank(&rt, m, &base, 2).unwrap()))
        .collect();
    let segments: Vec<FusedSegment> = banks
        .iter()
        .map(|b| FusedSegment { bank: b.clone(), len: 1 })
        .collect();
    let mut tokens = Vec::new();
    let mut type_ids = Vec::new();
    let mut mask = Vec::new();
    for (t, s, m) in &rows {
        tokens.extend_from_slice(t);
        type_ids.extend_from_slice(s);
        mask.extend_from_slice(m);
    }

    // warm both paths (compile cache, page faults), and check agreement
    let warm_fused = engine
        .fused_forward(&base.map, &segments, &tokens, &type_ids, &mask)
        .unwrap();
    for (i, r) in refs.iter().enumerate() {
        let want = run_ref(&rt, r, std::slice::from_ref(&rows[i]));
        assert_rows_close(&warm_fused[i], &want[0], 1e-5, &format!("warmup row {i}"));
    }

    let reps = 15;
    let t0 = Instant::now();
    for _ in 0..reps {
        for (i, r) in refs.iter().enumerate() {
            run_ref(&rt, r, std::slice::from_ref(&rows[i]));
        }
    }
    let per_task_s = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    for _ in 0..reps {
        engine
            .fused_forward(&base.map, &segments, &tokens, &type_ids, &mask)
            .unwrap();
    }
    let fused_s = t1.elapsed().as_secs_f64();
    assert!(
        per_task_s >= 2.0 * fused_s,
        "fused engine must be ≥2× on the low-rate shape: per-task {per_task_s:.4}s \
         vs fused {fused_s:.4}s over {reps} reps"
    );
}

/// Drive the same low-rate wave trace through a per-task and a fused
/// server: every prediction must match offline eval in both modes, and
/// fused mode must batch across tasks (mixed batch sizes observed,
/// strictly higher mean occupancy, fused_batches > 0).
#[test]
fn fused_server_occupancy_beats_per_task_on_same_trace() {
    let rt = runtime();
    let base = pretrained_base(&rt);
    let seq = rt.manifest.dims.seq;
    let fix = fixture();

    let store = Arc::new(AdapterStore::in_memory());
    let mut classes = BTreeMap::new();
    for (name, model, _, val) in &fix.models {
        store.register(name, model, *val).unwrap();
        classes.insert(name.clone(), 2);
    }
    let offline: Vec<Vec<usize>> = fix
        .models
        .iter()
        .map(|(_, m, d, _)| class_preds(&rt, m, &base, &d.test))
        .collect();

    let run_trace = |mode: ExecMode| -> (ServerMetrics, usize) {
        let server = Server::start(
            rt.clone(),
            &store,
            &base,
            &classes,
            ServerConfig {
                flush: FlushPolicy {
                    max_batch: 8,
                    max_delay: Duration::from_millis(2),
                },
                executors: 1,
                queue_capacity: 256,
                mode,
                ..Default::default()
            },
        )
        .unwrap();
        assert_eq!(server.mode(), mode);
        let waves = 8usize;
        let mut pending: Vec<(usize, usize, mpsc::Receiver<_>)> = Vec::new();
        for wave in 0..waves {
            for (ti, (name, _, data, _)) in fix.models.iter().enumerate() {
                let row = wave % data.test.n;
                let (tokens, type_ids, mask) = row_from_split(&data.test, row, seq);
                let (reply, rx) = mpsc::channel();
                server
                    .submit_blocking(Request {
                        task: name.clone(),
                        tokens,
                        segments: type_ids,
                        attn_mask: mask,
                        reply,
                        submitted: Instant::now(),
                        deadline: None,
                        trace: TraceHandle::none(),
                    })
                    .unwrap();
                pending.push((ti, row, rx));
            }
            // waves spaced past max_delay: per-task queues hold ≤1 row
            std::thread::sleep(Duration::from_millis(4));
        }
        let mut max_batch_size = 0usize;
        for (ti, row, rx) in pending {
            let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(
                resp.prediction.class(),
                Some(offline[ti][row]),
                "mode {mode:?} task {ti} row {row}: served prediction diverged"
            );
            max_batch_size = max_batch_size.max(resp.batch_size);
        }
        (server.shutdown(), max_batch_size)
    };

    let (per_task, per_task_max_bs) = run_trace(ExecMode::PerTask);
    let (fused, fused_max_bs) = run_trace(ExecMode::Fused);

    assert_eq!(per_task.fused_batches, 0);
    assert!(fused.fused_batches > 0, "no batch ran through the fused engine");
    // per-task mode can never mix tasks: with one row per task per wave
    // its batches stay at ≤ waves-that-backed-up rows; fused mode packs
    // a whole wave (4 tasks) into one batch
    assert!(
        fused_max_bs > 1,
        "fused mode never built a mixed batch (max size {fused_max_bs})"
    );
    assert!(
        fused_max_bs >= per_task_max_bs,
        "fused batches ({fused_max_bs}) smaller than per-task ({per_task_max_bs})"
    );
    assert!(
        fused.mean_occupancy() > per_task.mean_occupancy(),
        "fused occupancy {:.3} must beat per-task {:.3}",
        fused.mean_occupancy(),
        per_task.mean_occupancy()
    );
}

/// Hot registration while fused traffic flows: the new task's gatherable
/// bank installs without pausing the others, and its rows ride mixed
/// batches immediately.
#[test]
fn fused_hot_registration_is_gatherable_immediately() {
    let rt = runtime();
    let base = pretrained_base(&rt);
    let seq = rt.manifest.dims.seq;
    let fix = fixture();

    // three tenants up front, the fourth arrives live
    let store = Arc::new(AdapterStore::in_memory());
    let mut classes = BTreeMap::new();
    for (name, model, _, val) in fix.models.iter().take(3) {
        store.register(name, model, *val).unwrap();
        classes.insert(name.clone(), 2);
    }
    let (late_name, late_model, late_data, _) = &fix.models[3];
    let late_offline = class_preds(&rt, late_model, &base, &late_data.test);

    let server = Arc::new(
        Server::start(
            rt.clone(),
            &store,
            &base,
            &classes,
            ServerConfig {
                flush: FlushPolicy {
                    max_batch: 8,
                    max_delay: Duration::from_millis(2),
                },
                executors: 1,
                queue_capacity: 256,
                mode: ExecMode::Fused,
                ..Default::default()
            },
        )
        .unwrap(),
    );
    assert_eq!(server.mode(), ExecMode::Fused);
    assert_eq!(server.tasks().len(), 3);

    // background traffic on the first three tasks
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let traffic = {
        let server = server.clone();
        let stop = stop.clone();
        let rows: Vec<(String, RowIn)> = fix
            .models
            .iter()
            .take(3)
            .map(|(name, _, d, _)| (name.clone(), row_from_split(&d.test, 0, seq)))
            .collect();
        std::thread::spawn(move || {
            let (reply, rx) = mpsc::channel();
            let mut sent = 0usize;
            while !stop.load(std::sync::atomic::Ordering::Relaxed) {
                for (name, (tokens, type_ids, mask)) in &rows {
                    server
                        .submit_blocking(Request {
                            task: name.clone(),
                            tokens: tokens.clone(),
                            segments: type_ids.clone(),
                            attn_mask: mask.clone(),
                            reply: reply.clone(),
                            submitted: Instant::now(),
                            deadline: None,
                            trace: TraceHandle::none(),
                        })
                        .unwrap();
                    sent += 1;
                }
                std::thread::sleep(Duration::from_millis(3));
            }
            drop(reply);
            let mut got = 0usize;
            while got < sent && rx.recv_timeout(Duration::from_secs(30)).is_ok() {
                got += 1;
            }
            assert_eq!(got, sent, "background traffic lost replies");
        })
    };

    std::thread::sleep(Duration::from_millis(30));
    // hot-register the fourth task mid-traffic
    server.register_live(late_name, 2, late_model).unwrap();
    assert_eq!(server.tasks().len(), 4);

    // its rows serve correctly, through the fused path, right away
    for row in 0..8usize.min(late_data.test.n) {
        let (tokens, type_ids, mask) = row_from_split(&late_data.test, row, seq);
        let (reply, rx) = mpsc::channel();
        server
            .submit_blocking(Request {
                task: late_name.clone(),
                tokens,
                segments: type_ids,
                attn_mask: mask,
                reply,
                submitted: Instant::now(),
                deadline: None,
                trace: TraceHandle::none(),
            })
            .unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(
            resp.prediction.class(),
            Some(late_offline[row]),
            "hot-registered task row {row}"
        );
    }

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    traffic.join().unwrap();
    let server = Arc::try_unwrap(server).ok().expect("no other refs");
    let metrics = server.shutdown();
    assert!(metrics.fused_batches > 0);
}

/// Registration-time validation: malformed banks fail `prepare_task`
/// with descriptive errors instead of surfacing inside `execute`.
#[test]
fn malformed_banks_fail_registration_with_descriptive_errors() {
    let rt = runtime();
    let base = pretrained_base(&rt);
    let fix = fixture();
    let (_, good, _, _) = &fix.models[0];

    let store = Arc::new(AdapterStore::in_memory());
    let server = Server::start(
        rt.clone(),
        &store,
        &base,
        &BTreeMap::new(),
        ServerConfig::default(),
    )
    .unwrap();

    // the genuine bank is accepted
    assert!(server.prepare_task(2, good).is_ok());

    // (a) adapter size not in the preset → error names available sizes
    let mut bad = good.clone();
    bad.m = Some(5);
    let err = server.prepare_task(2, &bad).err().expect("must fail").to_string();
    assert!(err.contains("m=5"), "{err}");
    assert!(err.contains("available sizes"), "{err}");

    // (b) a leaf with the wrong shape → error names the leaf
    let mut bad = good.clone();
    let (key, tensor) = {
        let (k, t) = bad
            .trained
            .map
            .iter()
            .find(|(k, _)| k.contains("w_down"))
            .map(|(k, t)| (k.clone(), t.clone()))
            .unwrap();
        (k, t)
    };
    let truncated = Tensor::f32(vec![1], vec![tensor.as_f32()[0]]);
    bad.trained.map.insert(key.clone(), truncated);
    let err = server.prepare_task(2, &bad).err().expect("must fail").to_string();
    assert!(err.contains(&key), "{err}");
    assert!(err.contains("shape"), "{err}");

    // (c) an extra leaf that is not part of the trained group
    let mut bad = good.clone();
    bad.trained.insert("bogus/extra", Tensor::f32(vec![2], vec![0.0; 2]));
    let err = server.prepare_task(2, &bad).err().expect("must fail").to_string();
    assert!(err.contains("bogus/extra"), "{err}");

    // (d) a missing required leaf
    let mut bad = good.clone();
    bad.trained.map.remove("head/w");
    let err = server.prepare_task(2, &bad).err().expect("must fail").to_string();
    assert!(err.contains("head/w"), "{err}");

    // (e) unknown variant
    let mut bad = good.clone();
    bad.variant = "lora".to_string();
    let err = server.prepare_task(2, &bad).err().expect("must fail").to_string();
    assert!(err.contains("lora"), "{err}");

    // (f) cls head outside the padded class range
    let err = server.prepare_task(0, good).err().expect("must fail").to_string();
    assert!(err.contains("n_classes"), "{err}");
}
