//! AdapterStore: versioned per-task parameter banks.
//!
//! The paper's economics live here: one frozen base plus a small bank per
//! task. The store keeps every registered bank immutable (append-only
//! versions) — that is the mechanism behind "perfect memory of previous
//! tasks" (§1): adding task N+1 cannot touch the bytes serving tasks 1…N.
//! Banks persist to disk as `<root>/<task>/v<NNN>.bank` (binary) with a
//! `v<NNN>.json` sidecar, and reload into a byte-identical `TaskModel`.
//!
//! Durability rules:
//!
//! * **Atomic registration** — both files are written to a temporary name
//!   and renamed into place, bank first, sidecar last. The sidecar is the
//!   commit record: a crash mid-register leaves at worst an orphaned
//!   `.bank`/`.tmp` file that reload ignores, never a sidecar pointing at
//!   a torn bank.
//! * **Quarantine on reload** — a sidecar whose bank is missing or
//!   unreadable (external truncation, pre-atomic-write crashes) is
//!   skipped with a warning instead of poisoning every other task's
//!   banks. Surviving versions keep their on-disk version numbers, so
//!   [`AdapterStore::version`] answers by *number*, not position, and a
//!   subsequent [`AdapterStore::register`] appends after the highest
//!   survivor.
//! * **Reserved names** — directories starting with `_` or `.` under the
//!   root are internal (e.g. `_jobs`, the training service's checkpoint
//!   area) and are not treated as tasks; task names may not collide with
//!   them.
//! * **Paged residency** — disk-backed stores keep only metadata (and the
//!   bank's on-disk byte size) in RAM; model tensors are re-read from
//!   disk on demand via [`AdapterStore::fetch_latest`]. Reload still
//!   decodes every bank once (that is the torn-bank quarantine check),
//!   then drops the tensors. In-memory stores have no disk to page to
//!   and stay fully resident. The coordinator's paged bank cache sits on
//!   top of this through the [`BankSource`] seam.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use crate::eval::TaskModel;
use crate::model::params::NamedTensors;
use crate::util::json::Json;

/// Immutable metadata attached to a registered bank.
#[derive(Debug, Clone)]
pub struct BankMeta {
    pub task: String,
    pub version: usize,
    pub variant: String,
    pub m: Option<usize>,
    pub k: Option<usize>,
    pub kind: String,
    /// Head class count (`cls` kinds; 2 for the binary default). Stored
    /// so a replica that never saw the task registered can still admit
    /// it from the store alone — the cluster failover path.
    pub n_classes: usize,
    pub val_score: f64,
    pub trained_params: usize,
    pub trained_params_no_head: usize,
}

/// Where an entry's tensors live. Disk slots hold only the path; the
/// bytes are streamed back in by [`AdapterStore::fetch_latest`].
#[derive(Clone)]
enum Slot {
    Memory(Arc<TaskModel>),
    Disk { bank_path: PathBuf },
}

#[derive(Clone)]
struct Entry {
    meta: BankMeta,
    /// Serialized bank size — the cheap probe backing cache budgeting.
    bank_bytes: u64,
    slot: Slot,
}

/// Thread-safe in-memory store with optional disk persistence.
pub struct AdapterStore {
    root: Option<PathBuf>,
    tasks: Mutex<BTreeMap<String, Vec<Entry>>>,
}

impl AdapterStore {
    /// A store with no disk persistence (tests, demos).
    pub fn in_memory() -> AdapterStore {
        AdapterStore { root: None, tasks: Mutex::new(BTreeMap::new()) }
    }

    /// Open (creating if needed) a disk-backed store rooted at `root`,
    /// loading every bank already registered there.
    pub fn at(root: &Path) -> Result<AdapterStore> {
        std::fs::create_dir_all(root)
            .with_context(|| format!("creating store root {root:?}"))?;
        let store =
            AdapterStore { root: Some(root.to_path_buf()), tasks: Mutex::new(BTreeMap::new()) };
        store.reload()?;
        Ok(store)
    }

    /// Cheap reachability probe: in-memory stores are always reachable;
    /// a disk-backed store must have a listable root. The gateway's
    /// `/health` readiness section calls this per request, so it stays
    /// one `read_dir` open — no bank reads, no lock.
    pub fn probe(&self) -> bool {
        match &self.root {
            None => true,
            Some(root) => std::fs::read_dir(root).is_ok(),
        }
    }

    /// Register a new version for `task` with the binary-classification
    /// default head shape. See [`AdapterStore::register_with_classes`]
    /// for the full form — callers that know the real class count (the
    /// serving registration seam) must use it, or a cluster replica
    /// admitting the task from the store would rebuild the wrong head.
    pub fn register(&self, task: &str, model: &TaskModel, val_score: f64)
                    -> Result<BankMeta> {
        self.register_with_classes(task, model, 2, val_score)
    }

    /// Register a new version for `task`; returns the assigned version.
    ///
    /// Disk writes are atomic (tmp file + rename) with the `v<NNN>.json`
    /// sidecar renamed last as the commit record: a crash at any point
    /// leaves either the complete pair or nothing reload will serve.
    pub fn register_with_classes(
        &self,
        task: &str,
        model: &TaskModel,
        n_classes: usize,
        val_score: f64,
    ) -> Result<BankMeta> {
        validate_task_name(task)?;
        let _ord = crate::check::order::Held::enter(crate::check::order::STORE);
        let mut tasks = self.tasks.lock().unwrap();
        let versions = tasks.entry(task.to_string()).or_default();
        // after quarantine the survivors may be sparse — append past the
        // highest surviving version so a fresh bank never reuses a number
        // an older, readable bank already holds
        let version = versions.last().map(|e| e.meta.version).unwrap_or(0) + 1;
        let meta = BankMeta {
            task: task.to_string(),
            version,
            variant: model.variant.clone(),
            m: model.m,
            k: model.k,
            kind: model.kind.clone(),
            n_classes,
            val_score,
            trained_params: model.trained_param_count(),
            trained_params_no_head: model.trained_param_count_no_head(),
        };
        let encoded = model.trained.to_bytes();
        let bank_bytes = encoded.len() as u64;
        let slot = if let Some(root) = &self.root {
            let dir = root.join(task);
            std::fs::create_dir_all(&dir)?;
            let bank_path = dir.join(format!("v{version:03}.bank"));
            write_atomic(&bank_path, &encoded)?;
            let meta_path = dir.join(format!("v{version:03}.json"));
            write_atomic(&meta_path, meta_to_json(&meta).to_string().as_bytes())?;
            // written through — the tensors page back in on demand
            Slot::Disk { bank_path }
        } else {
            Slot::Memory(Arc::new(model.clone()))
        };
        versions.push(Entry { meta: meta.clone(), bank_bytes, slot });
        Ok(meta)
    }

    /// Latest version of a task's model. Convenience wrapper over
    /// [`AdapterStore::fetch_latest`] that logs and swallows read errors;
    /// the coordinator's fetch seam uses the fallible form directly.
    pub fn latest(&self, task: &str) -> Option<(BankMeta, Arc<TaskModel>)> {
        match self.fetch_latest(task) {
            Ok(v) => v,
            Err(e) => {
                crate::log_warn!("store", "latest bank for {task}: {e:#}");
                None
            }
        }
    }

    /// Latest version of a task's model, surfacing read/decode failures.
    /// For disk-backed stores this streams the bank back from disk (the
    /// entry was paged out after registration or reload).
    pub fn fetch_latest(&self, task: &str)
                        -> Result<Option<(BankMeta, Arc<TaskModel>)>> {
        // clone the entry under the lock, do I/O outside it
        let entry = {
            let tasks = self.tasks.lock().unwrap();
            tasks.get(task).and_then(|v| v.last()).cloned()
        };
        entry.map(resolve_entry).transpose()
    }

    /// Cheap probe: latest metadata only — never touches the bank file.
    pub fn latest_meta(&self, task: &str) -> Option<BankMeta> {
        let tasks = self.tasks.lock().unwrap();
        tasks.get(task).and_then(|v| v.last()).map(|e| e.meta.clone())
    }

    /// Cheap probe: serialized size in bytes of the latest bank.
    pub fn latest_bank_bytes(&self, task: &str) -> Option<u64> {
        let tasks = self.tasks.lock().unwrap();
        tasks.get(task).and_then(|v| v.last()).map(|e| e.bank_bytes)
    }

    /// A specific registered version (1-based), if it exists. Lookup is
    /// by version *number*, not position, so it agrees with
    /// [`AdapterStore::latest`] even when quarantine left holes in the
    /// on-disk sequence.
    pub fn version(&self, task: &str, version: usize)
                   -> Option<(BankMeta, Arc<TaskModel>)> {
        let entry = {
            let tasks = self.tasks.lock().unwrap();
            tasks
                .get(task)
                .and_then(|v| v.iter().find(|e| e.meta.version == version))
                .cloned()
        };
        match entry.map(resolve_entry).transpose() {
            Ok(v) => v,
            Err(e) => {
                crate::log_warn!("store", "bank {task} v{version}: {e:#}");
                None
            }
        }
    }

    /// All registered task names, sorted.
    pub fn task_names(&self) -> Vec<String> {
        self.tasks.lock().unwrap().keys().cloned().collect()
    }

    /// Count of banks across every task and version.
    pub fn total_versions(&self) -> usize {
        self.tasks.lock().unwrap().values().map(|v| v.len()).sum()
    }

    /// Parameter accounting across the store (Table 1/2 "total params"
    /// columns): `base_params` + one latest bank per task, expressed as a
    /// multiple of the base.
    pub fn total_params_ratio(&self, base_params: usize) -> f64 {
        let tasks = self.tasks.lock().unwrap();
        let extra: usize = tasks
            .values()
            .filter_map(|v| v.last())
            .map(|e| e.meta.trained_params_no_head)
            .sum();
        if base_params == 0 {
            // an empty base makes the ratio undefined; keep the result
            // total and JSON-safe (util::json renders NaN/inf as invalid
            // literals) — an empty store over an empty base costs
            // nothing, any bank over nothing saturates to f64::MAX
            return if extra == 0 { 1.0 } else { f64::MAX };
        }
        (base_params + extra) as f64 / base_params as f64
    }

    /// Reload from disk (no-op for in-memory stores).
    ///
    /// Crash recovery: a `v<NNN>.json` sidecar whose bank is missing or
    /// unreadable is **quarantined** — skipped with a warning — instead
    /// of failing the whole store; every other task and version keeps
    /// serving. Internal directories (names starting with `_` or `.`)
    /// are not tasks and are ignored. Duplicate version numbers within a
    /// task are genuine corruption and still fail loudly.
    pub fn reload(&self) -> Result<()> {
        let Some(root) = &self.root else { return Ok(()) };
        let mut tasks = self.tasks.lock().unwrap();
        tasks.clear();
        if !root.exists() {
            return Ok(());
        }
        for entry in std::fs::read_dir(root)? {
            let dir = entry?.path();
            if !dir.is_dir() {
                continue;
            }
            let task = dir.file_name().unwrap().to_string_lossy().to_string();
            if task.starts_with('_') || task.starts_with('.') {
                continue; // reserved for internal state (e.g. `_jobs`)
            }
            let mut versions: Vec<(usize, Entry)> = Vec::new();
            for f in std::fs::read_dir(&dir)? {
                let p = f?.path();
                if !p.extension().map(|e| e == "json").unwrap_or(false) {
                    continue;
                }
                match load_version(&p) {
                    Ok(entry) => versions.push((entry.meta.version, entry)),
                    Err(e) => {
                        crate::log_warn!(
                            "store",
                            "{task}: quarantining {p:?}: {e:#}"
                        );
                    }
                }
            }
            versions.sort_by_key(|(v, _)| *v);
            // duplicate numbers are corruption quarantine cannot explain;
            // holes are what quarantine (or a pre-crash orphan) leaves
            // behind, so they only warn
            for pair in versions.windows(2) {
                if pair[0].0 == pair[1].0 {
                    bail!("store {task}: duplicate version v{:03} on disk", pair[0].0);
                }
            }
            let dense = versions
                .iter()
                .enumerate()
                .all(|(i, (v, _))| *v == i + 1);
            if !dense && !versions.is_empty() {
                crate::log_warn!(
                    "store",
                    "{task}: non-dense versions on disk ({:?}) — \
                     quarantined or externally removed banks leave holes; \
                     surviving versions keep their numbers",
                    versions.iter().map(|(v, _)| *v).collect::<Vec<_>>()
                );
            }
            tasks.insert(task, versions.into_iter().map(|(_, e)| e).collect());
        }
        Ok(())
    }
}

/// Read one `v<NNN>.json` + `v<NNN>.bank` pair into an [`Entry`].
///
/// The bank is fully decoded here — that decode **is** the torn-bank
/// quarantine check — and then dropped: reload leaves a disk slot, so a
/// store with 10k tasks costs metadata, not tensors, until a task is
/// actually fetched.
fn load_version(meta_path: &Path) -> Result<Entry> {
    let meta = meta_from_json(
        &Json::parse(&std::fs::read_to_string(meta_path)?)
            .map_err(|e| anyhow::anyhow!("{meta_path:?}: {e}"))?,
    )?;
    let bank_path = meta_path.with_extension("bank");
    let bytes = read_bank_streamed(&bank_path)?;
    NamedTensors::from_bytes(&bytes)
        .with_context(|| format!("decoding bank {bank_path:?}"))?;
    Ok(Entry { meta, bank_bytes: bytes.len() as u64, slot: Slot::Disk { bank_path } })
}

/// Materialize an entry's model: memory slots clone the `Arc`, disk slots
/// stream the bank back in and decode it (same checks as reload).
fn resolve_entry(entry: Entry) -> Result<(BankMeta, Arc<TaskModel>)> {
    let Entry { meta, bank_bytes, slot } = entry;
    match slot {
        Slot::Memory(model) => Ok((meta, model)),
        Slot::Disk { bank_path } => {
            let bytes = read_bank_streamed(&bank_path)?;
            if bytes.len() as u64 != bank_bytes {
                bail!(
                    "bank {bank_path:?} changed size on disk: got {} bytes, \
                     registered {bank_bytes}",
                    bytes.len()
                );
            }
            let trained = NamedTensors::from_bytes(&bytes)
                .with_context(|| format!("decoding bank {bank_path:?}"))?;
            let model = TaskModel {
                variant: meta.variant.clone(),
                m: meta.m,
                k: meta.k,
                kind: meta.kind.clone(),
                trained,
            };
            Ok((meta, Arc::new(model)))
        }
    }
}

/// Stream a bank file in fixed-size chunks. Retries `Interrupted` reads
/// and reports short files explicitly (a torn read surfaces as a
/// descriptive error, not a decode panic downstream).
fn read_bank_streamed(path: &Path) -> Result<Vec<u8>> {
    use std::io::Read;
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening bank {path:?}"))?;
    let expect = f
        .metadata()
        .with_context(|| format!("probing bank {path:?}"))?
        .len() as usize;
    let mut buf = Vec::with_capacity(expect);
    let mut chunk = [0u8; 64 * 1024];
    loop {
        match f.read(&mut chunk) {
            Ok(0) => break,
            Ok(n) => buf.extend_from_slice(&chunk[..n]),
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => {
                return Err(e)
                    .with_context(|| format!("reading bank {path:?}"))
            }
        }
    }
    if buf.len() < expect {
        bail!("short read on bank {path:?}: got {} of {expect} bytes", buf.len());
    }
    Ok(buf)
}

/// The coordinator's fetch seam: everything the serving layer needs from
/// a bank store. [`AdapterStore`] is the production implementation; the
/// fault-injection tests wrap one to inject slow/short/failing reads
/// without touching production code.
pub trait BankSource: Send + Sync {
    /// Latest model for `task` — fallible, because disk slots re-read the
    /// bank file on demand.
    fn fetch_latest(&self, task: &str)
                    -> Result<Option<(BankMeta, Arc<TaskModel>)>>;
    /// Metadata-only probe (never touches the bank file).
    fn latest_meta(&self, task: &str) -> Option<BankMeta>;
    /// Serialized size of the latest bank, for budget estimates.
    fn latest_bank_bytes(&self, task: &str) -> Option<u64>;
    /// All registered task names, sorted.
    fn task_names(&self) -> Vec<String>;
}

impl BankSource for AdapterStore {
    fn fetch_latest(&self, task: &str)
                    -> Result<Option<(BankMeta, Arc<TaskModel>)>> {
        AdapterStore::fetch_latest(self, task)
    }

    fn latest_meta(&self, task: &str) -> Option<BankMeta> {
        AdapterStore::latest_meta(self, task)
    }

    fn latest_bank_bytes(&self, task: &str) -> Option<u64> {
        AdapterStore::latest_bank_bytes(self, task)
    }

    fn task_names(&self) -> Vec<String> {
        AdapterStore::task_names(self)
    }
}

/// Write `bytes` to `path` atomically: write a sibling `.tmp`, then
/// rename into place. Readers (and reload) never observe a torn file.
/// Shared with the training service's job checkpoints, which live under
/// the same root and follow the same durability rules.
pub fn write_atomic(path: &Path, bytes: &[u8]) -> Result<()> {
    let mut tmp_name = path
        .file_name()
        .with_context(|| format!("no file name in {path:?}"))?
        .to_os_string();
    tmp_name.push(".tmp");
    let tmp = path.with_file_name(tmp_name);
    std::fs::write(&tmp, bytes).with_context(|| format!("writing {tmp:?}"))?;
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming {tmp:?} into {path:?}"))?;
    Ok(())
}

/// Task names become directory names under the store root; keep them to
/// a safe charset and away from the `_`/`.` prefixes reserved for
/// internal state (reload would silently skip such a "task").
pub fn validate_task_name(task: &str) -> Result<()> {
    if task.is_empty() {
        bail!("task name is empty");
    }
    if task.starts_with('_') || task.starts_with('.') {
        bail!(
            "task name {task:?} starts with a reserved prefix \
             ('_' and '.' directories are internal store state)"
        );
    }
    if !task
        .chars()
        .all(|c| c.is_ascii_alphanumeric() || matches!(c, '_' | '-' | '.'))
    {
        bail!(
            "task name {task:?} contains characters outside \
             [A-Za-z0-9_.-] (it becomes a directory name)"
        );
    }
    Ok(())
}

fn meta_to_json(m: &BankMeta) -> Json {
    let mut pairs = vec![
        ("task", Json::str(&m.task)),
        ("version", Json::num(m.version as f64)),
        ("variant", Json::str(&m.variant)),
        ("kind", Json::str(&m.kind)),
        ("n_classes", Json::num(m.n_classes as f64)),
        ("val_score", Json::num(m.val_score)),
        ("trained_params", Json::num(m.trained_params as f64)),
        ("trained_params_no_head", Json::num(m.trained_params_no_head as f64)),
    ];
    if let Some(mm) = m.m {
        pairs.push(("m", Json::num(mm as f64)));
    }
    if let Some(k) = m.k {
        pairs.push(("k", Json::num(k as f64)));
    }
    Json::obj(pairs)
}

fn meta_from_json(j: &Json) -> Result<BankMeta> {
    Ok(BankMeta {
        task: j.at("task").as_str().context("task")?.to_string(),
        version: j.at("version").as_usize().context("version")?,
        variant: j.at("variant").as_str().context("variant")?.to_string(),
        m: j.get("m").and_then(|v| v.as_usize()),
        k: j.get("k").and_then(|v| v.as_usize()),
        kind: j.at("kind").as_str().context("kind")?.to_string(),
        // sidecars written before the cluster tier lack this field; the
        // binary default matches what those deployments served
        n_classes: j.get("n_classes").and_then(Json::as_usize).unwrap_or(2),
        val_score: j.at("val_score").as_f64().context("val_score")?,
        trained_params: j.at("trained_params").as_usize().context("tp")?,
        trained_params_no_head: j
            .at("trained_params_no_head")
            .as_usize()
            .context("tpnh")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tensor::Tensor;

    fn model(tag: f32) -> TaskModel {
        let mut trained = NamedTensors::default();
        trained.insert("adapters/x", Tensor::f32(vec![3], vec![tag; 3]));
        trained.insert("head/w", Tensor::f32(vec![2], vec![tag; 2]));
        TaskModel {
            variant: "adapter".into(),
            m: Some(8),
            k: None,
            kind: "cls".into(),
            trained,
        }
    }

    #[test]
    fn versions_are_append_only_and_isolated() {
        let s = AdapterStore::in_memory();
        s.register("a", &model(1.0), 0.5).unwrap();
        let m2 = s.register("a", &model(2.0), 0.7).unwrap();
        assert_eq!(m2.version, 2);
        // v1 still intact after v2 registration (perfect memory)
        let (meta1, model1) = s.version("a", 1).unwrap();
        assert_eq!(meta1.val_score, 0.5);
        assert_eq!(model1.trained.get("adapters/x").unwrap().as_f32(), &[1.0; 3]);
        let (meta_latest, _) = s.latest("a").unwrap();
        assert_eq!(meta_latest.version, 2);
    }

    #[test]
    fn params_ratio_counts_latest_only() {
        let s = AdapterStore::in_memory();
        s.register("a", &model(1.0), 0.5).unwrap();
        s.register("b", &model(1.0), 0.5).unwrap();
        // base 100, 2 tasks × 3 no-head params
        assert!((s.total_params_ratio(100) - 1.06).abs() < 1e-9);
    }

    #[test]
    fn disk_roundtrip() {
        let dir = std::env::temp_dir().join(format!("abstore_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let s = AdapterStore::at(&dir).unwrap();
            s.register("taskx", &model(3.5), 0.9).unwrap();
            s.register("taskx", &model(4.5), 0.95).unwrap();
            s.register("tasky", &model(7.0), 0.8).unwrap();
        }
        let s2 = AdapterStore::at(&dir).unwrap();
        assert_eq!(s2.task_names(), vec!["taskx", "tasky"]);
        assert_eq!(s2.total_versions(), 3);
        let (meta, m) = s2.latest("taskx").unwrap();
        assert_eq!(meta.version, 2);
        assert_eq!(meta.val_score, 0.95);
        assert_eq!(m.trained.get("adapters/x").unwrap().as_f32(), &[4.5; 3]);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn n_classes_persists_and_old_sidecars_default_binary() {
        let dir = std::env::temp_dir()
            .join(format!("abstore_ncls_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let s = AdapterStore::at(&dir).unwrap();
            let meta = s.register_with_classes("t", &model(1.0), 5, 0.9).unwrap();
            assert_eq!(meta.n_classes, 5);
        }
        // the class count survives the disk roundtrip …
        let s2 = AdapterStore::at(&dir).unwrap();
        assert_eq!(s2.latest_meta("t").unwrap().n_classes, 5);
        // … and a pre-cluster sidecar (no n_classes field) still parses,
        // defaulting to the binary head those deployments served
        let sidecar = dir.join("t").join("v001.json");
        let stripped: Json = Json::Obj(
            Json::parse(&std::fs::read_to_string(&sidecar).unwrap())
                .unwrap()
                .as_obj()
                .unwrap()
                .iter()
                .filter(|(k, _)| k.as_str() != "n_classes")
                .map(|(k, v)| (k.clone(), v.clone()))
                .collect(),
        );
        std::fs::write(&sidecar, stripped.to_string()).unwrap();
        let s3 = AdapterStore::at(&dir).unwrap();
        assert_eq!(s3.latest_meta("t").unwrap().n_classes, 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn probe_reports_store_reachability() {
        assert!(AdapterStore::in_memory().probe());
        let dir = std::env::temp_dir()
            .join(format!("abstore_probe_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let s = AdapterStore::at(&dir).unwrap();
        assert!(s.probe());
        std::fs::remove_dir_all(&dir).unwrap();
        assert!(!s.probe(), "a vanished root is unreachable");
    }

    #[test]
    fn missing_task_is_none() {
        let s = AdapterStore::in_memory();
        assert!(s.latest("zzz").is_none());
        assert!(s.version("zzz", 1).is_none());
    }

    #[test]
    fn params_ratio_is_total_on_empty_base() {
        let s = AdapterStore::in_memory();
        // empty base + empty store: no cost, and crucially never NaN/inf
        // (util::json would render either as an invalid literal)
        assert_eq!(s.total_params_ratio(0), 1.0);
        s.register("a", &model(1.0), 0.5).unwrap();
        let r = s.total_params_ratio(0);
        assert_eq!(r, f64::MAX, "saturates instead of inf");
        assert!(r.is_finite() && !r.is_nan());
    }

    #[test]
    fn task_names_are_validated() {
        let s = AdapterStore::in_memory();
        for bad in ["", "_jobs", ".hidden", "a/b", "a\\b", "..", "sp ace"] {
            assert!(
                s.register(bad, &model(1.0), 0.5).is_err(),
                "accepted bad task name {bad:?}"
            );
        }
        for good in ["rte_s", "my-task.v2", "A9"] {
            s.register(good, &model(1.0), 0.5).unwrap();
        }
    }

    #[test]
    fn register_leaves_no_tmp_files() {
        let dir =
            std::env::temp_dir().join(format!("abstore_tmp_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let s = AdapterStore::at(&dir).unwrap();
        s.register("t", &model(1.0), 0.5).unwrap();
        for f in std::fs::read_dir(dir.join("t")).unwrap() {
            let p = f.unwrap().path();
            assert_ne!(
                p.extension().map(|e| e.to_string_lossy().to_string()),
                Some("tmp".to_string()),
                "tmp file {p:?} left behind"
            );
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Crash recovery: one truncated bank and one orphaned sidecar must
    /// quarantine those versions only — every other version of the task
    /// and every other task reloads intact, lookups answer by version
    /// *number*, and a post-recovery register appends past the highest
    /// survivor instead of colliding.
    #[test]
    fn reload_quarantines_torn_and_orphaned_banks() {
        let dir =
            std::env::temp_dir().join(format!("abstore_crash_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let s = AdapterStore::at(&dir).unwrap();
            s.register("t", &model(1.0), 0.1).unwrap();
            s.register("t", &model(2.0), 0.2).unwrap();
            s.register("t", &model(3.0), 0.3).unwrap();
            s.register("u", &model(7.0), 0.7).unwrap();
        }
        // externally truncate v2's bank (torn write / disk damage) …
        let v2 = dir.join("t").join("v002.bank");
        let bytes = std::fs::read(&v2).unwrap();
        std::fs::write(&v2, &bytes[..bytes.len() / 2]).unwrap();
        // … and plant an orphan sidecar whose bank never made it to disk
        let meta9 = std::fs::read_to_string(dir.join("t").join("v001.json"))
            .unwrap()
            .replace("\"version\":1", "\"version\":9");
        std::fs::write(dir.join("t").join("v009.json"), meta9).unwrap();
        // internal dirs must not be read as tasks
        std::fs::create_dir_all(dir.join("_jobs")).unwrap();
        std::fs::write(dir.join("_jobs").join("job_1.json"), "{}").unwrap();

        let s = AdapterStore::at(&dir).unwrap();
        assert_eq!(s.task_names(), vec!["t", "u"], "_jobs leaked in as a task");
        // v1 and v3 survive; v2 (torn) and v9 (orphan) are quarantined
        assert_eq!(s.total_versions(), 3);
        assert!(s.version("t", 1).is_some());
        assert!(s.version("t", 2).is_none());
        assert!(s.version("t", 9).is_none());
        let (meta3, m3) = s.version("t", 3).unwrap();
        assert_eq!(meta3.version, 3);
        assert_eq!(m3.trained.get("adapters/x").unwrap().as_f32(), &[3.0; 3]);
        // latest agrees with lookup-by-number under the hole
        let (latest, _) = s.latest("t").unwrap();
        assert_eq!(latest.version, 3);
        // the other task is untouched
        assert_eq!(s.latest("u").unwrap().0.val_score, 0.7);
        // registering after recovery appends past the highest survivor
        let meta = s.register("t", &model(4.0), 0.4).unwrap();
        assert_eq!(meta.version, 4);
        let s2 = AdapterStore::at(&dir).unwrap();
        assert_eq!(s2.version("t", 4).unwrap().0.val_score, 0.4);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Parallel `register` of new versions (same task and different
    /// tasks) racing readers resolving `latest` — versions stay dense and
    /// append-only, readers never observe a torn entry, and the on-disk
    /// state reloads byte-identically.
    #[test]
    fn concurrent_register_with_readers_then_reload_byte_identity() {
        let dir = std::env::temp_dir()
            .join(format!("abstore_conc_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = AdapterStore::at(&dir).unwrap();
        let writers = 4usize;
        let per_writer = 6usize;

        std::thread::scope(|scope| {
            let store = &store;
            for w in 0..writers {
                scope.spawn(move || {
                    for i in 0..per_writer {
                        // every writer appends to a shared task and to
                        // its own task, interleaved
                        let tag = (w * 100 + i) as f32;
                        store.register("shared", &model(tag), 0.5).unwrap();
                        store
                            .register(&format!("own_{w}"), &model(tag), 0.5)
                            .unwrap();
                    }
                });
            }
            // readers race the writers
            for _ in 0..2 {
                scope.spawn(move || {
                    for _ in 0..200 {
                        if let Some((meta, m)) = store.latest("shared") {
                            // a resolved entry is always internally
                            // consistent: meta matches the model bytes
                            assert!(meta.version >= 1);
                            let x = m.trained.get("adapters/x").unwrap().as_f32();
                            assert_eq!(x[0], x[1]);
                            assert_eq!(x[1], x[2]);
                        }
                        std::hint::spin_loop();
                    }
                });
            }
        });

        // append-only + dense: every version 1..=n present, in order
        assert_eq!(store.total_versions(), writers * per_writer * 2);
        let shared_n = writers * per_writer;
        for v in 1..=shared_n {
            let (meta, _) = store.version("shared", v).unwrap();
            assert_eq!(meta.version, v);
        }

        // reload from disk: byte-identical banks for every version
        let reloaded = AdapterStore::at(&dir).unwrap();
        assert_eq!(reloaded.task_names(), store.task_names());
        for task in store.task_names() {
            let mut v = 1;
            while let Some((meta_a, model_a)) = store.version(&task, v) {
                let (meta_b, model_b) = reloaded
                    .version(&task, v)
                    .unwrap_or_else(|| panic!("{task} v{v} lost on reload"));
                assert_eq!(meta_a.version, meta_b.version);
                assert_eq!(meta_a.val_score, meta_b.val_score);
                assert_eq!(
                    model_a.trained.to_bytes(),
                    model_b.trained.to_bytes(),
                    "{task} v{v} bytes changed across reload"
                );
                v += 1;
            }
        }
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Disk-backed entries hold no tensors in RAM: the bank streams back
    /// in on fetch, errors surface through the fallible path, and the
    /// metadata probes never touch the file.
    #[test]
    fn disk_entries_page_out_and_stream_back() {
        let dir =
            std::env::temp_dir().join(format!("abstore_page_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let s = AdapterStore::at(&dir).unwrap();
        s.register("t", &model(5.0), 0.5).unwrap();

        // the cheap probes answer without the bank file present
        let bank = dir.join("t").join("v001.bank");
        let saved = std::fs::read(&bank).unwrap();
        std::fs::remove_file(&bank).unwrap();
        assert_eq!(s.latest_meta("t").unwrap().version, 1);
        assert_eq!(s.latest_bank_bytes("t").unwrap(), saved.len() as u64);
        // the fallible fetch reports the missing bank descriptively …
        let err = s.fetch_latest("t").unwrap_err();
        assert!(format!("{err:#}").contains("bank"), "{err:#}");
        // … and the infallible wrapper degrades to None
        assert!(s.latest("t").is_none());

        // heal: restore the file, fetch streams it back byte-identically
        std::fs::write(&bank, &saved).unwrap();
        let (meta, m) = s.fetch_latest("t").unwrap().unwrap();
        assert_eq!(meta.version, 1);
        assert_eq!(m.trained.to_bytes(), saved);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// A bank that changes size under the store (external truncation
    /// after reload's quarantine pass) fails fetch with a size check,
    /// not a decode panic.
    #[test]
    fn fetch_rejects_resized_bank() {
        let dir = std::env::temp_dir()
            .join(format!("abstore_resize_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let s = AdapterStore::at(&dir).unwrap();
        s.register("t", &model(1.0), 0.5).unwrap();
        let bank = dir.join("t").join("v001.bank");
        let bytes = std::fs::read(&bank).unwrap();
        std::fs::write(&bank, &bytes[..bytes.len() / 2]).unwrap();
        let err = s.fetch_latest("t").unwrap_err();
        assert!(format!("{err:#}").contains("changed size"), "{err:#}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// `reload` on a live store must not lose versions registered after
    /// the disk snapshot it re-reads (they are on disk too — register
    /// writes through).
    #[test]
    fn reload_is_idempotent_with_writethrough() {
        let dir = std::env::temp_dir()
            .join(format!("abstore_reload_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let store = AdapterStore::at(&dir).unwrap();
        store.register("t", &model(1.0), 0.4).unwrap();
        store.register("t", &model(2.0), 0.6).unwrap();
        store.reload().unwrap();
        assert_eq!(store.total_versions(), 2);
        let (meta, m) = store.latest("t").unwrap();
        assert_eq!(meta.version, 2);
        assert_eq!(m.trained.get("adapters/x").unwrap().as_f32(), &[2.0; 3]);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
