//! Hot registration: `POST /tasks` → store append → live bank swap.
//!
//! This operationalizes the store's append-only guarantee end to end: a
//! new task (or a new version of an existing one) becomes servable over
//! the network **without restarting or pausing other tasks**. The order
//! of operations matters:
//!
//! 1. decode + **prepare** — the bank is validated against the manifest
//!    and merged with the frozen base entirely off to the side. A
//!    malformed payload fails here and nothing has changed;
//! 2. **store append** — the immutable version record (disk write when
//!    the store is disk-backed);
//! 3. **install** — one map insert under a short write lock makes the
//!    banks visible to executors. In-flight batches for other tasks hold
//!    their own `Arc`s and never block on, or observe, the swap.
//!
//! The gateway serializes calls into this module (`reg_lock`), so store
//! version order always matches executor-side install order.

use anyhow::{Context, Result};

use super::protocol::{RegisterRequest, RegisterResponse};
use crate::coordinator::server::Server;
use crate::store::AdapterStore;

/// Handle one wire-format registration against a live server.
pub fn register_from_wire(
    store: &AdapterStore,
    server: &Server,
    req: &RegisterRequest,
) -> Result<RegisterResponse> {
    let model = req
        .to_model()
        .with_context(|| format!("decoding bank for task {:?}", req.task))?;
    // validate + build first: a bad bank must not leave a store version
    // behind that can never serve
    let prepared = server
        .prepare_task(req.n_classes, &model)
        .with_context(|| format!("bank for task {:?} is not servable", req.task))?;
    let meta = store
        .register(&req.task, &model, req.val_score)
        .with_context(|| format!("storing bank for task {:?}", req.task))?;
    server.install_task(&req.task, prepared);
    Ok(RegisterResponse::from_meta(&meta))
}
