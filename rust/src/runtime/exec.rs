//! Typed execution over PJRT: load HLO text → compile once → run many.
//!
//! `Runtime` owns the PJRT CPU client and a compile cache (compilation of
//! the larger train-step graphs costs seconds; every caller shares the
//! compiled executable). `Executable::run*` takes *banks* — slices of
//! tensors in manifest group order — validates them against the signature,
//! executes, and splits the result tuple back into output groups.
//!
//! Buffer management: the vendored `xla` crate's literal-based
//! `execute()` leaks every input device buffer (it `release()`s the
//! `BufferFromHostLiteral` results and never frees them), so all execution
//! here goes through `execute_b` with buffers owned on the Rust side.
//! That also enables the key serving optimization: long-lived banks (the
//! frozen base, a task's adapters) are uploaded **once** as a
//! [`DeviceBank`] and reused across steps/batches; only per-step data
//! (batches, scalars, updated trained params) is re-uploaded.
//!
//! Thread-safety: the `xla` wrappers are raw-pointer structs with no
//! `Send`/`Sync`, but the PJRT C API guarantees thread-safe
//! `Compile`/`Execute`/transfers (the CPU client runs its own thread
//! pool). The `SendSync` wrapper asserts that contract so the coordinator
//! can share `Arc<Executable>`/`DeviceBank`s across worker threads.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::{ExeSpec, LeafSpec, Manifest};
use crate::util::tensor::{Data, DType, Tensor};

/// Wrapper asserting PJRT thread-safety (see module docs).
struct SendSync<T>(T);
// SAFETY: PJRT's C API is documented thread-safe for compilation,
// execution and host↔device transfers; the CPU plugin serializes
// internally where required. The wrapped values are only used through
// &self methods.
unsafe impl<T> Send for SendSync<T> {}
unsafe impl<T> Sync for SendSync<T> {}

/// A bank: tensors for one contiguous input group, in manifest order.
pub type Bank = Vec<Tensor>;

/// A bank resident on the PJRT device, uploaded once and reused.
pub struct DeviceBank {
    bufs: Vec<SendSync<xla::PjRtBuffer>>,
    shapes: Vec<(Vec<usize>, DType)>,
}

impl DeviceBank {
    pub fn len(&self) -> usize {
        self.bufs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bufs.is_empty()
    }
}

/// Input argument: host tensors (uploaded per call) or a resident bank.
pub enum BankRef<'a> {
    Host(&'a Bank),
    Device(&'a DeviceBank),
}

pub struct Runtime {
    client: SendSync<xla::PjRtClient>,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Executable>>>,
    /// cumulative time spent in XLA compilation (perf accounting)
    compile_seconds: Mutex<f64>,
}

impl Runtime {
    /// Open the artifacts directory for `preset` under `root`.
    pub fn open(root: &Path, preset: &str) -> Result<Runtime> {
        let dir = root.join(preset);
        let manifest = Manifest::load(&dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            client: SendSync(client),
            manifest,
            cache: Mutex::new(HashMap::new()),
            compile_seconds: Mutex::new(0.0),
        })
    }

    /// Get (compiling on first use) the named executable.
    pub fn load(self: &Arc<Self>, name: &str) -> Result<Arc<Executable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self.manifest.exe(name)?.clone();
        let path = self.manifest.hlo_path(name)?;
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .0
            .compile(&comp)
            .with_context(|| format!("XLA compile of {name}"))?;
        *self.compile_seconds.lock().unwrap() += t0.elapsed().as_secs_f64();
        let exe = Arc::new(Executable { exe: SendSync(exe), rt: self.clone(), spec });
        self.cache
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert_with(|| exe.clone());
        Ok(exe)
    }

    /// Pre-compile several executables (startup warm-up).
    pub fn preload(self: &Arc<Self>, names: &[&str]) -> Result<()> {
        for n in names {
            self.load(n)?;
        }
        Ok(())
    }

    pub fn compile_seconds(&self) -> f64 {
        *self.compile_seconds.lock().unwrap()
    }

    pub fn cached_executables(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Upload one tensor to the device.
    pub fn upload_tensor(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        let buf = match &t.data {
            Data::F32(v) => {
                self.client.0.buffer_from_host_buffer::<f32>(v, &t.shape, None)
            }
            Data::I32(v) => {
                self.client.0.buffer_from_host_buffer::<i32>(v, &t.shape, None)
            }
        }
        .context("host→device transfer")?;
        Ok(buf)
    }

    /// Upload a whole bank for reuse across many executions.
    pub fn upload_bank(&self, bank: &Bank) -> Result<DeviceBank> {
        let mut bufs = Vec::with_capacity(bank.len());
        let mut shapes = Vec::with_capacity(bank.len());
        for t in bank {
            bufs.push(SendSync(self.upload_tensor(t)?));
            shapes.push((t.shape.clone(), t.dtype()));
        }
        Ok(DeviceBank { bufs, shapes })
    }
}

pub struct Executable {
    exe: SendSync<xla::PjRtLoadedExecutable>,
    rt: Arc<Runtime>,
    pub spec: ExeSpec,
}

impl Executable {
    /// Execute with all-host input banks in manifest group order.
    pub fn run(&self, banks: &[&Bank]) -> Result<Vec<Bank>> {
        let refs: Vec<BankRef> = banks.iter().map(|b| BankRef::Host(b)).collect();
        self.run_refs(&refs)
    }

    /// Execute with a mix of host banks and resident device banks.
    ///
    /// Returns one bank per *output group* (top-level tuple element), so a
    /// train step's `(trained, opt_m, opt_v, loss, metric)` comes back as
    /// five banks.
    pub fn run_refs(&self, banks: &[BankRef]) -> Result<Vec<Bank>> {
        let groups = self.spec.input_groups();
        if banks.len() != groups.len() {
            bail!(
                "{}: expected {} input banks ({:?}), got {}",
                self.spec.name,
                groups.len(),
                groups,
                banks.len()
            );
        }
        // validate + collect buffer pointers; temporaries kept alive in
        // `uploads` until after execution
        let mut uploads: Vec<xla::PjRtBuffer> = Vec::new();
        let mut order: Vec<(bool, usize, usize)> = Vec::new(); // (is_upload, bank idx, pos)
        let mut idx = 0usize;
        for (bi, (bank, group)) in banks.iter().zip(&groups).enumerate() {
            match bank {
                BankRef::Host(b) => {
                    for t in b.iter() {
                        let leaf = self.leaf(idx, group, &t.shape, t.dtype())?;
                        let _ = leaf;
                        order.push((true, uploads.len(), 0));
                        uploads.push(self.rt.upload_tensor(t)?);
                        idx += 1;
                    }
                }
                BankRef::Device(d) => {
                    for (pos, (shape, dt)) in d.shapes.iter().enumerate() {
                        self.leaf(idx, group, shape, *dt)?;
                        order.push((false, bi, pos));
                        idx += 1;
                    }
                }
            }
            if idx < self.spec.inputs.len() && &self.spec.inputs[idx].group == group {
                bail!(
                    "{}: bank for group {group:?} is missing tensors (next: {})",
                    self.spec.name,
                    self.spec.inputs[idx].name
                );
            }
        }
        if idx != self.spec.inputs.len() {
            bail!("{}: packed {idx}/{} inputs", self.spec.name, self.spec.inputs.len());
        }
        let arg_bufs: Vec<&xla::PjRtBuffer> = order
            .iter()
            .map(|&(is_up, a, b)| {
                if is_up {
                    &uploads[a]
                } else {
                    match &banks[a] {
                        BankRef::Device(d) => &d.bufs[b].0,
                        _ => unreachable!(),
                    }
                }
            })
            .collect();
        let outs = self
            .exe
            .0
            .execute_b::<&xla::PjRtBuffer>(&arg_bufs)
            .with_context(|| format!("executing {}", self.spec.name))?;
        drop(uploads);
        let mut tuple = outs[0][0]
            .to_literal_sync()
            .context("fetching result tuple")?;
        let parts = tuple.decompose_tuple().context("decomposing result")?;
        self.split_outputs(parts)
    }

    fn leaf(
        &self,
        idx: usize,
        group: &str,
        shape: &[usize],
        dtype: DType,
    ) -> Result<&LeafSpec> {
        let leaf = self.spec.inputs.get(idx).with_context(|| {
            format!("{}: bank for group {group:?} has too many tensors", self.spec.name)
        })?;
        if leaf.group != group {
            bail!(
                "{}: bank for group {group:?} has too many tensors (at {})",
                self.spec.name,
                leaf.name
            );
        }
        if shape != leaf.shape.as_slice() || dtype != leaf.dtype {
            bail!(
                "{}: input {} ({}) expects {:?} {}, got {:?} {}",
                self.spec.name,
                idx,
                leaf.name,
                leaf.shape,
                leaf.dtype.name(),
                shape,
                dtype.name()
            );
        }
        Ok(leaf)
    }

    fn split_outputs(&self, parts: Vec<xla::Literal>) -> Result<Vec<Bank>> {
        if parts.len() != self.spec.outputs.len() {
            bail!(
                "{}: XLA returned {} leaves, manifest says {}",
                self.spec.name,
                parts.len(),
                self.spec.outputs.len()
            );
        }
        let mut out: Vec<Bank> = Vec::new();
        let mut current_group: Option<&str> = None;
        for (lit, leaf) in parts.iter().zip(&self.spec.outputs) {
            let t = Tensor::from_literal(lit)
                .with_context(|| format!("{}: output {}", self.spec.name, leaf.name))?;
            if t.shape != leaf.shape {
                bail!(
                    "{}: output {} shape {:?} != manifest {:?}",
                    self.spec.name,
                    leaf.name,
                    t.shape,
                    leaf.shape
                );
            }
            if current_group != Some(leaf.group.as_str()) {
                out.push(Vec::new());
                current_group = Some(leaf.group.as_str());
            }
            out.last_mut().unwrap().push(t);
        }
        Ok(out)
    }
}
