//! Micro-benchmarks for the runtime + coordinator hot paths (criterion is
//! unavailable offline; `util::timer::Samples` provides the stats).
//!
//! Covers: executable compile+cache, fwd execution latency by batch
//! occupancy, adapter-bank swap (bank → literals) cost, store ops, router
//! throughput, tokenizer throughput, tensor packing.
//!
//! Run: `cargo bench --offline` (or `--bench micro`). Uses the `test`
//! preset so it is fast and deterministic.

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use adapterbert::coordinator::{FlushPolicy, Router};
use adapterbert::data::grammar::World;
use adapterbert::data::tasks;
use adapterbert::eval::fwd_param_banks;
use adapterbert::model::init;
use adapterbert::runtime::{Bank, Runtime};
use adapterbert::store::AdapterStore;
use adapterbert::tokenizer::Tokenizer;
use adapterbert::util::rng::Rng;
use adapterbert::util::tensor::Tensor;
use adapterbert::util::timer::Samples;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // warmup
    f();
    let mut s = Samples::default();
    for _ in 0..iters {
        s.time(&mut f);
    }
    println!(
        "{name:40} n={:4} mean {:9.3}ms  p50 {:9.3}ms  p95 {:9.3}ms",
        s.len(),
        s.mean_s() * 1e3,
        s.pctl_s(50.0) * 1e3,
        s.pctl_s(95.0) * 1e3
    );
}

fn main() -> anyhow::Result<()> {
    println!("== micro benches (test preset) ==");
    let rt = Arc::new(Runtime::open(Path::new("artifacts"), "test")?);
    let dims = rt.manifest.dims.clone();

    // --- compile + cache ---------------------------------------------------
    let t0 = Instant::now();
    let exe = rt.load("cls_fwd_adapter_m8")?;
    println!("first compile cls_fwd_adapter_m8: {:.1}ms",
             t0.elapsed().as_secs_f64() * 1e3);
    bench("compile cache hit", 100, || {
        let _ = rt.load("cls_fwd_adapter_m8").unwrap();
    });

    // --- fwd execution -----------------------------------------------------
    let spec = exe.spec.clone();
    let mk_zero = |group: &str| -> Bank {
        let r = spec.input_group_range(group).unwrap();
        spec.inputs[r]
            .iter()
            .map(|l| Tensor::zeros(&l.shape, l.dtype))
            .collect()
    };
    let base = init::init_group(&spec, "base", 0, 1e-2)?;
    let base_bank = base.to_bank(&spec, "base")?;
    let adapters = mk_zero("adapters");
    let head = mk_zero("head");
    let gates = mk_zero("gates");
    let tokens = mk_zero("tokens");
    let segments = mk_zero("segments");
    let mask: Bank = vec![Tensor::full_f32(
        &[spec.batch, dims.seq],
        1.0,
    )];
    bench("fwd execute (host banks)", 50, || {
        let banks: Vec<&Bank> = vec![
            &base_bank, &adapters, &head, &gates, &tokens, &segments, &mask,
        ];
        let _ = exe.run(&banks).unwrap();
    });

    // device-resident base (the serving path's bank cache)
    use adapterbert::runtime::BankRef;
    let dev_base = rt.upload_bank(&base_bank)?;
    let dev_adapters = rt.upload_bank(&adapters)?;
    let dev_head = rt.upload_bank(&head)?;
    let dev_gates = rt.upload_bank(&gates)?;
    bench("fwd execute (device param banks)", 50, || {
        let banks = vec![
            BankRef::Device(&dev_base),
            BankRef::Device(&dev_adapters),
            BankRef::Device(&dev_head),
            BankRef::Device(&dev_gates),
            BankRef::Host(&tokens),
            BankRef::Host(&segments),
            BankRef::Host(&mask),
        ];
        let _ = exe.run_refs(&banks).unwrap();
    });

    // --- adapter-bank swap (merge + pack) -----------------------------------
    let world = World::new(dims.vocab, 0);
    let task = tasks::find_spec("rte_s").unwrap();
    let _ = (world, task);
    let train_spec = rt.manifest.exe("cls_train_adapter_m8")?.clone();
    let (_, trained) = init::init_trained(&train_spec, &base, dims.n_layers, 0, 1e-2)?;
    let model = adapterbert::eval::TaskModel {
        variant: "adapter".into(),
        m: Some(8),
        k: None,
        kind: "cls".into(),
        trained,
    };
    bench("adapter bank swap (merge+pack)", 100, || {
        let _ = fwd_param_banks(&rt, &model, &base, None).unwrap();
    });

    // --- store ---------------------------------------------------------------
    let store = AdapterStore::in_memory();
    bench("store register+latest", 200, || {
        store.register("bench_task", &model, 0.9).unwrap();
        let _ = store.latest("bench_task").unwrap();
    });

    // --- router throughput ----------------------------------------------------
    bench("router 10k pushes (4 tasks)", 20, || {
        let mut r: Router<u64> = Router::new(FlushPolicy {
            max_batch: 32,
            max_delay: Duration::from_millis(1),
        });
        let now = Instant::now();
        for i in 0..10_000u64 {
            let t = format!("t{}", i % 4);
            let _ = r.push(&t, i, now);
        }
        let _ = r.drain(now);
    });

    // --- tokenizer -------------------------------------------------------------
    let tok = Tokenizer::new(dims.vocab);
    let mut rng = Rng::new(3);
    let text: String = (0..1000)
        .map(|_| tok.word(4 + rng.below(dims.vocab - 4) as i32).to_string())
        .collect::<Vec<_>>()
        .join(" ");
    bench("tokenizer encode 1k words", 100, || {
        let _ = tok.encode(&text);
    });

    // --- tensor packing ----------------------------------------------------------
    let t = Tensor::f32(vec![256, 64], vec![0.5; 256 * 64]);
    bench("tensor→literal 64KB", 200, || {
        let _ = t.to_literal().unwrap();
    });
    let one_bank: Bank = vec![t.clone()];
    bench("upload_bank 64KB", 200, || {
        let _ = rt.upload_bank(&one_bank).unwrap();
    });

    println!("== micro benches done ==");
    Ok(())
}
