//! Host-side tensor: the unit of parameter banks, batches and results.
//!
//! A deliberately small row-major container with exactly the two dtypes the
//! artifacts use (`f32`, `i32`), plus lossless conversion to/from
//! `xla::Literal` for PJRT execution and a compact binary (de)serialization
//! used by the `store` checkpoints.

use anyhow::{bail, Context, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
        }
    }

    pub fn from_name(s: &str) -> Result<Self> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other:?}"),
        }
    }
}

/// Row-major tensor. Scalars have an empty shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Data,
}

#[derive(Debug, Clone, PartialEq)]
pub enum Data {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: Data::F32(data) }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: Data::I32(data) }
    }

    pub fn zeros(shape: &[usize], dtype: DType) -> Self {
        let n = shape.iter().product();
        match dtype {
            DType::F32 => Tensor::f32(shape.to_vec(), vec![0.0; n]),
            DType::I32 => Tensor::i32(shape.to_vec(), vec![0; n]),
        }
    }

    pub fn full_f32(shape: &[usize], v: f32) -> Self {
        let n = shape.iter().product();
        Tensor::f32(shape.to_vec(), vec![v; n])
    }

    pub fn scalar_f32(v: f32) -> Self {
        Tensor::f32(vec![], vec![v])
    }

    pub fn scalar_i32(v: i32) -> Self {
        Tensor::i32(vec![], vec![v])
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            Data::F32(_) => DType::F32,
            Data::I32(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        match &self.data {
            Data::F32(v) => v.len(),
            Data::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            Data::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        match &mut self.data {
            Data::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            Data::I32(v) => v,
            _ => panic!("tensor is not i32"),
        }
    }

    pub fn scalar_value_f32(&self) -> f32 {
        assert!(self.len() == 1, "not a scalar: shape {:?}", self.shape);
        self.as_f32()[0]
    }

    // -- xla interop -------------------------------------------------------

    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            Data::F32(v) => {
                if self.shape.is_empty() {
                    xla::Literal::scalar(v[0])
                } else {
                    xla::Literal::vec1(v).reshape(&dims)?
                }
            }
            Data::I32(v) => {
                if self.shape.is_empty() {
                    xla::Literal::scalar(v[0])
                } else {
                    xla::Literal::vec1(v).reshape(&dims)?
                }
            }
        };
        Ok(lit)
    }

    pub fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit.array_shape().context("literal has no array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => Ok(Tensor::f32(dims, lit.to_vec::<f32>()?)),
            xla::ElementType::S32 => Ok(Tensor::i32(dims, lit.to_vec::<i32>()?)),
            other => bail!("unsupported literal element type {other:?}"),
        }
    }

    // -- binary (de)serialization (store checkpoints) -----------------------

    /// Layout: dtype(u8) rank(u32 LE) dims(u64 LE each) payload(LE).
    pub fn write_to(&self, out: &mut Vec<u8>) {
        out.push(match self.dtype() {
            DType::F32 => 0u8,
            DType::I32 => 1u8,
        });
        out.extend((self.shape.len() as u32).to_le_bytes());
        for &d in &self.shape {
            out.extend((d as u64).to_le_bytes());
        }
        match &self.data {
            Data::F32(v) => {
                for x in v {
                    out.extend(x.to_le_bytes());
                }
            }
            Data::I32(v) => {
                for x in v {
                    out.extend(x.to_le_bytes());
                }
            }
        }
    }

    pub fn read_from(buf: &[u8], pos: &mut usize) -> Result<Self> {
        let take = |pos: &mut usize, n: usize| -> Result<&[u8]> {
            if *pos + n > buf.len() {
                bail!("truncated tensor at byte {pos}");
            }
            let s = &buf[*pos..*pos + n];
            *pos += n;
            Ok(s)
        };
        let tag = take(pos, 1)?[0];
        let rank = u32::from_le_bytes(take(pos, 4)?.try_into().unwrap()) as usize;
        if rank > 16 {
            bail!("implausible tensor rank {rank}");
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(u64::from_le_bytes(take(pos, 8)?.try_into().unwrap()) as usize);
        }
        let n: usize = shape.iter().product();
        match tag {
            0 => {
                let raw = take(pos, n * 4)?;
                let v = raw
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Ok(Tensor::f32(shape, v))
            }
            1 => {
                let raw = take(pos, n * 4)?;
                let v = raw
                    .chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                Ok(Tensor::i32(shape, v))
            }
            other => bail!("bad dtype tag {other}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t = Tensor::zeros(&[2, 3], DType::F32);
        assert_eq!(t.len(), 6);
        assert_eq!(t.dtype(), DType::F32);
    }

    #[test]
    fn binary_roundtrip() {
        let a = Tensor::f32(vec![2, 2], vec![1.0, -2.5, 3.25, 0.0]);
        let b = Tensor::i32(vec![3], vec![7, -9, 11]);
        let s = Tensor::scalar_f32(0.125);
        let mut buf = Vec::new();
        a.write_to(&mut buf);
        b.write_to(&mut buf);
        s.write_to(&mut buf);
        let mut pos = 0;
        assert_eq!(Tensor::read_from(&buf, &mut pos).unwrap(), a);
        assert_eq!(Tensor::read_from(&buf, &mut pos).unwrap(), b);
        assert_eq!(Tensor::read_from(&buf, &mut pos).unwrap(), s);
        assert_eq!(pos, buf.len());
    }

    #[test]
    fn rejects_truncated() {
        let a = Tensor::f32(vec![4], vec![1.0; 4]);
        let mut buf = Vec::new();
        a.write_to(&mut buf);
        buf.truncate(buf.len() - 2);
        let mut pos = 0;
        assert!(Tensor::read_from(&buf, &mut pos).is_err());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let t = Tensor::f32(vec![2, 3], (0..6).map(|i| i as f32 * 0.5).collect());
        let lit = t.to_literal().unwrap();
        let back = Tensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_roundtrip_i32_and_scalar() {
        let t = Tensor::i32(vec![4], vec![1, -2, 3, -4]);
        let back = Tensor::from_literal(&t.to_literal().unwrap()).unwrap();
        assert_eq!(t, back);
        let s = Tensor::scalar_f32(2.5);
        let back = Tensor::from_literal(&s.to_literal().unwrap()).unwrap();
        assert_eq!(back.scalar_value_f32(), 2.5);
    }
}
